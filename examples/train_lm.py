"""End-to-end LM training through the full stack: model zoo config ->
sharded train_step -> AdamW -> deterministic data pipeline -> async
checkpoints -> restart.

    PYTHONPATH=src python examples/train_lm.py                 # ~15M params, quick
    PYTHONPATH=src python examples/train_lm.py --full          # ~100M, few hundred steps

Loss on the synthetic Markov stream drops well below ln(V) uniform entropy,
demonstrating real learning through the whole substrate.
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig, register
from repro.launch.train import train_loop

QUICK = ModelConfig(
    name="example-15m", family="dense",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
    d_ff=1024, vocab_size=2048, head_dim=32, tie_embeddings=True,
)
FULL = ModelConfig(
    name="example-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
    d_ff=3072, vocab_size=8192, head_dim=64, tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (slow on CPU)")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    cfg = FULL if args.full else QUICK
    register(cfg, cfg)  # make it addressable through the config registry
    steps = args.steps or (300 if args.full else 60)

    from repro.models import build_model
    n = build_model(cfg).param_count()
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, {steps} steps")

    with tempfile.TemporaryDirectory() as ckpt:
        out = train_loop(cfg.name, smoke=False, steps=steps, batch=8,
                         seq=256, microbatches=2, ckpt_dir=ckpt,
                         ckpt_interval=max(steps // 3, 10), log_every=10,
                         lr=3e-3)
    first, last = out["losses"][0], out["final_loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"(uniform entropy {__import__('math').log(cfg.vocab_size):.2f})")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
