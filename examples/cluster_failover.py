"""Fault-tolerant cluster scheduling: RLTune scheduling DL-platform jobs
(the assigned architectures, runtimes from the roofline cost model) on a
heterogeneous cluster with node failures, checkpoint/restart, and straggler
migration.

    PYTHONPATH=src python examples/cluster_failover.py
"""
import numpy as np

from repro.core import (FaultModel, PolicyPrioritizer, Simulator,
                        improvement, make_cluster, make_policy)
from repro.core.costmodel import generate_platform_trace
from repro.core.env import RLPrioritizer
from repro.core.trainer import RLTuneTrainer, TrainerConfig


def main() -> None:
    jobs = generate_platform_trace(160, seed=0, arrival_rate=0.05)
    archs = sorted({j.arch for j in jobs})
    print(f"[failover] 160 platform jobs over {len(archs)} architectures "
          f"(runtimes from roofline cost model)")

    cluster = make_cluster("helios")
    faults = FaultModel(mtbf_per_node=6 * 3600.0, repair_time=1800.0,
                        ckpt_interval=900.0, straggler_prob=0.15, seed=3)

    # quick RLTune training on the same workload distribution (no faults)
    cfg = TrainerConfig(trace="helios", base_policy="fcfs", metric="jct",
                        batch_size=96, batches_per_epoch=12, epochs=1)
    trainer = RLTuneTrainer(cfg, cluster=cluster,
                            jobs=generate_platform_trace(1600, seed=1))
    trainer.train()

    results = {}
    for name, prioritizer, alloc in (
        ("fcfs", PolicyPrioritizer(make_policy("fcfs", True)), "pack"),
        ("rltune", RLPrioritizer(trainer.agent, explore=False,
                                 use_estimates=True), "milp"),
    ):
        sim = Simulator(cluster, allocator=alloc, fault_model=faults,
                        straggler_migration=True)
        res = sim.run_batch([j.clone_pending() for j in jobs], prioritizer)
        results[name] = res
        print(f"  {name:7s}: jct={res.avg_jct:9.0f}s wait={res.avg_wait:8.0f}s "
              f"util={res.utilization:.3f} restarts={res.restarts} "
              f"(failures survived, work preserved at checkpoints)")

    imp = improvement(results["fcfs"].avg_jct, results["rltune"].avg_jct)
    print(f"[failover] RLTune vs FCFS under faults: JCT {imp:+.1f}%")


if __name__ == "__main__":
    main()
