"""Batched serving with continuous batching + KV caches across the model
zoo (prefill -> decode; attention KV, SWA ring buffers, Mamba states).

    PYTHONPATH=src python examples/serve_lm.py --arch jamba-v0.1-52b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=ALL_ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)   # reduced config on CPU
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=args.batch)

    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    prompt=list(rng.integers(1, cfg.vocab_size, size=12)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve_lm] {args.arch} (smoke config): {len(done)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req{r.req_id}: {r.output}")


if __name__ == "__main__":
    main()
