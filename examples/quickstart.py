"""Quickstart: train RLTune on a Helios-like trace and beat the base policy.

    PYTHONPATH=src python examples/quickstart.py [--batches 25] [--trace helios]

This is the paper's core loop end-to-end: synthetic production trace ->
feature building -> PPO prioritization + MILP allocation -> evaluation
against the base policy on held-out jobs (noisy runtime estimates).
"""
import argparse

import numpy as np

from repro.core import improvement
from repro.core.trainer import RLTuneTrainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="helios",
                    choices=["philly", "helios", "alibaba"])
    ap.add_argument("--base-policy", default="fcfs")
    ap.add_argument("--metric", default="wait",
                    choices=["wait", "jct", "bsld", "util"])
    ap.add_argument("--batches", type=int, default=25)
    ap.add_argument("--batch-size", type=int, default=128)
    args = ap.parse_args()

    cfg = TrainerConfig(trace=args.trace, base_policy=args.base_policy,
                        metric=args.metric, batch_size=args.batch_size,
                        batches_per_epoch=args.batches, epochs=1)
    trainer = RLTuneTrainer(cfg)
    print(f"[quickstart] training RLTune vs {args.base_policy} on "
          f"{args.trace} ({args.batches} batches of {args.batch_size} jobs)")
    hist = trainer.train(log_every=5)
    print(f"[quickstart] mean training reward: {hist[0].mean_reward:+.3f} "
          f"(positive = RL schedules better than the base policy)")

    ev = trainer.evaluate(num_batches=5)
    print("\n[quickstart] held-out evaluation (noisy user estimates):")
    for m in ("wait", "jct", "bsld", "util"):
        b, r = ev["base"][m], ev["rl"][m]
        imp = improvement(b, r, lower_is_better=(m != "util"))
        print(f"  {m:5s}: base={b:10.2f}  rltune={r:10.2f}  ({imp:+.1f}%)")


if __name__ == "__main__":
    main()
