"""Benchmark driver — one module per paper table/figure.

Prints human-readable tables plus ``name,us_per_call,derived`` CSV rows at
the end.  Modules may additionally expose a ``JSON_PATH`` machine-readable
artifact (e.g. ``BENCH_streaming.json``) that is listed in the run summary
so cross-PR perf tracking knows where to look.  Module selection:
``python -m benchmarks.run [module ...]`` with modules in {latency, kernels,
roofline, variability, naive, qssf, util, transfer, policies, streaming,
federation, rl_streaming, autoscaling, preemption, chaos, obs, scale_curve,
prediction}.
``--smoke`` runs every selected module that supports it in its fast CI mode
(modules whose ``run`` accepts a ``smoke`` kwarg; others run normally).
``--rss`` stamps peak-RSS (resource.getrusage) into every bench point of
modules that support it.  REPRO_BENCH_SCALE=full for paper-scale runs.

A module that raises marks the whole run failed: remaining modules still
execute (maximum signal per CI run), but the driver exits nonzero so the
pipeline cannot green-light on a half-complete benchmark sweep.
"""
from __future__ import annotations

import inspect
import os
import sys
import time

MODULES = ("latency", "kernels", "roofline", "variability", "naive", "qssf",
           "util", "transfer", "policies", "streaming", "federation",
           "rl_streaming", "autoscaling", "preemption", "chaos", "obs",
           "scale_curve", "prediction")


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if "--rss" in args:
        # env (not a module global) so benches see it regardless of import
        # order, and standalone `python -m benchmarks.bench_*` matches
        from benchmarks.common import RSS_ENV
        os.environ[RSS_ENV] = "1"
    want = [a for a in args if a not in ("--smoke", "--rss")] or list(MODULES)
    rows: list[str] = []
    artifacts: list[str] = []
    failed: list[str] = []
    t0 = time.time()
    special = {"roofline": "benchmarks.roofline",
               "naive": "benchmarks.bench_naive_vs_pro"}
    for name in want:
        modname = special.get(name, f"benchmarks.bench_{name}")
        mod = __import__(modname, fromlist=["run"])
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t1 = time.time()
        ok = True
        try:
            if smoke and "smoke" in inspect.signature(mod.run).parameters:
                mod.run(rows, smoke=True)
            else:
                mod.run(rows)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"[bench {name} FAILED] {e!r}")
            rows.append(f"{name}/FAILED,0,{e!r}")
            failed.append(name)
            ok = False
        path = getattr(mod, "JSON_PATH", None)
        # only report the artifact on success — a stale file from a prior
        # run must not be ingested as this run's numbers
        if ok and path and os.path.exists(path):
            artifacts.append(os.path.normpath(path))
        print(f"-- {name} done in {time.time() - t1:.0f}s")

    print(f"\n{'=' * 72}\n== CSV (name,us_per_call,derived)\n{'=' * 72}")
    for r in rows:
        print(r)
    for a in artifacts:
        print(f"# json artifact: {a}")
    print(f"# total bench time {time.time() - t0:.0f}s")
    if failed:
        print(f"# FAILED modules: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
