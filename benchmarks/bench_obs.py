"""Observability overhead benchmark: obs-off vs obs-on decision latency.

Streams the flash-crowd scenario through the engine twice per repeat —
once bare, once with the full ``repro.obs`` bundle (tracer + metrics +
audit) attached — at the deep queue window (qw=1024) where ranking cost
dominates, and reports the p99 decision-latency overhead the bundle adds.

Acceptance (tracked in ``BENCH_obs.json``): obs-on p99 decision latency
within 5% of obs-off at qw=1024, and obs-off runs bit-identical to obs-on
(same job tuples, same decision counters — the observer must not steer).
The obs-on arm's trace + Prometheus textfile are exported as artifacts so
the CI smoke job can validate and upload them.

Modes: REPRO_BENCH_SCALE=full streams 10k jobs x3 repeats; default
(quick) 6k x3; ``--smoke``/``run(smoke=True)`` 1.2k x1.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import provenance
from repro.core import PolicyPrioritizer, make_policy
from repro.obs import Observability, validate_trace
from repro.sched import SchedulerEngine, get_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_OBS_JOBS",
                              {"quick": 6_000, "full": 10_000}[SCALE]))
SMOKE_JOBS = 1_200
REPEATS = int(os.environ.get("REPRO_BENCH_OBS_REPEATS", 3))
SCENARIO = "flash-crowd"
QUEUE_WINDOW = 1024
P99_OVERHEAD_BOUND = 0.05

_HERE = os.path.dirname(os.path.abspath(__file__))
JSON_PATH = os.environ.get(
    "REPRO_BENCH_OBS_JSON",
    os.path.join(_HERE, os.pardir, "BENCH_obs.json"))
TRACE_PATH = os.environ.get(
    "REPRO_BENCH_OBS_TRACE",
    os.path.join(_HERE, "artifacts", "obs_trace.json"))
PROM_PATH = os.environ.get(
    "REPRO_BENCH_OBS_PROM",
    os.path.join(_HERE, "artifacts", "obs_metrics.prom"))


def _portable(path: str) -> str:
    """Repo-relative form for the committed JSON (absolute when outside)."""
    root = os.path.normpath(os.path.join(_HERE, os.pardir))
    p = os.path.normpath(path)
    return os.path.relpath(p, root) if p.startswith(root + os.sep) else p


class _TimedEngine(SchedulerEngine):
    """Times the whole scheduling pass — rank + placement + (when obs is
    attached) audit/trace emission — so the overhead figure charges the
    observability layer everything it actually adds to a decision."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.pass_lat: list[float] = []

    def _try_schedule(self) -> None:
        t0 = time.perf_counter()
        super()._try_schedule()
        self.pass_lat.append(time.perf_counter() - t0)


def _signature(engine) -> tuple:
    jobs = tuple(sorted(
        (j.job_id, round(j.submit_time, 6),
         round(j.first_start_time if j.first_start_time is not None else -1.0, 6),
         round(j.finish_time if j.finish_time is not None else -1.0, 6),
         j.restarts)
        for j in engine.completed))
    return jobs, (engine.decisions, engine.milp_calls, engine.backfills,
                  engine.restarts)


def stream_once(num_jobs: int, obs: Observability | None) -> dict:
    run = get_scenario(SCENARIO).build(num_jobs, seed=0)
    pri = PolicyPrioritizer(make_policy("fcfs"))
    hooks = tuple(obs.hooks()) if obs is not None else ()
    engine = _TimedEngine(run.spec, pri, allocator="pack",
                          fault_model=run.fault_model,
                          queue_window=QUEUE_WINDOW, hooks=hooks)
    jobs = [j.clone_pending() for j in run.jobs]
    t0 = time.perf_counter()
    feed = 0
    while True:
        nxt = engine.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt == float("inf"):
            break
        horizon = max(engine.now, nxt) + 3600.0
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= horizon:
            hi += 1
        if hi > feed:
            engine.submit(jobs[feed:hi])
            feed = hi
        engine.step(horizon)
    wall = time.perf_counter() - t0
    if obs is not None:
        obs.finalize(engine)
    lat = np.array(engine.pass_lat) if engine.pass_lat else np.zeros(1)
    return {
        "completed": len(engine.completed),
        "decisions": engine.decisions,
        "wall_s": wall,
        "lat_mean_ms": 1e3 * float(lat.mean()),
        "lat_p99_ms": 1e3 * float(np.percentile(lat, 99)),
        "signature": _signature(engine),
    }


def _emit_json(num_jobs: int, repeats: list[dict], best_off: dict,
               best_on: dict, overhead: float, identical: bool,
               trace_events: int, smoke: bool) -> dict:
    doc = {
        "bench": "obs",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "scenario": SCENARIO,
        "policy": "fcfs",
        "allocator": "pack",
        "queue_window": QUEUE_WINDOW,
        "repeats": [{k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in r.items() if k != "signature"}
                    for r in repeats],
        # min-p99 per arm across repeats: the least-noise estimate on a
        # shared CPU container; single repeats compare 1:1
        "p99_off_ms": round(best_off["lat_p99_ms"], 4),
        "p99_on_ms": round(best_on["lat_p99_ms"], 4),
        "mean_off_ms": round(best_off["lat_mean_ms"], 4),
        "mean_on_ms": round(best_on["lat_mean_ms"], 4),
        "p99_overhead": round(overhead, 4),
        "trace_events": trace_events,
        "trace_path": _portable(TRACE_PATH),
        "prom_path": _portable(PROM_PATH),
        "acceptance": {
            "p99_overhead_bound": P99_OVERHEAD_BOUND,
            "within_bound": bool(overhead <= P99_OVERHEAD_BOUND),
            "obs_off_bit_identical": bool(identical),
            "passed": bool(overhead <= P99_OVERHEAD_BOUND and identical),
        },
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = SMOKE_JOBS if smoke else NUM_JOBS
    n_rep = 1 if smoke else REPEATS
    print(f"# obs overhead: {num_jobs} jobs on {SCENARIO}, FCFS+pack, "
          f"qw={QUEUE_WINDOW}, {n_rep} repeat(s) per arm")
    print(f"{'arm':8s} {'rep':>3s} {'decisions':>9s} {'lat.mean':>9s} "
          f"{'lat.p99':>8s} {'wall(s)':>8s}")
    repeats: list[dict] = []
    offs: list[dict] = []
    ons: list[dict] = []
    last_obs = None
    for rep in range(n_rep):
        for arm in ("off", "on"):
            obs = Observability(name="bench") if arm == "on" else None
            r = stream_once(num_jobs, obs)
            assert r["completed"] == num_jobs, (arm, rep, r["completed"])
            r["arm"] = arm
            r["rep"] = rep
            (ons if arm == "on" else offs).append(r)
            repeats.append(r)
            if obs is not None:
                last_obs = obs
            print(f"{arm:8s} {rep:3d} {r['decisions']:9d} "
                  f"{r['lat_mean_ms']:7.3f}ms {r['lat_p99_ms']:6.3f}ms "
                  f"{r['wall_s']:8.1f}")

    identical = all(r["signature"] == offs[0]["signature"] for r in repeats)
    best_off = min(offs, key=lambda r: r["lat_p99_ms"])
    best_on = min(ons, key=lambda r: r["lat_p99_ms"])
    overhead = (best_on["lat_p99_ms"] / max(best_off["lat_p99_ms"], 1e-9)
                ) - 1.0

    doc_trace = last_obs.trace_document()
    problems = validate_trace(doc_trace)
    assert not problems, f"trace schema violations: {problems[:3]}"
    os.makedirs(os.path.dirname(TRACE_PATH), exist_ok=True)
    last_obs.export_trace(TRACE_PATH)
    last_obs.write_prometheus(PROM_PATH)

    doc = _emit_json(num_jobs, repeats, best_off, best_on, overhead,
                     identical, len(doc_trace["traceEvents"]), smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    print(f"# trace artifact {os.path.normpath(TRACE_PATH)} "
          f"({len(doc_trace['traceEvents'])} events, schema OK)")
    print(f"# prometheus artifact {os.path.normpath(PROM_PATH)}")
    print(f"# p99 overhead {100 * overhead:+.1f}% "
          f"(bound {100 * P99_OVERHEAD_BOUND:.0f}%), "
          f"bit-identical={identical} -> "
          f"{'PASS' if doc['acceptance']['passed'] else 'FAIL'}")
    if out is not None:
        out.append(f"obs/{SCENARIO}/qw{QUEUE_WINDOW}/p99_overhead,"
                   f"{1e3 * overhead:.1f},"
                   f"on {best_on['lat_p99_ms']:.3f}ms vs "
                   f"off {best_off['lat_p99_ms']:.3f}ms")
    return doc


if __name__ == "__main__":
    run([])
