"""Preemption benchmark: SLO-lane deadline enforcement vs. run-to-completion.

Streams the ``slo-lanes`` deadline storm (congestion spike + ~30% hard-
deadline jobs + elastic gangs) through ``run_scenario`` three ways — no
lifecycle controller (the run-to-completion baseline every prior PR
measured), the ``SloDeadlinePolicy`` alone, and the full controller
(SLO eviction + elastic grow/shrink) — and compares **deadline hit-rate**
(fraction of deadline-carrying jobs finishing by their deadline) against
overall schedule quality (worst rolling wait-p99) and the checkpoint-
restore overhead actually paid (resume-penalty GPU-hours).

Acceptance (recorded in ``BENCH_preemption.json``): the SLO-lane policy
must *improve* deadline hit-rate over the preemption-off baseline on the
congested scenario while keeping worst wait-p99 inside the documented band
``<= WAIT_BAND_FACTOR * baseline + WAIT_BAND_SLACK_S`` (best-effort work
legitimately waits longer when deadline work evicts it — the band caps how
much).  The preemption-off bit-identity pin (preemption=None == pre-
lifecycle engine on every registered scenario) lives in
``tests/test_lifecycle.py``.

Modes: REPRO_BENCH_SCALE=full streams 6k jobs, default (quick) 2k;
``--smoke`` caps at <=300 so CI exercises the full bench path.
REPRO_BENCH_PREEMPT_JOBS overrides the job count,
REPRO_BENCH_PREEMPT_JSON the artifact path (used by the tier-1 smoke test
to keep the committed artifact pristine).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

from benchmarks.common import provenance
from repro.lifecycle import (ElasticGangPolicy, PreemptionController,
                             SloDeadlinePolicy)
from repro.sched import get_scenario, run_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_PREEMPT_JOBS",
                              {"quick": 2_000, "full": 6_000}[SCALE]))
SMOKE_JOBS = 300
SCENARIOS = ("slo-lanes",)
#: wait-p99 degradation band the preemptive runs must stay inside
WAIT_BAND_FACTOR = 1.5
WAIT_BAND_SLACK_S = 1800.0
JSON_PATH = os.environ.get(
    "REPRO_BENCH_PREEMPT_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_preemption.json"))

#: controller configurations under test (fresh per stream — controllers
#: accumulate event logs)
CONTROLLERS = {
    "slo": lambda: PreemptionController([SloDeadlinePolicy()]),
    "slo+elastic": lambda: PreemptionController(
        [SloDeadlinePolicy(), ElasticGangPolicy()]),
}


def deadline_hit_rate(jobs) -> tuple[float, int]:
    """(hit rate over deadline-carrying jobs, deadline-job count)."""
    dl = [j for j in jobs if j.has_deadline]
    if not dl:
        return 1.0, 0
    hits = sum(1 for j in dl if j.finish_time <= j.deadline)
    return hits / len(dl), len(dl)


def stream_once(scenario: str, controller: str | None, num_jobs: int) -> dict:
    run = get_scenario(scenario).build(num_jobs, 0)
    ctl = CONTROLLERS[controller]() if controller else None
    t0 = time.perf_counter()
    sr = run_scenario(run, allocator="pack", rescan_interval=60.0,
                      sample_interval=3600.0, preemption=ctl)
    wall = time.perf_counter() - t0
    tel = sr.telemetry
    hit, n_dl = deadline_hit_rate(sr.batch.jobs)
    row = {
        "completed": len(sr.batch.jobs),
        "wall_s": wall,
        "jobs_per_s": len(sr.batch.jobs) / max(wall, 1e-9),
        "windows": sr.windows,
        "deadline_jobs": n_dl,
        "deadline_hit_rate": hit,
        "worst_wait_p99_h": tel.worst_wait_p99() / 3600.0,
        "avg_wait_h": sum(j.wait_time for j in sr.batch.jobs)
        / max(len(sr.batch.jobs), 1) / 3600.0,
        "utilization": sr.batch.utilization,
        "preemptions": sr.engine.preemptions,
        "resume_penalty_gpu_h": tel.resume_penalty_gpu_hours,
    }
    if ctl is not None:
        row["lifecycle_events"] = ctl.event_counts()
    return row


def _acceptance(results: dict[str, dict]) -> dict:
    """SLO-lane policy vs the preemption-off baseline on every scenario."""
    out: dict = {
        "controller": "slo",
        "wait_band": f"<= {WAIT_BAND_FACTOR} * baseline worst wait-p99 "
                     f"+ {WAIT_BAND_SLACK_S:.0f}s",
    }
    for scen in SCENARIOS:
        base = results.get(f"{scen}/off")
        slo = results.get(f"{scen}/slo")
        if base is None or slo is None:
            continue
        key = scen.replace("-", "_")
        band_h = (WAIT_BAND_FACTOR * base["worst_wait_p99_h"]
                  + WAIT_BAND_SLACK_S / 3600.0)
        out[f"{key}_hit_rate_off"] = round(base["deadline_hit_rate"], 4)
        out[f"{key}_hit_rate_slo"] = round(slo["deadline_hit_rate"], 4)
        out[f"{key}_improves_hit_rate"] = \
            bool(slo["deadline_hit_rate"] > base["deadline_hit_rate"])
        out[f"{key}_wait_p99_h"] = round(slo["worst_wait_p99_h"], 4)
        out[f"{key}_wait_band_h"] = round(band_h, 4)
        out[f"{key}_wait_within_band"] = \
            bool(slo["worst_wait_p99_h"] <= band_h)
    return out


def _emit_json(results: dict[str, dict], num_jobs: int, smoke: bool) -> dict:
    doc = {
        "bench": "preemption",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "policy": "fcfs",
        "allocator": "pack",
        "rescan_interval_s": 60.0,
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "acceptance": _acceptance(results),
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = min(NUM_JOBS, SMOKE_JOBS) if smoke else NUM_JOBS
    variants = [None] + sorted(CONTROLLERS)
    print(f"# preemption: {num_jobs} jobs/stream, FCFS+pack, 60s rescan, "
          f"controllers={','.join(c for c in variants if c)}")
    print(f"{'scenario':12s} {'controller':12s} {'hitRate':>8s} "
          f"{'waitP99h':>8s} {'preempts':>8s} {'penGPUh':>8s} {'wall(s)':>8s}")
    results: dict[str, dict] = {}
    for scenario in SCENARIOS:
        for controller in variants:
            label = controller or "off"
            r = stream_once(scenario, controller, num_jobs)
            assert r["completed"] == num_jobs, \
                (scenario, label, r["completed"])
            results[f"{scenario}/{label}"] = r
            print(f"{scenario:12s} {label:12s} {r['deadline_hit_rate']:8.3f} "
                  f"{r['worst_wait_p99_h']:8.2f} {r['preemptions']:8d} "
                  f"{r['resume_penalty_gpu_h']:8.2f} {r['wall_s']:8.1f}")
            if out is not None:
                out.append(f"preemption/{scenario}/{label}/deadline_hit_rate,"
                           f"{r['deadline_hit_rate']:.4f},"
                           f"wait_p99_h {r['worst_wait_p99_h']:.2f}")
    doc = _emit_json(results, num_jobs, smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    acc = doc["acceptance"]
    for scen in SCENARIOS:
        key = scen.replace("-", "_")
        if f"{key}_improves_hit_rate" in acc:
            imp = "IMPROVES" if acc[f"{key}_improves_hit_rate"] \
                else "DOES NOT IMPROVE"
            band = "WITHIN" if acc[f"{key}_wait_within_band"] else "OUTSIDE"
            print(f"# slo policy {imp} deadline hit-rate on {scen} "
                  f"({acc[f'{key}_hit_rate_off']:.3f} -> "
                  f"{acc[f'{key}_hit_rate_slo']:.3f}), wait-p99 {band} band "
                  f"({acc[f'{key}_wait_p99_h']:.2f}h vs "
                  f"{acc[f'{key}_wait_band_h']:.2f}h)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
