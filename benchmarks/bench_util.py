"""Paper Table 6: utilization improvement across policies and traces."""
from __future__ import annotations

from benchmarks.common import eval_pair, get_trainer, row

POLICIES = ("fcfs", "sjf")
TRACES = ("philly", "helios", "alibaba")


def run(out: list[str]) -> None:
    print("# Table 6: utilization improvement (RL vs base), util-trained")
    print(f"{'trace':10s} " + "".join(f"{p:>9s}" for p in POLICIES))
    for trace in TRACES:
        cells = []
        for pol in POLICIES:
            tr = get_trainer(trace, pol, metric="util")
            ev = eval_pair(tr)
            imp = ev["util"][2]
            cells.append(f"{imp:+8.2f}%")
            out.append(row(f"table6/{trace}/{pol}", 0.0, f"{imp:+.2f}%"))
        print(f"{trace:10s} " + "".join(cells))
