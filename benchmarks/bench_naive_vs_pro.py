"""Paper Fig 10: naive-RLTune (raw features, no MILP) vs pro-RLTune
(engineered features + sampling + MILP allocation), BSLD on Philly."""
from __future__ import annotations

from benchmarks.common import eval_pair, get_trainer, row
from repro.core import improvement


def run(out: list[str]) -> None:
    print("# Fig 10: naive-RLTune vs pro-RLTune (philly, BSLD)")
    res = {}
    for variant in ("naive", "pro"):
        tr = get_trainer("philly", "slurm-mf", "bsld", variant)
        ev = eval_pair(tr)
        res[variant] = ev["bsld"][1]
        print(f"  {variant:6s}: BSLD {ev['bsld'][0]:.2f} -> {ev['bsld'][1]:.2f}")
    gain = improvement(res["naive"], res["pro"])
    print(f"  pro over naive: {gain:+.1f}% BSLD")
    out.append(row("fig10/pro_over_naive_bsld", 0.0, f"{gain:+.1f}%"))
