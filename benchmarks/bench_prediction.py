"""Prediction benchmark: predictor-assisted EASY backfill vs blind backfill.

Streams the congested scenarios (``flash-crowd`` spike with pure-noise
estimates — the control where learning has nothing systematic to exploit,
``padded-estimates`` habitual walltime padding, ``overcommit-queue``
sustained overload with padded requests, ``mispredict-storm`` two-sided
cohort mis-estimation) through
``run_scenario`` twice — *blind*: declared-estimate backfill gating
(``predictor=None``, the baseline every prior PR measured) and *assisted*:
an online ``repro.predict.RuntimePredictor`` whose p90 quantile gates
backfill reservations, feeds MILP lookahead durations, and enforces
overruns — and compares completed-job wait-p99.

Acceptance (recorded in ``BENCH_prediction.json``):

- assisted backfill beats blind on wait-p99 on >= ``MIN_WINS`` of the
  scenarios (the prediction-assisted scheduling win);
- the MLP's prequential MAPE (predict-then-train, honest out-of-sample)
  beats the per-(user, gpus-bucket) running-mean baseline, pooled over all
  assisted streams;
- on ``mispredict-storm`` (30% of users declare 5-30% of their true
  runtime, 40% pad 3-8x) assisted wait-p99 stays inside the documented band
  ``<= WAIT_BAND_FACTOR * blind + WAIT_BAND_SLACK_S`` — mispredictions
  cost bounded overrun churn, not unbounded queue collapse.

The predictor-off / shadow-mode bit-identity pin (predictor=None ==
assist=False == pre-prediction engine on every registered scenario) lives
in ``tests/test_predict.py``.

Modes: REPRO_BENCH_SCALE=full streams 10k jobs, default (quick) 3k;
``--smoke`` caps at <= 600 so CI exercises the full bench path.
REPRO_BENCH_PREDICT_JOBS overrides the job count,
REPRO_BENCH_PREDICT_JSON the artifact path (used by the tier-1 smoke
test to keep the committed artifact pristine).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

import numpy as np

from benchmarks.common import provenance
from repro.core.policies import make_policy
from repro.core.prioritizer import PolicyPrioritizer
from repro.predict import RuntimePredictor
from repro.sched import get_scenario, run_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_PREDICT_JOBS",
                              {"quick": 3_000, "full": 10_000}[SCALE]))
SMOKE_JOBS = 600
SCENARIOS = ("flash-crowd", "padded-estimates", "overcommit-queue",
             "mispredict-storm")
STORM = "mispredict-storm"
#: assisted must beat blind wait-p99 on at least this many scenarios
MIN_WINS = 2
#: wait-p99 band assisted must stay inside when mispredictions storm
WAIT_BAND_FACTOR = 1.5
WAIT_BAND_SLACK_S = 1800.0
JSON_PATH = os.environ.get(
    "REPRO_BENCH_PREDICT_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_prediction.json"))


def _prioritizer() -> PolicyPrioritizer:
    # use_estimates=True: blind backfill gates on the declared (noisy)
    # estimate, never the oracle runtime — the deployable baseline
    return PolicyPrioritizer(make_policy("fcfs", use_estimates=True))


def stream_once(scenario: str, num_jobs: int,
                assisted: bool) -> tuple[dict, RuntimePredictor | None]:
    run = get_scenario(scenario).build(num_jobs, 0)
    pred = RuntimePredictor(assist=True, seed=0) if assisted else None
    t0 = time.perf_counter()
    sr = run_scenario(run, allocator="pack", rescan_interval=60.0,
                      sample_interval=3600.0, prioritizer=_prioritizer(),
                      predictor=pred)
    wall = time.perf_counter() - t0
    jobs = sr.batch.jobs
    waits = np.array([j.wait_time for j in jobs]) if jobs else np.zeros(1)
    eng = sr.engine
    row = {
        "completed": len(jobs),
        "wall_s": wall,
        "jobs_per_s": len(jobs) / max(wall, 1e-9),
        "windows": sr.windows,
        "wait_p50_h": float(np.percentile(waits, 50)) / 3600.0,
        "wait_p99_h": float(np.percentile(waits, 99)) / 3600.0,
        "avg_wait_h": float(waits.mean()) / 3600.0,
        "utilization": sr.batch.utilization,
        "backfills": eng.backfills,
        "bf_reservations": eng.bf_reservations,
        "bf_overruns": eng.bf_overruns,
    }
    if pred is not None:
        row["train_steps"] = pred.train_steps
        row["mape_mlp"] = pred.mape()
        row["mape_baseline"] = pred.baseline_mape()
    return row, pred


def _acceptance(results: dict[str, dict],
                preds: dict[str, RuntimePredictor]) -> dict:
    out: dict = {
        "min_wins": MIN_WINS,
        "wait_band": f"<= {WAIT_BAND_FACTOR} * blind wait-p99 "
                     f"+ {WAIT_BAND_SLACK_S:.0f}s",
    }
    wins = 0
    for scen in SCENARIOS:
        blind = results.get(f"{scen}/blind")
        asst = results.get(f"{scen}/assisted")
        if blind is None or asst is None:
            continue
        key = scen.replace("-", "_")
        won = bool(asst["wait_p99_h"] < blind["wait_p99_h"])
        wins += won
        out[f"{key}_blind_wait_p99_h"] = round(blind["wait_p99_h"], 4)
        out[f"{key}_assisted_wait_p99_h"] = round(asst["wait_p99_h"], 4)
        out[f"{key}_assisted_wins"] = won
    out["wins"] = wins
    out["assisted_beats_blind"] = bool(wins >= MIN_WINS)
    # pooled prequential MAPE across every assisted stream, step-weighted
    n = sum(p.train_steps for p in preds.values())
    mlp = sum(p.mape() * p.train_steps for p in preds.values()) / max(n, 1)
    base = sum(p.baseline_mape() * p.train_steps
               for p in preds.values()) / max(n, 1)
    out["mape_mlp"] = round(mlp, 4)
    out["mape_baseline"] = round(base, 4)
    out["mlp_beats_baseline"] = bool(mlp < base)
    blind = results.get(f"{STORM}/blind")
    asst = results.get(f"{STORM}/assisted")
    if blind is not None and asst is not None:
        band_h = (WAIT_BAND_FACTOR * blind["wait_p99_h"]
                  + WAIT_BAND_SLACK_S / 3600.0)
        out["storm_wait_band_h"] = round(band_h, 4)
        out["storm_within_band"] = bool(asst["wait_p99_h"] <= band_h)
    return out


def _emit_json(results: dict[str, dict],
               preds: dict[str, RuntimePredictor],
               num_jobs: int, smoke: bool) -> dict:
    doc = {
        "bench": "prediction",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "policy": "fcfs",
        "allocator": "pack",
        "rescan_interval_s": 60.0,
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "acceptance": _acceptance(results, preds),
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = min(NUM_JOBS, SMOKE_JOBS) if smoke else NUM_JOBS
    print(f"# prediction: {num_jobs} jobs/stream, FCFS(est)+pack, 60s "
          f"rescan, blind vs assisted backfill")
    print(f"{'scenario':18s} {'arm':9s} {'waitP99h':>8s} {'backfills':>9s} "
          f"{'overruns':>8s} {'MAPE':>6s} {'wall(s)':>8s}")
    results: dict[str, dict] = {}
    preds: dict[str, RuntimePredictor] = {}
    for scenario in SCENARIOS:
        for arm in ("blind", "assisted"):
            r, pred = stream_once(scenario, num_jobs, arm == "assisted")
            assert r["completed"] == num_jobs, (scenario, arm, r["completed"])
            results[f"{scenario}/{arm}"] = r
            if pred is not None:
                preds[scenario] = pred
            mape = f"{r['mape_mlp']:6.2f}" if "mape_mlp" in r else " " * 6
            print(f"{scenario:18s} {arm:9s} {r['wait_p99_h']:8.2f} "
                  f"{r['backfills']:9d} {r['bf_overruns']:8d} {mape} "
                  f"{r['wall_s']:8.1f}")
            if out is not None:
                out.append(f"prediction/{scenario}/{arm}/wait_p99_h,"
                           f"{r['wait_p99_h']:.4f},"
                           f"overruns {r['bf_overruns']}")
    doc = _emit_json(results, preds, num_jobs, smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    acc = doc["acceptance"]
    beat = "BEATS" if acc["assisted_beats_blind"] else "DOES NOT BEAT"
    print(f"# assisted {beat} blind on wait-p99 "
          f"({acc['wins']}/{len(SCENARIOS)} scenarios, need {MIN_WINS})")
    ml = "BEATS" if acc["mlp_beats_baseline"] else "DOES NOT BEAT"
    print(f"# MLP MAPE {acc['mape_mlp']:.2f} {ml} running-mean baseline "
          f"{acc['mape_baseline']:.2f}")
    if "storm_within_band" in acc:
        band = "WITHIN" if acc["storm_within_band"] else "OUTSIDE"
        key = STORM.replace("-", "_")
        print(f"# {STORM} assisted wait-p99 {band} band "
              f"({acc[f'{key}_assisted_wait_p99_h']:.2f}h vs "
              f"{acc['storm_wait_band_h']:.2f}h)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
