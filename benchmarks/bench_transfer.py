"""Paper Table 7: cross-policy transfer on Helios — train the agent against
one base policy, evaluate its ranking under every other base policy."""
from __future__ import annotations

from benchmarks.common import BATCH_SIZE, EVAL_BATCHES, get_trainer, row
from repro.core import improvement
from repro.core.trainer import RLTuneTrainer, TrainerConfig

POLICIES = ("fcfs", "sjf", "wfp3")


def run(out: list[str]) -> None:
    print("# Table 7: wait-time improvement, cross-policy transfer (helios)")
    agents = {p: get_trainer("helios", p, "wait").agent.state_dict()
              for p in POLICIES}
    hdr = "train\\test"
    print(f"{hdr:12s} " + "".join(f"{p:>9s}" for p in POLICIES))
    for src in POLICIES:
        cells = []
        for dst in POLICIES:
            cfg = TrainerConfig(trace="helios", base_policy=dst,
                                metric="wait", batch_size=BATCH_SIZE,
                                batches_per_epoch=1, epochs=1)
            tr = RLTuneTrainer(cfg)
            tr.agent.load_state_dict(agents[src])
            ev = tr.evaluate(num_batches=EVAL_BATCHES, batch_size=BATCH_SIZE)
            imp = improvement(ev["base"]["wait"], ev["rl"]["wait"])
            cells.append(f"{imp:+8.1f}%")
            out.append(row(f"table7/{src}->{dst}", 0.0, f"{imp:+.1f}%"))
        print(f"{src:12s} " + "".join(cells))
