"""Chaos benchmark: scheduler quality under injected failure bursts.

Streams the ``chaos-storm`` scenario (helios trace + rack-scoped failure
bursts, spot-reclamation waves against the P100 pool, a straggler storm,
and organic background faults) through ``run_scenario`` two ways — chaos
off on a fault-free cluster (the clean baseline every prior PR measured)
and chaos on with a deliberately strict ``DegradationPolicy`` (zero MILP
wall-clock budget + zero window deadline) so the control-plane degradation
ladder demonstrably fires: every multi-way placement falls back to greedy
and scheduling windows drop to FCFS ordering.

Acceptance (recorded in ``BENCH_chaos.json``): under the full storm the
worst rolling wait-p99 must stay inside
``<= WAIT_BAND_FACTOR * fault-free baseline + WAIT_BAND_SLACK_S`` and the
degradation ladder must actually activate (``milp_fallbacks > 0`` and
``degraded_windows > 0``).  The chaos-off bit-identity pin (chaos=None ==
pre-chaos engine on every registered scenario) lives in
``tests/test_chaos.py`` / ``tests/test_failover.py``.

Modes: REPRO_BENCH_SCALE=full streams 3k jobs, default (quick) 1.2k;
``--smoke`` caps at <=300 so CI exercises the full bench path.
REPRO_BENCH_CHAOS_JOBS overrides the job count, REPRO_BENCH_CHAOS_JSON
the artifact path (used by the tier-1 smoke test to keep the committed
artifact pristine).
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
import time

from benchmarks.common import provenance
from repro.chaos import DegradationPolicy
from repro.sched import get_scenario, run_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_CHAOS_JOBS",
                              {"quick": 1_200, "full": 3_000}[SCALE]))
SMOKE_JOBS = 300
SCENARIO = "chaos-storm"
#: wait-p99 band the chaos run must stay inside vs the fault-free baseline
WAIT_BAND_FACTOR = 2.0
WAIT_BAND_SLACK_S = 1800.0
JSON_PATH = os.environ.get(
    "REPRO_BENCH_CHAOS_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_chaos.json"))

#: strict ladder so fallback + FCFS degradation provably engage under storm
STRICT_DEGRADATION = DegradationPolicy(milp_budget_s=0.0, trip_after=1,
                                       reset_after_decisions=16,
                                       window_deadline_s=0.0)


def deadline_hit_rate(jobs) -> tuple[float, int]:
    """(hit rate over deadline-carrying jobs, deadline-job count)."""
    dl = [j for j in jobs if j.has_deadline]
    if not dl:
        return 1.0, 0
    hits = sum(1 for j in dl if j.finish_time <= j.deadline)
    return hits / len(dl), len(dl)


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def stream_once(chaos_on: bool, num_jobs: int) -> dict:
    run = get_scenario(SCENARIO).build(num_jobs, 0)
    if chaos_on:
        kwargs = {"degradation": STRICT_DEGRADATION}
    else:
        # the clean arm: no injected chaos AND no organic background faults
        run = dataclasses.replace(run, fault_model=None, chaos=None)
        kwargs = {"chaos": False}
    t0 = time.perf_counter()
    sr = run_scenario(run, allocator="milp", rescan_interval=60.0,
                      sample_interval=3600.0, **kwargs)
    wall = time.perf_counter() - t0
    tel = sr.telemetry
    eng = sr.engine
    hit, n_dl = deadline_hit_rate(sr.batch.jobs)
    jcts = [j.finish_time - j.submit_time for j in sr.batch.jobs]
    row = {
        "completed": len(sr.batch.jobs),
        "wall_s": wall,
        "jobs_per_s": len(sr.batch.jobs) / max(wall, 1e-9),
        "windows": sr.windows,
        "jct_p50_h": _percentile(jcts, 0.50) / 3600.0,
        "jct_p99_h": _percentile(jcts, 0.99) / 3600.0,
        "worst_wait_p99_h": tel.worst_wait_p99() / 3600.0,
        "deadline_jobs": n_dl,
        "deadline_hit_rate": hit,
        "utilization": sr.batch.utilization,
        "restarts": eng.restarts,
        "preemptions": eng.preemptions,
        "reclaimed_jobs": eng.reclaimed_jobs,
        "milp_fallbacks": eng.milp_fallbacks,
        "degraded_windows": eng.degraded_windows,
        "degraded_h": eng.degraded_s / 3600.0,
        "degraded_fraction": tel.degraded_fraction(),
        "peak_nodes_down": tel.peak_nodes_down(),
        "chaos_events": len(tel.chaos_events),
    }
    return row


def _acceptance(results: dict[str, dict]) -> dict:
    base = results.get("chaos-off")
    storm = results.get("chaos-on")
    out: dict = {
        "scenario": SCENARIO,
        "wait_band": f"<= {WAIT_BAND_FACTOR} * fault-free worst wait-p99 "
                     f"+ {WAIT_BAND_SLACK_S:.0f}s",
    }
    if base is None or storm is None:
        return out
    band_h = (WAIT_BAND_FACTOR * base["worst_wait_p99_h"]
              + WAIT_BAND_SLACK_S / 3600.0)
    out["wait_p99_off_h"] = round(base["worst_wait_p99_h"], 4)
    out["wait_p99_on_h"] = round(storm["worst_wait_p99_h"], 4)
    out["wait_band_h"] = round(band_h, 4)
    out["wait_within_band"] = bool(storm["worst_wait_p99_h"] <= band_h)
    out["milp_fallbacks"] = storm["milp_fallbacks"]
    out["ladder_fired"] = bool(storm["milp_fallbacks"] > 0
                               and storm["degraded_windows"] > 0)
    out["hit_rate_off"] = round(base["deadline_hit_rate"], 4)
    out["hit_rate_on"] = round(storm["deadline_hit_rate"], 4)
    return out


def _emit_json(results: dict[str, dict], num_jobs: int, smoke: bool) -> dict:
    doc = {
        "bench": "chaos",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "scenario": SCENARIO,
        "policy": "fcfs",
        "allocator": "milp",
        "rescan_interval_s": 60.0,
        "degradation": dataclasses.asdict(STRICT_DEGRADATION),
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "acceptance": _acceptance(results),
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = min(NUM_JOBS, SMOKE_JOBS) if smoke else NUM_JOBS
    print(f"# chaos: {num_jobs} jobs/stream on {SCENARIO}, FCFS+milp, "
          f"60s rescan, strict degradation ladder on the chaos arm")
    print(f"{'arm':10s} {'waitP99h':>8s} {'jctP99h':>8s} {'hitRate':>8s} "
          f"{'reclaim':>8s} {'fallbks':>8s} {'degWin':>7s} {'wall(s)':>8s}")
    results: dict[str, dict] = {}
    for label, chaos_on in (("chaos-off", False), ("chaos-on", True)):
        r = stream_once(chaos_on, num_jobs)
        assert r["completed"] == num_jobs, (label, r["completed"])
        results[label] = r
        print(f"{label:10s} {r['worst_wait_p99_h']:8.2f} "
              f"{r['jct_p99_h']:8.2f} {r['deadline_hit_rate']:8.3f} "
              f"{r['reclaimed_jobs']:8d} {r['milp_fallbacks']:8d} "
              f"{r['degraded_windows']:7d} {r['wall_s']:8.1f}")
        if out is not None:
            out.append(f"chaos/{SCENARIO}/{label}/wait_p99_h,"
                       f"{r['worst_wait_p99_h']:.4f},"
                       f"jct_p99_h {r['jct_p99_h']:.2f}")
    doc = _emit_json(results, num_jobs, smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    acc = doc["acceptance"]
    if "wait_within_band" in acc:
        band = "WITHIN" if acc["wait_within_band"] else "OUTSIDE"
        fired = "FIRED" if acc["ladder_fired"] else "DID NOT FIRE"
        print(f"# chaos wait-p99 {band} band "
              f"({acc['wait_p99_on_h']:.2f}h vs {acc['wait_band_h']:.2f}h "
              f"allowed); degradation ladder {fired} "
              f"({acc['milp_fallbacks']} MILP fallbacks)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
