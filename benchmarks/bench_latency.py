"""Paper Sec 5.7 operation costs: decision latency vs queue size, RL
inference latency (fused Pallas policy-MLP vs XLA), MILP solve time."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import (ClusterState, Job, choose_allocation, generate_trace,
                        make_cluster)
from repro.core.agent import PPOAgent, PPOConfig, actor_logits
from repro.core.features import build_state
from repro.kernels import ops


def _time(fn, n=20) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(out: list[str]) -> None:
    print("# Sec 5.7: decision latency scaling (state build + RL forward)")
    agent = PPOAgent(PPOConfig())
    cluster = ClusterState(make_cluster("helios"))
    for qsize in (128, 256, 512, 1024):
        jobs = generate_trace("helios", qsize, seed=1)
        t0 = time.perf_counter()
        ov, cv, mask = build_state(jobs, cluster, now=1e5)
        state_us = (time.perf_counter() - t0) * 1e6
        lg = actor_logits(agent.params, jnp.asarray(ov), jnp.asarray(mask))
        jax.block_until_ready(lg)
        fwd_us = _time(lambda: jax.block_until_ready(
            actor_logits(agent.params, jnp.asarray(ov), jnp.asarray(mask))))
        total_ms = (state_us + fwd_us) / 1e3
        print(f"  queue={qsize:5d}: state={state_us/1e3:7.1f}ms "
              f"rl_fwd={fwd_us/1e3:6.2f}ms total={total_ms:7.1f}ms")
        out.append(row(f"latency/queue_{qsize}", state_us + fwd_us,
                       f"{total_ms:.1f}ms"))

    print("# RL inference: XLA vs fused Pallas policy-MLP (interpret on CPU)")
    ov, cv, mask = build_state(generate_trace("helios", 256, seed=2),
                               cluster, 1e5)
    x, m = jnp.asarray(ov), jnp.asarray(mask)
    xla_us = _time(lambda: jax.block_until_ready(
        actor_logits(agent.params, x, m)))
    pal_us = _time(lambda: jax.block_until_ready(
        ops.policy_mlp(x, agent.params["actor"], m)))
    print(f"  xla={xla_us:.0f}us  pallas(interpret)={pal_us:.0f}us "
          f"(on-TPU target ~700us incl. state build; paper Sec 5.7)")
    out.append(row("latency/policy_mlp_xla", xla_us, "us"))
    out.append(row("latency/policy_mlp_pallas_interpret", pal_us, "us"))

    print("# MILP allocation solve time")
    j = Job(job_id=0, user=0, submit_time=0, runtime=100, est_runtime=100,
            num_gpus=4)
    look = [Job(job_id=i, user=0, submit_time=0, runtime=100,
                est_runtime=100, num_gpus=2) for i in range(1, 9)]
    ways = cluster.candidate_ways(j)
    milp_us = _time(lambda: choose_allocation(cluster, j, ways, look), n=10)
    print(f"  milp solve (top-K=8 lookahead): {milp_us/1e3:.1f}ms")
    out.append(row("latency/milp_solve", milp_us, f"{milp_us/1e3:.1f}ms"))
