"""Streaming engine benchmark: sustained jobs/sec and decision latency on
>=10k-job continuous streams (the paper's Sec. 3.1.2 service mode at scale).

Measures, per scenario and queue window:
- end-to-end simulated-stream throughput (completed jobs per wall-second)
- mean / p99 scheduler decision latency (wall time per prioritize+allocate
  round, the quantity a 1-minute Slurm rescan loop must stay under)
- rolling-telemetry summary (utilization, p99 queueing delay, peak queue)

A deep-queue point (flash-crowd @ queue_window=4096) tracks how decision
latency grows with window size; with the indexed pending queue + feasibility
cache the growth must stay sub-linear.  Results are written to
``BENCH_streaming.json`` at the repo root so the perf trajectory is tracked
across PRs, including the speedup over the recorded pre-optimization
baseline.

REPRO_BENCH_SCALE=full streams 20k jobs; default (quick) streams 10k.
"""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

from benchmarks.common import provenance
from repro.core import PolicyPrioritizer, make_policy
from repro.sched import RollingTelemetry, SchedulerEngine, get_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_STREAM_JOBS",
                              {"quick": 10_000, "full": 20_000}[SCALE]))
SCENARIOS = ("steady", "diurnal", "flash-crowd")
QUEUE_WINDOWS = (256, 1024)
#: deep-queue congestion point: decision latency must grow sub-linearly in
#: the window size (compare against the qw=1024 row of the same scenario)
DEEP_QUEUE = ("flash-crowd", 4096)
JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_streaming.json")

#: mean decision (rank) latency of the pre-optimization engine (naive
#: re-sort + scalar scoring), measured on this container at quick scale
#: (10k jobs, FCFS+pack) immediately before the indexed-queue/feasibility-
#: cache PR — the denominator for the tracked speedup.
PRE_PR_LAT_MEAN_MS = {
    "steady/qw256": 0.13, "steady/qw1024": 0.27,
    "diurnal/qw256": 0.09, "diurnal/qw1024": 0.27,
    "flash-crowd/qw256": 0.11, "flash-crowd/qw1024": 0.31,
}


class _DecisionTimer:
    """Wraps a prioritizer to record wall-clock rank() latency (both the
    plain protocol entry point and the engine's contiguous-field one)."""

    def __init__(self, base):
        self.base = base
        self.use_estimates = base.use_estimates
        self.lat: list[float] = []

    def rank(self, jobs, cluster, now):
        t0 = time.perf_counter()
        out = self.base.rank(jobs, cluster, now)
        self.lat.append(time.perf_counter() - t0)
        return out

    def rank_window(self, jobs, cluster, now, fields):
        base = getattr(self.base, "rank_window", None)
        if base is None:
            return self.rank(jobs, cluster, now)
        t0 = time.perf_counter()
        out = base(jobs, cluster, now, fields)
        self.lat.append(time.perf_counter() - t0)
        return out

    def observe_finish(self, job):
        self.base.observe_finish(job)


def stream_once(scenario: str, queue_window: int) -> dict:
    run = get_scenario(scenario).build(NUM_JOBS, seed=0)
    pri = _DecisionTimer(PolicyPrioritizer(make_policy("fcfs")))
    tel = RollingTelemetry(window=6 * 3600.0, sample_interval=3600.0)
    engine = SchedulerEngine(run.spec, pri, allocator="pack",
                             fault_model=run.fault_model,
                             queue_window=queue_window, hooks=(tel,))
    jobs = [j.clone_pending() for j in run.jobs]
    t0 = time.perf_counter()
    # stream in 1h-of-simulated-time chunks, stepping as each chunk lands;
    # the horizon is anchored on the next due arrival-or-event so traffic
    # gaps are skipped and no event ever runs ahead of an unfed arrival
    feed = 0
    while True:
        nxt = engine.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt == float("inf"):
            break
        horizon = max(engine.now, nxt) + 3600.0
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= horizon:
            hi += 1
        if hi > feed:
            engine.submit(jobs[feed:hi])
            feed = hi
        engine.step(horizon)
    wall = time.perf_counter() - t0
    tel.final(engine)
    lat = np.array(pri.lat) if pri.lat else np.zeros(1)
    util = [s.utilization for s in tel.samples]
    return {
        "completed": len(engine.completed),
        "wall_s": wall,
        "jobs_per_s": len(engine.completed) / max(wall, 1e-9),
        "decisions": engine.decisions,
        "lat_mean_ms": 1e3 * float(lat.mean()),
        "lat_p99_ms": 1e3 * float(np.percentile(lat, 99)),
        "util_mean": float(np.mean(util)) if util else 0.0,
        "wait_p99_h": tel.worst_wait_p99() / 3600.0,
        "peak_queue": tel.peak_queue_len(),
    }


def _emit_json(results: dict[str, dict]) -> dict:
    """Machine-readable perf record (tracked across PRs)."""
    speedup = {}
    if NUM_JOBS == 10_000:   # baseline was recorded at quick scale
        for key, base_ms in PRE_PR_LAT_MEAN_MS.items():
            if key in results and results[key]["lat_mean_ms"] > 0:
                speedup[key] = round(base_ms / results[key]["lat_mean_ms"], 2)
    deep_key = f"{DEEP_QUEUE[0]}/qw{DEEP_QUEUE[1]}"
    ref_qw = QUEUE_WINDOWS[-1]       # derived, so the grid can't diverge
    ref_key = f"{DEEP_QUEUE[0]}/qw{ref_qw}"
    growth = None
    if deep_key in results and ref_key in results \
            and results[ref_key]["lat_mean_ms"] > 0:
        ratio = results[deep_key]["lat_mean_ms"] / results[ref_key]["lat_mean_ms"]
        growth = {
            "ref_queue_window": ref_qw,
            "window_ratio": DEEP_QUEUE[1] / ref_qw,
            "latency_ratio": round(ratio, 3),
            "sublinear": bool(ratio < DEEP_QUEUE[1] / ref_qw),
        }
    doc = {
        "bench": "streaming",
        "scale": SCALE,
        "num_jobs": NUM_JOBS,
        "policy": "fcfs",
        "allocator": "pack",
        # wall-clock latencies are machine-specific: the speedup figures are
        # only meaningful when host matches the baseline's recorded host
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "baseline_host_note": "PRE_PR_LAT_MEAN_MS measured on the original "
                              "CI container at quick scale; compare "
                              "speedup_vs_pre_pr only on matching hardware",
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "pre_pr_baseline_lat_mean_ms": PRE_PR_LAT_MEAN_MS,
        "speedup_vs_pre_pr": speedup,
        "deep_queue_latency_growth": growth,
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None) -> None:
    print(f"# streaming engine: {NUM_JOBS} jobs/stream, FCFS+pack, "
          f"1h ingest chunks")
    print(f"{'scenario':12s} {'qwin':>5s} {'jobs/s':>8s} {'dec':>7s} "
          f"{'lat.mean':>9s} {'lat.p99':>8s} {'util':>5s} {'waitP99h':>8s} "
          f"{'peakQ':>6s} {'wall(s)':>8s}")
    grid = [(sc, qw) for sc in SCENARIOS for qw in QUEUE_WINDOWS]
    grid.append(DEEP_QUEUE)
    results: dict[str, dict] = {}
    for scenario, qw in grid:
        r = stream_once(scenario, qw)
        assert r["completed"] == NUM_JOBS, (scenario, qw, r["completed"])
        results[f"{scenario}/qw{qw}"] = r
        line = (f"{scenario:12s} {qw:5d} {r['jobs_per_s']:8.0f} "
                f"{r['decisions']:7d} {r['lat_mean_ms']:7.2f}ms "
                f"{r['lat_p99_ms']:6.2f}ms {r['util_mean']:5.2f} "
                f"{r['wait_p99_h']:8.1f} {r['peak_queue']:6d} "
                f"{r['wall_s']:8.1f}")
        print(line)
        if out is not None:
            # decision latency stays in milliseconds end to end (the seed
            # multiplied lat_mean_ms by 1e3 into a field read as ms)
            out.append(f"streaming/{scenario}/qw{qw}/lat_ms,"
                       f"{r['lat_mean_ms']:.3f},"
                       f"{r['jobs_per_s']:.0f} jobs/s")
    doc = _emit_json(results)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    if doc["speedup_vs_pre_pr"]:
        pretty = ", ".join(f"{k} {v:.1f}x"
                           for k, v in sorted(doc["speedup_vs_pre_pr"].items()))
        print(f"# decision-latency speedup vs pre-PR baseline: {pretty}")
    if doc["deep_queue_latency_growth"] is not None:
        g = doc["deep_queue_latency_growth"]
        print(f"# deep-queue growth {DEEP_QUEUE[0]} "
              f"qw{g['ref_queue_window']}->qw{DEEP_QUEUE[1]}: "
              f"latency x{g['latency_ratio']:.2f} over window x{g['window_ratio']:.0f} "
              f"({'sub-linear' if g['sublinear'] else 'SUPER-linear'})")


if __name__ == "__main__":
    run()
