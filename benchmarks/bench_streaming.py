"""Streaming engine benchmark: sustained jobs/sec and decision latency on
>=10k-job continuous streams (the paper's Sec. 3.1.2 service mode at scale).

Measures, per scenario and queue window:
- end-to-end simulated-stream throughput (completed jobs per wall-second)
- mean / p99 scheduler decision latency (wall time per prioritize+allocate
  round, the quantity a 1-minute Slurm rescan loop must stay under)
- rolling-telemetry summary (utilization, p99 queueing delay, peak queue)

REPRO_BENCH_SCALE=full streams 20k jobs; default (quick) streams 10k.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import PolicyPrioritizer, make_policy
from repro.sched import RollingTelemetry, SchedulerEngine, get_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_STREAM_JOBS",
                              {"quick": 10_000, "full": 20_000}[SCALE]))
SCENARIOS = ("steady", "diurnal", "flash-crowd")
QUEUE_WINDOWS = (256, 1024)


class _DecisionTimer:
    """Wraps a prioritizer to record wall-clock rank() latency."""

    def __init__(self, base):
        self.base = base
        self.use_estimates = base.use_estimates
        self.lat: list[float] = []

    def rank(self, jobs, cluster, now):
        t0 = time.perf_counter()
        out = self.base.rank(jobs, cluster, now)
        self.lat.append(time.perf_counter() - t0)
        return out

    def observe_finish(self, job):
        self.base.observe_finish(job)


def stream_once(scenario: str, queue_window: int) -> dict:
    run = get_scenario(scenario).build(NUM_JOBS, seed=0)
    pri = _DecisionTimer(PolicyPrioritizer(make_policy("fcfs")))
    tel = RollingTelemetry(window=6 * 3600.0, sample_interval=3600.0)
    engine = SchedulerEngine(run.spec, pri, allocator="pack",
                             fault_model=run.fault_model,
                             queue_window=queue_window, hooks=(tel,))
    jobs = [j.clone_pending() for j in run.jobs]
    t0 = time.perf_counter()
    # stream in 1h-of-simulated-time chunks, stepping as each chunk lands;
    # the horizon is anchored on the next due arrival-or-event so traffic
    # gaps are skipped and no event ever runs ahead of an unfed arrival
    feed = 0
    while True:
        nxt = engine.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt == float("inf"):
            break
        horizon = max(engine.now, nxt) + 3600.0
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= horizon:
            hi += 1
        if hi > feed:
            engine.submit(jobs[feed:hi])
            feed = hi
        engine.step(horizon)
    wall = time.perf_counter() - t0
    tel.final(engine)
    lat = np.array(pri.lat) if pri.lat else np.zeros(1)
    util = [s.utilization for s in tel.samples]
    return {
        "completed": len(engine.completed),
        "wall_s": wall,
        "jobs_per_s": len(engine.completed) / max(wall, 1e-9),
        "decisions": engine.decisions,
        "lat_mean_ms": 1e3 * float(lat.mean()),
        "lat_p99_ms": 1e3 * float(np.percentile(lat, 99)),
        "util_mean": float(np.mean(util)) if util else 0.0,
        "wait_p99_h": tel.worst_wait_p99() / 3600.0,
        "peak_queue": tel.peak_queue_len(),
    }


def run(out: list[str] | None = None) -> None:
    print(f"# streaming engine: {NUM_JOBS} jobs/stream, FCFS+pack, "
          f"1h ingest chunks")
    print(f"{'scenario':12s} {'qwin':>5s} {'jobs/s':>8s} {'dec':>7s} "
          f"{'lat.mean':>9s} {'lat.p99':>8s} {'util':>5s} {'waitP99h':>8s} "
          f"{'peakQ':>6s} {'wall(s)':>8s}")
    for scenario in SCENARIOS:
        for qw in QUEUE_WINDOWS:
            r = stream_once(scenario, qw)
            assert r["completed"] == NUM_JOBS, (scenario, qw, r["completed"])
            line = (f"{scenario:12s} {qw:5d} {r['jobs_per_s']:8.0f} "
                    f"{r['decisions']:7d} {r['lat_mean_ms']:7.2f}ms "
                    f"{r['lat_p99_ms']:6.2f}ms {r['util_mean']:5.2f} "
                    f"{r['wait_p99_h']:8.1f} {r['peak_queue']:6d} "
                    f"{r['wall_s']:8.1f}")
            print(line)
            if out is not None:
                out.append(f"streaming/{scenario}/qw{qw},"
                           f"{1e3 * r['lat_mean_ms']:.1f},"
                           f"{r['jobs_per_s']:.0f} jobs/s")


if __name__ == "__main__":
    run()
