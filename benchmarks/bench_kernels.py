"""Kernel micro-benchmarks: wall time of the XLA reference paths on CPU (the
Pallas kernels themselves are TPU-target; interpret mode timing is not
meaningful, so we report the oracle path + kernel call integrity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.kernels import ref


def _time(fn, n=5) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(out: list[str]) -> None:
    print("# kernel microbenches (CPU: ref path timed; Pallas = interpret)")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    B, H, KV, L, D = 1, 8, 2, 1024, 128
    q = jax.random.normal(ks[0], (B, H, L, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, L, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, L, D), jnp.float32)
    kr = jnp.repeat(k, H // KV, axis=1).reshape(B * H, L, D)
    vr = jnp.repeat(v, H // KV, axis=1).reshape(B * H, L, D)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
    us = _time(lambda: jax.block_until_ready(f(q.reshape(B * H, L, D), kr, vr)))
    flops = 4 * B * H * L * L * D * 0.5
    print(f"  attention ref  L={L}: {us/1e3:8.1f}ms  "
          f"({flops/us*1e6/1e12:.3f} TFLOP/s cpu)")
    out.append(row("kernels/attention_ref_1k", us, f"{flops/us*1e6/1e12:.3f}TF/s"))

    Bm, Lm, Hm, P, N = 1, 1024, 4, 64, 128
    xh = jax.random.normal(ks[3], (Bm, Lm, Hm, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[4], (Bm, Lm, Hm)))
    A = -jnp.exp(jax.random.normal(key, (Hm,)) * 0.3)
    Bs = jax.random.normal(ks[0], (Bm, Lm, N)) * 0.3
    Cs = jax.random.normal(ks[1], (Bm, Lm, N)) * 0.3
    from repro.models.mamba import ssd_chunked
    g = jax.jit(lambda *a: ssd_chunked(*a, chunk=256))
    us = _time(lambda: jax.block_until_ready(g(xh, dt, A, Bs, Cs)))
    print(f"  ssd chunked    L={Lm}: {us/1e3:8.1f}ms")
    out.append(row("kernels/ssd_chunked_1k", us, "ms"))

    x = jax.random.normal(ks[2], (4096, 256))
    w = jax.random.normal(ks[3], (256, 64)) * 0.1
    r = jax.jit(lambda a, b: ref.moe_router_ref(a, b, 8))
    us = _time(lambda: jax.block_until_ready(r(x, w)))
    print(f"  moe router     T=4096: {us/1e3:8.2f}ms")
    out.append(row("kernels/moe_router_4k", us, "ms"))
