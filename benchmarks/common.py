"""Shared benchmark helpers: agent training/caching, evaluation, CSV rows,
and the provenance stamp every ``BENCH_*.json`` artifact carries."""
from __future__ import annotations

import datetime
import os
import platform
import subprocess
import sys
import time


from repro.core import improvement
from repro.core.trainer import RLTuneTrainer, TrainerConfig

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
AGENTS = os.path.join(ART, "agents")
os.makedirs(AGENTS, exist_ok=True)

# benchmark scale knobs (CPU container budget); REPRO_BENCH_SCALE=full for
# paper-scale runs (100 batches/epoch, batch 256)
SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
TRAIN_BATCHES = int(os.environ.get("REPRO_BENCH_TRAIN_BATCHES",
                                   {"quick": 60, "full": 100}[SCALE]))
BATCH_SIZE = {"quick": 128, "full": 256}[SCALE]
EVAL_BATCHES = int(os.environ.get("REPRO_BENCH_EVAL_BATCHES",
                                  {"quick": 4, "full": 10}[SCALE]))


#: set by ``benchmarks.run --rss`` (or exported directly): benches that
#: consult ``rss_enabled()`` stamp ``peak_rss_mb`` into every bench point
RSS_ENV = "REPRO_BENCH_RSS"


def rss_enabled() -> bool:
    return os.environ.get(RSS_ENV, "") not in ("", "0")


def peak_rss_mb() -> float | None:
    """Peak resident set size of this process in MB (None where the
    ``resource`` module is unavailable, e.g. non-POSIX hosts).  Linux
    reports ``ru_maxrss`` in KB, macOS in bytes — normalized here so the
    stamped JSON is comparable across hosts."""
    try:
        import resource
    except ImportError:
        return None
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        peak /= 1024.0
    return round(peak / 1024.0, 1)


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def provenance(seed: int | None = None) -> dict:
    """Provenance stamp for ``BENCH_*.json`` artifacts: enough to answer
    "what code, what toolchain, what knobs, when" for any number a later
    PR compares against.  ``jax`` is imported guarded — CPU-only containers
    without it still produce a valid stamp."""
    try:
        import jax
        jax_version = jax.__version__
    except Exception:  # noqa: BLE001 — any import-time failure reads as absent
        jax_version = None
    try:
        import numpy
        numpy_version = numpy.__version__
    except Exception:  # noqa: BLE001
        numpy_version = None
    stamp = {
        "git_sha": _git_sha(),
        "python": sys.version.split()[0],
        "jax": jax_version,
        "numpy": numpy_version,
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "wall_clock_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "scale": SCALE,
    }
    if seed is not None:
        stamp["seed"] = seed
    return stamp


def agent_path(trace: str, policy: str, metric: str, variant: str) -> str:
    return os.path.join(AGENTS, f"{trace}__{policy}__{metric}__{variant}")


def get_trainer(trace: str, policy: str, metric: str = "wait",
                variant: str = "pro", train: bool = True,
                seed: int = 0) -> RLTuneTrainer:
    """Train (or load cached) RLTune agent for (trace, base policy, metric)."""
    from repro.ckpt.checkpoint import latest_step, load_checkpoint, \
        save_checkpoint
    cfg = TrainerConfig(trace=trace, base_policy=policy, metric=metric,
                        variant=variant, batch_size=BATCH_SIZE,
                        batches_per_epoch=TRAIN_BATCHES, epochs=1, seed=seed)
    tr = RLTuneTrainer(cfg)
    path = agent_path(trace, policy, metric, variant)
    if train:
        if latest_step(path) is not None:
            state, _ = load_checkpoint(path, tr.agent.state_dict())
            tr.agent.load_state_dict(state)
        else:
            t0 = time.time()
            tr.train()
            save_checkpoint(path, 1, tr.agent.state_dict())
            print(f"#   trained {trace}/{policy}/{metric}/{variant} "
                  f"in {time.time() - t0:.0f}s")
    return tr


def eval_pair(tr: RLTuneTrainer, num_batches: int = 0) -> dict:
    ev = tr.evaluate(num_batches=num_batches or EVAL_BATCHES,
                     batch_size=BATCH_SIZE)
    out = {}
    for m in ("wait", "jct", "bsld", "util"):
        out[m] = (ev["base"][m], ev["rl"][m],
                  improvement(ev["base"][m], ev["rl"][m],
                              lower_is_better=(m != "util")))
    return out


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
