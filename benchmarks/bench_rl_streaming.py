"""Streaming-RL benchmark: streaming-trained vs batch-trained vs FCFS.

Trains two PPO agents — one on streaming episodes cut from live
``SchedulerEngine`` runs (``repro.rl.StreamingTrainer``: dense shaped
rewards, GAE, warm congested clusters), one on the legacy idle-cluster
batch pairs (``RLTuneTrainer``: sparse terminal reward) — then evaluates
both greedily through ``service.run_stream`` against the FCFS baseline on
identical builds of three registered scenarios.  The scenarios are the
*congested* regimes (flash-crowd spike, diurnal peak, SKU contention)
where prioritization actually matters; on the idle 'steady' control FCFS
is near-optimal by construction.

The ``acceptance`` block records whether the streaming-trained agent beats
FCFS on mean wait or mean JCT per scenario (the ISSUE-4 criterion: >= 2 of
3), so the trajectory is tracked across PRs in ``BENCH_rl_streaming.json``.

Modes: quick (default) / REPRO_BENCH_SCALE=full scale the training budget;
``--smoke`` (or ``run(smoke=True)``) shrinks everything so CI can exercise
the whole bench path in seconds.  REPRO_BENCH_RL_JSON overrides the
artifact path (used by the tier-1 smoke test to keep the committed
artifact pristine); REPRO_BENCH_RL_STREAMS overrides the training budget.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

from benchmarks.common import provenance
from repro.core.agent import PPOConfig
from repro.rl import (RLTuneTrainer, StreamingConfig, StreamingTrainer,
                      TrainerConfig)

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
SCENARIOS = ("flash-crowd", "diurnal", "sku-skew")
EVAL_JOBS = {"quick": 256, "full": 512}[SCALE]
STREAMS = int(os.environ.get("REPRO_BENCH_RL_STREAMS",
                             {"quick": 24, "full": 64}[SCALE]))
BATCHES = {"quick": 16, "full": 48}[SCALE]
JSON_PATH = os.environ.get(
    "REPRO_BENCH_RL_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_rl_streaming.json"))


def _streaming_cfg(smoke: bool) -> StreamingConfig:
    if smoke:
        return StreamingConfig(scenarios=SCENARIOS, num_jobs=64, streams=2,
                               horizon=6, warmup_windows=2,
                               rescan_interval=300.0, seed=0)
    return StreamingConfig(
        scenarios=SCENARIOS, num_jobs=192, streams=STREAMS, horizon=12,
        warmup_windows=4, rescan_interval=300.0, seed=0,
        ppo=PPOConfig(episodes_per_update=2))


def _batch_cfg(smoke: bool) -> TrainerConfig:
    if smoke:
        return TrainerConfig(trace="helios", batch_size=32,
                             batches_per_epoch=2, epochs=1, variant="pro")
    return TrainerConfig(trace="helios", batch_size=96,
                         batches_per_epoch=BATCHES, epochs=1, variant="pro")


def _acceptance(results: dict[str, dict]) -> dict:
    """streaming vs FCFS per scenario on mean wait / mean JCT."""
    wins = 0
    out: dict = {"criterion": "streaming beats fcfs on mean wait or "
                              "mean JCT on >= 2 scenarios"}
    for name, row in results.items():
        s, f = row["streaming"], row["fcfs"]
        wait_beat = s["mean_wait"] < f["mean_wait"]
        jct_beat = s["mean_jct"] < f["mean_jct"]
        out[name] = {
            "streaming_wait_h": round(s["mean_wait"] / 3600.0, 4),
            "fcfs_wait_h": round(f["mean_wait"] / 3600.0, 4),
            "streaming_jct_h": round(s["mean_jct"] / 3600.0, 4),
            "fcfs_jct_h": round(f["mean_jct"] / 3600.0, 4),
            "beats_fcfs": bool(wait_beat or jct_beat),
        }
        wins += int(wait_beat or jct_beat)
    out["scenarios_beaten"] = wins
    out["passed"] = bool(wins >= 2)
    return out


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    eval_jobs = 96 if smoke else EVAL_JOBS
    scfg = _streaming_cfg(smoke)
    bcfg = _batch_cfg(smoke)

    t0 = time.perf_counter()
    streaming = StreamingTrainer(scfg)
    eps = streaming.train()
    t_stream = time.perf_counter() - t0
    print(f"# streaming: {scfg.streams} streams x {scfg.num_jobs} jobs -> "
          f"{len(eps)} episodes, "
          f"{sum(e.steps for e in eps)} decisions in {t_stream:.0f}s")

    t0 = time.perf_counter()
    batch = RLTuneTrainer(bcfg)
    batch.train()
    t_batch = time.perf_counter() - t0
    print(f"# batch: {bcfg.batches_per_epoch} x {bcfg.batch_size}-job pairs "
          f"({bcfg.variant}) in {t_batch:.0f}s")

    # identical scenario builds for every contender: evaluate the batch
    # agent through the same streaming harness
    batch_eval = StreamingTrainer(scfg, agent=batch.agent)

    results: dict[str, dict] = {}
    print(f"{'scenario':14s} {'contender':11s} {'waitH':>8s} {'jctH':>8s} "
          f"{'bsld':>7s} {'util':>5s}")
    for name in SCENARIOS:
        ev_s = streaming.evaluate((name,), num_jobs=eval_jobs, seed=1234)
        ev_b = batch_eval.evaluate((name,), num_jobs=eval_jobs, seed=1234,
                                   baselines=())
        row = {"streaming": ev_s[name]["rl"], "fcfs": ev_s[name]["fcfs"],
               "batch": ev_b[name]["rl"]}
        results[name] = row
        for contender in ("streaming", "batch", "fcfs"):
            m = row[contender]
            print(f"{name:14s} {contender:11s} {m['mean_wait']/3600:8.3f} "
                  f"{m['mean_jct']/3600:8.3f} {m['bsld']:7.2f} "
                  f"{m['utilization']:5.2f}")
            if out is not None:
                out.append(f"rl_streaming/{name}/{contender}/wait_h,"
                           f"{m['mean_wait']/3600:.4f},"
                           f"jct_h {m['mean_jct']/3600:.4f}")

    acc = _acceptance(results)
    doc = {
        "bench": "rl_streaming",
        "scale": "smoke" if smoke else SCALE,
        "eval_jobs": eval_jobs,
        "train": {"streams": scfg.streams, "jobs_per_stream": scfg.num_jobs,
                  "horizon": scfg.horizon, "episodes": len(eps),
                  "streaming_train_s": round(t_stream, 1),
                  "batch_pairs": bcfg.batches_per_epoch,
                  "batch_train_s": round(t_batch, 1)},
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {c: {m: round(v, 4) for m, v in cm.items()}
                        for c, cm in r.items()} for k, r in results.items()},
        "acceptance": acc,
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    print(f"# streaming beats fcfs on {acc['scenarios_beaten']}/"
          f"{len(SCENARIOS)} scenarios -> "
          f"{'PASS' if acc['passed'] else 'FAIL'} (criterion: >= 2)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
