"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

from benchmarks.common import ART, row

DRY = os.path.join(ART, "dryrun")


def load_cells(mesh_tag: str) -> list[dict]:
    d = os.path.join(DRY, mesh_tag)
    if not os.path.isdir(d):
        return []
    cells = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                cells.append(json.load(fh))
    return cells


def run(out: list[str]) -> None:
    for mesh_tag in ("singlepod", "multipod"):
        cells = load_cells(mesh_tag)
        if not cells:
            print(f"# roofline: no {mesh_tag} artifacts "
                  f"(run python -m repro.launch.dryrun first)")
            continue
        print(f"\n# Roofline ({mesh_tag}): per-chip seconds per step")
        print(f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
              f"{'coll_s':>10s} {'dominant':>11s} {'roof%':>6s} "
              f"{'useful':>7s} {'mem/dev':>8s}")
        for c in cells:
            dom = c["dominant"].replace("_s", "")
            print(f"{c['arch']:22s} {c['shape']:12s} "
                  f"{c['compute_s']:9.3f} {c['memory_s']:9.3f} "
                  f"{c['collective_s']:10.3f} {dom:>11s} "
                  f"{100*c['roofline_fraction']:5.1f}% "
                  f"{c['useful_flops_frac']:7.2f} "
                  f"{(c['memory']['arg_bytes']+c['memory']['temp_bytes'])/2**30:7.1f}G")
            out.append(row(
                f"roofline/{mesh_tag}/{c['arch']}/{c['shape']}",
                c["compute_s"] * 1e6,
                f"dom={dom};roof={100*c['roofline_fraction']:.1f}%"))
