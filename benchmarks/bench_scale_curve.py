"""Scale-curve benchmark: deep queues, tiled 100k–1M-job traces, 10k-node
fleets (ROADMAP "Raw speed, round 3").

Four point families, all written to ``BENCH_scale_curve.json``:

- **window curve** — flash-crowd stream at queue_window 1024/4096/16384:
  jobs/s plus mean/p99 decision latency, compared against the hard-coded
  pre-PR baseline (sub-linear p99 growth and the >=2x deep-queue jobs/s
  win are the acceptance gates);
- **trace curve** — the same stream tiled (re-id + time-shift) to 100k
  jobs (quick) or 1M (full), streamed in compact completed-summary mode
  so memory stays bounded; peak RSS is stamped per point;
- **fleet point** — a synthetic 10k-node federation (8 members x 1250
  nodes) stepped serially and with ``parallel=True``; the two runs must
  be bit-identical in job tuples and decision counters (CI fails on
  divergence);
- **MILP cache point** — repeated-shape ``choose_allocation`` bursts with
  the solution cache on vs off.

Modes: quick (default) / REPRO_BENCH_SCALE=full (1M-job trace point);
``smoke=True`` shrinks to <=1k jobs and qw<=2048 for CI.
REPRO_BENCH_SCALE_CURVE_JSON overrides the artifact path (used by the CI
scale-smoke job to keep the committed artifact pristine).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import platform
import time

import numpy as np

from benchmarks.common import peak_rss_mb, provenance
from repro.core import PolicyPrioritizer, make_policy
from repro.core.milp import choose_allocation
from repro.core.types import ClusterSpec, Job, NodeSpec
from repro.fed import run_fleet
from repro.fed.scenarios import FleetRun
from repro.sched import SchedulerEngine, get_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
#: base stream size for the window curve — the pre-PR baseline below was
#: measured at exactly this size, so overriding it voids the speedup gate
NUM_JOBS = int(os.environ.get("REPRO_BENCH_SCALE_CURVE_JOBS", 10_000))
TRACE_JOBS = {"quick": 100_000, "full": 1_000_000}[SCALE]
QUEUE_WINDOWS = (1024, 4096, 16384)
SMOKE_WINDOWS = (1024, 2048)
FLEET_MEMBERS = 8
FLEET_NODES_PER_MEMBER = 1250          # 8 x 1250 = 10k nodes
SMOKE_NODES_PER_MEMBER = 125
JSON_PATH = os.environ.get(
    "REPRO_BENCH_SCALE_CURVE_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_scale_curve.json"))

#: pre-PR engine measured on this container immediately before the
#: deep-queue pass (commit 88ace55: no backfill vectorization, no negative-
#: shape memo, no schedulability pre-gate), flash-crowd 10k jobs FCFS+pack —
#: the denominators for the tracked >=2x deep-queue acceptance gate.
PRE_PR = {
    "qw1024": {"jobs_per_s": 1040.7, "lat_p99_ms": 0.0376},
    "qw4096": {"jobs_per_s": 496.1, "lat_p99_ms": 0.0948},
    "qw16384": {"jobs_per_s": 442.8, "lat_p99_ms": 0.1066},
}
#: the two deepest-queue points must beat PRE_PR jobs/s by this factor
SPEEDUP_GATE = 2.0


class _DecisionTimer:
    """Wraps a prioritizer to record wall-clock rank() latency (both the
    plain protocol entry point and the engine's contiguous-field one)."""

    def __init__(self, base):
        self.base = base
        self.use_estimates = base.use_estimates
        self.lat: list[float] = []

    def rank(self, jobs, cluster, now):
        t0 = time.perf_counter()
        out = self.base.rank(jobs, cluster, now)
        self.lat.append(time.perf_counter() - t0)
        return out

    def rank_window(self, jobs, cluster, now, fields):
        base = getattr(self.base, "rank_window", None)
        if base is None:
            return self.rank(jobs, cluster, now)
        t0 = time.perf_counter()
        out = base(jobs, cluster, now, fields)
        self.lat.append(time.perf_counter() - t0)
        return out

    def observe_finish(self, job):
        self.base.observe_finish(job)


def _stream(spec, fault_model, jobs: list[Job], queue_window: int, *,
            compact: bool = False, deep_lookahead_k: int | None = None) -> dict:
    """Stream ``jobs`` through a fresh engine (1h ingest chunks, the
    bench_streaming driver) and report throughput + decision latency."""
    pri = _DecisionTimer(PolicyPrioritizer(make_policy("fcfs")))
    engine = SchedulerEngine(spec, pri, allocator="pack",
                             fault_model=fault_model,
                             queue_window=queue_window,
                             completed_summary=compact,
                             deep_lookahead_k=deep_lookahead_k)
    jobs = [j.clone_pending() for j in jobs]
    t0 = time.perf_counter()
    feed = 0
    while True:
        nxt = engine.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt == float("inf"):
            break
        horizon = max(engine.now, nxt) + 3600.0
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= horizon:
            hi += 1
        if hi > feed:
            engine.submit(jobs[feed:hi])
            feed = hi
        engine.step(horizon)
    wall = time.perf_counter() - t0
    lat = np.array(pri.lat) if pri.lat else np.zeros(1)
    return {
        "completed": engine.completed_count,
        "wall_s": wall,
        "jobs_per_s": engine.completed_count / max(wall, 1e-9),
        "decisions": engine.decisions,
        "backfills": engine.backfills,
        "lat_mean_ms": 1e3 * float(lat.mean()),
        "lat_p99_ms": 1e3 * float(np.percentile(lat, 99)),
        "compact_mode": compact,
        "peak_rss_mb": peak_rss_mb(),
    }


def _tile_jobs(base: list[Job], tiles: int) -> list[Job]:
    """Tile a trace ``tiles`` times: each copy is re-identified and
    time-shifted past the previous tile's last arrival, so a 10k-job
    scenario becomes a continuous 100k/1M-job stream with the same local
    arrival structure."""
    lo = min(j.submit_time for j in base)
    hi = max(j.submit_time for j in base)
    span = (hi - lo) + 60.0
    stride = max(j.job_id for j in base) + 1
    out: list[Job] = []
    for t in range(tiles):
        for j in base:
            out.append(dataclasses.replace(
                j, job_id=j.job_id + t * stride,
                submit_time=j.submit_time + t * span))
    return out


def _fleet_run(nodes_per_member: int, num_jobs: int, seed: int) -> FleetRun:
    """Synthetic large fleet: FLEET_MEMBERS uniform members, mixed small/
    large jobs arriving over ~4 simulated hours."""
    rng = np.random.default_rng(seed)
    clusters = []
    for m in range(FLEET_MEMBERS):
        nodes = [NodeSpec(node_id=i, gpu_type="V100", num_gpus=8,
                          num_cpus=96, mem_gb=768.0)
                 for i in range(nodes_per_member)]
        clusters.append(ClusterSpec(nodes=nodes, name=f"pod{m}"))
    arrivals = np.sort(rng.uniform(0.0, 4 * 3600.0, size=num_jobs))
    sizes = rng.choice([1, 2, 4, 8], size=num_jobs,
                       p=[0.45, 0.25, 0.2, 0.1])
    runtimes = np.clip(rng.lognormal(7.0, 1.0, size=num_jobs), 120.0, 86400.0)
    jobs = [Job(job_id=i, user=int(rng.integers(0, 64)),
                submit_time=float(arrivals[i]), runtime=float(runtimes[i]),
                est_runtime=float(runtimes[i]), num_gpus=int(sizes[i]),
                gpu_type="V100", vc=int(rng.integers(0, 4)))
            for i in range(num_jobs)]
    return FleetRun(name=f"scale-fleet-{FLEET_MEMBERS}x{nodes_per_member}",
                    clusters=tuple(clusters), jobs=jobs,
                    fault_models=(None,) * FLEET_MEMBERS)


def _fleet_sig(sr) -> tuple:
    """Bit-identity signature: completed job tuples + per-member decision
    counters + routing counts."""
    jobs = tuple(sorted((j.job_id, j.submit_time, j.first_start_time,
                         j.finish_time, j.num_gpus)
                        for j in sr.result.jobs))
    eng = sr.fed.engines
    return (jobs, tuple(e.decisions for e in eng),
            tuple(e.backfills for e in eng), tuple(sr.fed.routed))


def _fleet_point(nodes_per_member: int, num_jobs: int) -> dict:
    run = _fleet_run(nodes_per_member, num_jobs, seed=0)
    walls = {}
    sigs = {}
    for mode, par in (("serial", False), ("parallel", True)):
        t0 = time.perf_counter()
        sr = run_fleet(run, seed=0, router="jsq", allocator="pack",
                       rescan_interval=60.0, sample_interval=3600.0,
                       parallel=par)
        walls[mode] = time.perf_counter() - t0
        sigs[mode] = _fleet_sig(sr)
    identical = sigs["serial"] == sigs["parallel"]
    return {
        "members": FLEET_MEMBERS,
        "total_nodes": FLEET_MEMBERS * nodes_per_member,
        "total_gpus": FLEET_MEMBERS * nodes_per_member * 8,
        "num_jobs": num_jobs,
        "completed": len(sigs["serial"][0]),
        "wall_serial_s": walls["serial"],
        "wall_parallel_s": walls["parallel"],
        "parallel_speedup": walls["serial"] / max(walls["parallel"], 1e-9),
        "serial_parallel_identical": identical,
        "peak_rss_mb": peak_rss_mb(),
    }


def _milp_cache_point(calls: int) -> dict:
    """Repeated-shape allocation burst: the solution cache must turn the
    steady-state solve into a dict hit.  Multi-node gangs (12–32 GPUs on
    8-GPU nodes) make spread and pack genuinely distinct, so the binary
    way1-vs-way2 solve actually runs; ways/lookahead construction sits
    outside the timed region — only ``choose_allocation`` is measured."""
    spec = ClusterSpec(nodes=[NodeSpec(node_id=i, gpu_type="V100",
                                       num_gpus=8, num_cpus=96, mem_gb=768.0)
                              for i in range(16)])
    from repro.core.cluster import ClusterState
    cluster = ClusterState(spec)
    # fragment half the nodes (4 of 8 GPUs busy) so spread and pack are
    # genuinely distinct placements and the binary solve actually runs
    for i in range(8):
        filler = Job(job_id=9_000 + i, user=0, submit_time=0.0,
                     runtime=86400.0, est_runtime=86400.0, num_gpus=4,
                     gpu_type="V100")
        cluster.allocate(filler, {i: 4})
    shapes = (8, 12, 16, 24)
    probes = []
    for k, g in enumerate(shapes):
        job = Job(job_id=k, user=0, submit_time=0.0, runtime=3600.0,
                  est_runtime=3600.0, num_gpus=g, gpu_type="V100")
        look = [Job(job_id=100 + k * 8 + i, user=0, submit_time=0.0,
                    runtime=1800.0, est_runtime=1800.0,
                    num_gpus=shapes[(k + i) % len(shapes)],
                    gpu_type="V100") for i in range(4)]
        probes.append((job, cluster.candidate_ways(job), look))
    walls = {}
    for mode, cached in (("uncached", False), ("cached", True)):
        if hasattr(cluster, "_milp_sol_cache"):
            del cluster._milp_sol_cache
        t0 = time.perf_counter()
        for k in range(calls):
            job, ways, look = probes[k % len(probes)]
            choose_allocation(cluster, job, ways, look,
                              solution_cache=cached)
        walls[mode] = time.perf_counter() - t0
    return {
        "calls": calls,
        "wall_uncached_s": walls["uncached"],
        "wall_cached_s": walls["cached"],
        "cache_speedup": walls["uncached"] / max(walls["cached"], 1e-9),
    }


def _emit_json(doc: dict) -> None:
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = 1_000 if smoke else NUM_JOBS
    windows = SMOKE_WINDOWS if smoke else QUEUE_WINDOWS
    trace_jobs = 1_000 if smoke else TRACE_JOBS
    nodes_per = SMOKE_NODES_PER_MEMBER if smoke else FLEET_NODES_PER_MEMBER
    fleet_jobs = 800 if smoke else num_jobs
    milp_calls = 200 if smoke else 2_000

    base = get_scenario("flash-crowd").build(num_jobs, seed=0)

    # ---- window curve -------------------------------------------------
    print(f"# scale curve: flash-crowd {num_jobs} jobs, FCFS+pack")
    print(f"{'point':18s} {'jobs/s':>8s} {'dec':>7s} {'lat.p99':>9s} "
          f"{'rss(MB)':>8s} {'wall(s)':>8s}")
    window_curve: dict[str, dict] = {}
    for qw in windows:
        r = _stream(base.spec, base.fault_model, base.jobs, qw)
        assert r["completed"] == num_jobs, (qw, r["completed"])
        window_curve[f"qw{qw}"] = r
        print(f"{'window/qw' + str(qw):18s} {r['jobs_per_s']:8.0f} "
              f"{r['decisions']:7d} {r['lat_p99_ms']:7.4f}ms "
              f"{r['peak_rss_mb'] or 0:8.0f} {r['wall_s']:8.1f}")
        if out is not None:
            out.append(f"scale_curve/qw{qw}/lat_p99_ms,"
                       f"{r['lat_p99_ms']:.4f},"
                       f"{r['jobs_per_s']:.0f} jobs/s")

    # ---- trace-size curve (tiled, compact completed mode) -------------
    tiles = max(1, math.ceil(trace_jobs / num_jobs))
    trace = _tile_jobs(base.jobs, tiles)[:trace_jobs] if tiles > 1 \
        else base.jobs
    # tiling never splits a tile: trace_jobs is a whole multiple upstream,
    # but guard the slice anyway so overrides can't strand arrivals
    r = _stream(base.spec, base.fault_model, trace, windows[0],
                compact=True, deep_lookahead_k=4)
    assert r["completed"] == len(trace), (len(trace), r["completed"])
    trace_curve = {f"jobs{len(trace)}": r}
    print(f"{'trace/' + str(len(trace)):18s} {r['jobs_per_s']:8.0f} "
          f"{r['decisions']:7d} {r['lat_p99_ms']:7.4f}ms "
          f"{r['peak_rss_mb'] or 0:8.0f} {r['wall_s']:8.1f}")
    if out is not None:
        out.append(f"scale_curve/trace{len(trace)}/jobs_per_s,"
                   f"{r['jobs_per_s']:.0f},"
                   f"rss {r['peak_rss_mb'] or 0:.0f}MB compact")

    # ---- fleet point (serial vs parallel, bit-identity gate) ----------
    fp = _fleet_point(nodes_per, fleet_jobs)
    print(f"{'fleet/' + str(fp['total_nodes']) + 'n':18s} "
          f"{fp['num_jobs'] / max(fp['wall_serial_s'], 1e-9):8.0f} "
          f"{'-':>7s} {'-':>9s} {fp['peak_rss_mb'] or 0:8.0f} "
          f"{fp['wall_serial_s']:8.1f}")
    print(f"# fleet parallel: x{fp['parallel_speedup']:.2f} vs serial, "
          f"identical={fp['serial_parallel_identical']}")
    if out is not None:
        out.append(f"scale_curve/fleet{fp['total_nodes']}n/wall_s,"
                   f"{fp['wall_serial_s']:.2f},"
                   f"parallel x{fp['parallel_speedup']:.2f} "
                   f"identical={fp['serial_parallel_identical']}")

    # ---- MILP solution-cache point ------------------------------------
    mp = _milp_cache_point(milp_calls)
    print(f"# milp cache: {mp['calls']} calls, "
          f"x{mp['cache_speedup']:.1f} cached vs uncached")

    # ---- gates ---------------------------------------------------------
    lo_key, hi_key = f"qw{windows[0]}", f"qw{windows[-1]}"
    lat_ratio = (window_curve[hi_key]["lat_p99_ms"]
                 / max(window_curve[lo_key]["lat_p99_ms"], 1e-9))
    window_ratio = windows[-1] / windows[0]
    gates: dict = {
        "p99_latency_growth": {
            "window_ratio": window_ratio,
            "latency_ratio": round(lat_ratio, 3),
            "sublinear": bool(lat_ratio < window_ratio),
        },
        "fleet_serial_parallel_identical": fp["serial_parallel_identical"],
    }
    speedups = {}
    if not smoke and num_jobs == 10_000:   # baseline recorded at this size
        for key, basev in PRE_PR.items():
            if key in window_curve:
                speedups[key] = round(
                    window_curve[key]["jobs_per_s"] / basev["jobs_per_s"], 2)
        deepest = [f"qw{w}" for w in windows[-2:]]
        gates["deep_queue_speedup"] = {
            "gate": SPEEDUP_GATE,
            "points": {k: speedups.get(k) for k in deepest},
            "passed": all((speedups.get(k) or 0) >= SPEEDUP_GATE
                          for k in deepest),
        }

    doc = {
        "bench": "scale_curve",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "trace_jobs": len(trace),
        "policy": "fcfs",
        "allocator": "pack",
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "baseline_host_note": "PRE_PR measured on the original CI container "
                              "at 10k jobs; compare speedup_vs_pre_pr only "
                              "on matching hardware",
        "window_curve": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                             for m, v in r.items()}
                         for k, r in window_curve.items()},
        "trace_curve": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                            for m, v in r.items()}
                        for k, r in trace_curve.items()},
        "fleet": {m: (round(v, 4) if isinstance(v, float) else v)
                  for m, v in fp.items()},
        "milp_cache": {m: (round(v, 4) if isinstance(v, float) else v)
                       for m, v in mp.items()},
        "pre_pr_baseline": PRE_PR,
        "speedup_vs_pre_pr": speedups,
        "gates": gates,
        "provenance": provenance(seed=0),
    }
    _emit_json(doc)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    g = gates["p99_latency_growth"]
    print(f"# p99 growth {lo_key}->{hi_key}: latency x{g['latency_ratio']:.2f}"
          f" over window x{g['window_ratio']:.0f} "
          f"({'sub-linear' if g['sublinear'] else 'SUPER-linear'})")
    if speedups:
        pretty = ", ".join(f"{k} {v:.2f}x" for k, v in sorted(speedups.items()))
        print(f"# jobs/s vs pre-PR: {pretty}")
    if not fp["serial_parallel_identical"]:
        raise AssertionError("parallel federation diverged from serial")
    return doc


if __name__ == "__main__":
    run()
