"""Federation benchmark: routing policies over heterogeneous GPU fleets.

Streams >=10k-job fleet scenarios through ``FederatedScheduler`` (one
engine per cluster, lockstep rescan windows) once per registered router and
compares fleet-level outcomes — JCT p50/p99, queueing-delay p99, fleet
utilization, cross-cluster Jain fairness, and the routed-job distribution.

The headline comparison is on the 3-cluster size-skewed fleet
(``fleet-skewed-flash``): a uniform stateless ``hash`` baseline drowns the
small cluster, so load-aware (``jsq``) and SKU-aware (``sku-affinity``)
routing must beat it on fleet wait-p99.  The verdicts are recorded in the
``acceptance`` block of ``BENCH_federation.json`` so the trajectory is
tracked across PRs.

Modes: REPRO_BENCH_SCALE=full streams 20k jobs, default (quick) 10k;
``--smoke`` (or ``run(smoke=True)``) caps the stream at <=1k jobs so CI can
exercise the whole bench path cheaply.  REPRO_BENCH_FED_JOBS overrides the
job count, REPRO_BENCH_FED_JSON the artifact path (used by the tier-1 smoke
test to keep the committed artifact pristine).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

from benchmarks.common import provenance
from repro.fed import list_routers, run_fleet

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_FED_JOBS",
                              {"quick": 10_000, "full": 20_000}[SCALE]))
SMOKE_JOBS = 1_000
SCENARIOS = ("fleet-skewed-flash", "fleet-sku-split")
#: the acceptance comparison runs on the size-skewed fleet
ACCEPTANCE_SCENARIO = "fleet-skewed-flash"
JSON_PATH = os.environ.get(
    "REPRO_BENCH_FED_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_federation.json"))


def stream_once(scenario: str, router: str, num_jobs: int) -> dict:
    t0 = time.perf_counter()
    sr = run_fleet(scenario, num_jobs=num_jobs, seed=0, router=router,
                   allocator="pack", rescan_interval=60.0,
                   sample_interval=3600.0)
    wall = time.perf_counter() - t0
    res = sr.result
    return {
        "completed": len(res.jobs),
        "wall_s": wall,
        "jobs_per_s": len(res.jobs) / max(wall, 1e-9),
        "windows": sr.windows,
        "routed": list(res.routed),
        "jct_p50_h": res.jct_p50 / 3600.0,
        "jct_p99_h": res.jct_p99 / 3600.0,
        "wait_p50_h": res.wait_p50 / 3600.0,
        "wait_p99_h": res.wait_p99 / 3600.0,
        "avg_wait_h": res.avg_wait / 3600.0,
        "utilization": res.utilization,
        "fairness": res.fairness,
    }


def _acceptance(results: dict[str, dict]) -> dict:
    """jsq / sku-affinity vs the hash baseline on the skewed fleet."""
    out: dict = {"scenario": ACCEPTANCE_SCENARIO}
    base = results.get(f"{ACCEPTANCE_SCENARIO}/hash")
    if base is None:
        return out
    for name in ("jsq", "sku-affinity"):
        r = results.get(f"{ACCEPTANCE_SCENARIO}/{name}")
        if r is None:
            continue
        key = name.replace("-", "_")
        out[f"{key}_wait_p99_h"] = round(r["wait_p99_h"], 4)
        out[f"{key}_beats_hash"] = bool(r["wait_p99_h"] < base["wait_p99_h"])
    out["hash_wait_p99_h"] = round(base["wait_p99_h"], 4)
    return out


def _emit_json(results: dict[str, dict], num_jobs: int, smoke: bool) -> dict:
    doc = {
        "bench": "federation",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "policy": "fcfs",
        "allocator": "pack",
        "rescan_interval_s": 60.0,
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "acceptance": _acceptance(results),
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = min(NUM_JOBS, SMOKE_JOBS) if smoke else NUM_JOBS
    routers = list_routers()
    print(f"# federation: {num_jobs} jobs/stream, FCFS+pack, 60s lockstep "
          f"windows, routers={','.join(routers)}")
    print(f"{'scenario':20s} {'router':16s} {'waitP99h':>8s} {'jctP99h':>8s} "
          f"{'util':>5s} {'fair':>5s} {'routed':>22s} {'wall(s)':>8s}")
    results: dict[str, dict] = {}
    for scenario in SCENARIOS:
        for router in routers:
            r = stream_once(scenario, router, num_jobs)
            assert r["completed"] == num_jobs, \
                (scenario, router, r["completed"])
            results[f"{scenario}/{router}"] = r
            print(f"{scenario:20s} {router:16s} {r['wait_p99_h']:8.2f} "
                  f"{r['jct_p99_h']:8.2f} {r['utilization']:5.2f} "
                  f"{r['fairness']:5.2f} {str(r['routed']):>22s} "
                  f"{r['wall_s']:8.1f}")
            if out is not None:
                out.append(f"federation/{scenario}/{router}/wait_p99_h,"
                           f"{r['wait_p99_h']:.4f},"
                           f"util {r['utilization']:.2f}")
    doc = _emit_json(results, num_jobs, smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    acc = doc["acceptance"]
    for name in ("jsq", "sku_affinity"):
        if f"{name}_beats_hash" in acc:
            verdict = "BEATS" if acc[f"{name}_beats_hash"] else "LOSES TO"
            print(f"# {name} {verdict} hash on {ACCEPTANCE_SCENARIO} "
                  f"wait-p99 ({acc[f'{name}_wait_p99_h']:.2f}h vs "
                  f"{acc['hash_wait_p99_h']:.2f}h)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
