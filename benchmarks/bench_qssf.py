"""Paper Table 8 + Fig 17: RLTune vs QSSF (history-informed SOTA) on Philly,
all four metrics, plus a long-horizon consecutive-jobs JCT comparison."""
from __future__ import annotations


from benchmarks.common import SCALE, eval_pair, get_trainer, row
from repro.core import (PolicyPrioritizer, Simulator, improvement,
                        make_policy)


def run(out: list[str]) -> None:
    print("# Table 8: QSSF vs RLTune on Philly (backfilling on)")
    tr = get_trainer("philly", "qssf", "wait")
    ev = eval_pair(tr)
    print(f"{'metric':8s} {'QSSF':>10s} {'RLTune':>10s} {'improvement':>12s}")
    for m in ("wait", "bsld", "jct", "util"):
        b, r, imp = ev[m]
        print(f"{m:8s} {b:10.2f} {r:10.2f} {imp:+11.1f}%")
        out.append(row(f"table8/{m}", 0.0, f"{imp:+.1f}%"))

    # Fig 17: long-horizon consecutive jobs (scaled from the paper's 10k)
    n = 2048 if SCALE == "quick" else 10_000
    print(f"\n# Fig 17: {n} consecutive jobs, JCT")
    from repro.core import generate_trace
    jobs = generate_trace("philly", n, seed=77)
    qssf_res = Simulator(tr.cluster, allocator="pack").run_batch(
        [j.clone_pending() for j in jobs],
        PolicyPrioritizer(make_policy("qssf", True)))
    from repro.core.env import RLPrioritizer
    rl_res = Simulator(tr.cluster, allocator="milp").run_batch(
        [j.clone_pending() for j in jobs],
        RLPrioritizer(tr.agent, explore=False, use_estimates=True))
    imp = improvement(qssf_res.avg_jct, rl_res.avg_jct)
    print(f"  QSSF JCT={qssf_res.avg_jct:.0f}s  RLTune JCT={rl_res.avg_jct:.0f}s"
          f"  ({imp:+.1f}%)")
    out.append(row("fig17/jct_10k", 0.0, f"{imp:+.1f}%"))
