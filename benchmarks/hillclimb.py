"""§Perf hillclimb driver: re-lower chosen cells under candidate configs and
record hypothesis -> change -> before/after (EXPERIMENTS.md §Perf).

Cells (chosen per the assignment):
  - mamba2-780m  x train_4k : worst roofline fraction (0.4%)
  - qwen3-moe    x train_4k : most collective-bound absolute (131 s/chip)
  - yi-6b        x train_4k : representative dense-FSDP production case

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [cell ...]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import json      # noqa: E402
import sys       # noqa: E402
import time      # noqa: E402

from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import ModelImpl  # noqa: E402
from repro.sharding.specs import (DEFAULT_RULES, DP_ONLY_RULES,  # noqa: E402
                                  TP_ONLY_RULES)

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts",
                   "hillclimb")
os.makedirs(ART, exist_ok=True)

# experiment list: (cell, tag, hypothesis, kwargs for lower_cell)
EXPERIMENTS = {
    "mamba2": [
        ("mamba2-780m", "train_4k", "baseline",
         "as-recorded baseline (FSDP rules, mb=2)", {}),
        ("mamba2-780m", "train_4k", "tp_only",
         "0.78B params fit TP-only; dropping FSDP removes per-layer weight "
         "all-gathers + full-size grad all-reduces over data (predict "
         "collective ~10-100x down)",
         dict(rules=TP_ONLY_RULES)),
        ("mamba2-780m", "train_4k", "tp_only_mb1",
         "collectives scale with microbatch count; mb 2->1 halves grad "
         "reduction traffic (activation temp x2, fits)",
         dict(rules=TP_ONLY_RULES, microbatches=1)),
        ("mamba2-780m", "train_4k", "dp_only_mb1",
         "0.78B params+opt = 7.8 GiB replicated per chip: pure DP over all "
         "256 chips removes every TP activation all-reduce; only one "
         "~6 GiB grad all-reduce remains (predict coll ~0.2s, "
         "compute-bound at last)",
         dict(rules=DP_ONLY_RULES, microbatches=1)),
    ],
    "yi": [
        ("yi-6b", "train_4k", "baseline",
         "as-recorded baseline (FSDP rules, mb=4)", {}),
        ("yi-6b", "train_4k", "tp_only",
         "6B params+opt = 3.75 GiB/chip TP-only; removing FSDP eliminates "
         "920 GiB/chip of gathers -> grad all-reduce only (predict "
         "collective ~0.3-1s, compute-bound)",
         dict(rules=TP_ONLY_RULES)),
        ("yi-6b", "train_4k", "tp_only_mb2",
         "fewer microbatches: grad all-reduce bytes scale with mb (4->2)",
         dict(rules=TP_ONLY_RULES, microbatches=2)),
        ("yi-6b", "train_4k", "tp_only_mb2_chunkloss",
         "chunked cross-entropy shrinks the live fp32 logits slab",
         dict(rules=TP_ONLY_RULES, microbatches=2,
              impl=ModelImpl(loss_chunk=512))),
        ("yi-6b", "train_4k", "tp_only_mb1",
         "continue the confirmed mb trend under TP-only (11.3s at mb=2 -> "
         "predict ~5.6s at mb=1; activation AR bytes halve again)",
         dict(rules=TP_ONLY_RULES, microbatches=1,
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked"))),
        ("yi-6b", "train_4k", "fsdp_mb1_chunked",
         "REVISED after tp_only refutation: FSDP's gathers were cheap "
         "(16 GiB); the 900 GiB is per-mb grad all-reduces. mb=1 pays the "
         "grad reduction ONCE (predict coll ~1-3s, compute-bound); chunked "
         "attention + chunked loss absorb the 4x activation growth",
         dict(microbatches=1,
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked"))),
    ],
    "qwen3": [
        ("qwen3-moe-235b-a22b", "train_4k", "baseline",
         "as-recorded baseline (FSDP required at 235B; mb=16)", {}),
        ("qwen3-moe-235b-a22b", "train_4k", "mb8_chunked",
         "FSDP gathers repeat per microbatch: mb 16->8 halves collective; "
         "chunked attention + chunked loss absorb the 2x activation growth",
         dict(microbatches=8,
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked"))),
        ("qwen3-moe-235b-a22b", "train_4k", "mb4_chunked",
         "push further: mb 16->4 quarters collective traffic",
         dict(microbatches=4,
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked"))),
        ("qwen3-moe-235b-a22b", "train_4k", "mb8_dots",
         "remat=dots saves matmul outputs so backward skips the re-gather "
         "forward pass (predict collective x2/3, temp up)",
         dict(microbatches=8,
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked",
                             remat_policy="dots"))),
        ("qwen3-moe-235b-a22b", "train_4k", "expert_2d",
         "REVISED after mb refutation (XLA hoists gathers; cost is per-layer "
         "full-size expert-grad all-reduces over data). Shard expert FFN dim "
         "over data too (E->model, F->data): weights fully 2D-sharded, so "
         "each chip reduces only its 1/16 F-slice — the reduce-scatter "
         "effect the CPU pipeline won't emit (predict collective ~x5 down)",
         dict(microbatches=8,
              rules=dict(DEFAULT_RULES, embed=None, ffn="data"),
              impl=ModelImpl(loss_chunk=512, attn="xla_chunked"))),
    ],
}


def run_cell(cell: str) -> list[dict]:
    mesh = make_production_mesh()
    out = []
    for arch, shape, tag, hypothesis, kw in EXPERIMENTS[cell]:
        t0 = time.time()
        try:
            rec, compiled = lower_cell(arch, shape, mesh, **kw)
            del compiled
            rec.update(tag=tag, hypothesis=hypothesis, cell=cell)
            out.append(rec)
            print(f"[{cell}/{tag}] comp={rec['compute_s']:.3f}s "
                  f"mem={rec['memory_s']:.3f}s coll={rec['collective_s']:.3f}s "
                  f"dom={rec['dominant']} roof={100*rec['roofline_fraction']:.1f}% "
                  f"temp={rec['memory']['temp_bytes']/2**30:.1f}GiB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"[{cell}/{tag}] FAILED: {e!r}", flush=True)
            out.append({"cell": cell, "tag": tag, "hypothesis": hypothesis,
                        "error": repr(e)})
    with open(os.path.join(ART, f"{cell}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    cells = sys.argv[1:] or list(EXPERIMENTS)
    for cell in cells:
        print(f"\n=== hillclimb {cell} ===")
        run_cell(cell)


if __name__ == "__main__":
    main()
