"""Autoscaling benchmark: elastic capacity vs. a static peak-provisioned
cluster.

Streams the congestion scenarios with shaped supply pressure (``diurnal``
day/night swings, ``flash-crowd`` spike) through ``run_scenario`` three
ways — static capacity (the baseline every prior PR measured), the
``target-util`` hysteresis controller, and the ``queue-pressure`` dual-
watermark controller — and compares *provisioned* GPU-hours (the integral
of non-retired capacity over simulated time, i.e. what an elastic
deployment pays for) against schedule quality (worst rolling wait-p99).

Acceptance (recorded in ``BENCH_autoscaling.json``): on both scenarios the
hysteresis ``target-util`` controller must cut provisioned GPU-hours vs.
static peak capacity while holding worst wait-p99 inside the documented
band ``<= WAIT_BAND_FACTOR * static + WAIT_BAND_SLACK_S``.  The
disabled-autoscaler bit-identity pin (autoscaler=None == pre-autoscaling
engine on every registered scenario, single-cluster and 1-member
federation) lives in ``tests/test_autoscaling.py``.

Modes: REPRO_BENCH_SCALE=full streams 10k jobs, default (quick) 3k;
``--smoke`` caps at <=300 so CI exercises the full bench path.
REPRO_BENCH_AUTOSCALE_JOBS overrides the job count,
REPRO_BENCH_AUTOSCALE_JSON the artifact path (used by the tier-1 smoke
test to keep the committed artifact pristine).
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

from benchmarks.common import provenance
from repro.scale import (QueuePressureAutoscaler, TargetUtilizationAutoscaler,
                         pools_from_spec)
from repro.sched import get_scenario, run_scenario

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")
NUM_JOBS = int(os.environ.get("REPRO_BENCH_AUTOSCALE_JOBS",
                              {"quick": 3_000, "full": 10_000}[SCALE]))
SMOKE_JOBS = 300
SCENARIOS = ("diurnal", "flash-crowd")
#: wait-p99 degradation band the elastic runs must stay inside
WAIT_BAND_FACTOR = 1.5
WAIT_BAND_SLACK_S = 1800.0
JSON_PATH = os.environ.get(
    "REPRO_BENCH_AUTOSCALE_JSON",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                 "BENCH_autoscaling.json"))

#: controller configurations under test (pools derived per scenario spec)
CONTROLLERS = {
    "target-util": lambda spec: TargetUtilizationAutoscaler(
        pools_from_spec(spec, min_frac=0.25),
        util_low=0.6, util_high=0.85, max_pending_for_down=4,
        cooldown_s=1800.0),
    "queue-pressure": lambda spec: QueuePressureAutoscaler(
        pools_from_spec(spec, min_frac=0.25),
        wait_up_s=1800.0, wait_down_s=300.0, util_down=0.55,
        cooldown_s=1800.0),
}


def stream_once(scenario: str, controller: str | None, num_jobs: int) -> dict:
    run = get_scenario(scenario).build(num_jobs, 0)
    autoscaler = CONTROLLERS[controller](run.spec) if controller else None
    t0 = time.perf_counter()
    sr = run_scenario(run, allocator="pack", rescan_interval=60.0,
                      sample_interval=3600.0, autoscaler=autoscaler)
    wall = time.perf_counter() - t0
    tel = sr.telemetry
    row = {
        "completed": len(sr.batch.jobs),
        "wall_s": wall,
        "jobs_per_s": len(sr.batch.jobs) / max(wall, 1e-9),
        "windows": sr.windows,
        "provisioned_gpu_h": tel.provisioned_gpu_hours,
        "used_gpu_h": tel.used_gpu_hours,
        "worst_wait_p99_h": tel.worst_wait_p99() / 3600.0,
        "avg_wait_h": sum(j.wait_time for j in sr.batch.jobs)
        / max(len(sr.batch.jobs), 1) / 3600.0,
        "utilization": sr.batch.utilization,
    }
    if autoscaler is not None:
        row["scale_events"] = autoscaler.event_counts()
        row["scale_events_total"] = len(autoscaler.events)
    return row


def _acceptance(results: dict[str, dict]) -> dict:
    """target-util vs the static baseline on every scenario."""
    out: dict = {
        "controller": "target-util",
        "wait_band": f"<= {WAIT_BAND_FACTOR} * static worst wait-p99 "
                     f"+ {WAIT_BAND_SLACK_S:.0f}s",
    }
    for scen in SCENARIOS:
        base = results.get(f"{scen}/static")
        elastic = results.get(f"{scen}/target-util")
        if base is None or elastic is None:
            continue
        key = scen.replace("-", "_")
        saved = 1.0 - elastic["provisioned_gpu_h"] \
            / max(base["provisioned_gpu_h"], 1e-9)
        band_h = (WAIT_BAND_FACTOR * base["worst_wait_p99_h"]
                  + WAIT_BAND_SLACK_S / 3600.0)
        out[f"{key}_gpu_hours_saved_frac"] = round(saved, 4)
        out[f"{key}_cuts_gpu_hours"] = bool(saved > 0.0)
        out[f"{key}_wait_p99_h"] = round(elastic["worst_wait_p99_h"], 4)
        out[f"{key}_wait_band_h"] = round(band_h, 4)
        out[f"{key}_wait_within_band"] = \
            bool(elastic["worst_wait_p99_h"] <= band_h)
    return out


def _emit_json(results: dict[str, dict], num_jobs: int, smoke: bool) -> dict:
    doc = {
        "bench": "autoscaling",
        "scale": "smoke" if smoke else SCALE,
        "num_jobs": num_jobs,
        "policy": "fcfs",
        "allocator": "pack",
        "rescan_interval_s": 60.0,
        "host": platform.node() or "unknown",
        "machine": platform.machine(),
        "results": {k: {m: (round(v, 4) if isinstance(v, float) else v)
                        for m, v in r.items()} for k, r in results.items()},
        "acceptance": _acceptance(results),
        "provenance": provenance(seed=0),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def run(out: list[str] | None = None, smoke: bool = False) -> dict:
    num_jobs = min(NUM_JOBS, SMOKE_JOBS) if smoke else NUM_JOBS
    variants = [None] + sorted(CONTROLLERS)
    print(f"# autoscaling: {num_jobs} jobs/stream, FCFS+pack, 60s rescan, "
          f"controllers={','.join(c for c in variants if c)}")
    print(f"{'scenario':14s} {'controller':14s} {'provGPUh':>9s} "
          f"{'usedGPUh':>9s} {'waitP99h':>8s} {'events':>7s} {'wall(s)':>8s}")
    results: dict[str, dict] = {}
    for scenario in SCENARIOS:
        for controller in variants:
            label = controller or "static"
            r = stream_once(scenario, controller, num_jobs)
            assert r["completed"] == num_jobs, \
                (scenario, label, r["completed"])
            results[f"{scenario}/{label}"] = r
            print(f"{scenario:14s} {label:14s} {r['provisioned_gpu_h']:9.0f} "
                  f"{r['used_gpu_h']:9.0f} {r['worst_wait_p99_h']:8.2f} "
                  f"{r.get('scale_events_total', 0):7d} {r['wall_s']:8.1f}")
            if out is not None:
                out.append(f"autoscaling/{scenario}/{label}/provisioned_gpu_h,"
                           f"{r['provisioned_gpu_h']:.1f},"
                           f"wait_p99_h {r['worst_wait_p99_h']:.2f}")
    doc = _emit_json(results, num_jobs, smoke)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
    acc = doc["acceptance"]
    for scen in SCENARIOS:
        key = scen.replace("-", "_")
        if f"{key}_cuts_gpu_hours" in acc:
            cut = "CUTS" if acc[f"{key}_cuts_gpu_hours"] else "DOES NOT CUT"
            band = "WITHIN" if acc[f"{key}_wait_within_band"] else "OUTSIDE"
            print(f"# target-util {cut} provisioned GPU-hours on {scen} "
                  f"({acc[f'{key}_gpu_hours_saved_frac']:.1%} saved), "
                  f"wait-p99 {band} band "
                  f"({acc[f'{key}_wait_p99_h']:.2f}h vs "
                  f"{acc[f'{key}_wait_band_h']:.2f}h)")
    return doc


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
