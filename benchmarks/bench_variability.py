"""Paper Fig 6: batch-wise workload variability — jobs exceeding the global
median wait vs total cumulative wait per consecutive batch window.  Shows the
bursty, non-stationary pressure that motivates the reward normalization."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import (PolicyPrioritizer, Simulator, generate_trace,
                        make_cluster, make_policy)


def run(out: list[str]) -> None:
    print("# Fig 6: batch-wise congestion trajectories (FCFS, 20x128 jobs)")
    for trace in ("philly", "helios"):
        jobs = generate_trace(trace, 20 * 128, seed=5)
        sim = Simulator(make_cluster(trace), allocator="pack")
        waits_per_batch = []
        for i in range(20):
            batch = [j.clone_pending() for j in jobs[i * 128:(i + 1) * 128]]
            res = sim.run_batch(batch, PolicyPrioritizer(make_policy("fcfs")))
            waits_per_batch.append([j.wait_time for j in res.jobs])
        all_waits = np.concatenate(waits_per_batch)
        median = float(np.median(all_waits))
        over = [int(np.sum(np.asarray(w) > median)) for w in waits_per_batch]
        tot = [float(np.sum(w)) / 3600.0 for w in waits_per_batch]
        cv_over = float(np.std(over) / (np.mean(over) + 1e-9))
        print(f"  {trace:8s}: jobs>median per batch min={min(over)} "
              f"max={max(over)} (cv={cv_over:.2f}); total wait per batch "
              f"min={min(tot):.1f}h max={max(tot):.1f}h")
        out.append(row(f"fig6/{trace}/burstiness_cv", 0.0, f"{cv_over:.2f}"))
        # the paper's point: heavy variability across consecutive batches
        assert max(tot) > 2 * (min(tot) + 1e-9) or max(over) > 2 * min(over) + 1
