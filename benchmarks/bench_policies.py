"""Paper Table 9 + Figs 11/12/14/15/16: RLTune vs base policies and vs the
RLScheduler / SchedInspector mechanisms, across the three traces."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (BATCH_SIZE, EVAL_BATCHES, eval_pair,
                               get_trainer, row)
from repro.core import PolicyPrioritizer, Simulator, make_policy

TRACES = ("philly", "helios", "alibaba")


def run(out: list[str]) -> None:
    print("# Table 9: policy comparison (per-trace: BSLD / WT / JCT / Util)")
    header = f"{'policy':16s} " + "".join(
        f"| {t:^34s} " for t in TRACES) + "| time(s)"
    print(header)

    # base policies (FIFO row of Table 9) — direct simulation
    for pol in ("fcfs", "sjf"):
        cells = []
        t0 = time.time()
        for trace in TRACES:
            tr = get_trainer(trace, pol, train=False)
            base_jobs = tr._batches(tr.eval_jobs, EVAL_BATCHES, BATCH_SIZE,
                                    np.random.default_rng(1234))
            sim = Simulator(tr.cluster, allocator="pack")
            ms = {"wait": [], "jct": [], "bsld": [], "util": []}
            for b in base_jobs:
                res = sim.run_batch([j.clone_pending() for j in b],
                                    PolicyPrioritizer(make_policy(pol, True)))
                ms["wait"].append(res.avg_wait)
                ms["jct"].append(res.avg_jct)
                ms["bsld"].append(res.avg_bsld)
                ms["util"].append(res.utilization)
            cells.append(f"{np.mean(ms['bsld']):7.1f} {np.mean(ms['wait']):8.0f} "
                         f"{np.mean(ms['jct']):8.0f} {np.mean(ms['util']):4.2f}")
        print(f"{pol:16s} " + "".join(f"| {c} " for c in cells)
              + f"| {time.time() - t0:.0f}")

    # RL variants: RLTune (pro), RLScheduler mechanism (naive), SchedInspector
    for variant, label in (("pro", "RLTune"), ("naive", "RLScheduler*"),
                           ("inspector", "SchedInspector*")):
        cells = []
        t0 = time.time()
        for trace in TRACES:
            tr = get_trainer(trace, "fcfs", "wait", variant)
            ev = eval_pair(tr)
            cells.append(f"{ev['bsld'][1]:7.1f} {ev['wait'][1]:8.0f} "
                         f"{ev['jct'][1]:8.0f} {ev['util'][1]:4.2f}")
            if variant == "pro":
                out.append(row(f"table9/{trace}/wait_improvement_pct", 0.0,
                               f"{ev['wait'][2]:+.1f}%"))
        print(f"{label:16s} " + "".join(f"| {c} " for c in cells)
              + f"| {time.time() - t0:.0f}")

    # Fig 12-style per-base-policy improvements (wait) on each trace
    print("\n# Fig 11/12: RL-enabled wait-time improvement per base policy")
    for trace in TRACES:
        for pol in ("fcfs", "sjf"):
            tr = get_trainer(trace, pol, "wait", "pro")
            ev = eval_pair(tr)
            b, r, imp = ev["wait"]
            print(f"  {trace:8s} {pol:6s}: {b:9.1f} -> {r:9.1f}  ({imp:+.1f}%)")
            out.append(row(f"fig12/{trace}/{pol}", 0.0, f"{imp:+.1f}%"))

    # Fig 16: Slurm multifactor baseline (BSLD)
    print("\n# Fig 16: vs Slurm multifactor (BSLD)")
    for trace in ("philly", "helios"):
        tr = get_trainer(trace, "slurm-mf", "bsld", "pro")
        ev = eval_pair(tr)
        b, r, imp = ev["bsld"]
        print(f"  {trace:8s} slurm-mf: BSLD {b:8.2f} -> {r:8.2f} ({imp:+.1f}%)")
        out.append(row(f"fig16/{trace}/slurm_bsld", 0.0, f"{imp:+.1f}%"))
