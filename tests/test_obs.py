"""Tests for repro.obs: MultiHooks fan-out, span tracer, metrics registry,
decision audit log, report CLI, and the obs-off bit-identity guarantee."""
import io
import json
import math

import pytest

from repro.core import PolicyPrioritizer, make_policy
from repro.obs import (DecisionAuditLog, EngineMetricsHook, MetricsRegistry,
                       Observability, SpanTracer, merge_documents,
                       validate_trace)
from repro.obs.report import analyze, main as report_main, print_report
from repro.sched import (EngineHooks, MultiHooks, SchedulerEngine,
                         get_scenario, list_scenarios, run_scenario,
                         run_stream)


def _make_engine(spec, policy="fcfs", **kw):
    return SchedulerEngine(spec, PolicyPrioritizer(make_policy(policy)), **kw)


def _signature(engine):
    jobs = tuple(sorted(
        (j.job_id, round(j.submit_time, 6),
         round(j.first_start_time if j.first_start_time is not None else -1, 6),
         round(j.finish_time if j.finish_time is not None else -1, 6),
         j.restarts)
        for j in engine.completed))
    return jobs, (engine.decisions, engine.milp_calls, engine.backfills,
                  engine.restarts)


def _drain_scenario(scenario, n, seed, hooks=()):
    run = get_scenario(scenario).build(n, seed)
    eng = _make_engine(run.spec, allocator="pack",
                       fault_model=run.fault_model, hooks=hooks)
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    return eng


# --------------------------------------------------------------- MultiHooks --


class _Recorder(EngineHooks):
    def __init__(self, tag, log):
        self.tag = tag
        self.log = log

    def on_submit(self, job, now):
        self.log.append((self.tag, "submit", job.job_id))

    def on_start(self, job, now):
        self.log.append((self.tag, "start", job.job_id))


class _Exploder(EngineHooks):
    def on_start(self, job, now):
        raise RuntimeError("observer bug")


def test_multihooks_preserves_child_order():
    log = []
    mh = MultiHooks(_Recorder("a", log), _Recorder("b", log))

    class _J:
        job_id = 7
    mh.on_submit(_J(), 0.0)
    assert log == [("a", "submit", 7), ("b", "submit", 7)]


def test_multihooks_skips_inherited_noops_and_wants():
    log = []
    mh = MultiHooks(_Recorder("a", log))
    assert mh.wants("on_submit") and mh.wants("on_start")
    # _Recorder only overrides on_submit/on_start — the rest stay no-ops
    assert not mh.wants("on_finish")
    assert not mh.wants("on_decision_audit")
    # nested MultiHooks delegate through wants()
    outer = MultiHooks(mh)
    assert outer.wants("on_submit") and not outer.wants("on_finish")


def test_multihooks_accepts_duck_typed_partial_hooks():
    """A plain object with one hook method — no EngineHooks subclassing —
    still receives its events through the fan-out."""
    seen = []

    class _Partial:
        def on_finish(self, job, now):
            seen.append(job.job_id)

    mh = MultiHooks(_Partial())
    assert mh.wants("on_finish") and not mh.wants("on_submit")

    class _J:
        job_id = 3
    mh.on_finish(_J(), 1.0)
    assert seen == [3]


def test_multihooks_isolates_raising_child():
    log = []
    mh = MultiHooks(_Recorder("a", log), _Exploder(), _Recorder("b", log))

    class _J:
        job_id = 1
    mh.on_start(_J(), 0.0)
    # both healthy children ran despite the middle one raising
    assert log == [("a", "start", 1), ("b", "start", 1)]
    assert mh.error_counts == {"on_start:RuntimeError": 1}
    assert len(mh.errors) == 1


def test_multihooks_error_recording_is_capped():
    mh = MultiHooks(_Exploder())

    class _J:
        job_id = 1
    for _ in range(MultiHooks.MAX_RECORDED_ERRORS + 25):
        mh.on_start(_J(), 0.0)
    assert len(mh.errors) == MultiHooks.MAX_RECORDED_ERRORS
    cap = MultiHooks.MAX_RECORDED_ERRORS + 25
    assert mh.error_counts["on_start:RuntimeError"] == cap


def test_raising_hook_does_not_corrupt_engine_state():
    """State-machine invariant pin: a user hook raising on every on_start
    must leave the schedule itself untouched — same completions, same
    counters as a hook-free run, and no job stuck in a half-started state."""
    from repro.core.types import JobState
    bare = _drain_scenario("steady", 80, 0)
    mh = MultiHooks(_Exploder())
    observed = _drain_scenario("steady", 80, 0, hooks=(mh,))
    assert _signature(observed) == _signature(bare)
    assert mh.error_counts["on_start:RuntimeError"] > 0
    assert not observed.pending and not observed.running
    assert all(j.state == JobState.COMPLETED for j in observed.completed)


def test_service_forwards_full_surface_to_partial_hook():
    """run_stream composes user hooks via MultiHooks: a duck-typed partial
    observer sees lifecycle events without subclassing EngineHooks."""
    run = get_scenario("steady").build(60, 0)

    class _Counts:
        def __init__(self):
            self.submits = 0
            self.finishes = 0

        def on_submit(self, job, now):
            self.submits += 1

        def on_finish(self, job, now):
            self.finishes += 1

    c = _Counts()
    res = run_stream(run.spec, [j.clone_pending() for j in run.jobs],
                     PolicyPrioritizer(make_policy("fcfs")),
                     allocator="pack", fault_model=run.fault_model,
                     hooks=(c,))
    assert c.submits == 60
    assert c.finishes == len(res.engine.completed) == 60


# ------------------------------------------------------------------ metrics --


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help", cluster="a")
    c.inc()
    c.inc(2.5)
    assert reg.value("repro_test_total", cluster="a") == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("repro_test_gauge", "help")
    g.set(4)
    g.dec(1.5)
    assert reg.value("repro_test_gauge") == 2.5
    h = reg.histogram("repro_test_seconds", "help", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 3 and h.sum == 55.5
    # cumulative() excludes +Inf; the overflow shows up via count
    assert h.cumulative() == [1, 2]
    assert h.quantile(0.5) == 10.0 and h.quantile(1.0) == math.inf


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("repro_x_total", "h", path="milp")
    assert reg.counter("repro_x_total", "h", path="milp") is a
    b = reg.counter("repro_x_total", "h", path="greedy")
    assert b is not a
    with pytest.raises(ValueError):
        reg.gauge("repro_x_total", "h")


def test_prometheus_render_format():
    reg = MetricsRegistry()
    reg.counter("repro_jobs_total", "jobs seen", cluster='he"l\\o\n').inc(2)
    reg.histogram("repro_lat_seconds", "latency", buckets=(0.1, 1.0)) \
        .observe(0.5)
    text = reg.render()
    assert "# HELP repro_jobs_total jobs seen\n" in text
    assert "# TYPE repro_jobs_total counter\n" in text
    # label values escape backslash, quote, and newline
    assert 'cluster="he\\"l\\\\o\\n"' in text
    assert 'repro_lat_seconds_bucket{le="0.1"} 0\n' in text
    assert 'repro_lat_seconds_bucket{le="1"} 1\n' in text
    assert 'repro_lat_seconds_bucket{le="+Inf"} 1\n' in text
    assert "repro_lat_seconds_sum 0.5\n" in text
    assert "repro_lat_seconds_count 1\n" in text
    # ends with exactly one trailing newline
    assert text.endswith("\n") and not text.endswith("\n\n")


def test_registry_merge_sums_everything():
    def mk(n):
        reg = MetricsRegistry()
        reg.counter("repro_c_total", "h", cluster=n).inc(1)
        reg.gauge("repro_q", "h").set(2)
        reg.histogram("repro_h_seconds", "h", buckets=(1.0,)).observe(0.5)
        return reg

    merged = MetricsRegistry.merged([mk("a"), mk("b")])
    assert merged.value("repro_c_total", cluster="a") == 1
    assert merged.value("repro_c_total", cluster="b") == 1
    # gauges sum across members: fleet queue lengths are additive
    assert merged.value("repro_q") == 4
    fam = merged.as_dict()["repro_h_seconds"]
    series = next(iter(fam["series"].values()))
    assert series["count"] == 2 and series["sum"] == 1.0


def test_histogram_merge_rejects_mismatched_buckets():
    r1 = MetricsRegistry()
    r1.histogram("repro_h_seconds", "h", buckets=(1.0,))
    r2 = MetricsRegistry()
    r2.histogram("repro_h_seconds", "h", buckets=(2.0,))
    with pytest.raises(ValueError):
        r1.merge(r2)


def test_engine_metrics_hook_on_real_run():
    reg = MetricsRegistry()
    hook = EngineMetricsHook(reg, cluster="t")
    eng = _drain_scenario("steady", 60, 0, hooks=(hook,))
    assert reg.value("repro_jobs_submitted_total", cluster="t") == 60
    assert reg.value("repro_jobs_finished_total", cluster="t") == 60
    assert reg.value("repro_decisions_total", cluster="t") == eng.decisions
    text = reg.render()
    assert "repro_job_wait_seconds_bucket" in text


# ------------------------------------------------------------------- tracer --


def test_tracer_span_model_and_validation():
    obs = Observability(name="t", metrics=False, audit=False)
    res = run_scenario("steady", num_jobs=40, seed=0, obs=obs)
    doc = obs.trace_document()
    assert validate_trace(doc) == []
    evs = doc["traceEvents"]
    queued = [e for e in evs if e.get("name") == "queued" and e["ph"] == "X"]
    running = [e for e in evs if e.get("name") == "running" and e["ph"] == "X"]
    finishes = [e for e in evs if e.get("name") == "finish"]
    assert len(queued) >= 40 and len(running) >= 40 and len(finishes) == 40
    assert all(e["dur"] >= 0 for e in queued + running)
    # control-plane spans live on their own pid, in wall-clock time
    ctl = [e for e in evs if e.get("cat") == "control"]
    assert ctl and all(e["pid"] != queued[0]["pid"] for e in ctl)
    assert res.obs is obs


def test_tracer_preempt_and_fault_instants():
    obs = Observability(name="t", metrics=False, audit=False)
    run_scenario("fault-storm", num_jobs=60, seed=2, obs=obs)
    evs = obs.trace_document()["traceEvents"]
    evicted = [e for e in evs if e.get("name") == "running"
               and e.get("args", {}).get("evicted")]
    assert evicted, "fault kills must close running spans as evicted"
    assert validate_trace(obs.trace_document()) == []


def test_tracer_finalize_closes_open_spans():
    tracer = SpanTracer(name="x")

    class _J:
        job_id = 1
        num_gpus = 2
        restarts = 0
    tracer.on_submit(_J(), 100.0)
    tracer.finalize(200.0)
    doc = tracer.to_document()
    assert validate_trace(doc) == []
    open_spans = [e for e in doc["traceEvents"]
                  if e.get("args", {}).get("open_at_end")]
    assert len(open_spans) == 1 and open_spans[0]["name"] == "queued"
    # finalize is idempotent
    tracer.finalize(300.0)
    assert len(tracer.to_document()["traceEvents"]) \
        == len(doc["traceEvents"])


def test_tracer_caps_events_and_counts_drops():
    tracer = SpanTracer(name="x", max_events=4)

    class _J:
        num_gpus = 1
        restarts = 0
    for i in range(10):
        j = _J()
        j.job_id = i
        tracer.on_submit(j, float(i))
        tracer.on_start(j, float(i) + 1.0)   # emits the queued span
    doc = tracer.to_document()
    assert len(doc["traceEvents"]) <= 4 + 2   # + process metadata events
    assert doc["otherData"]["dropped_events"] > 0
    assert validate_trace(doc) == []


def test_validate_trace_flags_malformed_documents():
    assert validate_trace({"no": "events"})
    assert validate_trace({"traceEvents": [{"ph": "X"}]})
    assert validate_trace(
        {"traceEvents": [{"name": "a", "ph": "Z", "ts": 0,
                          "pid": 1, "tid": 1}]})
    assert validate_trace(
        {"traceEvents": [{"name": "a", "ph": "X", "ts": -5.0,
                          "pid": 1, "tid": 1, "dur": 1}]})
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0,
                           "pid": 1, "tid": 1, "dur": 2.0}]}
    assert validate_trace(ok) == []


def test_merge_documents_concatenates_and_sums():
    t1 = SpanTracer(name="a", member=0)
    t2 = SpanTracer(name="b", member=1)

    class _J:
        job_id = 1
        num_gpus = 1
        restarts = 0
    t1.on_submit(_J(), 0.0)
    t2.on_submit(_J(), 0.0)
    t1.finalize(10.0)
    t2.finalize(10.0)
    merged = merge_documents([t1.to_document(), t2.to_document()])
    assert validate_trace(merged) == []
    pids = {e["pid"] for e in merged["traceEvents"] if e.get("cat") == "job"}
    assert len(pids) == 2
    assert set(merged["otherData"]["sim_t0"]) == {str(p) for p in pids}


# -------------------------------------------------------------------- audit --


def test_audit_log_aggregates_real_run():
    obs = Observability(name="t", trace=False, metrics=False)
    res = run_scenario("flash-crowd", num_jobs=120, seed=0, obs=obs)
    log = obs.audit
    assert log.decisions == res.engine.decisions
    s = log.summary()
    assert s["decisions"] == log.decisions
    assert sum(s["path_counts"].values()) == s["decisions"]
    assert s["alloc_counts"].get("heuristic", 0) \
        + s["alloc_counts"].get("milp", 0) \
        + s["alloc_counts"].get("greedy-fallback", 0) \
        + s["alloc_counts"].get("none", 0) == s["decisions"]
    assert json.dumps(s)   # JSON-serializable by contract


def test_audit_records_fcfs_degraded_path():
    from repro.chaos import DegradationPolicy
    run = get_scenario("chaos-storm").build(100, 0)
    log = DecisionAuditLog()
    eng = SchedulerEngine(
        run.spec, PolicyPrioritizer(make_policy("fcfs")), allocator="milp",
        fault_model=run.fault_model, hooks=(log,),
        degradation=DegradationPolicy(window_deadline_s=0.0,
                                      fcfs_windows=2))
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    assert eng.degraded_windows > 0
    assert log.path_counts.get("fcfs-degraded", 0) > 0
    assert log.summary()["path_counts"]["fcfs-degraded"] > 0


def test_audit_ring_truncates_but_counters_do_not():
    log = DecisionAuditLog(keep=5)
    for i in range(12):
        log.on_decision_audit(
            {"now": float(i), "path": "policy", "window": 1,
             "rank_wall_s": 0.001, "top_job": i, "placed": True,
             "alloc": "heuristic", "skips": {"head-no-placement": 1},
             "backfills": 0})
    assert len(log.records) == 5
    assert log.decisions == 12
    assert log.skip_counts["head-no-placement"] == 12


# ------------------------------------------------------------------- report --


def test_report_cli_validates_and_prints(tmp_path, capsys):
    obs = Observability(name="t")
    run_scenario("flash-crowd", num_jobs=100, seed=0, obs=obs)
    path = tmp_path / "trace.json"
    obs.export_trace(str(path))
    rc = report_main([str(path), "--validate", "--top", "5"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "trace OK" in out
    assert "critical path" in out
    assert "decision paths" in out
    assert "top queueing causes" in out


def test_report_cli_rejects_corrupt_and_invalid(tmp_path, capsys):
    missing = tmp_path / "nope.json"
    assert report_main([str(missing)]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert report_main([str(bad), "--validate"]) == 1
    err = capsys.readouterr().err
    assert "schema violation" in err


def test_report_analyze_matches_audit_counts(tmp_path):
    obs = Observability(name="t")
    res = run_scenario("flash-crowd", num_jobs=100, seed=0, obs=obs)
    model = analyze(obs.trace_document())
    assert sum(model["path_counts"].values()) == res.engine.decisions
    assert model["blocked_windows"] == obs.audit.blocked_windows
    buf = io.StringIO()
    print_report(obs.trace_document(), top=3, out=buf)
    assert "critical path" in buf.getvalue()


# ------------------------------------------------------------- bit-identity --


@pytest.mark.parametrize("scenario", sorted(list_scenarios()))
def test_obs_off_is_bit_identical_per_scenario(scenario):
    """The full bundle (trace + metrics + audit) must observe, never steer:
    job tuples and decision counters match an unobserved run exactly."""
    base = run_scenario(scenario, num_jobs=90, seed=1)
    obs = Observability(name=scenario)
    got = run_scenario(scenario, num_jobs=90, seed=1, obs=obs)
    assert _signature(got.engine) == _signature(base.engine)
    assert validate_trace(obs.trace_document()) == []


def test_obs_off_is_bit_identical_federation():
    from repro.fed import run_fleet
    def sig(res):
        jobs = tuple(sorted(
            (j.job_id, round(j.submit_time, 6),
             round(j.first_start_time if j.first_start_time is not None
                   else -1, 6),
             round(j.finish_time if j.finish_time is not None else -1, 6),
             j.restarts) for j in res.result.jobs))
        return jobs, tuple((e.decisions, e.milp_calls, e.backfills)
                           for e in res.fed.engines)

    base = sig(run_fleet("fleet-skewed-flash", num_jobs=120, seed=3))
    obs = Observability(name="fleet")
    got = run_fleet("fleet-skewed-flash", num_jobs=120, seed=3, obs=obs)
    assert sig(got) == base
    doc = obs.trace_document()
    assert validate_trace(doc) == []
    # one job pid per member plus the fleet's own — distinct trace rows
    jp = {e["pid"] for e in doc["traceEvents"] if e.get("cat") == "job"}
    assert len(jp) >= 3
    assert "repro_fed_routed_total" in obs.prometheus()
    assert set(got.obs.audit_summary()["members"]) \
        == {"helios-large", "helios-mid", "helios-small"}


# --------------------------------------------------------------- engine API --


def test_add_hook_rebuilds_gated_dispatch():
    run = get_scenario("steady").build(30, 0)
    eng = _make_engine(run.spec, allocator="pack")
    assert eng._audit_obs == [] and eng._alloc_obs == []
    log = DecisionAuditLog()
    eng.add_hook(log)
    assert log in eng._audit_obs
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    assert log.decisions == eng.decisions


def test_save_load_state_rebuilds_obs_dispatch():
    # flash-crowd saturates the cluster: jobs are still pending at the
    # snapshot, so the restored engine must make fresh audited decisions
    run = get_scenario("flash-crowd").build(120, 0)
    obs = Observability(name="t", trace=False, metrics=False)
    eng = _make_engine(run.spec, allocator="pack", hooks=obs.hooks())
    jobs = [j.clone_pending() for j in run.jobs]
    eng.submit(jobs)
    eng.step(jobs[0].submit_time + 3600.0)
    blob = eng.save_state()
    restored = SchedulerEngine.load_state(blob)
    # hooks are deliberately dropped on restore; dispatch lists match
    assert restored._audit_obs == []
    log = DecisionAuditLog()
    restored.add_hook(log)
    assert log in restored._audit_obs
    restored.drain()
    eng.drain()
    assert _signature(restored) == _signature(eng)
    assert log.decisions > 0


def test_observability_finalize_idempotent_and_exports(tmp_path):
    obs = Observability(name="t")
    run_scenario("steady", num_jobs=40, seed=0, obs=obs)
    n = len(obs.trace_document()["traceEvents"])
    obs.finalize(None)
    assert len(obs.trace_document()["traceEvents"]) == n
    prom = tmp_path / "m.prom"
    obs.write_prometheus(str(prom))
    assert "repro_jobs_submitted_total" in prom.read_text()
    tr = tmp_path / "t.json"
    obs.export_trace(str(tr))
    assert validate_trace(json.loads(tr.read_text())) == []


def test_observability_switches_disable_components():
    obs = Observability(trace=False, metrics=False, audit=False)
    assert obs.hooks() == ()
    assert obs.tracer is None and obs.metrics_hook is None \
        and obs.audit is None
    run_scenario("steady", num_jobs=20, seed=0, obs=obs)
    assert obs.trace_document()["traceEvents"] == []


def test_controller_ticks_recorded_in_metrics():
    obs = Observability(name="t", trace=False, audit=False)
    run_scenario("chaos-storm", num_jobs=80, seed=0, obs=obs)
    reg = obs.merged_registry()
    assert reg.value("repro_controller_ticks_total",
                     cluster="t", controller="chaos") > 0
    assert reg.value("repro_rescan_windows_total", cluster="t") > 0


def test_fleet_window_note_requires_no_nan():
    obs = Observability(name="f")
    obs.note_window(0.0, 0.001, 3)
    obs.note_controller("autoscaler", 2, 0.002, 60.0)
    assert validate_trace(obs.trace_document()) == []
    assert math.isfinite(
        obs.merged_registry().value("repro_rescan_windows_total"))
