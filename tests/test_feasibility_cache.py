"""Version-counter correctness of the ClusterState feasibility cache.

Every mutation (allocate / release / fail_node / recover_node / load_from)
must invalidate cached ``can_schedule_now`` / ``candidate_ways`` /
``find_placement`` results and the per-SKU free-GPU tallies — a stale hit
would let the engine schedule onto resources that no longer exist (or miss
resources that just freed up)."""
import numpy as np
import pytest

from repro.core import ClusterState, Job, make_cluster


def mk_job(i, gpus, gpu_type="any"):
    return Job(job_id=i, user=0, submit_time=0.0, runtime=100.0,
               est_runtime=100.0, num_gpus=gpus, gpu_type=gpu_type)


def cached():
    return ClusterState(make_cluster("helios"), cache=True)


def uncached():
    return ClusterState(make_cluster("helios"))


def test_version_bumps_on_every_mutation():
    c = cached()
    v0 = c.version
    j = mk_job(0, 4)
    p = c.find_placement(j, "pack")
    assert c.version == v0                      # queries never bump
    c.allocate(j, p)
    v1 = c.version
    assert v1 > v0
    c.release(j, p)
    v2 = c.version
    assert v2 > v1
    c.fail_node(0)
    v3 = c.version
    assert v3 > v2
    c.recover_node(0)
    assert c.version > v3


def test_cache_hits_within_a_version():
    c = cached()
    j = mk_job(0, 4)
    ways1 = c.candidate_ways(j)
    ways2 = c.candidate_ways(j)
    assert ways1 is ways2                       # memoized, not recomputed
    p1 = c.find_placement(j, "pack")
    p2 = c.find_placement(j, "pack")
    assert p1 is p2
    # a same-shape different job object hits the same entry
    twin = mk_job(99, 4)
    assert c.candidate_ways(twin) is ways1


def test_allocate_release_invalidate_feasibility():
    c = cached()
    total = int(c.free_gpus.sum())
    hog = mk_job(0, total)
    assert c.can_schedule_now(hog)              # idle cluster fits everything
    pl = c.find_placement(hog, "pack")
    c.allocate(hog, pl)
    assert not c.can_schedule_now(hog)          # stale True would be a bug
    assert c.candidate_ways(hog) == []
    small = mk_job(1, 1)
    assert not c.can_schedule_now(small)
    c.release(hog, pl)
    assert c.can_schedule_now(hog)              # stale False would be a bug
    assert c.can_schedule_now(small)
    assert len(c.candidate_ways(hog)) >= 1


def test_fail_node_invalidates_sku_feasibility():
    """The fail_node mid-window case: a SKU-constrained job cached as
    schedulable must flip to unschedulable when its only nodes go down."""
    c = cached()
    sku = str(c.gpu_types[0])
    sku_nodes = [i for i, t in enumerate(c.gpu_types) if t == sku]
    per_node = int(c.free_gpus[sku_nodes[0]])
    j = mk_job(0, per_node, gpu_type=sku)
    assert c.can_schedule_now(j)
    assert len(c.candidate_ways(j)) >= 1
    for i in sku_nodes:
        c.fail_node(i)
    assert not c.can_schedule_now(j)
    assert c.candidate_ways(j) == []
    assert c.free_gpus_of_type(sku) == 0        # tallies invalidated too
    for i in sku_nodes:
        c.recover_node(i)
    assert c.can_schedule_now(j)
    assert c.free_gpus_of_type(sku) == per_node * len(sku_nodes)


def test_tallies_track_allocations():
    c = cached()
    free0, by_type0 = c.free_gpu_tallies()
    j = mk_job(0, 4)
    pl = c.find_placement(j, "pack")
    c.allocate(j, pl)
    free1, by_type1 = c.free_gpu_tallies()
    assert free1 == free0 - 4
    assert sum(by_type1.values()) == sum(by_type0.values()) - 4
    c.release(j, pl)
    assert c.free_gpu_tallies() == (free0, by_type0)


def test_load_from_invalidates_scratch_cache():
    """Scratch reuse in _earliest_start: load_from must flush the previous
    what-if state's cache, or reservations would be computed against a
    stale snapshot."""
    src = cached()
    scratch = ClusterState(make_cluster("helios"), cache=True)
    total = int(src.free_gpus.sum())
    hog = mk_job(0, total)
    pl = src.find_placement(hog, "pack")
    src.allocate(hog, pl)
    scratch.load_from(src)
    assert not scratch.can_schedule_now(mk_job(1, 1))
    src.release(hog, pl)
    scratch.load_from(src)
    assert scratch.can_schedule_now(mk_job(1, 1))
    np.testing.assert_array_equal(scratch.free_gpus, src.free_gpus)


def test_cached_equals_uncached_after_mutation_storm():
    """Randomized allocate/release/fail/recover sequence: the cached cluster
    answers every feasibility query exactly like an uncached twin."""
    rng = np.random.default_rng(7)
    a, b = cached(), uncached()
    live = []
    probes = [mk_job(1000 + k, int(g)) for k, g in
              enumerate(rng.integers(1, 17, 6))]
    probes += [mk_job(2000, 4, gpu_type=str(a.gpu_types[0]))]
    for step in range(200):
        op = rng.integers(0, 4)
        if op == 0:
            j = mk_job(step, int(rng.integers(1, 9)))
            p = a.find_placement(j, "pack")
            assert p == b.find_placement(j, "pack")
            if p is not None:
                a.allocate(j, p)
                b.allocate(j, p)
                live.append((j, p))
        elif op == 1 and live:
            j, p = live.pop(int(rng.integers(0, len(live))))
            a.release(j, p)
            b.release(j, p)
        elif op == 2:
            n = int(rng.integers(0, len(a.node_down)))
            if not a.node_down[n] and not any(n in p for _, p in live):
                a.fail_node(n)
                b.fail_node(n)
        elif op == 3:
            n = int(rng.integers(0, len(a.node_down)))
            if a.node_down[n]:
                a.recover_node(n)
                b.recover_node(n)
        for probe in probes:
            assert a.can_schedule_now(probe) == b.can_schedule_now(probe)
            assert a.candidate_ways(probe) == b.candidate_ways(probe)
            assert a.free_gpus_of_type(probe.gpu_type) == \
                b.free_gpus_of_type(probe.gpu_type)


def test_unknown_sku_and_eligibility_masks():
    c = cached()
    ghost = mk_job(0, 1, gpu_type="TPUv9")
    assert not c.can_schedule_now(ghost)
    assert c.free_gpus_of_type("TPUv9") == 0
    assert not c.nodes_for(ghost).any()
    anyjob = mk_job(1, 1)
    assert c.nodes_for(anyjob).sum() == len(c.gpu_types)
    c.fail_node(0)
    assert c.nodes_for(anyjob).sum() == len(c.gpu_types) - 1


def test_oversubscription_raises_under_dash_O():
    """The allocate guard is a RuntimeError, not an assert, so it survives
    `python -O` — and a failed allocate must leave the cluster (and its
    cache) exactly as it was: validation happens before any mutation."""
    c = cached()
    j = mk_job(0, 7)
    free0 = c.free_gpus.copy()
    v0 = c.version
    assert c.can_schedule_now(j)
    with pytest.raises(RuntimeError):
        c.allocate(j, {0: int(c.free_gpus[0]) + 1})
    np.testing.assert_array_equal(c.free_gpus, free0)
    assert c.version == v0
    assert c.can_schedule_now(j)
    with pytest.raises(RuntimeError):            # double release guarded too
        c.release(j, {0: 1})
    np.testing.assert_array_equal(c.free_gpus, free0)


def test_add_remove_node_invalidate_placement_caches():
    """Elastic-capacity mutations must invalidate find_placement /
    candidate_ways / eligibility exactly like fail/recover: a stale miss
    would hide new capacity, a stale hit would place onto retired nodes."""
    from repro.core.types import NodeSpec

    c = cached()
    big = mk_job(0, 16, gpu_type="A100")
    assert not c.can_schedule_now(big)            # no such SKU yet
    assert c.candidate_ways(big) == []
    v0, tv0 = c.version, c.topo_version
    nid = c.add_node(NodeSpec(0, "A100", 16, 128, 1024.0, 2.0))
    assert c.version > v0 and c.topo_version > tv0
    assert c.can_schedule_now(big)                # stale False would be a bug
    assert c.candidate_ways(big) == [{nid: 16}]
    assert c.eligible_mask("A100")[nid]

    v1, tv1 = c.version, c.topo_version
    assert c.remove_node(nid) is True             # idle -> immediate retire
    assert c.version > v1 and c.topo_version > tv1
    assert not c.can_schedule_now(big)            # stale True would be a bug
    assert c.candidate_ways(big) == []
    assert not c.eligible_mask("A100")[nid]


def test_cordon_drain_invalidates_mid_version():
    """remove_node on a busy node (cordon) and the auto-retire on release
    both bump the version: placements cached before either step must not
    survive it."""
    c = cached()
    j = mk_job(0, 4, gpu_type="V100")
    pl = c.find_placement(j, "pack")
    (node, _), = pl.items()
    c.allocate(j, pl)
    probe = mk_job(1, 2, gpu_type="V100")
    assert c.can_schedule_now(probe)
    c.remove_node(node)                           # cordons
    pl2 = c.find_placement(probe, "pack")
    assert pl2 is None or node not in pl2         # no stale placement on it
    v = c.version
    c.release(j, pl)                              # drain completes -> retire
    assert c.version > v
    assert bool(c.retired[node])
    pl3 = c.find_placement(probe, "pack")
    assert pl3 is None or node not in pl3
