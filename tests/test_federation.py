"""Tests for repro.fed: routers, FederatedScheduler, fleet scenarios, and
the snapshot-hardening that keeps degenerate fleet members NaN-free."""
import json
import math
import os
import subprocess
import sys

import pytest
from conftest import REPO, SRC

from repro.core import PolicyPrioritizer, make_cluster, make_policy
from repro.core.types import Job
from repro.fed import (FederatedScheduler, FleetRun, ClusterInfo,
                       ClusterView, capable_clusters, get_fleet_scenario,
                       list_fleet_scenarios, list_routers, make_router,
                       merge_streams, run_fleet)
from repro.sched import (QuotaPrioritizer, SchedulerEngine, get_scenario,
                         list_scenarios, run_scenario, wrap_tenancy)
from repro.sched.engine import EngineSnapshot


def _mk_job(jid, gpus=1, gpu_type="any", submit=0.0, runtime=100.0):
    return Job(job_id=jid, user=0, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, gpu_type=gpu_type)


def _mk_view(idx, *, total=16, by_type=None, free=8, free_by_type=None,
             submitted=0, completed=0, pending=0, running=0):
    info = ClusterInfo(index=idx, name=f"c{idx}", total_gpus=total,
                       total_by_type=by_type or {"V100": total})
    snap = EngineSnapshot(
        now=0.0, submitted=submitted, num_pending=pending,
        num_running=running, num_completed=completed, free_gpus=free,
        utilization=0.0, fragmentation=0.0, decisions=0, milp_calls=0,
        backfills=0, restarts=0,
        free_gpus_by_type=free_by_type or {"V100": free})
    return ClusterView(info, snap)


# ------------------------------------------------------------------ routers ----


def test_capable_clusters_filters_and_degrades():
    views = [_mk_view(0, total=8, by_type={"P100": 8}),
             _mk_view(1, total=32, by_type={"V100": 32})]
    job = _mk_job(0, gpus=4, gpu_type="V100")
    assert capable_clusters(job, views) == [1]
    # nobody has A100: degrade to the largest overall cluster, never crash
    job = _mk_job(1, gpus=4, gpu_type="A100")
    assert capable_clusters(job, views) == [1]
    job = _mk_job(2, gpus=2, gpu_type="any")
    assert capable_clusters(job, views) == [0, 1]


def test_jsq_routes_to_shortest_queue():
    views = [_mk_view(0, submitted=10, completed=2),   # load 8
             _mk_view(1, submitted=5, completed=2),    # load 3
             _mk_view(2, submitted=9, completed=6)]    # load 3 (tie -> 1)
    assert make_router("jsq").route(_mk_job(0), views) == 1


def test_free_gpus_routes_to_most_free():
    views = [_mk_view(0, free=2), _mk_view(1, free=12), _mk_view(2, free=12)]
    assert make_router("free-gpus").route(_mk_job(0), views) == 1


def test_hash_router_deterministic_and_capable():
    views = [_mk_view(0, total=8, by_type={"P100": 8}),
             _mk_view(1, total=32, by_type={"V100": 32}),
             _mk_view(2, total=32, by_type={"V100": 32})]
    r = make_router("hash")
    picks = [r.route(_mk_job(i, gpus=1, gpu_type="V100"), views)
             for i in range(64)]
    assert picks == [r.route(_mk_job(i, gpus=1, gpu_type="V100"), views)
                     for i in range(64)]
    assert set(picks) <= {1, 2} and len(set(picks)) == 2   # spreads, capably


def test_sku_affinity_prefers_free_sku_then_falls_back():
    views = [
        _mk_view(0, total=16, by_type={"V100": 16}, free=8,
                 free_by_type={"V100": 8}),
        _mk_view(1, total=16, by_type={"V100": 8, "P100": 8}, free=12,
                 free_by_type={"V100": 2, "P100": 10}),
    ]
    r = make_router("sku-affinity")
    # V100 free on both, cluster 0 has more of the SKU despite fewer total
    assert r.route(_mk_job(0, gpus=4, gpu_type="V100"), views) == 0
    # nobody has 4 V100 free right now -> shortest queue among capable
    views[0].snap = _mk_view(0, free=1, free_by_type={"V100": 1},
                             submitted=9).snap
    views[1].snap = _mk_view(1, free=1, free_by_type={"V100": 1},
                             submitted=3).snap
    assert r.route(_mk_job(1, gpus=4, gpu_type="V100"), views) == 1


def test_weighted_random_deterministic_and_weighted():
    views = [_mk_view(0, total=4), _mk_view(1, total=60)]
    a = make_router("weighted-random", seed=7)
    b = make_router("weighted-random", seed=7)
    pa = [a.route(_mk_job(i), views) for i in range(200)]
    pb = [b.route(_mk_job(i), views) for i in range(200)]
    assert pa == pb
    assert pa.count(1) > pa.count(0)   # capacity-weighted


def test_make_router_unknown_name():
    with pytest.raises(KeyError, match="unknown router"):
        make_router("no-such-router")


# --------------------------------------------------- differential equivalence ----


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_single_cluster_hash_identical_to_bare_engine(name):
    """Acceptance pin: a 1-cluster federation with the stateless hash router
    is bit-identical to a bare SchedulerEngine on every registered scenario
    (routing, per-job submission, and lockstep windows are unobservable)."""
    run = get_scenario(name).build(64, seed=5)
    if run.chaos is not None:
        # chaos applies at rescan-window edges, so the windowed service
        # loop (ChaosInjector) is the bare-engine reference here — the
        # fleet side wraps the same schedule in a FleetChaosInjector
        sr0 = run_scenario(run, allocator="pack", rescan_interval=60.0)
        eng = sr0.engine
        bare = {j.job_id: (j.start_time, j.finish_time, j.restarts)
                for j in sr0.batch.jobs}
    else:
        pri = wrap_tenancy(PolicyPrioritizer(make_policy("fcfs")),
                           run.sla_users, run.vc_quotas)
        hooks = (pri,) if isinstance(pri, QuotaPrioritizer) else ()
        eng = SchedulerEngine(run.spec, pri, allocator="pack",
                              fault_model=run.fault_model, hooks=hooks)
        if isinstance(pri, QuotaPrioritizer):
            pri.engine = eng
        eng.submit([j.clone_pending() for j in run.jobs])
        eng.drain()
        bare = {j.job_id: (j.start_time, j.finish_time, j.restarts)
                for j in eng.completed}

    sr = run_fleet(FleetRun.from_scenario(run), router="hash",
                   allocator="pack", rescan_interval=60.0)
    fed = {j.job_id: (j.start_time, j.finish_time, j.restarts)
           for j in sr.result.jobs}
    assert bare == fed
    assert eng.decisions == sr.result.per_cluster[0].decisions
    assert eng.backfills == sr.result.per_cluster[0].backfills


def test_single_cluster_milp_allocator_identical():
    """The equivalence holds through the MILP allocation path too."""
    run = get_scenario("steady").build(48, seed=2)
    eng = SchedulerEngine(run.spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="milp")
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    sr = run_fleet(FleetRun.from_scenario(run), router="hash",
                   allocator="milp")
    assert {j.job_id: j.finish_time for j in eng.completed} == \
        {j.job_id: j.finish_time for j in sr.result.jobs}


def test_fed_drain_equals_windowed_lockstep():
    """With stateless routing the assignment is feed-order-invariant, so
    upfront submit + drain() must equal windowed lockstep stepping on a
    multi-cluster fleet (window edges are unobservable to the engines).
    Load-aware routers are *expected* to route differently under different
    rescan cadences — that is the point of streaming routing."""
    run = get_fleet_scenario("fleet-skewed-flash").build(90, seed=4)
    fed = FederatedScheduler(run.clusters, "hash",
                             fault_models=run.fault_models,
                             allocator="pack")
    fed.submit([j.clone_pending() for j in run.jobs])
    fed.drain()
    drained = {j.job_id: (j.start_time, j.finish_time)
               for j in fed.result().jobs}
    sr = run_fleet(run, router="hash", allocator="pack",
                   rescan_interval=120.0)
    windowed = {j.job_id: (j.start_time, j.finish_time)
                for j in sr.result.jobs}
    assert drained == windowed


# ------------------------------------------------------------ fleet behavior ----


@pytest.mark.parametrize("name", list_fleet_scenarios())
def test_fleet_scenario_smoke(name):
    """Every fleet scenario builds deterministically and streams to
    completion under every router with sane fleet metrics."""
    sc = get_fleet_scenario(name)
    r1, r2 = sc.build(30, seed=3), sc.build(30, seed=3)
    assert [j.submit_time for j in r1.jobs] == \
        [j.submit_time for j in r2.jobs]
    assert [j.job_id for j in r1.jobs] == list(range(len(r1.jobs)))
    sr = run_fleet(r1, router="jsq", allocator="pack",
                   rescan_interval=300.0)
    res = sr.result
    assert len(res.jobs) == 30
    assert sum(res.routed) == 30
    assert res.wait_p50 <= res.wait_p99
    assert res.jct_p50 <= res.jct_p99
    assert 0.0 <= res.utilization <= 1.0
    assert 0.0 < res.fairness <= 1.0
    assert all(tel is not None and tel.samples for tel in sr.telemetries)


def test_fleet_snapshot_aggregates():
    run = get_fleet_scenario("fleet-steady").build(36, seed=1)
    fed = FederatedScheduler(run.clusters, "jsq", allocator="pack",
                             fault_models=run.fault_models)
    fed.submit([j.clone_pending() for j in run.jobs])
    fed.step(fed.next_event_time() + 3600.0)
    snap = fed.snapshot()
    assert snap.submitted == 36
    assert sum(snap.routed) == 36
    assert snap.num_pending == sum(s.num_pending for s in snap.clusters)
    assert snap.free_gpus == sum(s.free_gpus for s in snap.clusters)
    assert 0.0 <= snap.utilization <= 1.0
    assert 0.0 < snap.fairness <= 1.0
    fed.drain()
    assert fed.done and fed.snapshot().num_completed == 36
    # every routed job is accounted to exactly one cluster
    assert sorted(fed.routes) == [j.job_id for j in sorted(
        run.jobs, key=lambda j: j.job_id)]


def test_jsq_spares_small_cluster_vs_hash():
    """On the size-skewed fleet, hash routes ~uniformly while jsq must shift
    load away from the small cluster toward the large one."""
    run = get_fleet_scenario("fleet-skewed-flash").build(300, seed=0)
    frac = {}
    for router in ("hash", "jsq"):
        sr = run_fleet(run, router=router, allocator="pack")
        frac[router] = sr.result.routed[0] / sum(sr.result.routed)
    assert frac["jsq"] < frac["hash"]


def test_sku_split_affinity_routes_sku_jobs_home():
    """In the A100-island fleet, every A100 job must land on the island and
    V100 jobs must land on the pool (capability filter + affinity)."""
    run = get_fleet_scenario("fleet-sku-split").build(80, seed=6)
    sr = run_fleet(run, router="sku-affinity", allocator="pack")
    fed = sr.fed
    by_id = {j.job_id: j for j in run.jobs}
    for jid, cluster in fed.routes.items():
        if by_id[jid].gpu_type == "A100":
            assert cluster == 0
        elif by_id[jid].gpu_type == "V100":
            assert cluster == 1


def test_degenerate_all_failed_cluster_cannot_nan_the_router():
    """Bugfix pin: a fleet member whose nodes have ALL failed must expose
    zero free GPUs and finite ratios, and every router must keep returning
    valid indices (no NaN propagation into routing or fleet aggregates)."""
    specs = (make_cluster("helios"), make_cluster("helios"))
    fed = FederatedScheduler(specs, "jsq", allocator="pack")
    dead = fed.engines[0].cluster
    for node in range(len(specs[0].nodes)):
        dead.fail_node(node)
    fed._refresh_views()
    dead_snap = fed.engines[0].snapshot()
    assert dead_snap.free_gpus == 0
    assert dead_snap.utilization == 0.0 and not math.isnan(dead_snap.utilization)
    assert dead_snap.fragmentation == 0.0
    snap = fed.snapshot()
    assert not math.isnan(snap.utilization) and not math.isnan(snap.fairness)
    for name in list_routers():
        idx = make_router(name, seed=1).route(_mk_job(3, gpus=2), fed._views)
        assert idx in (0, 1)
    # free-gpus must avoid the dead cluster outright
    assert make_router("free-gpus").route(_mk_job(4, gpus=2), fed._views) == 1


def test_merge_streams_unique_ids_and_order():
    a = [_mk_job(0, submit=5.0), _mk_job(1, submit=1.0)]
    b = [_mk_job(0, submit=3.0)]
    merged = merge_streams([a, b])
    assert [j.job_id for j in merged] == [0, 1, 2]
    assert [j.submit_time for j in merged] == [1.0, 3.0, 5.0]
    # inputs are cloned, not mutated
    assert a[0].job_id == 0 and b[0].job_id == 0


def test_federation_validates_inputs():
    with pytest.raises(ValueError, match="at least one cluster"):
        FederatedScheduler([], "jsq")
    with pytest.raises(ValueError, match="fault models"):
        FederatedScheduler([make_cluster("helios")], "jsq",
                           fault_models=[None, None])
    with pytest.raises(KeyError, match="unknown fleet scenario"):
        get_fleet_scenario("no-such-fleet")


# ----------------------------------------------------------------- tooling ----


def test_bench_federation_smoke(tmp_path):
    """The registered federation bench must run end-to-end in --smoke mode
    and emit a well-formed acceptance block (benches can't silently rot)."""
    json_path = tmp_path / "BENCH_federation.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_BENCH_FED_JOBS"] = "120"
    env["REPRO_BENCH_FED_JSON"] = str(json_path)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_federation", "--smoke"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    doc = json.loads(json_path.read_text())
    assert doc["bench"] == "federation" and doc["num_jobs"] == 120
    assert doc["scale"] == "smoke"
    acc = doc["acceptance"]
    assert "jsq_beats_hash" in acc and "sku_affinity_beats_hash" in acc
    for row in doc["results"].values():
        assert row["completed"] == 120
        for v in row.values():
            if isinstance(v, float):
                assert math.isfinite(v)


def test_bench_federation_registered():
    import benchmarks.run as brun
    assert "federation" in brun.MODULES
