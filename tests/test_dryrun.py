"""Dry-run integration: production-mesh lower+compile for representative
cells (subprocess with 512 fake devices) + roofline parsing units."""
import os

import pytest

from conftest import run_py


@pytest.mark.parametrize("arch,shape", [
    ("stablelm-1.6b", "train_4k"),
    ("mamba2-780m", "long_500k"),
    ("whisper-tiny", "decode_32k"),
])
def test_lower_cell_singlepod(arch, shape):
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
rec, c = lower_cell({arch!r}, {shape!r}, make_production_mesh())
assert rec["compile_s"] > 0
assert rec["collective_total"] >= 0
assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")
print("cell-ok", rec["dominant"])
"""
    out = run_py(code, devices=512, timeout=900)
    assert "cell-ok" in out


def test_multipod_mesh_shards_pod_axis():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh(multi_pod=True)
assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
rec, c = lower_cell("granite-moe-1b-a400m", "train_4k", mesh, microbatches=2)
print("multipod-ok", rec["chips"])
"""
    out = run_py(code, devices=512, timeout=900)
    assert "multipod-ok 512" in out


def test_collective_parser_units():
    from repro.launch.roofline import collective_bytes
    hlo = """
HloModule test
%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256] all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}
%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256] all-gather(%a), dimensions={0}
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""
    got = collective_bytes(hlo)
    unit = 128 * 256 * 4
    assert got["all-gather"] == unit
    assert got["all-reduce"] == unit * 8        # x while trip count
    counts = got["_counts"]
    assert counts["all-reduce"] == 8


def test_roofline_terms():
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(197e12, 819e9, 50e9)     # exactly 1s each
    assert abs(t["compute_s"] - 1) < 1e-9
    assert abs(t["memory_s"] - 1) < 1e-9
    assert abs(t["collective_s"] - 1) < 1e-9
    assert t["roofline_fraction"] == 1.0


def test_analytic_cost_sane():
    from repro.configs import get_config
    from repro.launch.roofline import analytic_cost, model_flops
    from repro.models.lm import LM
    cfg = get_config("yi-6b")
    model = LM(cfg)
    ana = analytic_cost(cfg, "train_4k", microbatches=4, chips=256,
                        model=model)
    mf = model_flops(cfg, "train_4k", model.active_param_count())
    # analytic hardware flops within [1x, 3x] of 6ND
    assert mf <= ana["flops_global"] <= 3 * mf


def test_artifacts_exist_for_all_cells():
    """After the full dry-run, every applicable cell has a JSON artifact."""
    base = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "artifacts", "dryrun", "singlepod")
    if not os.path.isdir(base):
        pytest.skip("full dry-run artifacts not generated yet")
    from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
    missing = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_applicable(cfg, shape):
                continue
            p = os.path.join(base, f"{arch}__{shape}.json")
            if not os.path.exists(p):
                missing.append(f"{arch}/{shape}")
    assert not missing, f"missing dry-run cells: {missing}"
