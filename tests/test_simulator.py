from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import (FaultModel, PolicyPrioritizer, Simulator,
                        generate_trace, make_cluster, make_policy)
from repro.core.types import JobState


def run(jobs, policy="fcfs", **kw):
    sim = Simulator(make_cluster("helios"), **kw)
    return sim.run_batch([j.clone_pending() for j in jobs],
                         PolicyPrioritizer(make_policy(policy)))


def test_all_jobs_complete(helios_jobs):
    res = run(helios_jobs[:64])
    assert len(res.jobs) == 64
    for j in res.jobs:
        assert j.state == JobState.COMPLETED
        assert j.start_time >= j.submit_time - 1e-9
        assert j.finish_time > j.start_time


def test_metrics_consistency(helios_jobs):
    res = run(helios_jobs[:64])
    assert res.avg_jct >= res.avg_wait
    assert res.avg_bsld >= 1.0
    assert 0.0 <= res.utilization <= 1.0
    assert res.score("util") == -res.utilization


def test_heterogeneous_speedup(helios_jobs):
    """V100 placements finish faster than runtime (speed 1.5)."""
    res = run(helios_jobs[:64])
    quick = [j for j in res.jobs
             if j.finish_time - j.start_time < j.runtime * 0.99]
    assert quick, "some jobs should land on fast V100 nodes"


def test_allocators_differ(helios_jobs):
    waits = {}
    for alloc in ("pack", "spread", "milp"):
        res = run(helios_jobs[:96], allocator=alloc)
        waits[alloc] = res.total_wait
        assert len(res.jobs) == 96
    assert len(set(round(w, 3) for w in waits.values())) >= 1  # all complete


def test_backfill_reduces_wait():
    jobs = generate_trace("philly", 128, seed=7)
    spec = make_cluster("philly")
    r_on = Simulator(spec, backfill=True, allocator="pack").run_batch(
        [j.clone_pending() for j in jobs],
        PolicyPrioritizer(make_policy("fcfs")))
    r_off = Simulator(spec, backfill=False, allocator="pack").run_batch(
        [j.clone_pending() for j in jobs],
        PolicyPrioritizer(make_policy("fcfs")))
    assert r_on.backfills >= 0
    assert r_on.total_wait <= r_off.total_wait * 1.05


def test_fault_injection_restarts():
    jobs = generate_trace("philly", 48, seed=3)
    fm = FaultModel(mtbf_per_node=3 * 3600.0, repair_time=600.0, seed=1)
    sim = Simulator(make_cluster("philly"), fault_model=fm, allocator="pack")
    res = sim.run_batch([j.clone_pending() for j in jobs],
                        PolicyPrioritizer(make_policy("fcfs")))
    assert len(res.jobs) == 48          # completes despite failures
    assert res.restarts > 0             # failures actually hit running jobs
    assert all(j.finish_time > 0 for j in res.jobs)


def test_checkpoint_limits_lost_work():
    """With checkpointing, a restarted job's total span stays bounded."""
    jobs = generate_trace("philly", 32, seed=11)
    fm = FaultModel(mtbf_per_node=2 * 3600.0, repair_time=300.0,
                    ckpt_interval=600.0, seed=2)
    sim = Simulator(make_cluster("philly"), fault_model=fm, allocator="pack")
    res = sim.run_batch([j.clone_pending() for j in jobs],
                        PolicyPrioritizer(make_policy("fcfs")))
    for j in res.jobs:
        if j.restarts:
            # span <= wait + (restarts+1) x runtime + repair slack
            span = j.finish_time - j.submit_time
            assert span < j.wait_time + (j.restarts + 1) * j.runtime / 0.2


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from(["fcfs", "sjf", "wfp3"]))
def test_property_completion(seed, policy):
    jobs = generate_trace("helios", 32, seed=seed)
    res = run(jobs, policy=policy)
    assert len(res.jobs) == 32
    ids = sorted(j.job_id for j in res.jobs)
    assert ids == sorted(j.job_id for j in jobs)     # conservation
    # gang: every job fully placed exactly while running
    assert all(j.placement is None or j.state == JobState.COMPLETED
               for j in res.jobs)
