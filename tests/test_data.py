import numpy as np
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.data import SyntheticLMDataset, batch_for


def test_restart_determinism():
    """Step k yields identical data across dataset instances (restart-safe)."""
    a = SyntheticLMDataset(512, 64, 8, seed=3)
    b = SyntheticLMDataset(512, 64, 8, seed=3)
    for k in (0, 5, 100):
        np.testing.assert_array_equal(a.batch_at(k)["tokens"],
                                      b.batch_at(k)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              a.batch_at(1)["tokens"])


def test_host_sharding_partitions_batch():
    """Host shards are disjoint slices of the same global stream."""
    full = SyntheticLMDataset(512, 32, 8, seed=1, num_hosts=1, host_id=0)
    parts = [SyntheticLMDataset(512, 32, 8, seed=1, num_hosts=4, host_id=i)
             for i in range(4)]
    sizes = [p.batch_at(0)["tokens"].shape[0] for p in parts]
    assert sizes == [2, 2, 2, 2]
    # different hosts see different data at the same step
    assert not np.array_equal(parts[0].batch_at(0)["tokens"],
                              parts[1].batch_at(0)["tokens"])


def test_labels_are_next_tokens():
    ds = SyntheticLMDataset(512, 64, 4, seed=0)
    b = ds.batch_at(0)
    # the stream is contiguous: labels[t] == tokens[t+1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """85% of transitions follow the deterministic jump table."""
    ds = SyntheticLMDataset(512, 4096, 2, seed=7)
    b = ds.batch_at(0)
    toks, labels = b["tokens"], b["labels"]
    jump = ds._jump
    pred = (toks.astype(np.int64) + jump[toks % 256]) % 512
    frac = float(np.mean(pred == labels))
    assert 0.75 < frac < 0.95


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=1024),
       st.integers(min_value=0, max_value=10_000))
def test_tokens_in_range(vocab, step):
    ds = SyntheticLMDataset(vocab, 16, 4, seed=0)
    b = ds.batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < vocab


def test_batch_for_vlm_audio():
    from repro.configs import get_config
    b = batch_for(get_config("internvl2-2b"), "train_4k", num_hosts=64)
    assert "patch_embeds" in b and b["patch_embeds"].shape[0] == 4
    b2 = batch_for(get_config("whisper-tiny"), "train_4k", num_hosts=64)
    assert "audio_frames" in b2
