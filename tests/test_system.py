"""End-to-end behaviour tests for the paper's system (RLTune)."""
import numpy as np

from repro.core import improvement, reward_from_scores
from repro.core.trainer import RLTuneTrainer, TrainerConfig


def test_reward_sign_convention():
    assert reward_from_scores(100.0, 50.0) > 0    # RL better -> positive
    assert reward_from_scores(50.0, 100.0) < 0
    assert reward_from_scores(0.0, 0.0) == 0.0
    assert abs(reward_from_scores(1e-9, 1e9)) <= 10.0  # clipped


def test_trainer_pipelines_identical_jobs():
    """Base and RL pipelines must see identical job copies (paper Fig. 8)."""
    cfg = TrainerConfig(trace="helios", base_policy="fcfs", batch_size=32,
                        batches_per_epoch=1, epochs=1)
    tr = RLTuneTrainer(cfg)
    batch = tr.train_jobs[:32]
    base_res, rl_res = tr.run_batch_pair(batch, explore=False,
                                         use_estimates=False)
    assert {j.job_id for j in base_res.jobs} == {j.job_id for j in rl_res.jobs}
    # pipelines must not mutate the source jobs
    assert all(j.start_time < 0 for j in batch)


def test_training_produces_learning_signal():
    cfg = TrainerConfig(trace="philly", base_policy="fcfs", metric="wait",
                        batch_size=48, batches_per_epoch=6, epochs=1, seed=0)
    tr = RLTuneTrainer(cfg)
    hist = tr.train()
    assert len(hist[0].rewards) == 6
    assert all(np.isfinite(r) for r in hist[0].rewards)
    assert any(r != 0 for r in hist[0].rewards)


def test_evaluation_reports_all_metrics():
    cfg = TrainerConfig(trace="helios", base_policy="sjf", batch_size=32,
                        batches_per_epoch=2, epochs=1)
    tr = RLTuneTrainer(cfg)
    tr.train()
    ev = tr.evaluate(num_batches=2, batch_size=32)
    for side in ("base", "rl"):
        for metric in ("wait", "jct", "bsld", "util"):
            assert np.isfinite(ev[side][metric])
    assert ev["base"]["bsld"] >= 1.0 and ev["rl"]["bsld"] >= 1.0


def test_variants_run():
    for variant in ("naive", "inspector"):
        cfg = TrainerConfig(trace="helios", base_policy="fcfs", batch_size=24,
                            batches_per_epoch=2, epochs=1, variant=variant)
        tr = RLTuneTrainer(cfg)
        hist = tr.train()
        assert len(hist[0].rewards) == 2


def test_transfer_across_policies():
    """Agent trained on FCFS evaluated under SJF (paper Table 7 mechanism)."""
    cfg = TrainerConfig(trace="helios", base_policy="fcfs", batch_size=32,
                        batches_per_epoch=3, epochs=1)
    tr = RLTuneTrainer(cfg)
    tr.train()
    state = tr.agent.state_dict()
    cfg2 = TrainerConfig(trace="helios", base_policy="sjf", batch_size=32,
                         batches_per_epoch=1, epochs=1)
    tr2 = RLTuneTrainer(cfg2)
    tr2.agent.load_state_dict(state)
    ev = tr2.evaluate(num_batches=2, batch_size=32)
    assert np.isfinite(ev["rl"]["wait"])


def test_improvement_helper():
    assert improvement(100, 50) == 50.0
    assert improvement(100, 150) == -50.0
    assert improvement(1.0, 2.0, lower_is_better=False) == 100.0


def test_costmodel_platform_trace():
    from repro.core.costmodel import generate_platform_trace, step_time
    jobs = generate_platform_trace(16, seed=0)
    assert len(jobs) == 16
    assert all(j.runtime >= 60 for j in jobs)
    assert all(j.arch for j in jobs)
    t1 = step_time("yi-6b", "train_4k", chips=256, sku="v5e")
    t2 = step_time("yi-6b", "train_4k", chips=64, sku="v5e")
    assert t2 > t1  # fewer chips -> slower


def test_live_driver_rescan_and_sla():
    """Live mode (paper Sec 3.1.2/5.6): 1-minute rescan loop + SLA bypass."""
    from repro.core import generate_trace, make_cluster
    from repro.core.agent import PPOAgent, PPOConfig
    from repro.core.live import LiveConfig, run_live

    jobs = generate_trace("helios", 48, seed=9)
    sla_user = jobs[10].user
    agent = PPOAgent(PPOConfig(seed=0))
    cfg = LiveConfig(rescan_interval=60.0, sla_users=frozenset({sla_user}))
    res, rescans = run_live(make_cluster("helios"), jobs, agent, cfg)
    assert len(res.jobs) == 48
    assert rescans >= 1
    # SLA jobs never wait longer than the batch's worst non-SLA job
    sla_waits = [j.wait_time for j in res.jobs if j.user == sla_user]
    other = [j.wait_time for j in res.jobs if j.user != sla_user]
    if sla_waits and other:
        assert max(sla_waits) <= max(other) + 1e-6
