import numpy as np
import pytest
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import ClusterState, Job, choose_allocation, make_cluster
from repro.core.milp import (_SKELETONS, _greedy_choice, _solve_milp,
                             _solve_milp_reference)


def mk(i, gpus, cpus=0, mem=0.0):
    return Job(job_id=i, user=0, submit_time=0, runtime=100, est_runtime=100,
               num_gpus=gpus, req_cpus=cpus, req_mem_gb=mem)


def test_skeleton_solver_matches_reference_differential():
    """The memoized constraint-skeleton solver (bounds filled in place) must
    return the identical MILPResult as the per-call dense builder across
    random cluster states, job shapes, and look-ahead depths — including
    repeated hits on the same cached skeleton."""
    rng = np.random.default_rng(42)
    checked = 0
    for trace in ("helios", "philly", "alibaba"):
        for _ in range(12):
            c = ClusterState(make_cluster(trace))
            for i in range(int(rng.integers(0, 6))):
                filler = mk(1000 + i, int(rng.integers(1, 8)),
                            cpus=int(rng.integers(0, 16)),
                            mem=float(rng.integers(0, 64)))
                pl = c.find_placement(filler, "pack")
                if pl:
                    c.allocate(filler, pl)
            j = mk(0, int(rng.integers(1, 17)),
                   cpus=int(rng.integers(0, 32)),
                   mem=float(rng.integers(0, 128)))
            ways = c.candidate_ways(j)
            if len(ways) < 2:
                continue
            look = [mk(10 + i, int(rng.integers(1, 9)))
                    for i in range(int(rng.integers(0, 5)))]
            a = _solve_milp(c, j, ways[:2], look)
            b = _solve_milp_reference(c, j, ways[:2], look)
            assert (a is None) == (b is None)
            if a is not None:
                assert a.placement == b.placement
                assert a.way_index == b.way_index
                assert a.objective == pytest.approx(b.objective, abs=1e-9)
                assert a.lookahead_scheduled == b.lookahead_scheduled
            checked += 1
    assert checked >= 10


def test_skeleton_cache_is_bounded_and_reused():
    """One skeleton per (n_nodes, gpn, K): repeated solves on the same
    cluster shape reuse the cached structure instead of growing the dict."""
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 4)
    ways = c.candidate_ways(j)
    look = [mk(10, 2), mk(11, 2)]
    before = len(_SKELETONS)
    for _ in range(5):
        assert _solve_milp(c, j, ways[:2], look) is not None
    after = len(_SKELETONS)
    assert after - before <= 1


def test_single_way_short_circuit():
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 80)  # needs every GPU -> exactly one way
    ways = c.candidate_ways(j)
    res = choose_allocation(c, j, ways)
    assert res.placement in ways and not res.used_solver


def test_solver_picks_feasible_way():
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 4)
    ways = c.candidate_ways(j)
    res = choose_allocation(c, j, ways, lookahead=[])
    assert sum(res.placement.values()) == 4
    assert res.used_solver or len(ways) == 1
    # chosen placement must be allocatable
    c.allocate(j, res.placement)
    c.release(j, res.placement)


def test_lookahead_influences_choice():
    """With an 8-GPU job waiting, the solver should leave a node whole."""
    c = ClusterState(make_cluster("helios"))
    # fill most nodes so spreading would fragment the last full nodes
    for i in range(8):
        filler = mk(100 + i, 6)
        c.allocate(filler, {i: 6})
    j = mk(0, 4)
    big = mk(1, 8)
    ways = c.candidate_ways(j)
    res = choose_allocation(c, j, ways, lookahead=[big])
    c.allocate(j, res.placement)
    assert c.can_schedule_now(big), \
        "look-ahead MILP must preserve an 8-GPU hole"


def test_respects_cpu_mem_constraints():
    c = ClusterState(make_cluster("helios"))
    # drain CPU on node 0 so it cannot host GPU jobs despite free GPUs
    c.free_cpus[0] = 1
    j = mk(0, 8, cpus=32, mem=64.0)
    ways = c.candidate_ways(j)
    res = choose_allocation(c, j, ways)
    frac = {n: g / 8 for n, g in res.placement.items()}
    for n, g in res.placement.items():
        assert c.free_cpus[n] >= round(32 * frac[n])


def test_greedy_fallback():
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 4)
    ways = c.candidate_ways(j)
    res = _greedy_choice(c, j, ways, [mk(1, 8)])
    assert res.placement in ways


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=4))
def test_solver_feasibility_property(gpus, n_look):
    """Whatever the MILP picks must satisfy every per-node resource bound."""
    c = ClusterState(make_cluster("helios"))
    j = mk(0, gpus)
    ways = c.candidate_ways(j)
    if not ways:
        return
    look = [mk(10 + i, 2) for i in range(n_look)]
    res = choose_allocation(c, j, ways, lookahead=look)
    assert sum(res.placement.values()) == gpus
    for n, g in res.placement.items():
        assert g <= c.free_gpus[n]
