"""Tests for repro.rl: GAE pathway, episode cutting, streaming trainer,
legacy-wrapper equivalence, and the registered bench's smoke mode."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import REPO, SRC

from repro.core.agent import PPOAgent, PPOConfig, gae_advantages
from repro.core.env import RLPrioritizer, StreamStats
from repro.core.types import Job
from repro.rl import (EpisodeCutter, RewardWeights, StreamingConfig,
                      StreamingTrainer, WindowStats, shaped_reward)
from repro.sched import get_scenario


def _state(n=6, seed=0):
    from repro.core.features import CV_SIZE, MAX_QUEUE_SIZE, OV_SIZE
    rng = np.random.default_rng(seed)
    ov = np.zeros((MAX_QUEUE_SIZE, OV_SIZE), np.float32)
    cv = np.zeros((MAX_QUEUE_SIZE, CV_SIZE), np.float32)
    ov[:n] = rng.random((n, OV_SIZE))
    cv[:n] = rng.random((n, CV_SIZE))
    mask = np.zeros((MAX_QUEUE_SIZE,), np.float32)
    mask[:n] = 1
    return ov, cv, mask


# --------------------------------------------------------------- GAE agent ----


def test_gae_advantages_matches_hand_computation():
    rewards = np.array([1.0, 0.0, -1.0], dtype=np.float32)
    values = np.array([0.5, 0.2, 0.1], dtype=np.float32)
    gamma, lam, boot = 0.9, 0.8, 0.3
    deltas = [1.0 + 0.9 * 0.2 - 0.5,
              0.0 + 0.9 * 0.1 - 0.2,
              -1.0 + 0.9 * 0.3 - 0.1]
    a2 = deltas[2]
    a1 = deltas[1] + gamma * lam * a2
    a0 = deltas[0] + gamma * lam * a1
    adv = gae_advantages(rewards, values, boot, gamma, lam)
    np.testing.assert_allclose(adv, [a0, a1, a2], rtol=1e-6)


def test_gae_terminal_vs_bootstrap_differ():
    rewards = np.zeros(4, dtype=np.float32)
    values = np.full(4, 0.5, dtype=np.float32)
    a_term = gae_advantages(rewards, values, 0.0, 0.99, 0.95)
    a_boot = gae_advantages(rewards, values, 1.0, 0.99, 0.95)
    assert a_boot[-1] > a_term[-1]


def test_finish_episode_dense_updates_params():
    import jax
    agent = PPOAgent(PPOConfig(seed=3))
    ov, cv, mask = _state(8)
    for _ in range(6):
        agent.act(ov, cv, mask, explore=True, record=True)
    assert agent.rollout_len == 6
    before = jax.tree.map(np.array, agent.params)
    st = agent.finish_episode_dense(np.linspace(-1, 1, 6),
                                    bootstrap_value=0.2)
    assert st["updated"] == 1.0 and st["steps"] == 6
    assert agent.rollout_len == 0
    diffs = jax.tree.map(lambda a, b: float(np.abs(a - b).max()),
                         before, agent.params)
    assert max(jax.tree.leaves(diffs)) > 0


def test_finish_episode_dense_rejects_misaligned_rewards():
    agent = PPOAgent(PPOConfig(seed=4))
    ov, cv, mask = _state(5)
    agent.act(ov, cv, mask, explore=True, record=True)
    with pytest.raises(ValueError, match="rewards"):
        agent.finish_episode_dense(np.zeros(3))


def test_dense_pooling_respects_episodes_per_update():
    agent = PPOAgent(PPOConfig(seed=5, episodes_per_update=2))
    ov, cv, mask = _state(4)
    updated = []
    for _ in range(4):
        agent.act(ov, cv, mask, explore=True, record=True)
        st = agent.finish_episode_dense(np.ones(1))
        updated.append(st["updated"])
    assert updated == [0.0, 1.0, 0.0, 1.0]


def test_terminal_and_dense_buffers_are_independent():
    """A dense episode must not leak into the pinned terminal pathway."""
    agent = PPOAgent(PPOConfig(seed=6, episodes_per_update=2))
    ov, cv, mask = _state(4)
    agent.act(ov, cv, mask, explore=True, record=True)
    agent.finish_episode_dense(np.ones(1))        # pools in _dense
    agent.act(ov, cv, mask, explore=True, record=True)
    st = agent.finish_episode(reward=1.0)          # pools in _episodes
    assert st["updated"] == 0.0                    # only 1 of 2 terminal eps


# ---------------------------------------------------------- reward shaping ----


def test_shaped_reward_signs():
    w = RewardWeights()
    base = WindowStats(time=0.0, wait_p99=3600.0, utilization=0.5, backlog=10)
    better = WindowStats(time=1.0, wait_p99=1800.0, utilization=0.6, backlog=5)
    worse = WindowStats(time=1.0, wait_p99=7200.0, utilization=0.4, backlog=40)
    assert shaped_reward(base, better, w) > 0
    assert shaped_reward(base, worse, w) < 0
    assert shaped_reward(base, base, w) == 0.0


def test_shaped_reward_clips():
    w = RewardWeights(clip=2.0)
    base = WindowStats(time=0.0, wait_p99=0.0, utilization=0.0, backlog=0)
    spike = WindowStats(time=1.0, wait_p99=1e9, utilization=0.0, backlog=0)
    assert shaped_reward(base, spike, w) == -2.0


def test_stream_stats_ewma():
    s = StreamStats(alpha=0.5)
    j1 = Job(job_id=1, user=0, submit_time=0.0, runtime=100.0,
             est_runtime=100.0, num_gpus=1)
    j1.start_time, j1.finish_time = 10.0, 110.0
    s.update(j1)
    assert s.ewma_wait == pytest.approx(10.0)      # first finish seeds
    j2 = Job(job_id=2, user=0, submit_time=0.0, runtime=100.0,
             est_runtime=100.0, num_gpus=1)
    j2.start_time, j2.finish_time = 30.0, 130.0
    s.update(j2)
    assert s.ewma_wait == pytest.approx(20.0)      # halfway to 30


# ----------------------------------------------------------- episode cutter ----


def _train_one_stream(scenario="flash-crowd", num_jobs=64, horizon=4,
                      warmup=0, seed=0):
    cfg = StreamingConfig(scenarios=(scenario,), num_jobs=num_jobs,
                          horizon=horizon, warmup_windows=warmup,
                          rescan_interval=300.0, seed=seed)
    tr = StreamingTrainer(cfg)
    eps = tr.train_stream(scenario, seed=seed)
    return tr, eps


def test_cutter_cuts_fixed_horizon_episodes():
    tr, eps = _train_one_stream(horizon=4)
    assert len(eps) >= 2
    # every mid-stream episode is exactly horizon windows; only the last may
    # be a shorter terminal remainder (a stream draining exactly on a cut
    # boundary leaves no terminal remainder at all)
    for e in eps[:-1]:
        assert e.windows == 4 and not e.terminal
    assert eps[-1].windows <= 4
    if eps[-1].terminal:
        assert eps[-1].windows <= 4
    assert all(e.steps > 0 for e in eps)
    assert all(np.isfinite(e.reward_sum) and np.isfinite(e.loss) for e in eps)
    # the agent's rollout buffer must be drained after flush
    assert tr.agent.rollout_len == 0


def test_cutter_reward_step_alignment():
    """Every recorded decision receives exactly one reward entry."""
    agent = PPOAgent(PPOConfig(seed=0))
    pri = RLPrioritizer(agent, explore=True, streaming=True)
    cutter = EpisodeCutter(agent, pri, horizon=1000)   # never auto-cuts
    run = get_scenario("steady").build(48, seed=2)
    from repro.sched import run_stream
    run_stream(run.spec, [j.clone_pending() for j in run.jobs], pri,
               rescan_interval=300.0, allocator="pack", chunked_submit=True,
               hooks=(cutter,), on_window=cutter.on_window)
    assert cutter.decisions > 0                 # per-decision hook fired
    recorded = agent.rollout_len
    assert recorded > 0
    st = cutter.flush()
    assert st is not None and st.steps == recorded
    assert st.terminal


def test_cutter_carry_survives_decisionless_tail():
    """Reward deferred from decision-less windows must not be dropped at an
    episode cut: with recorded steps it folds into the last step; with none
    it survives into the next episode."""
    agent = PPOAgent(PPOConfig(seed=11))
    pri = RLPrioritizer(agent, explore=True, streaming=True)
    cutter = EpisodeCutter(agent, pri, horizon=100)

    class _Eng:   # minimal engine surface for _probe via telemetry.probe
        now = 0.0
        pending = []
        running = {}

        class cluster:
            total_gpus = np.array([8])
            free_gpus = np.array([8])
            retired = np.zeros(1, dtype=bool)

    eng = _Eng()
    cutter.telemetry.on_tick(0.0, eng)
    # one recorded decision, then a window boundary with backlog growth
    ov, cv, mask = _state(4)
    agent.act(ov, cv, mask, explore=True, record=True)
    eng.now, eng.pending = 300.0, [None] * 8     # backlog 8 -> negative r
    cutter.telemetry.on_tick(300.0, eng)
    cutter.on_window(eng, 300.0, 1)
    assert len(cutter._rewards) == 1 and cutter._rewards[0] < 0
    # decision-less window with backlog fully drained -> deferred positive r
    eng.now, eng.pending = 600.0, []
    cutter.telemetry.on_tick(600.0, eng)
    cutter.on_window(eng, 600.0, 2)
    assert cutter._carry > 0
    carried = cutter._carry
    before_last = cutter._rewards[-1]
    st = cutter.cut(terminal=True)
    assert st is not None
    assert st.reward_sum == pytest.approx(before_last + carried)
    assert cutter._carry == 0.0


def test_cutter_warmup_skips_recording():
    """Warm-up windows run the policy but record nothing."""
    tr_cold, eps_cold = _train_one_stream(horizon=1000, warmup=0, seed=3)
    tr_warm, eps_warm = _train_one_stream(horizon=1000, warmup=6, seed=3)
    # identical stream; the warm run records strictly fewer decisions
    assert sum(e.steps for e in eps_warm) < sum(e.steps for e in eps_cold)


def test_streaming_trainer_scenario_distribution_deterministic():
    cfg = StreamingConfig(scenarios=("steady", "flash-crowd"), num_jobs=32,
                          streams=2, horizon=4, warmup_windows=0,
                          rescan_interval=600.0, seed=9)
    a = StreamingTrainer(cfg).train()
    b = StreamingTrainer(cfg).train()
    assert [(e.scenario, e.steps, e.windows) for e in a] == \
        [(e.scenario, e.steps, e.windows) for e in b]
    assert [e.reward_sum for e in a] == pytest.approx(
        [e.reward_sum for e in b])


def test_streaming_evaluate_reports_all_contenders():
    tr, _ = _train_one_stream(num_jobs=32, horizon=4)
    ev = tr.evaluate(("steady",), num_jobs=32, seed=7, baselines=("fcfs",
                                                                  "sjf"))
    row = ev["steady"]
    assert set(row) == {"rl", "fcfs", "sjf"}
    for m in row.values():
        assert m["completed"] == 32
        for v in m.values():
            assert np.isfinite(v)


@pytest.mark.slow
def test_streaming_training_multi_stream_runs_and_learns_signal():
    """Multi-stream training (slow tier): rewards stay finite and at least
    one PPO update fires per stream on congested scenarios."""
    cfg = StreamingConfig(scenarios=("flash-crowd", "sku-skew"), num_jobs=128,
                          streams=4, horizon=8, warmup_windows=2,
                          rescan_interval=300.0, seed=1)
    tr = StreamingTrainer(cfg)
    eps = tr.train()
    assert len(eps) >= 4
    assert all(np.isfinite(e.reward_sum) for e in eps)
    assert any(e.updated for e in eps)


# ------------------------------------------------------------ legacy wrapper ----


def test_core_trainer_is_rl_batch_reexport():
    import repro.core.trainer as legacy
    import repro.rl.batch as batch
    assert legacy.RLTuneTrainer is batch.RLTuneTrainer
    assert legacy.TrainerConfig is batch.TrainerConfig
    assert legacy.improvement is batch.improvement
    # and the lazy package attribute resolves to the same object
    import repro.core
    assert repro.core.RLTuneTrainer is batch.RLTuneTrainer


def test_legacy_batch_trainer_deterministic_across_runs():
    """Same config + seeds => identical rewards (no hidden state leaks from
    the refactor; the terminal pathway is pinned)."""
    from repro.core.trainer import RLTuneTrainer, TrainerConfig
    cfg = TrainerConfig(trace="helios", base_policy="fcfs", batch_size=24,
                        batches_per_epoch=2, epochs=1, seed=3)
    h1 = RLTuneTrainer(cfg).train()
    h2 = RLTuneTrainer(cfg).train()
    assert h1[0].rewards == pytest.approx(h2[0].rewards)
    assert h1[0].losses == pytest.approx(h2[0].losses)


# ------------------------------------------------------------------- bench ----


def test_run_py_registers_rl_bench():
    sys.path.insert(0, REPO)
    try:
        from benchmarks import run as bench_run
        assert "rl_streaming" in bench_run.MODULES
    finally:
        sys.path.remove(REPO)


def test_bench_rl_streaming_smoke(tmp_path):
    """The registered RL bench must run end-to-end in --smoke mode and emit
    a valid acceptance block (exercised by tier-1 so it can't rot)."""
    out_json = tmp_path / "BENCH_rl_streaming.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_BENCH_RL_JSON"] = str(out_json)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_rl_streaming", "--smoke"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    doc = json.loads(out_json.read_text())
    assert doc["scale"] == "smoke"
    assert set(doc["results"]) == {"flash-crowd", "diurnal", "sku-skew"}
    for row in doc["results"].values():
        assert set(row) == {"streaming", "batch", "fcfs"}
    acc = doc["acceptance"]
    assert "scenarios_beaten" in acc and "passed" in acc
