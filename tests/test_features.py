import numpy as np
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import ClusterState, make_cluster
from repro.core.features import (CV_SIZE, MAX_QUEUE_SIZE, NUM_FEATURES,
                                 OV_SIZE, build_features, build_state,
                                 critic_features, sample_features)
from repro.core.trace import generate_trace


def test_feature_matrix_shape(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    feats = build_features(helios_jobs[:32], c, now=1e5)
    assert feats.shape == (32, NUM_FEATURES)
    assert np.isfinite(feats).all()


def test_state_padding(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    ov, cv, mask = build_state(helios_jobs[:10], c, now=1e5)
    assert ov.shape == (MAX_QUEUE_SIZE, OV_SIZE)
    assert cv.shape == (MAX_QUEUE_SIZE, CV_SIZE)
    assert mask.sum() == 10
    assert (ov[10:] == 0).all()


def test_overflow_truncated(helios_cluster):
    jobs = generate_trace("helios", 300, seed=2)
    c = ClusterState(helios_cluster)
    ov, cv, mask = build_state(jobs, c, now=1e6)
    assert mask.sum() == MAX_QUEUE_SIZE


def test_sampler_conditions(helios_jobs, helios_cluster):
    """High fragmentation selects job_size; low selects urgency (Sec 3.2)."""
    c = ClusterState(helios_cluster)
    feats = build_features(helios_jobs[:8], c, now=1e5)
    # low fragmentation: idle cluster -> CFF small? construct both regimes
    _, names_low = sample_features(feats, c)
    # fragment: take a few GPUs on every node
    for i in range(len(c.gpu_types)):
        c.free_gpus[i] = 2
    _, names_high = sample_features(build_features(helios_jobs[:8], c, 1e5), c)
    assert len(names_low) == OV_SIZE and len(names_high) == OV_SIZE
    assert ("urgency" in names_low) or ("job_size" in names_high)


def test_raw_vs_engineered(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    ov_raw, _, _ = build_state(helios_jobs[:8], c, 1e5, raw=True)
    ov_eng, _, _ = build_state(helios_jobs[:8], c, 1e5, raw=False)
    assert not np.allclose(ov_raw, ov_eng)


@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=0, max_value=1e7), st.booleans())
def test_features_bounded(now, use_est):
    jobs = generate_trace("helios", 16, seed=5)
    c = ClusterState(make_cluster("helios"))
    feats = build_features(jobs, c, now, use_estimates=use_est)
    assert np.isfinite(feats).all()
    assert (feats >= -1.0 - 1e-6).all() and (feats <= 2.0 + 1e-6).all()
