import numpy as np
import pytest
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import ClusterState, make_cluster
from repro.core.features import (CV_SIZE, MAX_QUEUE_SIZE, NUM_FEATURES,
                                 OV_SIZE, build_features, build_state,
                                 sample_features)
from repro.core.trace import generate_trace


def test_feature_matrix_shape(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    feats = build_features(helios_jobs[:32], c, now=1e5)
    assert feats.shape == (32, NUM_FEATURES)
    assert np.isfinite(feats).all()


def test_state_padding(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    ov, cv, mask = build_state(helios_jobs[:10], c, now=1e5)
    assert ov.shape == (MAX_QUEUE_SIZE, OV_SIZE)
    assert cv.shape == (MAX_QUEUE_SIZE, CV_SIZE)
    assert mask.sum() == 10
    assert (ov[10:] == 0).all()


def test_overflow_truncated(helios_cluster):
    jobs = generate_trace("helios", 300, seed=2)
    c = ClusterState(helios_cluster)
    ov, cv, mask = build_state(jobs, c, now=1e6)
    assert mask.sum() == MAX_QUEUE_SIZE


def test_sampler_conditions(helios_jobs, helios_cluster):
    """High fragmentation selects job_size; low selects urgency (Sec 3.2)."""
    c = ClusterState(helios_cluster)
    feats = build_features(helios_jobs[:8], c, now=1e5)
    # low fragmentation: idle cluster -> CFF small? construct both regimes
    _, names_low = sample_features(feats, c)
    # fragment: take a few GPUs on every node
    for i in range(len(c.gpu_types)):
        c.free_gpus[i] = 2
    _, names_high = sample_features(build_features(helios_jobs[:8], c, 1e5), c)
    assert len(names_low) == OV_SIZE and len(names_high) == OV_SIZE
    assert ("urgency" in names_low) or ("job_size" in names_high)


def test_raw_vs_engineered(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    ov_raw, _, _ = build_state(helios_jobs[:8], c, 1e5, raw=True)
    ov_eng, _, _ = build_state(helios_jobs[:8], c, 1e5, raw=False)
    assert not np.allclose(ov_raw, ov_eng)


@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=0, max_value=1e7), st.booleans())
def test_features_bounded(now, use_est):
    jobs = generate_trace("helios", 16, seed=5)
    c = ClusterState(make_cluster("helios"))
    feats = build_features(jobs, c, now, use_estimates=use_est)
    assert np.isfinite(feats).all()
    assert (feats >= -1.0 - 1e-6).all() and (feats <= 2.0 + 1e-6).all()


# ------------------------------------------- vectorized FBM differential ----
# The RL path's per-decision feature matrix was an O(window * 17) Python
# loop; the vectorized path over the engine's WindowFields views must be
# bit-identical (same float32 matrix, bit for bit) so RL schedules and
# training trajectories cannot drift.

from repro.core.features import _build_features_scalar  # noqa: E402
from repro.core.prioritizer import WindowFields  # noqa: E402


def _varied_cluster(trace, seed):
    c = ClusterState(make_cluster(trace), cache=True)
    jobs = generate_trace(trace, 12, seed=seed)
    for j in jobs:
        pl = c.find_placement(j, "pack")
        if pl is not None:
            c.allocate(j, pl)
    return c


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["helios", "philly", "alibaba"]),
       st.integers(min_value=0, max_value=10_000),
       st.booleans())
def test_vectorized_features_bit_identical(trace, seed, use_est):
    jobs = generate_trace(trace, 64, seed=seed % 997)
    c = _varied_cluster(trace, seed % 31)
    now = jobs[len(jobs) // 2].submit_time + float(seed % 7919)
    ref = _build_features_scalar(jobs, c, now, use_estimates=use_est)
    vec = build_features(jobs, c, now, use_estimates=use_est,
                         fields=WindowFields.from_jobs(jobs))
    assert vec.dtype == ref.dtype
    assert np.array_equal(ref, vec)


def test_vectorized_features_empty_and_downed_nodes():
    c = ClusterState(make_cluster("helios"), cache=True)
    c.fail_node(0)
    assert np.array_equal(
        build_features([], c, 0.0, fields=WindowFields.from_jobs([])),
        _build_features_scalar([], c, 0.0))
    jobs = generate_trace("helios", 8, seed=3)
    assert np.array_equal(
        build_features(jobs, c, 1e5, fields=WindowFields.from_jobs(jobs)),
        _build_features_scalar(jobs, c, 1e5))


def test_build_state_fields_path_identical(helios_jobs, helios_cluster):
    c = ClusterState(helios_cluster)
    jobs = helios_jobs[:48]
    fields = WindowFields.from_jobs(jobs)
    for raw in (False, True):
        ov_a, cv_a, m_a = build_state(jobs, c, 1e5, raw=raw)
        ov_b, cv_b, m_b = build_state(jobs, c, 1e5, raw=raw, fields=fields)
        assert np.array_equal(ov_a, ov_b)
        assert np.array_equal(cv_a, cv_b)
        assert np.array_equal(m_a, m_b)


def test_rl_prioritizer_rank_window_matches_rank():
    """The engine hands RLPrioritizer.rank_window its field views; the
    returned permutation (and hence the schedule) must equal rank()'s."""
    from repro.core.agent import PPOAgent, PPOConfig
    from repro.core.env import RLPrioritizer

    jobs = generate_trace("helios", 40, seed=9)
    c = ClusterState(make_cluster("helios"), cache=True)
    fields = WindowFields.from_jobs(jobs)
    pri = RLPrioritizer(PPOAgent(PPOConfig(seed=3)), explore=False)
    a = pri.rank(jobs, c, 1e4)
    b = pri.rank_window(jobs, c, 1e4, fields)
    assert a == b


def test_rl_stream_rank_window_schedule_identical():
    """Stream-level differential: an engine using the rank_window fast path
    (fields from its pending index) schedules bit-identically to one forced
    onto the rank() fallback."""
    from repro.core.agent import PPOAgent, PPOConfig
    from repro.core.env import RLPrioritizer
    from repro.sched import SchedulerEngine, get_scenario

    run = get_scenario("flash-crowd").build(64, seed=6)
    fins = []
    for strip_rank_window in (False, True):
        pri = RLPrioritizer(PPOAgent(PPOConfig(seed=11)), explore=False)
        eng = SchedulerEngine(run.spec, pri, allocator="pack")
        if strip_rank_window:
            eng._rank_window = None     # force the rank() fallback
        eng.submit([j.clone_pending() for j in run.jobs])
        eng.drain()
        fins.append({j.job_id: (j.start_time, j.finish_time)
                     for j in eng.completed})
        assert len(fins[-1]) == 64
    assert fins[0] == fins[1]


@pytest.mark.parametrize("trace,seed,use_est", [
    ("helios", 0, False), ("helios", 13, True),
    ("philly", 4, False), ("philly", 7, True),
    ("alibaba", 2, False), ("alibaba", 29, True),
])
def test_vectorized_features_bit_identical_fixed(trace, seed, use_est):
    """Deterministic cover for the differential (the hypothesis variant is
    skipped on minimal installs without the [test] extra)."""
    jobs = generate_trace(trace, 96, seed=seed)
    c = _varied_cluster(trace, seed)
    now = jobs[48].submit_time + 123.0
    ref = _build_features_scalar(jobs, c, now, use_estimates=use_est)
    vec = build_features(jobs, c, now, use_estimates=use_est,
                         fields=WindowFields.from_jobs(jobs))
    assert np.array_equal(ref, vec)


# ------------------------------------------------------- edge-case coverage --


def test_features_zero_gpu_and_oversized_jobs():
    """Degenerate demands (0 GPUs, demand far past capacity) must stay
    finite and in range on both builder paths."""
    from repro.core import Job
    c = ClusterState(make_cluster("helios"))
    jobs = [
        Job(job_id=1, user=0, submit_time=0.0, runtime=100.0,
            est_runtime=100.0, num_gpus=0),
        Job(job_id=2, user=1, submit_time=0.0, runtime=100.0,
            est_runtime=100.0, num_gpus=10_000),
    ]
    for use_est in (False, True):
        f_scalar = _build_features_scalar(jobs, c, 50.0,
                                          use_estimates=use_est)
        f_vec = build_features(jobs, c, 50.0, use_estimates=use_est,
                               fields=WindowFields.from_jobs(jobs))
        for f in (f_scalar, f_vec):
            assert np.isfinite(f).all()
            assert (np.abs(f) <= 1.0 + 1e-6).all()
        assert np.array_equal(f_scalar, f_vec)


def test_features_empty_cluster_context():
    """A cluster with every node retired/down reports zero capacity; the
    builders must not divide by it."""
    c = ClusterState(make_cluster("helios"))
    c.retired[:] = True
    c.version += 1
    jobs = generate_trace("helios", 8, seed=1)
    feats = build_features(jobs, c, now=10.0)
    assert feats.shape == (8, NUM_FEATURES)
    assert np.isfinite(feats).all()


def test_features_nan_inf_inputs_guarded():
    """Corrupt trace fields (NaN/inf runtimes, estimates, memory) must not
    leak NaN into the policy/predictor batch."""
    from repro.core import Job
    bad = [
        Job(job_id=1, user=0, submit_time=0.0, runtime=float("nan"),
            est_runtime=float("inf"), num_gpus=2),
        Job(job_id=2, user=1, submit_time=float("nan"), runtime=100.0,
            est_runtime=-float("inf"), num_gpus=2,
            req_mem_gb=float("nan")),
    ]
    c = ClusterState(make_cluster("helios"))
    for use_est in (False, True):
        feats = build_features(bad, c, now=5.0, use_estimates=use_est)
        assert np.isfinite(feats).all()
        scalar = _build_features_scalar(bad, c, 5.0, use_estimates=use_est)
        assert np.isfinite(scalar).all()


def test_features_guard_identity_on_finite_inputs():
    """The NaN/inf guard is nan_to_num — bit-identity for every well-formed
    trace is what keeps the pinned schedules unchanged."""
    jobs = generate_trace("philly", 64, seed=9)
    c = ClusterState(make_cluster("philly"))
    feats = build_features(jobs, c, now=1e4)
    assert np.array_equal(feats, np.nan_to_num(feats, nan=0.0,
                                               posinf=1.0, neginf=-1.0))
