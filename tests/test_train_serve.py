"""Training-loop and serving integration tests (CPU, reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import build_model
from repro.train import OptConfig, make_train_step, opt_init
from repro.train.compression import dequantize_int8, quantize_int8


def test_loss_decreases():
    cfg = get_config("yi-6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_init(params)
    step = jax.jit(make_train_step(model, OptConfig(lr=3e-3, warmup_steps=2,
                                                    total_steps=30)))
    ds = SyntheticLMDataset(cfg.vocab_size, 64, 8, seed=0)
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_microbatch_equivalence():
    """mb=2 gradient accumulation ~ mb=1 on the same global batch."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    ds = SyntheticLMDataset(cfg.vocab_size, 32, 8, seed=1)
    b = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(model, oc, microbatches=1)(
        params, opt_init(params), b)
    p2, _, m2 = make_train_step(model, oc, microbatches=2)(
        params, opt_init(params), b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 0.05


def test_train_loop_with_checkpoint_restart(tmp_path):
    from repro.launch.train import train_loop
    out1 = train_loop("granite-moe-1b-a400m", smoke=True, steps=6, batch=4,
                      seq=32, ckpt_dir=str(tmp_path), ckpt_interval=3,
                      log_every=0)
    # restart: resumes from step 6 checkpoint and continues to 8
    out2 = train_loop("granite-moe-1b-a400m", smoke=True, steps=8, batch=4,
                      seq=32, ckpt_dir=str(tmp_path), ckpt_interval=3,
                      log_every=0)
    assert len(out2["losses"]) == 2  # only steps 6..8 ran


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2)
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i, prompt=list(rng.integers(1, 500, size=5)),
                    max_new_tokens=4) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.output) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size + 200 for r in done for t in r.output)


def test_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)) * 3)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


def test_compressed_pod_allreduce_subprocess():
    from conftest import run_py
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS
from repro.train.compression import pod_allreduce_compressed
mesh = jax.make_mesh((4,), ("pod",))
x = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)
def f(xs):
    out = pod_allreduce_compressed({"g": xs[0]}, "pod")
    return out["g"][None]
y = shard_map(f, mesh=mesh, in_specs=(PS("pod"),), out_specs=PS("pod"))(x)
want = jnp.mean(x, axis=0)
err = float(jnp.max(jnp.abs(y[0] - want)))
assert err < 0.2, err
print("compress-ok", err)
"""
    out = run_py(code, devices=4)
    assert "compress-ok" in out


def test_pipeline_parallel_subprocess():
    """GPipe over 4 stages == sequential application of all stages."""
    from conftest import run_py
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import make_pipelined_apply
S, M, mb, L, d = 4, 8, 2, 4, 16
mesh = jax.make_mesh((S,), ("pod",))
k = jax.random.PRNGKey(0)
Ws = jax.random.normal(k, (S, d, d)) * 0.3
def stage_fn(W, x):
    return jnp.tanh(x @ W)
h = jax.random.normal(jax.random.PRNGKey(1), (M, mb, L, d))
apply = make_pipelined_apply(stage_fn, mesh, axis_name="pod",
                             num_microbatches=M)
got = apply(Ws, h)
want = h
for s in range(S):
    want = jnp.tanh(want @ Ws[s])
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-4, err
print("pipeline-ok", err)
"""
    out = run_py(code, devices=4)
    assert "pipeline-ok" in out
