import numpy as np
import pytest

from repro.core import PROFILES, generate_trace, load_trace_csv, make_cluster
from repro.core.trace import batch_iter, train_eval_split


def test_deterministic():
    a = generate_trace("philly", 100, seed=3)
    b = generate_trace("philly", 100, seed=3)
    assert [(j.submit_time, j.runtime, j.num_gpus) for j in a] == \
        [(j.submit_time, j.runtime, j.num_gpus) for j in b]
    c = generate_trace("philly", 100, seed=4)
    assert [j.runtime for j in a] != [j.runtime for j in c]


@pytest.mark.parametrize("name", list(PROFILES))
def test_statistics_match_profile(name):
    prof = PROFILES[name]
    jobs = generate_trace(name, 4000, seed=0)
    # arrival rate within 3x of profile (bursty MMPP inflates it)
    span = jobs[-1].submit_time - jobs[0].submit_time
    rate = len(jobs) / span
    assert prof.arrival_rate / 2 < rate < prof.arrival_rate * 4
    # runtime scale: sample mean within an order of magnitude (heavy tails)
    mean_rt = np.mean([j.runtime for j in jobs])
    assert prof.runtime_mean / 5 < mean_rt < prof.runtime_mean * 5
    # demand distribution covers the profile's support
    demands = {j.num_gpus for j in jobs}
    assert {d for d, _ in prof.gpu_demand} >= demands
    assert all(j.submit_time <= jobs[i + 1].submit_time
               for i, j in enumerate(jobs[:-1]))


def test_clusters():
    for name in ("philly", "helios", "alibaba", "slurm-testbed"):
        spec = make_cluster(name)
        assert spec.total_gpus > 0
        assert len(spec.gpu_types) >= 1
    assert make_cluster("slurm-testbed").total_gpus == 2 * 4 + 2 * 2 + 1


def test_csv_roundtrip(tmp_path):
    jobs = generate_trace("helios", 20, seed=1)
    p = tmp_path / "t.csv"
    with open(p, "w") as f:
        f.write("job_id,user,submit_time,runtime,num_gpus,gpu_type\n")
        for j in jobs:
            f.write(f"{j.job_id},{j.user},{j.submit_time},{j.runtime},"
                    f"{j.num_gpus},{j.gpu_type}\n")
    loaded = load_trace_csv(str(p))
    assert len(loaded) == 20
    assert loaded[0].num_gpus == jobs[0].num_gpus


def test_split_and_batches():
    jobs = generate_trace("helios", 300, seed=0)
    tr, ev = train_eval_split(jobs, 0.9)
    assert len(tr) == 270 and len(ev) == 30
    batches = list(batch_iter(jobs, 64))
    assert all(len(b) == 64 for b in batches)


def test_csv_missing_duration_marks_unknown(tmp_path):
    """Empty or absent runtime cells load as unknown-duration jobs
    (predictor-served) instead of rejecting the file."""
    p = tmp_path / "partial.csv"
    with open(p, "w") as f:
        f.write("job_id,user,submit_time,runtime,est_runtime,num_gpus\n")
        f.write("1,0,0.0,500.0,450.0,2\n")       # fully specified
        f.write("2,1,10.0,,300.0,4\n")           # no runtime, has estimate
        f.write("3,2,20.0,,,1\n")                # neither
    jobs = {j.job_id: j for j in load_trace_csv(str(p))}
    assert len(jobs) == 3
    assert jobs[1].duration_known and jobs[1].runtime == 500.0
    assert not jobs[2].duration_known
    assert jobs[2].runtime == 300.0 == jobs[2].est_runtime
    assert not jobs[3].duration_known
    assert jobs[3].runtime == 3600.0             # documented default
    # clones (scenario replay path) preserve the flag
    assert not jobs[2].clone_pending().duration_known


def test_csv_no_runtime_column_at_all(tmp_path):
    p = tmp_path / "nort.csv"
    with open(p, "w") as f:
        f.write("job_id,submit_time,est_runtime,num_gpus\n")
        f.write("7,5.0,120.0,2\n")
    (j,) = load_trace_csv(str(p))
    assert not j.duration_known
    assert j.runtime == 120.0
