from jax.sharding import PartitionSpec as PS

from repro.sharding.specs import logical_spec, sanitize_spec, spec_tree


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.empty(shape)


MESH2 = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_mapping():
    assert logical_spec(("batch", "seq", "embed_act"), mesh=MESH2) == \
        PS("data")
    assert logical_spec(("batch", None, "vocab"), mesh=MESH2) == \
        PS("data", None, "model")


def test_pod_axis_dropped_on_single_pod():
    s2 = logical_spec(("batch",), mesh=MESH2)
    s3 = logical_spec(("batch",), mesh=MESH3)
    assert s2 == PS("data")
    assert s3 == PS(("pod", "data"))


def test_no_duplicate_axis_use():
    # embed->data and batch->(pod,data) in one spec: data used once
    spec = logical_spec(("batch", "embed"), mesh=MESH2)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))


def test_sanitize_drops_indivisible():
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = PS("data", "model")
    assert sanitize_spec(spec, (32, 64), mesh) == PS("data", "model")
    assert sanitize_spec(spec, (32, 6), mesh) == PS("data")
    assert sanitize_spec(PS(("pod", "data")), (3,), MESH3) == PS()
    # tuple prefix kept when only the tail fails
    assert sanitize_spec(PS(("pod", "data")), (4,), MESH3) == PS("pod")


def test_spec_tree():
    tree = {"w": ("embed", "ffn"), "b": (None,)}
    out = spec_tree(tree, mesh=MESH2)
    assert out["w"] == PS("data", "model")
    assert out["b"] == PS()
