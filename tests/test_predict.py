"""Tests for repro.predict: quantile MLP training, running-mean baseline,
est-anchored cold start, backfill reservation/overrun mechanics, MILP
duration weights, autoscaler forecasts, failover, kernel parity, and the
predictor-off / shadow-mode bit-identity pins."""
import numpy as np
import pytest

from repro.core import ClusterState, Job, choose_allocation, make_cluster
from repro.core.milp import _lookahead_weights
from repro.core.policies import make_policy
from repro.core.prioritizer import PolicyPrioritizer
from repro.core.types import ClusterSpec, NodeSpec
from repro.predict import (CONTEXT_NAMES, PREDICT_FEATURES, OverrunPolicy,
                           QuantileMLP, RunningMeanBaseline, RuntimePredictor)
from repro.sched import (SchedulerEngine, get_scenario, list_scenarios,
                         run_scenario)


def mk(i, gpus, runtime=100.0, est=None, submit=0.0, user=0):
    return Job(job_id=i, user=user, submit_time=submit, runtime=runtime,
               est_runtime=est if est is not None else runtime,
               num_gpus=gpus)


def _est_pri():
    return PolicyPrioritizer(make_policy("fcfs", use_estimates=True))


def _signature(engine):
    jobs = tuple(sorted(
        (j.job_id, round(j.submit_time, 6),
         round(j.first_start_time if j.first_start_time is not None else -1,
               6),
         round(j.finish_time if j.finish_time is not None else -1, 6),
         j.restarts)
        for j in engine.completed))
    return jobs, (engine.decisions, engine.milp_calls, engine.backfills,
                  engine.restarts, engine.bf_reservations,
                  engine.bf_overruns)


# ---------------------------------------------------------------- the model --


def test_untrained_predictor_reproduces_declared_estimate():
    """Zero-init head: before any training, p50 == p90 == est (no
    cold-start cliff when assist is on from the first job)."""
    p = RuntimePredictor(assist=True)
    jobs = [mk(1, 2, runtime=500.0, est=1234.0),
            mk(2, 4, runtime=50.0, est=60.0)]
    p50, p90 = p.predict_quantiles(jobs)
    assert np.allclose(p50, [1234.0, 60.0])
    assert np.allclose(p90, [1234.0, 60.0])


def test_quantile_heads_ordered_and_floored():
    p = RuntimePredictor(assist=True)
    rng = np.random.default_rng(7)
    for k in range(200):
        j = mk(k, int(rng.integers(1, 8)), est=1000.0,
               runtime=float(rng.lognormal(7.0, 1.0)), user=k % 5)
        p.on_submit(j, 0.0)
        p.on_finish(j, j.runtime)
    jobs = [mk(900 + i, 2, est=1000.0, user=i % 5) for i in range(8)]
    p50, p90 = p.predict_quantiles(jobs)
    assert (p90 >= p50).all()
    assert (p50 >= 1.0).all()


def test_sgd_learns_systematic_underestimate():
    """A cohort declaring 10% of true runtime: the trained p50 must move
    the anchor toward the truth and beat the raw estimate's error."""
    p = RuntimePredictor(assist=True, lr=0.05)
    rng = np.random.default_rng(3)
    for k in range(400):
        rt = float(rng.lognormal(8.0, 0.3))
        j = mk(k, int(rng.integers(1, 5)), runtime=rt, est=0.1 * rt,
               user=k % 4)
        p.on_submit(j, float(k))
        p.on_finish(j, float(k) + rt)
    probe = [mk(9000 + i, 2, runtime=3000.0, est=300.0, user=i % 4)
             for i in range(16)]
    p50, _ = p.predict_quantiles(probe)
    # est error |300 - 3000| = 2700; trained prediction must close most
    assert np.abs(p50 - 3000.0).mean() < 1500.0
    assert p.mape() < p.baseline_mape() or p.mape() < 0.5


def test_running_mean_baseline_buckets_and_fallbacks():
    b = RunningMeanBaseline()
    assert b.predict(mk(1, 2, est=700.0)) == 700.0       # empty: est anchor
    b.observe(mk(2, 2, runtime=100.0, user=1), 100.0)
    b.observe(mk(3, 2, runtime=300.0, user=1), 300.0)
    assert b.predict(mk(4, 2, user=1)) == pytest.approx(200.0)  # key mean
    # unseen user falls back to the global mean, not the estimate
    assert b.predict(mk(5, 2, user=9, est=9999.0)) == pytest.approx(200.0)
    # same user, very different gpu bucket -> global mean too
    assert b.predict(mk(6, 64, user=1)) == pytest.approx(200.0)


def test_prequential_errors_are_out_of_sample():
    """MAPE must be recorded from the *pre-update* prediction: a constant-
    runtime stream still shows a nonzero first error (est anchor off)."""
    p = RuntimePredictor(assist=True)
    j = mk(1, 2, runtime=1000.0, est=2000.0)
    p.on_submit(j, 0.0)
    p.on_finish(j, 1000.0)
    assert p.mape() == pytest.approx(1.0)  # |2000-1000|/1000, pre-training


def test_unknown_duration_jobs_served_from_baseline_anchor():
    """A job without a usable declared estimate anchors on the running-mean
    baseline instead (unknown-duration trace rows)."""
    p = RuntimePredictor(assist=True)
    for k in range(5):
        p.baseline.observe(mk(k, 2, runtime=800.0, user=3), 800.0)
    j = mk(99, 2, runtime=500.0, est=float("nan"), user=3)
    p50, _ = p.predict_quantiles([j])       # untrained head: anchor exactly
    assert p50[0] == pytest.approx(800.0)
    j2 = mk(100, 2, runtime=500.0, est=-1.0, user=3)
    assert p.reserve_runtime(j2) == pytest.approx(800.0)


def test_kernel_forward_matches_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.kernels.ops import predict_mlp
    mlp = QuantileMLP(seed=3)
    rng = np.random.default_rng(0)
    mlp.params["w3"][:] = rng.normal(0, 0.1,
                                     mlp.params["w3"].shape).astype(np.float32)
    mlp.params["b3"][:] = rng.normal(0, 0.1,
                                     mlp.params["b3"].shape).astype(np.float32)
    X = rng.normal(0, 1, (6, PREDICT_FEATURES)).astype(np.float32)
    out = np.asarray(predict_mlp(X, mlp.params))
    assert out.shape == (6, 2)
    assert np.allclose(out, mlp.forward(X), atol=1e-5)


def test_context_features_shape():
    eng = SchedulerEngine(make_cluster("helios"), _est_pri(),
                          allocator="pack")
    p = RuntimePredictor(assist=True)
    p.bind(eng)
    ctx = p._context(eng)
    assert ctx.shape == (len(CONTEXT_NAMES),)
    assert np.isfinite(ctx).all()
    assert PREDICT_FEATURES == 17 + len(CONTEXT_NAMES)


# --------------------------------------------------- reservations / overrun --


def _tiny_spec():
    return ClusterSpec(nodes=[NodeSpec(0, "V100", 8, 64, 512.0, 1.0)],
                       name="tiny")


def test_backfill_overrun_preempts_and_bars_offender():
    """A backfilled job blowing its p90 reservation is checkpoint-preempted
    (grace elapsed, head job waiting) and barred from further predictor-
    gated backfill; the head job then starts on the freed GPUs."""
    p = RuntimePredictor(assist=True, overrun=OverrunPolicy(grace_s=60.0))
    eng = SchedulerEngine(_tiny_spec(), _est_pri(), allocator="pack",
                          hooks=(p,), predictor=p)
    j1 = mk(1, 4, runtime=5000.0, submit=0.0)
    j2 = mk(2, 8, runtime=100.0, submit=10.0)          # head, blocked
    j3 = mk(3, 4, runtime=20000.0, est=100.0, submit=20.0)  # liar, backfills
    j4 = mk(4, 1, runtime=50.0, submit=6000.0)         # wakes the engine
    eng.submit([j1, j2, j3, j4])
    eng.drain()
    assert eng.bf_reservations >= 1
    assert eng.bf_overruns == 1
    assert 3 in eng._bf_overrun_jobs
    done = {j.job_id: j for j in eng.completed}
    assert set(done) == {1, 2, 3, 4}
    assert done[3].restarts >= 1                        # evicted, resumed
    # the overrun must not starve the head job until the liar finishes
    assert done[2].first_start_time < 20000.0


def test_reservation_cleared_on_normal_finish():
    """A backfilled job finishing inside its reservation leaves no deadline
    behind and counts no overrun."""
    p = RuntimePredictor(assist=True)
    eng = SchedulerEngine(_tiny_spec(), _est_pri(), allocator="pack",
                          hooks=(p,), predictor=p)
    eng.submit([mk(1, 4, runtime=5000.0, submit=0.0),
                mk(2, 8, runtime=100.0, submit=10.0),
                mk(3, 4, runtime=80.0, est=100.0, submit=20.0)])
    eng.drain()
    assert eng.bf_reservations == 1
    assert eng.bf_overruns == 0
    assert not eng._bf_deadlines
    assert p.reservations == 1
    slacks, cur = p.recent_slacks(0)
    assert cur == 1 and len(slacks) == 1 and slacks[0] >= 0.0


def test_trained_predictor_blocks_known_liar_backfill():
    """After training on a lying cohort, the p90 gate must refuse the
    backfill the declared estimate would have taken."""
    p = RuntimePredictor(assist=True)
    # teach it: user 7's jobs declare 100 but run 20000
    for k in range(300):
        j = mk(1000 + k, 4, runtime=20000.0, est=100.0, user=7)
        p.on_submit(j, 0.0)
        p.on_finish(j, 20000.0)
    eng = SchedulerEngine(_tiny_spec(), _est_pri(), allocator="pack",
                          hooks=(p,), predictor=p)
    p.bind(eng)
    eng.submit([mk(1, 4, runtime=5000.0, submit=0.0),
                mk(2, 8, runtime=100.0, submit=10.0),
                mk(3, 4, runtime=20000.0, est=100.0, submit=20.0, user=7)])
    eng.drain()
    assert eng.bf_overruns == 0                 # never backfilled -> no blow
    done = {j.job_id: j for j in eng.completed}
    assert done[2].first_start_time <= 5000.0 + 1e-6


# ----------------------------------------------------------- MILP durations --


def test_lookahead_weights_clamped_and_none_passthrough():
    assert _lookahead_weights([], None) is None
    assert _lookahead_weights([mk(1, 2)], None) is None
    w = _lookahead_weights([mk(1, 2), mk(2, 2), mk(3, 2)],
                           [60.0, 3600.0, 1e9])
    assert w == [0.1, 1.0, 8.0]
    # durations shorter than the lookahead pad with the 1h declared default
    w2 = _lookahead_weights([mk(1, 2), mk(2, 2)], [7200.0])
    assert w2 == [2.0, 1.0]


def test_choose_allocation_durations_none_bit_identical():
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 4)
    ways = c.candidate_ways(j)
    look = [mk(10, 2), mk(11, 8), mk(12, 1)]
    a = choose_allocation(c, j, ways, look, solution_cache=False)
    b = choose_allocation(c, j, ways, look, solution_cache=False,
                          durations=None)
    assert a.placement == b.placement and a.way_index == b.way_index
    assert a.objective == b.objective


def test_choose_allocation_durations_reweight_objective():
    """Long predicted durations upweight a lookahead job's term; the solve
    stays feasible and the cache keys the two variants apart."""
    c = ClusterState(make_cluster("helios"))
    j = mk(0, 4)
    ways = c.candidate_ways(j)
    look = [mk(10, 2), mk(11, 8)]
    base = choose_allocation(c, j, ways, look)
    wtd = choose_allocation(c, j, ways, look,
                            durations=[8 * 3600.0, 60.0])
    assert wtd.placement in [w for w in ways]
    # same cluster version: both results must have come from distinct
    # cache entries, not one clobbering the other
    again = choose_allocation(c, j, ways, look)
    assert again.objective == base.objective


# ------------------------------------------------------ autoscaler forecast --


def test_autoscaler_forecast_none_without_assist():
    from repro.scale import QueuePressureAutoscaler, pools_from_spec
    spec = make_cluster("helios")
    asc = QueuePressureAutoscaler(pools_from_spec(spec))
    eng = SchedulerEngine(spec, _est_pri(), allocator="pack")
    assert asc._forecast_gpu_hours(eng) is None
    shadow = RuntimePredictor(assist=False)
    eng2 = SchedulerEngine(spec, _est_pri(), allocator="pack",
                           predictor=shadow)
    assert asc._forecast_gpu_hours(eng2) is None


def test_autoscaler_forecast_triggers_scale_up():
    from repro.scale import QueuePressureAutoscaler, pools_from_spec
    spec = make_cluster("helios")
    asc = QueuePressureAutoscaler(pools_from_spec(spec, max_frac=2.0),
                                  forecast_up_gpu_hours=4.0)
    pred = RuntimePredictor(assist=True)
    eng = SchedulerEngine(spec, _est_pri(), allocator="pack",
                          hooks=(pred,), predictor=pred)
    # saturate, then stack a predicted backlog the wait-p99 has not seen
    eng.submit([mk(1, 80, runtime=40000.0, submit=0.0)]
               + [mk(10 + i, 8, runtime=7200.0, submit=1.0)
                  for i in range(6)])
    eng.step(2.0)
    fc = asc._forecast_gpu_hours(eng)
    assert fc is not None and fc > 4.0
    direction, reason = asc.desired_direction(eng, 2.0, None)
    assert direction == 1 and "forecast" in reason


def test_target_util_forecast_holds_scale_down():
    from repro.scale import TargetUtilizationAutoscaler, pools_from_spec
    spec = make_cluster("helios")
    asc = TargetUtilizationAutoscaler(pools_from_spec(spec),
                                      max_pending_for_down=64,
                                      forecast_hold_gpu_hours=2.0)
    pred = RuntimePredictor(assist=True)
    eng = SchedulerEngine(spec, _est_pri(), allocator="pack",
                          hooks=(pred,), predictor=pred)
    # idle cluster (util 0 < util_low) but a fat predicted backlog
    eng.submit([mk(10 + i, 100, runtime=7200.0, submit=0.0)
                for i in range(4)])
    eng.step(1.0)
    direction, reason = asc.desired_direction(eng, 1.0, None)
    assert direction == 0 and "hold" in reason


# ----------------------------------------------------------------- failover --


def test_failover_roundtrip_preserves_predictor():
    from repro.core.trace import generate_trace
    p = RuntimePredictor(assist=True, seed=0)
    eng = SchedulerEngine(make_cluster("helios"), _est_pri(),
                          allocator="pack", hooks=(p,), predictor=p)
    jobs = generate_trace("helios", 60, seed=5)
    eng.submit(jobs)
    eng.step(jobs[30].submit_time)
    blob = eng.save_state()
    eng2 = SchedulerEngine.load_state(blob)
    assert eng2.predictor is not None
    assert eng2.predictor.engine is eng2         # rebound, not pickled ref
    assert eng2.predictor in eng2.hooks          # training resumes
    eng.drain()
    eng2.drain()
    assert _signature(eng) == _signature(eng2)
    assert eng.predictor.train_steps == eng2.predictor.train_steps


# ------------------------------------------------------------- bit-identity --


@pytest.mark.parametrize("scenario", sorted(list_scenarios()))
def test_shadow_predictor_is_bit_identical_per_scenario(scenario):
    """assist=False trains from the hook stream but must never steer: job
    tuples and every decision/backfill counter match predictor=None."""
    base = run_scenario(scenario, num_jobs=90, seed=1)
    shadow = RuntimePredictor(assist=False, seed=0)
    got = run_scenario(scenario, num_jobs=90, seed=1, predictor=shadow)
    assert _signature(got.engine) == _signature(base.engine)
    assert got.engine.bf_reservations == 0
    assert got.engine.bf_overruns == 0
    assert shadow.train_steps == len(got.batch.jobs)  # it did observe


def test_shadow_predictor_is_bit_identical_federation():
    from repro.fed import run_fleet

    def sig(res):
        jobs = tuple(sorted(
            (j.job_id, round(j.submit_time, 6),
             round(j.first_start_time if j.first_start_time is not None
                   else -1, 6),
             round(j.finish_time if j.finish_time is not None else -1, 6),
             j.restarts) for j in res.result.jobs))
        return jobs, tuple((e.decisions, e.milp_calls, e.backfills,
                            e.bf_reservations, e.bf_overruns)
                           for e in res.fed.engines)

    base = sig(run_fleet("fleet-skewed-flash", num_jobs=120, seed=3))
    got = run_fleet("fleet-skewed-flash", num_jobs=120, seed=3,
                    predictor_factory=lambda i, spec:
                    RuntimePredictor(assist=False, seed=i))
    assert sig(got) == base


def test_assisted_run_changes_backfill_and_reports_metrics():
    """Assist mode must actually engage on a congested scenario: committed
    reservations, telemetry mirrors, and obs metrics all light up."""
    from repro.obs import Observability
    pred = RuntimePredictor(assist=True, seed=0)
    obs = Observability(name="predict-test")
    sr = run_scenario("flash-crowd", num_jobs=200, seed=1, allocator="pack",
                      prioritizer=_est_pri(), predictor=pred, obs=obs)
    assert sr.engine.bf_reservations > 0
    assert pred.train_steps == len(sr.batch.jobs)
    last = sr.telemetry.samples[-1]
    assert last.bf_reservations == sr.engine.bf_reservations
    assert last.bf_overruns == sr.engine.bf_overruns
    assert 0.0 <= last.bf_overrun_ratio <= 1.0
    assert last.prediction_mape > 0.0
    text = obs.prometheus()
    assert "repro_prediction_mape" in text
    assert "repro_predicted_backfills_total" in text
    assert "repro_reservation_slack_seconds" in text


def test_overrun_ratio_zero_division_safe():
    from repro.sched.telemetry import TelemetrySample
    s = TelemetrySample(time=0.0, window=1.0, finished_in_window=0,
                        throughput_jph=0.0, jct_p50=0.0, jct_p95=0.0,
                        jct_p99=0.0, wait_p50=0.0, wait_p95=0.0,
                        wait_p99=0.0, utilization=0.0, queue_len=0,
                        running=0, requeues=0, vc_fairness=1.0)
    assert s.bf_overrun_ratio == 0.0


# -------------------------------------------------------- scenario registry --


def test_mispredict_storm_registered_and_lying():
    run = get_scenario("mispredict-storm").build(300, 0)
    ratios = np.array([j.est_runtime / max(j.runtime, 1e-9)
                       for j in run.jobs])
    liars = (ratios < 0.5).mean()
    assert 0.1 < liars < 0.5                     # ~30% of users lowball
    assert "mispredict-storm" in list_scenarios()
