"""Tests for repro.scale + the elastic-capacity plumbing underneath it:
ClusterState add/remove/cordon/retire drain semantics, cache invalidation
on capacity version bumps, engine reschedule, controller hysteresis /
cooldown / bounds / stall override, service- and federation-level
integration, and the disabled-autoscaler bit-identity pins."""
import json
import math
import os
import subprocess
import sys

import pytest
from conftest import REPO, SRC

from repro.core import (ClusterState, PolicyPrioritizer, make_cluster,
                        make_policy)
from repro.core.types import Job, NodeSpec
from repro.fed import FederatedScheduler, FleetRun, run_fleet
from repro.scale import (PoolSpec, QueuePressureAutoscaler,
                         TargetUtilizationAutoscaler, list_autoscalers,
                         make_autoscaler, pools_from_spec)
from repro.sched import (QuotaPrioritizer, SchedulerEngine, get_scenario,
                         list_scenarios, run_scenario, run_stream,
                         wrap_tenancy)


def mk_job(i, gpus=1, gpu_type="any", submit=0.0, runtime=1000.0):
    return Job(job_id=i, user=0, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, gpu_type=gpu_type)


def frozen_autoscaler(spec):
    """A controller that can never act: the band spans [0, 1] so the
    signal cannot leave it.  Attaching it must be unobservable."""
    return TargetUtilizationAutoscaler(pools_from_spec(spec),
                                       util_low=0.0, util_high=1.0)


# ----------------------------------------------------- cluster elasticity ----


def test_add_node_grows_capacity_and_placement():
    c = ClusterState(make_cluster("helios"), cache=True)
    total0, by0 = c.free_gpu_tallies()
    v0, tv0 = c.version, c.topo_version
    nid = c.add_node(NodeSpec(0, "A100", 8, 96, 768.0, 2.0))
    assert nid == 10 and len(c.spec.nodes) == 11
    assert c.spec.nodes[nid].node_id == nid
    assert c.version > v0 and c.topo_version > tv0
    total1, by1 = c.free_gpu_tallies()
    assert total1 == total0 + 8
    assert by1["A100"] == 8 and by1["V100"] == by0["V100"]
    # the new SKU is immediately placeable
    j = mk_job(0, gpus=8, gpu_type="A100")
    assert c.can_schedule_now(j)
    assert c.find_placement(j, "pack") == {nid: 8}


def test_remove_idle_node_retires_immediately():
    c = ClusterState(make_cluster("helios"), cache=True)
    total0, _ = c.free_gpu_tallies()
    assert c.remove_node(3) is True
    assert bool(c.retired[3]) and not bool(c.cordoned[3])
    total1, _ = c.free_gpu_tallies()
    assert total1 == total0 - int(c.total_gpus[3])
    assert c.provisioned_gpu_totals()[0] == total1
    # placement never lands on a retired node (fresh query + cached re-read)
    big = mk_job(0, gpus=int(c.total_gpus[3]), gpu_type="P100")
    for _ in range(2):
        pl = c.find_placement(big, "spread")
        assert pl is None or 3 not in pl
    with pytest.raises(ValueError, match="already retired"):
        c.remove_node(3)
    with pytest.raises(ValueError, match="no such node"):
        c.remove_node(99)


def test_remove_busy_node_cordons_then_auto_retires():
    c = ClusterState(make_cluster("helios"), cache=True)
    j = mk_job(0, gpus=4, gpu_type="P100")
    pl = c.find_placement(j, "pack")
    (node, _), = pl.items()
    c.allocate(j, pl)
    assert c.remove_node(node) is False          # busy -> draining
    assert bool(c.cordoned[node]) and not bool(c.retired[node])
    # still provisioned (the operator pays for it until it drains) ...
    assert c.provisioned_gpu_totals()[0] == int(c.total_gpus.sum())
    # ... but excluded from placement and the free tallies
    assert not c.eligible_mask("P100")[node]
    free, by = c.free_gpu_tallies()
    assert by["P100"] == int(c.free_gpus[c.sku_mask("P100")
                                         & c.placeable_mask()].sum())
    # draining completes on the last release: cordon -> retired
    c.release(j, pl)
    assert bool(c.retired[node]) and not bool(c.cordoned[node])
    assert c.provisioned_gpu_totals()[0] == \
        int(c.total_gpus.sum()) - int(c.total_gpus[node])


def test_uncordon_readmits_draining_node():
    c = ClusterState(make_cluster("helios"), cache=True)
    j = mk_job(0, gpus=2, gpu_type="V100")
    pl = c.find_placement(j, "pack")
    (node, _), = pl.items()
    c.allocate(j, pl)
    c.remove_node(node)
    assert not c.eligible_mask("V100")[node]
    c.uncordon_node(node)
    assert c.eligible_mask("V100")[node]
    c.release(j, pl)                              # no drain: not cordoned
    assert not bool(c.retired[node])


def test_capacity_bumps_invalidate_tallies_and_ratios():
    """Satellite pin: per-SKU free tallies and the memoized up-only ratios
    must invalidate on add_node/remove_node version bumps, not just
    fail/recover — a stale hit would route jobs onto vanished capacity."""
    c = ClusterState(make_cluster("helios"), cache=True)
    tallies0 = c.free_gpu_tallies()
    util0 = c.utilization(up_only=True)
    frag0 = c.fragmentation(up_only=True)
    assert c.free_gpu_tallies() is tallies0       # memoized within a version

    v = c.version
    c.remove_node(0)
    assert c.version > v
    t1 = c.free_gpu_tallies()
    assert t1 is not tallies0
    assert t1[0] == tallies0[0] - int(c.total_gpus[0])
    assert c.fragmentation(up_only=True) != frag0 or \
        c.utilization(up_only=True) == util0      # ratios recomputed, no stale

    # allocate everything on one SKU, then add a node of it: a stale
    # can_schedule_now=False must flip to True
    j = mk_job(1, gpus=8, gpu_type="V100")
    while c.can_schedule_now(j):
        c.allocate(j, c.find_placement(j, "pack"))
        j = mk_job(j.job_id + 1, gpus=8, gpu_type="V100")
    assert not c.can_schedule_now(j)
    util_before = c.utilization(up_only=True)
    c.add_node(NodeSpec(0, "V100", 8, 64, 512.0, 1.5))
    assert c.can_schedule_now(j)                  # stale False would be a bug
    assert c.free_gpu_tallies()[1]["V100"] >= 8
    assert c.utilization(up_only=True) < util_before


def test_retired_node_survives_fail_recover():
    """recover_node on a retired slot must not resurrect its capacity."""
    c = ClusterState(make_cluster("helios"), cache=True)
    c.remove_node(2)
    before = c.free_gpu_tallies()
    c.fail_node(2)
    c.recover_node(2)
    assert c.free_gpu_tallies() == before
    assert not c.eligible_mask("any")[2]


# ------------------------------------------------------------ engine level ----


def test_engine_drains_cordoned_node_and_places_elsewhere():
    spec = make_cluster("helios")
    eng = SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="pack")
    jobs = [mk_job(i, gpus=8, gpu_type="any", submit=float(i),
                   runtime=5000.0) for i in range(4)]
    eng.submit([j for j in jobs])
    eng.step(10.0)
    assert eng.snapshot().num_running == 4
    victim = next(iter(eng.running.values()))[1]  # placement of one job
    (node, _), = victim.items()
    assert eng.cluster.remove_node(node) is False
    assert eng.snapshot().cordoned == 1
    eng.drain()
    assert eng.done
    assert bool(eng.cluster.retired[node])        # drained after finish
    assert eng.snapshot().cordoned == 0
    assert eng.snapshot().total_gpus == \
        int(eng.cluster.total_gpus.sum()) - int(eng.cluster.total_gpus[node])


def test_reschedule_starts_starved_job_after_scale_up():
    spec = make_cluster("slurm-testbed")      # biggest node: 4 GPUs
    eng = SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="pack")
    eng.submit([mk_job(0, gpus=16, gpu_type="A100", runtime=100.0)])
    eng.drain()
    assert not eng.done and eng.next_event_time() == math.inf
    eng.cluster.add_node(NodeSpec(0, "A100", 16, 128, 1024.0, 2.0))
    eng.reschedule(at=50.0)
    assert eng.now == 50.0 and eng.snapshot().num_running == 1
    eng.drain()
    assert eng.done


def test_reschedule_refuses_to_skip_queued_events():
    spec = make_cluster("helios")
    eng = SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="pack")
    eng.submit([mk_job(0, gpus=1, submit=100.0, runtime=50.0)])
    with pytest.raises(RuntimeError, match="queued event"):
        eng.reschedule(at=1e9)


# -------------------------------------------------------------- controllers ----


def test_pools_from_spec_bounds():
    pools = pools_from_spec(make_cluster("helios"), min_frac=0.25)
    assert set(pools) == {"V100", "P100"}
    for p in pools.values():
        assert p.min_nodes == 2 and p.max_nodes == 5
        assert p.template.gpu_type == p.gpu_type
    grow = pools_from_spec(make_cluster("helios"), max_frac=1.5)
    assert all(p.max_nodes == 8 for p in grow.values())


def test_autoscaler_validation():
    with pytest.raises(ValueError, match="at least one pool"):
        TargetUtilizationAutoscaler({})
    pools = pools_from_spec(make_cluster("helios"))
    with pytest.raises(ValueError, match="util_low < util_high"):
        TargetUtilizationAutoscaler(pools, util_low=0.9, util_high=0.5)
    with pytest.raises(ValueError, match="wait_down_s < wait_up_s"):
        QueuePressureAutoscaler(pools, wait_up_s=10.0, wait_down_s=60.0)
    with pytest.raises(KeyError, match="unknown autoscaler"):
        make_autoscaler("no-such", make_cluster("helios"))
    assert list_autoscalers() == ["queue-pressure", "target-util"]


def _idle_engine(spec=None):
    spec = spec or make_cluster("helios")
    return SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                           allocator="pack")


def test_target_util_hysteresis_band():
    eng = _idle_engine()
    pools = pools_from_spec(eng.spec)
    a = TargetUtilizationAutoscaler(pools, util_low=0.3, util_high=0.8,
                                    cooldown_s=0.0)
    # idle cluster: util 0 < low -> scale down (cordon/retire one node)
    ev = a.control(eng, 100.0)
    assert len(ev) == 1 and ev[0].action in ("retire", "cordon")
    # fill the cluster: util 1.0 > high -> scale up
    eng2 = _idle_engine()
    eng2.submit([mk_job(i, gpus=8, runtime=1e5, submit=0.0)
                 for i in range(12)])
    eng2.step(1.0)
    assert eng2.snapshot().utilization > 0.8
    a2 = TargetUtilizationAutoscaler(pools_from_spec(eng2.spec, max_frac=2.0),
                                     util_low=0.3, util_high=0.8,
                                     cooldown_s=0.0)
    ev2 = a2.control(eng2, 10.0)
    assert len(ev2) == 1 and ev2[0].action == "add"
    # mid-band: no action
    a3 = TargetUtilizationAutoscaler(pools, util_low=0.0, util_high=1.0,
                                     cooldown_s=0.0)
    assert a3.control(eng2, 20.0) == []


def test_cooldown_blocks_consecutive_actions():
    eng = _idle_engine()
    a = TargetUtilizationAutoscaler(pools_from_spec(eng.spec),
                                    util_low=0.5, util_high=0.9,
                                    cooldown_s=3600.0)
    assert len(a.control(eng, 0.0)) == 1
    assert a.control(eng, 1800.0) == []           # inside cooldown
    assert len(a.control(eng, 3700.0)) == 1       # cooldown expired


def test_bounds_respected():
    eng = _idle_engine()
    a = TargetUtilizationAutoscaler(
        pools_from_spec(eng.spec, min_frac=0.4),   # min 2 of 5 per pool
        util_low=0.9, util_high=0.95, cooldown_s=0.0)
    downs = 0
    for k in range(20):
        if not a.control(eng, float(k)):
            break
        downs += 1
    # 10 nodes, min 2 per SKU pool -> exactly 6 scale-downs then hold
    assert downs == 6
    for sku in ("V100", "P100"):
        assert a._active_count(eng.cluster, sku) == 2


def test_stall_override_ignores_cooldown_and_scales_up():
    eng = _idle_engine(make_cluster("slurm-testbed"))
    eng.submit([mk_job(0, gpus=64, gpu_type="P100", runtime=100.0)])
    eng.drain()
    assert not eng.done                            # unplaceable at 14 GPUs
    pools = {"P100": PoolSpec("P100", NodeSpec(0, "P100", 32, 128, 1024.0,
                                               1.0), 1, 4)}
    a = TargetUtilizationAutoscaler(pools, cooldown_s=1e12)
    ev = a.control(eng, 200.0, stalled=True)
    assert [e.action for e in ev] == ["add"]
    ev2 = a.control(eng, 300.0, stalled=True)     # still starved: 32 < 64
    assert [e.action for e in ev2] == ["add"]
    eng.drain()
    assert eng.done                                # 2x32 placed the gang


def test_scale_up_prefers_uncordon_over_add():
    eng = _idle_engine()
    jobs = [mk_job(i, gpus=8, runtime=1e5) for i in range(10)]
    eng.submit(jobs)
    eng.step(1.0)
    node = next(iter(eng.running.values()))[1]
    (nid, _), = node.items()
    eng.cluster.remove_node(nid)                  # cordons (busy)
    a = TargetUtilizationAutoscaler(pools_from_spec(eng.spec, max_frac=2.0),
                                    util_low=0.1, util_high=0.5,
                                    cooldown_s=0.0)
    ev = a.control(eng, 10.0)
    assert [e.action for e in ev] == ["uncordon"] and ev[0].node_id == nid
    assert not bool(eng.cluster.cordoned[nid])


def test_queue_pressure_scales_on_backlog():
    eng = _idle_engine()
    eng.submit([mk_job(i, gpus=8, runtime=1e5) for i in range(14)])
    eng.step(1.0)
    snap = eng.snapshot()
    assert snap.num_pending > 0 and snap.free_gpus == 0
    a = QueuePressureAutoscaler(pools_from_spec(eng.spec, max_frac=2.0),
                                cooldown_s=0.0)
    ev = a.control(eng, 10.0)
    assert len(ev) == 1 and ev[0].action == "add"
    assert "backlog" in ev[0].reason


# ------------------------------------------------------- service integration ----


def test_autoscaled_stream_cuts_provisioned_gpu_hours():
    """The headline behavior at test scale: on diurnal traffic a hysteresis
    controller completes every job with fewer provisioned GPU-hours than
    the static run, and the events/cost are visible in telemetry."""
    static = run_scenario("diurnal", num_jobs=220, seed=0, allocator="pack",
                          rescan_interval=300.0)
    assert len(static.batch.jobs) == 220
    run = get_scenario("diurnal").build(220, 0)
    asc = TargetUtilizationAutoscaler(
        pools_from_spec(run.spec, min_frac=0.25), util_low=0.6,
        util_high=0.85, max_pending_for_down=4, cooldown_s=1800.0)
    elastic = run_scenario(run, allocator="pack", rescan_interval=300.0,
                           autoscaler=asc)
    assert len(elastic.batch.jobs) == 220
    t_s, t_e = static.telemetry, elastic.telemetry
    assert t_e.provisioned_gpu_hours < t_s.provisioned_gpu_hours
    assert asc.events and t_e.scale_events == asc.events
    # the original spec must not have been mutated by scale-ups
    assert len(run.spec.nodes) == 10


def test_stalled_stream_scales_up_to_finish():
    """A scenario whose jobs exceed current capacity: the stall override
    must grow the cluster instead of ending the stream incomplete."""
    spec = make_cluster("slurm-testbed")
    jobs = [mk_job(0, gpus=2, gpu_type="P100", runtime=500.0, submit=0.0),
            mk_job(1, gpus=24, gpu_type="P100", runtime=500.0, submit=60.0)]
    pools = {"P100": PoolSpec("P100", NodeSpec(0, "P100", 8, 64, 512.0, 1.0),
                              1, 6)}
    asc = TargetUtilizationAutoscaler(pools, cooldown_s=1e12)
    sr = run_stream(spec, jobs, PolicyPrioritizer(make_policy("fcfs")),
                    allocator="pack", rescan_interval=60.0, autoscaler=asc)
    assert len(sr.batch.jobs) == 2                 # both completed
    assert any(e.action == "add" and "stall" in e.reason for e in asc.events)
    # without the controller the same stream ends incomplete
    sr0 = run_stream(spec, [j.clone_pending() for j in jobs],
                     PolicyPrioritizer(make_policy("fcfs")),
                     allocator="pack", rescan_interval=60.0)
    assert len(sr0.batch.jobs) == 1


# ------------------------------------------------- disabled == bit-identical ----


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_disabled_autoscaler_bit_identical(name):
    """Acceptance pin: attaching a controller that never acts (and the
    spec-cloning plumbing that comes with ``autoscaler=...``) must be
    bit-identical to ``autoscaler=None`` on every registered scenario."""
    base = run_scenario(get_scenario(name).build(64, seed=5),
                        allocator="pack", rescan_interval=300.0)
    run = get_scenario(name).build(64, seed=5)
    frozen = run_scenario(run, allocator="pack", rescan_interval=300.0,
                          autoscaler=frozen_autoscaler(run.spec))
    a = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in base.batch.jobs}
    b = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in frozen.batch.jobs}
    assert a == b
    assert base.batch.decisions == frozen.batch.decisions
    assert base.batch.backfills == frozen.batch.backfills


@pytest.mark.parametrize("name", ["steady", "fault-storm", "multi-tenant",
                                  "trace-replay"])
def test_one_member_fed_frozen_autoscaler_identical_to_bare_engine(name):
    """1-member federation with a frozen controller == bare engine (the
    federation autoscaler plumbing is unobservable when disabled)."""
    run = get_scenario(name).build(48, seed=5)
    pri = wrap_tenancy(PolicyPrioritizer(make_policy("fcfs")),
                       run.sla_users, run.vc_quotas)
    hooks = (pri,) if isinstance(pri, QuotaPrioritizer) else ()
    eng = SchedulerEngine(run.spec, pri, allocator="pack",
                          fault_model=run.fault_model, hooks=hooks)
    if isinstance(pri, QuotaPrioritizer):
        pri.engine = eng
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    bare = {j.job_id: (j.start_time, j.finish_time, j.restarts)
            for j in eng.completed}

    sr = run_fleet(FleetRun.from_scenario(run), router="hash",
                   allocator="pack", rescan_interval=60.0,
                   autoscaler_factory=lambda i, spec: frozen_autoscaler(spec))
    fed = {j.job_id: (j.start_time, j.finish_time, j.restarts)
           for j in sr.result.jobs}
    assert bare == fed


# ------------------------------------------------------ federation scaling ----


def test_fed_router_sees_scaled_capacity():
    """Satellite pin: after a member scales up past its static capacity,
    the capable-cluster filter (static ClusterInfo) must see the new
    totals — a job sized for the scaled member routes there instead of
    degrading to the bigger cluster."""
    small = make_cluster("slurm-testbed")    # 13 GPUs, biggest node 4
    big = make_cluster("helios")             # 80 GPUs
    pools = {"P100": PoolSpec("P100", NodeSpec(0, "P100", 32, 256, 2048.0,
                                               1.0), 1, 4)}
    asc = TargetUtilizationAutoscaler(pools, cooldown_s=1e12)
    fed = FederatedScheduler([small, big], "sku-affinity", allocator="pack",
                             autoscalers=[asc, None])
    info0 = fed.infos[0]
    assert info0.capacity_for("P100") == 8
    # grow the small member beyond its static capacity, tick the views
    fed.engines[0].cluster.add_node(pools["P100"].template)
    fed._refresh_views()
    assert fed.infos[0].capacity_for("P100") == 40
    assert fed.infos[0].total_gpus == 45
    # a 24-GPU P100 job is now capable only on the scaled member
    fed.submit([mk_job(7, gpus=24, gpu_type="P100", runtime=100.0)])
    assert fed.routes[7] == 0
    fed.drain()
    assert fed.done


def test_fed_autoscaler_validation():
    with pytest.raises(ValueError, match="autoscalers"):
        FederatedScheduler([make_cluster("helios")], "jsq",
                           autoscalers=[None, None])


# ----------------------------------------------------------------- tooling ----


def test_bench_autoscaling_smoke(tmp_path):
    """The registered autoscaling bench must run end-to-end in --smoke mode
    and emit a well-formed acceptance block."""
    json_path = tmp_path / "BENCH_autoscaling.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_BENCH_AUTOSCALE_JOBS"] = "150"
    env["REPRO_BENCH_AUTOSCALE_JSON"] = str(json_path)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_autoscaling", "--smoke"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    doc = json.loads(json_path.read_text())
    assert doc["bench"] == "autoscaling" and doc["num_jobs"] == 150
    assert doc["scale"] == "smoke"
    acc = doc["acceptance"]
    for scen in ("diurnal", "flash_crowd"):
        assert f"{scen}_cuts_gpu_hours" in acc
        assert f"{scen}_wait_within_band" in acc
    for row in doc["results"].values():
        assert row["completed"] == 150
        for v in row.values():
            if isinstance(v, float):
                assert math.isfinite(v)


def test_bench_autoscaling_registered():
    import benchmarks.run as brun
    assert "autoscaling" in brun.MODULES
