import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt import checkpoint as ckpt_mod
from repro.ckpt.checkpoint import latest_step


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (8, 16)),
                  "b": jnp.zeros((16,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    restored, step = load_checkpoint(str(tmp_path), t)
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["a"]["w"]),
                               np.asarray(t["a"]["w"]))
    assert restored["a"]["b"].dtype == jnp.bfloat16


def test_roundtrip_zlib_fallback_codec(tmp_path, monkeypatch):
    """A checkpoint written on a minimal install (no zstandard) must
    round-trip through the stdlib zlib codec, and the manifest must say
    so — a zstd reader is never required to restore it."""
    monkeypatch.setattr(ckpt_mod, "_zstd", None)
    monkeypatch.setattr(ckpt_mod, "_CODEC", "zlib")
    t = _tree(1)
    d = save_checkpoint(str(tmp_path), 9, t)
    import msgpack
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        assert msgpack.unpackb(f.read())["codec"] == "zlib"
    restored, step = load_checkpoint(str(tmp_path), t)
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["a"]["w"]),
                               np.asarray(t["a"]["w"]))
    assert restored["a"]["b"].dtype == jnp.bfloat16


def test_codec_error_paths(monkeypatch):
    with pytest.raises(ValueError, match="unknown checkpoint codec"):
        ckpt_mod._decompress(b"x", "lz4")
    monkeypatch.setattr(ckpt_mod, "_zstd", None)
    with pytest.raises(RuntimeError, match="compress"):
        ckpt_mod._decompress(b"x", "zstd")


def test_atomicity_tmp_cleanup(tmp_path):
    t = _tree()
    final = save_checkpoint(str(tmp_path), 1, t)
    assert final.endswith("step_00000001")
    assert latest_step(str(tmp_path)) == 1
    # a second save at a new step becomes latest
    save_checkpoint(str(tmp_path), 2, t)
    assert latest_step(str(tmp_path)) == 2


def test_manager_interval_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=2, keep=2)
    t = _tree()
    for step in range(1, 9):
        mgr.maybe_save(step, t)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 8
    import os
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) <= 2


def test_manager_gc_keeps_exactly_newest(tmp_path):
    """Retention is exact: keep=3 leaves precisely the three newest step
    directories, and restore reads the newest survivor."""
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=3)
    t = _tree()
    for step in range(1, 7):
        mgr.maybe_save(step, t)
    mgr.wait()
    kept = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                  if d.startswith("step_") and not d.endswith(".tmp"))
    assert kept == [4, 5, 6]
    _, step = mgr.restore(t)
    assert step == 6


def test_manager_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1)
    t = _tree(3)
    mgr.maybe_save(4, t)
    mgr.wait()
    restored, step = mgr.restore(t)
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["a"]["w"]),
                               np.asarray(t["a"]["w"]))


def test_elastic_reshard_subprocess(tmp_path, request):
    """Save on 1 device, restore onto an 8-device (4,2) mesh with sharding."""
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    from conftest import run_py
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as PS
from repro.ckpt import load_checkpoint
mesh = jax.make_mesh((4, 2), ("data", "model"))
target = {{"a": {{"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
               "b": jax.ShapeDtypeStruct((16,), jnp.bfloat16)}},
          "step": jax.ShapeDtypeStruct((), jnp.int32)}}
specs = {{"a": {{"w": PS("data", "model"), "b": PS()}}, "step": PS()}}
tree, step = load_checkpoint({str(tmp_path)!r}, target, mesh=mesh,
                             spec_tree=specs)
assert step == 3
assert len(tree["a"]["w"].sharding.device_set) == 8
print("reshard-ok", float(jnp.sum(tree["a"]["w"])))
"""
    out = run_py(code, devices=8)
    assert "reshard-ok" in out
