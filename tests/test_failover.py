"""Control-plane failover tests: ``SchedulerEngine.save_state`` /
``load_state`` must restore a mid-stream engine **bit-identically** — a
restored run finishes exactly like a run that never crashed, on every
registered scenario, at arbitrary cut points, through the MILP path, the
degradation ladder, and mid-flight preemptions."""
import math

import pytest
from conftest import hypothesis_or_stubs

from repro.chaos import DegradationPolicy
from repro.core import PolicyPrioritizer, make_policy
from repro.lifecycle import CkptCostModel
from repro.sched import (QuotaPrioritizer, RollingTelemetry, SchedulerEngine,
                         get_scenario, list_scenarios, wrap_tenancy)

given, settings, st = hypothesis_or_stubs()


def fresh_engine(run, *, allocator="pack", degradation=None):
    """A drain-mode engine wired exactly like the service loop wires one
    (tenancy wrap + incremental quota hook + engine back-reference)."""
    pri = wrap_tenancy(PolicyPrioritizer(make_policy("fcfs")),
                       run.sla_users, run.vc_quotas)
    hooks = (pri,) if isinstance(pri, QuotaPrioritizer) else ()
    eng = SchedulerEngine(run.spec, pri, allocator=allocator,
                          fault_model=run.fault_model, hooks=hooks,
                          degradation=degradation)
    if isinstance(pri, QuotaPrioritizer):
        pri.engine = eng
    eng.submit([j.clone_pending() for j in run.jobs])
    return eng


def fingerprint(eng):
    jobs = sorted((j.job_id, j.start_time, j.finish_time, j.num_gpus,
                   j.restarts) for j in eng.completed)
    return (jobs, eng.decisions, eng.backfills, eng.milp_calls,
            eng.restarts, eng.preemptions, eng.now)


def roundtrip_equals_straight(name, cut, *, num_jobs=60, allocator="pack",
                              degradation=None):
    straight = fresh_engine(get_scenario(name).build(num_jobs, 0),
                            allocator=allocator, degradation=degradation)
    straight.drain()

    crashed = fresh_engine(get_scenario(name).build(num_jobs, 0),
                           allocator=allocator, degradation=degradation)
    crashed.step(math.inf, max_events=cut)
    blob = crashed.save_state()
    del crashed                                   # the control plane died
    restored = SchedulerEngine.load_state(blob)
    restored.drain()
    assert fingerprint(restored) == fingerprint(straight), (name, cut)


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_roundtrip_matches_uninterrupted_run(name):
    for cut in (1, 7, 23):
        roundtrip_equals_straight(name, cut)


def test_roundtrip_through_milp_allocator():
    roundtrip_equals_straight("steady", 11, allocator="milp")


def test_roundtrip_with_degradation_ladder_engaged():
    deg = DegradationPolicy(milp_budget_s=0.0, trip_after=1,
                            reset_after_decisions=8, window_deadline_s=0.0)
    roundtrip_equals_straight("steady", 17, allocator="milp",
                              degradation=deg)


def test_roundtrip_preserves_degradation_counters():
    deg = DegradationPolicy(milp_budget_s=0.0, trip_after=1,
                            reset_after_decisions=8)
    eng = fresh_engine(get_scenario("steady").build(40, 0),
                       allocator="milp", degradation=deg)
    eng.step(math.inf, max_events=40)
    restored = SchedulerEngine.load_state(eng.save_state())
    assert restored.milp_fallbacks == eng.milp_fallbacks
    assert restored.degradation == deg
    assert restored._deg_fallback_open == eng._deg_fallback_open
    restored.drain()
    assert restored.done and restored.milp_fallbacks > 0


def test_roundtrip_after_midstream_preemption():
    def run_one(save_after_preempt):
        eng = fresh_engine(get_scenario("steady").build(40, 0))
        eng.step(600.0)
        victim = next(iter(eng.running), None)
        if victim is not None:
            eng.preempt_job(victim, CkptCostModel(ckpt_interval=1800.0,
                                                  restore_s=120.0))
            eng.reschedule(at=eng.now)
        if save_after_preempt:
            eng = SchedulerEngine.load_state(eng.save_state())
        eng.drain()
        return fingerprint(eng)

    assert run_one(True) == run_one(False)


def test_save_state_does_not_disturb_live_engine():
    """Taking a snapshot mid-stream (detaching the prioritizer back-ref)
    must leave the live engine able to continue bit-identically."""
    straight = fresh_engine(get_scenario("multi-tenant").build(50, 0))
    straight.drain()
    live = fresh_engine(get_scenario("multi-tenant").build(50, 0))
    live.step(math.inf, max_events=13)
    live.save_state()                              # snapshot, then carry on
    assert getattr(live.prioritizer, "engine", live) is live
    live.drain()
    assert fingerprint(live) == fingerprint(straight)


def test_load_state_reattaches_fresh_hooks():
    eng = fresh_engine(get_scenario("steady").build(30, 0))
    eng.step(math.inf, max_events=9)
    tel = RollingTelemetry(window=6 * 3600.0, sample_interval=600.0)
    restored = SchedulerEngine.load_state(eng.save_state(), hooks=[tel])
    assert tel in restored.hooks
    restored.drain()
    assert restored.done
    assert tel._last_t is not None                 # the observer saw ticks


@given(cut=st.integers(min_value=0, max_value=400))
@settings(max_examples=20, deadline=None)
def test_roundtrip_fuzzed_cut_points(cut):
    """The restore point must be unobservable wherever the crash lands —
    before the first decision, mid-backfill, past the last event."""
    roundtrip_equals_straight("flash-crowd", cut, num_jobs=40)
