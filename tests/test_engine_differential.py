"""Differential equivalence: optimized engine vs. retained naive reference.

The optimized hot path (indexed pending queue, version-keyed feasibility
cache, scratch ClusterState reuse, batch scoring) must be **bit-identical**
to the seed's naive loop (full re-sort + linear scans + scalar scoring) —
same completion order, same per-job start/finish times, same BatchResult
aggregates — on every stream we can throw at it."""
import numpy as np
import pytest
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import (FaultModel, PolicyPrioritizer, make_cluster,
                        make_policy)
from repro.core.types import Job
from repro.sched import SchedulerEngine, get_scenario, list_scenarios, \
    run_stream


def _run(spec, jobs, policy, *, optimized, allocator="pack",
         fault_model=None, queue_window=None, backfill=True):
    pri = PolicyPrioritizer(make_policy(policy), batch=optimized)
    engine = SchedulerEngine(spec, pri, allocator=allocator,
                             backfill=backfill, fault_model=fault_model,
                             queue_window=queue_window, optimized=optimized)
    engine.submit([j.clone_pending() for j in jobs])
    engine.run_until_complete()
    r = engine.result()
    return {
        "completion_order": [j.job_id for j in engine.completed],
        "times": {j.job_id: (j.start_time, j.finish_time, j.restarts)
                  for j in r.jobs},
        "agg": (r.makespan, r.total_wait, r.gpu_seconds_used, r.decisions,
                r.milp_calls, r.backfills, r.restarts),
    }


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_differential_all_scenarios(name):
    """Random 200-job streams from every registered scenario: optimized and
    naive engines produce identical completion order and BatchResult."""
    run = get_scenario(name).build(200, seed=11)
    opt = _run(run.spec, run.jobs, "fcfs", optimized=True,
               fault_model=run.fault_model)
    ref = _run(run.spec, run.jobs, "fcfs", optimized=False,
               fault_model=run.fault_model)
    assert opt["completion_order"] == ref["completion_order"]
    assert opt["times"] == ref["times"]
    assert opt["agg"] == ref["agg"]


@pytest.mark.parametrize("policy", ["sjf", "wfp3", "unicep", "f1", "qssf",
                                    "slurm-mf"])
def test_differential_policies(policy):
    """Batch scoring must not perturb the schedule for any base policy."""
    run = get_scenario("steady").build(160, seed=3)
    opt = _run(run.spec, run.jobs, policy, optimized=True)
    ref = _run(run.spec, run.jobs, policy, optimized=False)
    assert opt == ref


def test_differential_milp_allocator():
    """The MILP path consumes cached candidate_ways / eligibility masks."""
    run = get_scenario("sku-skew").build(96, seed=5)
    opt = _run(run.spec, run.jobs, "fcfs", optimized=True, allocator="milp")
    ref = _run(run.spec, run.jobs, "fcfs", optimized=False, allocator="milp")
    assert opt == ref


def test_differential_narrow_window_and_service_driver():
    """Tiny ranking window forces heavy window churn on the indexed queue;
    the rescan-interval service driver must agree too."""
    run = get_scenario("flash-crowd").build(200, seed=9)
    outs = []
    for optimized in (True, False):
        pri = PolicyPrioritizer(make_policy("fcfs"), batch=optimized)
        sr = run_stream(run.spec, [j.clone_pending() for j in run.jobs], pri,
                        rescan_interval=60.0, allocator="pack",
                        queue_window=8, fault_model=run.fault_model,
                        chunked_submit=True, optimized=optimized)
        outs.append({j.job_id: (j.start_time, j.finish_time)
                     for j in sr.batch.jobs})
    assert outs[0] == outs[1]


def _mk_stream(seed: int, n: int = 200) -> list[Job]:
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(90.0, n))
    jobs = []
    for i in range(n):
        rt = float(rng.lognormal(6.0, 1.5)) + 1.0
        jobs.append(Job(
            job_id=i, user=int(rng.integers(0, 12)),
            submit_time=float(t[i]), runtime=rt,
            est_runtime=rt * float(rng.uniform(0.5, 2.0)),
            num_gpus=int(rng.choice([1, 1, 2, 4, 8, 16])),
            gpu_type=str(rng.choice(["any", "any", "V100", "P100"])),
            vc=int(rng.integers(0, 4))))
    return jobs


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_synthetic_streams(seed):
    """Fully synthetic random streams (SKU mix, noisy estimates, faults)."""
    spec = make_cluster("helios")
    fm = FaultModel(mtbf_per_node=6 * 3600.0, repair_time=900.0, seed=seed)
    jobs = _mk_stream(seed)
    opt = _run(spec, jobs, "fcfs", optimized=True, fault_model=fm)
    ref = _run(spec, jobs, "fcfs", optimized=False, fault_model=fm)
    assert opt == ref


@pytest.mark.parametrize("name", ["multi-tenant", "sla-mix"])
@pytest.mark.parametrize("policy", ["slurm-mf", "qssf"])
def test_differential_tenancy_wrapped_rank_window(name, policy):
    """Tenancy wrappers (SLA lane / VC-quota gate) now expose ``rank_window``
    serving engine-maintained field views (incl. the new user/vc arrays) to
    their base — the wrapped fields path must schedule bit-identically to
    the naive scalar path."""
    from repro.sched import run_stream as _rs, wrap_tenancy

    run = get_scenario(name).build(160, seed=7)
    outs = []
    for optimized in (True, False):
        pri = PolicyPrioritizer(make_policy(policy), batch=optimized)
        pri = wrap_tenancy(pri, run.sla_users, run.vc_quotas)
        sr = _rs(run.spec, [j.clone_pending() for j in run.jobs], pri,
                 rescan_interval=60.0, allocator="pack",
                 fault_model=run.fault_model, chunked_submit=True,
                 optimized=optimized)
        outs.append({j.job_id: (j.start_time, j.finish_time)
                     for j in sr.batch.jobs})
    assert outs[0] == outs[1]


def test_rank_window_fields_match_rank_for_all_policies():
    """Field-array scoring (incl. user/vc served from the indexed queue)
    must order every built-in policy's window identically to the per-job
    scalar path, including history-dependent state (fair-share usage, QSSF
    runtime history)."""
    from repro.core.policies import BASE_POLICIES
    from repro.core.prioritizer import WindowFields
    from repro.core.cluster import ClusterState

    run = get_scenario("multi-tenant").build(96, seed=13)
    jobs = run.jobs
    cluster = ClusterState(run.spec)
    now = jobs[-1].submit_time + 3600.0
    for policy in BASE_POLICIES:
        pa = PolicyPrioritizer(make_policy(policy), batch=True)
        pb = PolicyPrioritizer(make_policy(policy), batch=False)
        # warm history-dependent policies with identical finish streams
        for j in jobs[:32]:
            fin = j.clone_pending()
            fin.start_time, fin.finish_time = j.submit_time, \
                j.submit_time + j.runtime
            pa.observe_finish(fin)
            pb.observe_finish(fin)
        window = jobs[32:]
        fields = WindowFields.from_jobs(window)
        assert pa.rank_window(window, cluster, now, fields) == \
            pb.rank(window, cluster, now), policy


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from(sorted(list_scenarios())),
       st.sampled_from(["fcfs", "sjf", "wfp3", "qssf"]))
def test_differential_property(seed, scenario, policy):
    """Hypothesis sweep: any (seed, scenario, policy) triple schedules
    identically on both engine paths."""
    run = get_scenario(scenario).build(64, seed=seed % 997)
    opt = _run(run.spec, run.jobs, policy, optimized=True,
               fault_model=run.fault_model)
    ref = _run(run.spec, run.jobs, policy, optimized=False,
               fault_model=run.fault_model)
    assert opt == ref
