"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + NaN assertions, and prefill/decode consistency vs the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.data import batch_for
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, L=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(B, L)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)) * 0.02,
            cfg.dtype)
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model)) * 0.02,
            cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits = model.forward(params, batch["tokens"],
                           patch_embeds=batch.get("patch_embeds"),
                           audio_frames=batch.get("audio_frames"))
    from repro.configs.base import padded_vocab
    assert logits.shape == (2, 32, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one train step reduces nothing catastrophically
    from repro.train import OptConfig, make_train_step, opt_init
    step = make_train_step(model, OptConfig(lr=1e-3, warmup_steps=1,
                                            total_steps=10))
    params2, _, metrics = step(params, opt_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill(t..L-1) must reproduce forward's next-token
    logits — exercises KV caches, ring buffers, and mamba states."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, B=2, L=16)
    toks = batch["tokens"]

    full = model.forward(params, toks,
                         patch_embeds=batch.get("patch_embeds"),
                         audio_frames=batch.get("audio_frames"))
    pre_logits, cache = model.prefill(
        params, toks[:, :-1], patch_embeds=batch.get("patch_embeds"),
        audio_frames=batch.get("audio_frames"), pad_to=toks.shape[1] + 4)
    # prefill last-token logits == forward at position L-2
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full[:, -2, :]), rtol=0.15,
                               atol=0.15)
    step_logits, cache = model.decode_step(params, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full[:, -1, :]), rtol=0.2, atol=0.2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_batch_for_matches_specs(arch):
    cfg = get_config(arch, smoke=False)
    from repro.configs.base import input_specs
    specs = input_specs(cfg, "train_4k")
    # host-sharded batch materialization (host 0 of 64)
    b = batch_for(cfg, "train_4k", num_hosts=64, host_id=0)
    assert b["tokens"].shape[0] == specs["tokens"].shape[0] // 64
    assert b["tokens"].shape[1] == specs["tokens"].shape[1]


def test_vlm_patch_positions():
    cfg = get_config("internvl2-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    b = _batch(cfg, B=1, L=8)
    logits = model.forward(params, b["tokens"], patch_embeds=b["patch_embeds"])
    assert logits.shape[1] == 8  # text positions only


def test_swa_limits_context():
    """h2o-danube smoke has window=16: token 31 must not see token 0."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 40)), jnp.int32)
    base = model.forward(params, toks)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
    pert = model.forward(params, toks2)
    # far-beyond-window positions unaffected by token-0 change
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), atol=1e-2)
