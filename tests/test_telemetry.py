"""Direct unit pins for repro.sched.telemetry rolling windows.

The streaming-RL reward shaper consumes these numbers (wait percentiles,
windowed utilization, backlog) at every rescan-window boundary, so window
eviction, percentile edge cases, and empty-window guards need direct pins —
not just the end-to-end scenario goldens.
"""
import math

import numpy as np
import pytest

from repro.core.types import Job
from repro.sched import RollingTelemetry, jain_index


class _FakeCluster:
    def __init__(self, total=(8, 8), free=(8, 8)):
        self.total_gpus = np.array(total, dtype=np.int64)
        self.free_gpus = np.array(free, dtype=np.int64)
        self.retired = np.zeros(len(total), dtype=bool)


class _FakeEngine:
    """Just enough engine surface for RollingTelemetry hooks/samples."""

    def __init__(self, cluster=None):
        self.cluster = cluster or _FakeCluster()
        self.pending = []
        self.running = {}


def _finished_job(jid, submit, start, finish, vc=0, gpus=1):
    j = Job(job_id=jid, user=0, submit_time=submit, runtime=finish - start,
            est_runtime=finish - start, num_gpus=gpus, vc=vc)
    j.start_time = start
    j.finish_time = finish
    return j


def _tick(tel, eng, now, busy_free=None):
    if busy_free is not None:
        eng.cluster.free_gpus = np.array(busy_free, dtype=np.int64)
    tel.on_tick(now, eng)


def test_window_eviction_drops_old_finishes():
    tel = RollingTelemetry(window=1000.0, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    for t in (100.0, 200.0, 300.0):
        tel.on_finish(_finished_job(int(t), 0.0, t - 50.0, t), t)
        _tick(tel, eng, t)
    assert len(tel._fin) == 3
    # advancing past 100+window must evict exactly the first record
    _tick(tel, eng, 1150.0)
    assert [r.t for r in tel._fin] == [200.0, 300.0]
    s = tel._sample(1150.0, eng)
    assert s.finished_in_window == 2
    # ... and total_finished keeps counting everything ever finished
    assert tel.total_finished == 3


def test_requeue_eviction():
    tel = RollingTelemetry(window=500.0, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    tel.on_requeue(_finished_job(1, 0.0, 10.0, 20.0), 100.0)
    tel.on_requeue(_finished_job(2, 0.0, 10.0, 20.0), 400.0)
    _tick(tel, eng, 450.0)
    assert tel._sample(450.0, eng).requeues == 2
    _tick(tel, eng, 700.0)   # 100 < 700 - 500 evicts the first
    assert tel._sample(700.0, eng).requeues == 1


def test_single_record_percentiles_degenerate():
    """One finished job: every percentile equals its value."""
    tel = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    tel.on_finish(_finished_job(1, 0.0, 30.0, 130.0), 130.0)  # wait 30, jct 130
    _tick(tel, eng, 130.0)
    s = tel._sample(130.0, eng)
    assert s.wait_p50 == s.wait_p95 == s.wait_p99 == pytest.approx(30.0)
    assert s.jct_p50 == s.jct_p95 == s.jct_p99 == pytest.approx(130.0)
    assert s.finished_in_window == 1


def test_empty_window_guards():
    """No finishes / no segments: percentiles and throughput read 0, the
    utilization falls back to the last observed busy fraction — never NaN."""
    tel = RollingTelemetry(window=3600.0, sample_interval=math.inf)
    eng = _FakeEngine()
    s = tel._sample(0.0, eng)
    for v in (s.jct_p50, s.jct_p99, s.wait_p50, s.wait_p99,
              s.throughput_jph, s.utilization):
        assert v == 0.0 and np.isfinite(v)
    assert s.vc_fairness == 1.0
    # after one tick with a half-busy cluster but still zero span, the
    # utilization guard returns the instantaneous busy fraction
    _tick(tel, eng, 10.0, busy_free=(4, 4))
    assert tel._windowed_util(10.0) == pytest.approx(0.5)


def test_windowed_util_exact_integration():
    """Utilization is integrated piecewise-exactly between ticks."""
    tel = RollingTelemetry(window=1000.0, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0, busy_free=(8, 8))     # busy 0.0 for [0, 100)
    _tick(tel, eng, 100.0, busy_free=(0, 8))   # busy 0.5 for [100, 300)
    _tick(tel, eng, 300.0, busy_free=(0, 0))   # busy 1.0 for [300, 400)
    _tick(tel, eng, 400.0)
    want = (100 * 0.0 + 200 * 0.5 + 100 * 1.0) / 400.0
    assert tel._windowed_util(400.0) == pytest.approx(want)
    # segments fully left of the window are clipped out exactly
    _tick(tel, eng, 1150.0)   # busy 1.0 for [400, 1150)
    lo = 1150.0 - 1000.0
    want = (0.5 * (300 - lo) + 1.0 * (1150 - 300)) / 1000.0
    assert tel._windowed_util(1150.0) == pytest.approx(want)


def test_sample_interval_and_final():
    """Samples are emitted on the simulated-time grid; final() always
    appends one closing sample."""
    tel = RollingTelemetry(window=1e6, sample_interval=100.0)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    for t in (50.0, 120.0, 250.0):
        _tick(tel, eng, t)
    assert len(tel.samples) == 2          # at >=100 and >=220
    tel.final(eng)
    assert len(tel.samples) == 3
    assert tel.samples[-1].time == 250.0


def test_vc_fairness_from_gpu_seconds():
    tel = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    # two VCs, equal GPU-seconds -> Jain == 1.0
    tel.on_finish(_finished_job(1, 0.0, 0.0, 100.0, vc=0, gpus=2), 100.0)
    tel.on_finish(_finished_job(2, 0.0, 0.0, 200.0, vc=1, gpus=1), 200.0)
    _tick(tel, eng, 200.0)
    s = tel._sample(200.0, eng)
    assert s.vc_fairness == pytest.approx(1.0)
    # skewed shares drop below 1
    tel.on_finish(_finished_job(3, 0.0, 0.0, 300.0, vc=0, gpus=8), 300.0)
    s = tel._sample(300.0, eng)
    assert s.vc_fairness < 1.0


def test_jain_index_reference_values():
    assert jain_index([5.0, 5.0, 5.0, 5.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 3.0]) == pytest.approx(16.0 / 20.0)
    assert jain_index([]) == 1.0


def test_degraded_ratio_boundaries():
    """degraded_fraction / degraded_ratio pinned at both boundaries: 0.0
    with no degradation, exactly 1.0 when the whole observed span (or a
    zero-length single-tick span) was FCFS-degraded."""
    tel = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng = _FakeEngine()
    # no ticks at all -> 0.0, never a ZeroDivisionError
    assert tel.degraded_fraction() == 0.0
    assert tel.degraded_ratio == 0.0

    _tick(tel, eng, 0.0)
    _tick(tel, eng, 100.0)
    assert tel.degraded_fraction() == 0.0

    # 100%-degraded window: degraded_s covers the whole span
    eng.degraded_s = 100.0
    _tick(tel, eng, 100.0)
    assert tel.degraded_fraction() == 1.0
    # degraded_s overshooting the span (window-bucket rounding) stays clamped
    eng.degraded_s = 150.0
    _tick(tel, eng, 100.0)
    assert tel.degraded_fraction() == 1.0

    # zero-length span (single observed tick) inside a degraded window
    tel2 = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng2 = _FakeEngine()
    eng2.degraded_s = 30.0
    _tick(tel2, eng2, 50.0)
    assert tel2.degraded_fraction() == 1.0
    # ... and 0.0 when nothing was degraded at that tick
    tel3 = RollingTelemetry(window=1e6, sample_interval=math.inf)
    _tick(tel3, _FakeEngine(), 50.0)
    assert tel3.degraded_fraction() == 0.0


def test_sample_percentiles_exact_vs_numpy_reference():
    """The vectorized one-pass percentile path (single multi-q
    ``np.percentile`` over the ring view) must equal a per-quantile
    ``np.percentile`` over the raw wait/jct arrays bit-for-bit — the
    contract that made the sort-once rewrite a pure optimization."""
    tel = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    rng = np.random.default_rng(7)
    waits, jcts = [], []
    t = 50_000.0   # keep start/submit positive for the longest runtimes
    for jid in range(257):   # odd count: exercises interpolated quantiles
        wait = float(rng.uniform(0.0, 5000.0))
        run = float(rng.uniform(60.0, 20000.0))
        t += float(rng.uniform(1.0, 30.0))
        start = t - run
        submit = start - wait
        tel.on_finish(_finished_job(jid, submit, start, t), t)
        # mirror the exact float ops Job.wait_time / Job.jct perform so the
        # comparison below is bit-exact, not approx
        waits.append(start - submit)
        jcts.append(t - submit)
    _tick(tel, eng, t)
    s = tel._sample(t, eng)
    w = np.array(waits)
    j = np.array(jcts)
    # exact equality, not approx: same float64 data, same interpolation
    assert s.wait_p50 == float(np.percentile(w, 50))
    assert s.wait_p95 == float(np.percentile(w, 95))
    assert s.wait_p99 == float(np.percentile(w, 99))
    assert s.jct_p50 == float(np.percentile(j, 50))
    assert s.jct_p95 == float(np.percentile(j, 95))
    assert s.jct_p99 == float(np.percentile(j, 99))
    assert s.finished_in_window == 257


def test_milp_fallback_rate_boundaries():
    """milp_fallback_rate pinned at 0.0 (solver never eligible, or never
    fell back) and exactly 1.0 (every eligible alloc degraded to greedy)."""
    tel = RollingTelemetry(window=1e6, sample_interval=math.inf)
    eng = _FakeEngine()
    _tick(tel, eng, 0.0)
    assert tel.milp_fallback_rate() == 0.0     # no calls, no fallbacks

    eng.milp_calls = 7
    _tick(tel, eng, 10.0)
    assert tel.milp_fallback_rate() == 0.0     # calls but zero fallbacks

    eng.milp_fallbacks = 7
    _tick(tel, eng, 20.0)
    assert tel.milp_fallback_rate() == 0.5

    eng.milp_calls = 0
    _tick(tel, eng, 30.0)
    assert tel.milp_fallback_rate() == 1.0     # 100% of eligible allocs fell back
