"""Shared fixtures.  NOTE: device count stays 1 here (smoke tests / benches
must see one device); multi-device tests spawn subprocesses with their own
XLA_FLAGS per the dry-run contract."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, devices: int = 0, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess (optionally with N fake devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def helios_jobs():
    from repro.core import generate_trace
    return generate_trace("helios", 256, seed=0)


@pytest.fixture(scope="session")
def helios_cluster():
    from repro.core import make_cluster
    return make_cluster("helios")
