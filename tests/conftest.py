"""Shared fixtures.  NOTE: device count stays 1 here (smoke tests / benches
must see one device); multi-device tests spawn subprocesses with their own
XLA_FLAGS per the dry-run contract."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def hypothesis_or_stubs():
    """Import (given, settings, st) from hypothesis, or — on minimal installs
    without the [test] extra — return stand-ins that keep the module
    collectable and mark each property test as skipped."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
        return given, settings, st
    except ImportError:
        skip = pytest.mark.skip(reason="hypothesis not installed")

        def given(*a, **kw):
            def deco(fn):
                @skip
                def stub():
                    raise AssertionError("skipped: hypothesis missing")
                stub.__name__ = fn.__name__
                stub.__doc__ = fn.__doc__
                return stub
            return deco

        def settings(*a, **kw):
            return lambda fn: fn

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **kw: None

        return given, settings, _Strategies()


def run_py(code: str, devices: int = 0, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess (optionally with N fake devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def helios_jobs():
    from repro.core import generate_trace
    return generate_trace("helios", 256, seed=0)


@pytest.fixture(scope="session")
def helios_cluster():
    from repro.core import make_cluster
    return make_cluster("helios")
