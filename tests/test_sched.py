"""Tests for repro.sched: streaming engine, scenarios, telemetry, service."""
import math

import pytest

from repro.core import (FaultModel, PolicyPrioritizer, Simulator,
                        generate_trace, make_cluster, make_policy)
from repro.core.types import JobState
from repro.sched import (RollingTelemetry, SchedulerEngine, get_scenario,
                         jain_index, list_scenarios, run_scenario, run_stream)

# Golden aggregates recorded from the seed implementation (pre-engine
# Simulator.run_batch) on fixed seeds — the engine-backed path must stay
# bit-identical: (makespan, total_wait, gpu_seconds, decisions, milp_calls,
# backfills, restarts).
SEED_GOLDENS = {
    ("helios", 96, 0, "fcfs", "milp", True, False):
        (15713.6353051043, 21243.23142577523, 354981.51819661586,
         160, 65, 23, 0),
    ("helios", 96, 0, "sjf", "pack", False, False):
        (17240.76681510536, 33201.677919136404, 360452.05060567195,
         184, 0, 0, 0),
    ("philly", 64, 3, "fcfs", "pack", True, True):
        (204802.50966770164, 71493.66006047613, 6324307.354041935,
         377, 0, 11, 258),
    ("alibaba", 80, 5, "wfp3", "spread", True, False):
        (159707.73323363136, 18867.45225254594, 538229.1101009173,
         143, 0, 9, 0),
}


def _make_engine(spec, policy="fcfs", **kw):
    return SchedulerEngine(spec, PolicyPrioritizer(make_policy(policy)), **kw)


@pytest.mark.parametrize("key", sorted(SEED_GOLDENS, key=str))
def test_run_batch_matches_seed_goldens(key):
    """Simulator.run_batch (now an engine wrapper) is bit-identical to the
    pre-extraction event loop on fixed seeds."""
    trace, n, seed, policy, allocator, backfill, faults = key
    fm = FaultModel(mtbf_per_node=3 * 3600.0, repair_time=600.0, seed=1) \
        if faults else None
    jobs = generate_trace(trace, n, seed=seed)
    sim = Simulator(make_cluster(trace), allocator=allocator,
                    backfill=backfill, fault_model=fm)
    r = sim.run_batch([j.clone_pending() for j in jobs],
                      PolicyPrioritizer(make_policy(policy)))
    got = (r.makespan, r.total_wait, r.gpu_seconds_used, r.decisions,
           r.milp_calls, r.backfills, r.restarts)
    assert got == SEED_GOLDENS[key]


def test_streaming_resume_equals_drain(helios_jobs, helios_cluster):
    """Two step() calls produce exactly the same schedule as one drain()."""
    jobs = helios_jobs[:160]
    e1 = _make_engine(helios_cluster, allocator="pack")
    e1.submit([j.clone_pending() for j in jobs])
    e1.drain()

    e2 = _make_engine(helios_cluster, allocator="pack")
    e2.submit([j.clone_pending() for j in jobs])
    mid = jobs[80].submit_time
    e2.step(mid)
    snap = e2.snapshot()
    assert 0 < snap.num_completed < len(jobs)   # genuinely paused mid-stream
    e2.step(math.inf)

    f1 = {j.job_id: j.finish_time for j in e1.result().jobs}
    f2 = {j.job_id: j.finish_time for j in e2.result().jobs}
    assert f1 == f2
    assert e1.decisions == e2.decisions
    assert e1.backfills == e2.backfills


def test_incremental_submit_equals_upfront(helios_jobs, helios_cluster):
    """Feeding jobs in chunks (true streaming) changes nothing vs. upfront
    submission: arrivals only take effect at their event instant."""
    jobs = helios_jobs[:120]
    e1 = _make_engine(helios_cluster, allocator="pack")
    e1.submit([j.clone_pending() for j in jobs])
    e1.drain()

    e2 = _make_engine(helios_cluster, allocator="pack")
    clones = [j.clone_pending() for j in jobs]
    e2.submit(clones[:50])
    e2.step(clones[50].submit_time - 1.0)
    assert not e2.done
    e2.submit(clones[50:])
    e2.drain()

    f1 = {j.job_id: j.finish_time for j in e1.result().jobs}
    f2 = {j.job_id: j.finish_time for j in e2.result().jobs}
    assert f1 == f2


def test_engine_cluster_persists_across_submissions(helios_cluster):
    """The cluster is never reset between waves — running jobs survive."""
    wave1 = generate_trace("helios", 24, seed=21)
    e = _make_engine(helios_cluster, allocator="pack")
    e.submit([j.clone_pending() for j in wave1])
    e.drain()
    assert e.done and len(e.completed) == 24
    t_end = e.now
    wave2 = [j.clone_pending() for j in generate_trace("helios", 24, seed=22)]
    for j in wave2:
        j.job_id += 1000
        j.submit_time += t_end          # arrive after wave 1 drained
    e.submit(wave2)
    e.drain()
    assert len(e.completed) == 48
    assert e.result().makespan > t_end - e.t0 - 1e-6


def test_queue_window_configurable(helios_cluster):
    jobs = generate_trace("helios", 64, seed=13)
    narrow = _make_engine(helios_cluster, allocator="pack", queue_window=4)
    narrow.submit([j.clone_pending() for j in jobs])
    narrow.drain()
    assert narrow.queue_window == 4
    assert len(narrow.completed) == 64
    default = _make_engine(helios_cluster, allocator="pack")
    assert default.queue_window == 10 * 256


@pytest.mark.parametrize("name", list_scenarios())
def test_scenario_smoke(name):
    """Every registered scenario builds deterministically and streams a small
    run to completion with rolling telemetry."""
    sc = get_scenario(name)
    r1 = sc.build(32, seed=3)
    r2 = sc.build(32, seed=3)
    assert [j.submit_time for j in r1.jobs] == [j.submit_time for j in r2.jobs]
    assert all(r1.jobs[i].submit_time <= r1.jobs[i + 1].submit_time
               for i in range(len(r1.jobs) - 1))
    sr = run_scenario(name, num_jobs=32, seed=3, rescan_interval=300.0,
                      sample_interval=1800.0, allocator="pack")
    assert len(sr.batch.jobs) == 32
    assert all(j.state == JobState.COMPLETED for j in sr.batch.jobs)
    assert sr.telemetry.samples, "telemetry must emit at least one sample"
    last = sr.telemetry.samples[-1]
    assert 0.0 <= last.utilization <= 1.0
    assert 0.0 < last.vc_fairness <= 1.0


def test_scenario_registry():
    assert len(list_scenarios()) >= 5
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_flash_crowd_spikes_queue():
    """The flash-crowd scenario must actually pile up a queue."""
    sr = run_scenario("flash-crowd", num_jobs=96, seed=0,
                      rescan_interval=300.0, sample_interval=600.0,
                      allocator="pack")
    assert sr.telemetry.peak_queue_len() >= 5


def test_telemetry_rolls_and_integrates(helios_cluster):
    jobs = generate_trace("helios", 96, seed=8)
    tel = RollingTelemetry(window=2 * 3600.0, sample_interval=600.0)
    sr = run_stream(helios_cluster, [j.clone_pending() for j in jobs],
                    PolicyPrioritizer(make_policy("fcfs")),
                    allocator="pack", telemetry=tel, chunked_submit=True)
    assert tel.total_finished == 96
    assert len(tel.samples) >= 2
    for s in tel.samples:
        assert 0.0 <= s.utilization <= 1.0
        assert s.jct_p50 <= s.jct_p95 <= s.jct_p99
        assert s.wait_p50 <= s.wait_p95 <= s.wait_p99
    # rolling eviction: window never reports more than everything finished
    assert max(s.finished_in_window for s in tel.samples) <= 96
    assert sr.windows > 0


def test_jain_index_bounds():
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1.0)  # zeros excluded
    assert jain_index([3.0, 1.0]) < 1.0
    assert jain_index([]) == 1.0


def test_run_stream_matches_run_batch(helios_jobs, helios_cluster):
    """The windowed service driver equals batch drain exactly (window
    boundaries are unobservable to the schedule)."""
    jobs = helios_jobs[:96]
    sim = Simulator(helios_cluster, allocator="pack")
    rb = sim.run_batch([j.clone_pending() for j in jobs],
                       PolicyPrioritizer(make_policy("fcfs")))
    sr = run_stream(helios_cluster, [j.clone_pending() for j in jobs],
                    PolicyPrioritizer(make_policy("fcfs")),
                    rescan_interval=60.0, allocator="pack")
    fb = {j.job_id: j.finish_time for j in rb.jobs}
    fs = {j.job_id: j.finish_time for j in sr.batch.jobs}
    assert fb == fs
    assert rb.decisions == sr.batch.decisions


def test_sla_lane_scenario():
    """sla-mix: SLA users' jobs never wait longer than the worst best-effort
    job (the bypass lane schedules them first)."""
    sc = get_scenario("sla-mix")
    run = sc.build(64, seed=2)
    assert run.sla_users
    sr = run_scenario(run, allocator="pack", rescan_interval=300.0)
    sla = [j.wait_time for j in sr.batch.jobs if j.user in run.sla_users]
    other = [j.wait_time for j in sr.batch.jobs if j.user not in run.sla_users]
    if sla and other:
        assert max(sla) <= max(other) + 1e-6


def test_chunked_hop_feeds_arrivals_before_queued_events(helios_cluster):
    """Regression: a traffic gap larger than the rescan interval whose hopped
    window contains both an unfed arrival and a queued finish must process
    the arrival first (chunked service == upfront submission)."""
    from repro.core.types import Job

    def mk(i, submit, runtime):
        return Job(job_id=i, user=0, submit_time=submit, runtime=runtime,
                   est_runtime=runtime, num_gpus=2)

    # job0 finishes at t=5030; job1 arrives at t=5000 inside the same
    # 60s window reached by hopping over the [60, 4980] gap
    jobs = [mk(0, 0.0, 5030.0), mk(1, 5000.0, 100.0)]
    results = {}
    for chunked in (False, True):
        sr = run_stream(helios_cluster, [j.clone_pending() for j in jobs],
                        PolicyPrioritizer(make_policy("fcfs")),
                        rescan_interval=60.0, allocator="pack",
                        chunked_submit=chunked)
        results[chunked] = {j.job_id: (j.start_time, j.finish_time)
                            for j in sr.batch.jobs}
    assert results[False] == results[True]
    assert results[True][1][0] == pytest.approx(5000.0)  # starts on arrival


def test_chunked_scenario_service_equals_upfront():
    """diurnal has multi-window troughs: the chunked rescan driver must
    still equal upfront submission job-for-job."""
    sc = get_scenario("diurnal")
    run = sc.build(48, seed=7)
    fins = []
    for chunked in (False, True):
        sr = run_stream(run.spec, [j.clone_pending() for j in run.jobs],
                        PolicyPrioritizer(make_policy("fcfs")),
                        rescan_interval=60.0, allocator="pack",
                        chunked_submit=chunked)
        fins.append({j.job_id: j.finish_time for j in sr.batch.jobs})
    assert fins[0] == fins[1]


def test_naive_reference_matches_seed_golden():
    """The retained naive engine path (optimized=False, scalar scoring) is
    the seed implementation and must still hit the golden aggregates."""
    key = ("helios", 96, 0, "fcfs", "milp", True, False)
    trace, n, seed, policy, allocator, backfill, _ = key
    jobs = generate_trace(trace, n, seed=seed)
    sim = Simulator(make_cluster(trace), allocator=allocator,
                    backfill=backfill, optimized=False)
    r = sim.run_batch([j.clone_pending() for j in jobs],
                      PolicyPrioritizer(make_policy(policy), batch=False))
    got = (r.makespan, r.total_wait, r.gpu_seconds_used, r.decisions,
           r.milp_calls, r.backfills, r.restarts)
    assert got == SEED_GOLDENS[key]


def test_pending_queue_stays_sorted():
    """Indexed-queue invariant: `pending` is sorted by (submit_time, job_id)
    after every step, including requeues from faults."""
    jobs = generate_trace("philly", 64, seed=3)
    fm = FaultModel(mtbf_per_node=3 * 3600.0, repair_time=600.0, seed=1)
    e = _make_engine(make_cluster("philly"), allocator="pack", fault_model=fm)
    e.submit([j.clone_pending() for j in jobs])
    checked = 0
    while e._events:
        e.step(e.next_event_time())
        keys = [(j.submit_time, j.job_id) for j in e.pending]
        assert keys == sorted(keys)
        checked += 1
    assert checked > 0 and e.done


def test_finish_index_mirrors_running_set():
    """Finish-time-index invariant: after every step — through starts,
    finishes, fault kills, and straggler rescales — `_finish_index` holds
    exactly the running set's (finish_time, job_id) pairs, sorted."""
    jobs = generate_trace("philly", 64, seed=3)
    fm = FaultModel(mtbf_per_node=3 * 3600.0, repair_time=600.0,
                    straggler_prob=0.3, straggler_slowdown=0.4, seed=1)
    e = _make_engine(make_cluster("philly"), allocator="pack", fault_model=fm)
    e.submit([j.clone_pending() for j in jobs])
    checked = 0
    while e._events:
        e.step(e.next_event_time())
        expect = sorted((rec[3], jid) for jid, rec in e.running.items())
        assert e._finish_index == expect
        checked += 1
    assert checked > 0 and e.done


def test_guard_raises_runtime_error(helios_cluster):
    """The runaway guard must be a RuntimeError (asserts vanish under
    `python -O`)."""
    jobs = generate_trace("helios", 32, seed=4)
    e = _make_engine(helios_cluster, allocator="pack")
    e.submit([j.clone_pending() for j in jobs])
    e._guard_budget = 3
    with pytest.raises(RuntimeError, match="stuck"):
        e.drain()


def test_fault_storm_restarts():
    sr = run_scenario("fault-storm", num_jobs=32, seed=1,
                      rescan_interval=600.0, allocator="pack")
    assert len(sr.batch.jobs) == 32
    assert sr.batch.restarts > 0
    assert sr.telemetry.samples[-1].requeues >= 0


# ------------------------------------------------------------ trace replay ----


def test_trace_replay_tiles_and_is_deterministic():
    """trace-replay adapts CSV rows through repro.core.trace: truncation
    below the fixture size, tiling above it (copies time-shifted past the
    span), sequential re-ids, and seed-independence (a replay has no RNG)."""
    from repro.sched.scenarios import replay_trace_jobs, _DEFAULT_TRACE_CSV

    base = get_scenario("trace-replay").build(12, seed=0)
    again = get_scenario("trace-replay").build(12, seed=99)
    assert [j.submit_time for j in base.jobs] == \
        [j.submit_time for j in again.jobs]       # seed is ignored
    assert [j.job_id for j in base.jobs] == list(range(12))

    tiled = replay_trace_jobs(_DEFAULT_TRACE_CSV, 100)
    assert len(tiled) == 100
    ts = [j.submit_time for j in tiled]
    assert ts == sorted(ts)
    # the second copy repeats the first, shifted by one period
    assert tiled[48].runtime == tiled[0].runtime
    assert tiled[48].submit_time > tiled[47].submit_time


def test_trace_replay_env_override(tmp_path, monkeypatch):
    """REPRO_TRACE_CSV points the registered scenario at an external trace
    (the tests/ fixture here) without touching the registry."""
    import os
    from repro.sched.scenarios import TRACE_CSV_ENV

    fixture = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "data", "trace_replay.csv")
    monkeypatch.setenv(TRACE_CSV_ENV, fixture)
    run = get_scenario("trace-replay").build(24, seed=0)
    assert len(run.jobs) == 24
    assert all(j.gpu_type in ("P100", "any") for j in run.jobs)  # philly-ish
    sr = run_scenario(run, allocator="pack", rescan_interval=300.0)
    assert len(sr.batch.jobs) == 24
    assert all(j.state == JobState.COMPLETED for j in sr.batch.jobs)

    monkeypatch.setenv(TRACE_CSV_ENV, str(tmp_path / "missing.csv"))
    with pytest.raises(FileNotFoundError):
        get_scenario("trace-replay").build(8, seed=0)
