"""Tests for repro.chaos: correlated chaos schedules and injectors, the
engine's forced-fault entry points (rack bursts, spot reclamation), the
control-plane degradation ladder, federation blackouts with deferred-route
backoff, and the chaos-off bit-identity pin."""
import dataclasses
import json
import math
import os
import subprocess
import sys

import pytest
from conftest import REPO, SRC

from repro.chaos import (SPOT_RECLAMATION_COST, ChaosInjector, ChaosSchedule,
                         DegradationPolicy)
from repro.core import PolicyPrioritizer, make_policy
from repro.core.types import ClusterSpec, Job, NodeSpec
from repro.fed import (FederatedScheduler, FleetRun, get_fleet_scenario,
                       list_fleet_scenarios, run_fleet)
from repro.scale import PoolSpec
from repro.sched import (SchedulerEngine, get_scenario, list_scenarios,
                         run_scenario)
from repro.sched.engine import EngineSnapshot


def mk_job(i, gpus=1, gpu_type="any", submit=0.0, runtime=1000.0, **kw):
    return Job(job_id=i, user=0, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, gpu_type=gpu_type, **kw)


def two_node_engine(**kw):
    spec = ClusterSpec([NodeSpec(0, "P100", 8, 64, 512.0, 1.0),
                        NodeSpec(1, "V100", 8, 64, 512.0, 1.5)], name="duo")
    return SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                           allocator="pack", **kw)


def job_tuples(jobs):
    return sorted((j.job_id, j.start_time, j.finish_time, j.num_gpus,
                   j.restarts) for j in jobs)


# ---------------------------------------------------------------- schedule ----


def test_rack_burst_emits_matched_pair():
    sched = ChaosSchedule().add_rack_burst(100.0, [0, 1], 500.0, note="pdu")
    kinds = [e.kind for e in sched.events]
    assert kinds == ["fail", "recover"]
    assert sched.events[0].nodes == sched.events[1].nodes == (0, 1)
    assert sched.events[1].time == 600.0


def test_straggler_storm_and_blackout_pairs():
    sched = (ChaosSchedule()
             .add_straggler_storm(10.0, [3], 90.0, slowdown=0.25)
             .add_blackout(50.0, cluster=2, duration=25.0))
    kinds = [e.kind for e in sched.events]
    assert kinds == ["slow", "unslow", "blackout", "restore"]
    assert sched.events[0].slowdown == 0.25
    assert sched.events[3].time == 75.0 and sched.events[3].cluster == 2


def test_sorted_events_is_stable_on_time_ties():
    sched = (ChaosSchedule()
             .add_spot_wave(100.0, sku="P100", count=2, down_for=50.0)
             .add_spot_wave(100.0, sku="V100", count=1, down_for=50.0))
    order = [e.sku for _, _, e in sched.sorted_events()]
    assert order == ["P100", "V100"]          # insertion order breaks ties


def test_spot_waves_target_only_preemptible_pools():
    tmpl = NodeSpec(0, "T4", 2, 16, 128.0, 0.5)
    pools = {"T4": PoolSpec("T4", tmpl, 1, 5, preemptible=True),
             "A100": PoolSpec("A100", tmpl, 1, 4)}
    sched = ChaosSchedule().spot_waves_for_pools(
        pools, [100.0, 200.0], frac=0.5, down_for=300.0)
    assert [e.kind for e in sched.events] == ["reclaim", "reclaim"]
    assert all(e.sku == "T4" for e in sched.events)
    assert all(e.count == math.ceil(0.5 * 5) for e in sched.events)


def test_engine_injector_rejects_fleet_events():
    inj = ChaosInjector(ChaosSchedule().add_blackout(0.0, 0, 10.0))
    eng = two_node_engine()
    with pytest.raises(ValueError, match="FleetChaosInjector"):
        inj.control(eng, 0.0)


# ------------------------------------------------- engine chaos entry points ----


def test_rack_burst_kills_requeues_and_recovers():
    eng = two_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=50_000.0),
                mk_job(1, gpus=8, runtime=50_000.0)])
    eng.step(100.0)
    assert len(eng.running) == 2
    inj = ChaosInjector(ChaosSchedule().add_rack_burst(200.0, [0], 1000.0))
    acts = inj.control(eng, 200.0)
    assert [a.kind for a in acts] == ["fail"]
    assert acts[0].jobs_hit == 1
    assert eng.cluster.node_down[0] and not eng.cluster.node_down[1]
    assert eng.snapshot().nodes_down == 1
    eng.step(1200.0)
    acts = inj.control(eng, 1200.0)
    assert [a.kind for a in acts] == ["recover"]
    assert not eng.cluster.node_down[0]
    assert inj.next_time() == math.inf
    eng.drain()
    assert eng.done and len(eng.completed) == 2
    # the killed gang restarted at least once
    assert eng.restarts >= 1


def test_force_fail_is_idempotent_and_bounds_checked():
    eng = two_node_engine()
    assert eng.force_fail(0) == 0                  # nothing running: 0 hit
    assert eng.force_fail(0) == 0                  # already down: no-op
    assert eng.force_fail(99) == 0                 # out of range: no-op
    assert eng.force_recover(0) is True
    assert eng.force_recover(0) is False           # already up: no-op


def test_force_slow_rescales_and_unslow_restores():
    eng = two_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=10_000.0)])
    eng.step(0.0)
    assert eng.force_slow(0, 0.5)
    assert eng.slow_nodes.get(0) == 0.5
    assert eng.force_unslow(0)
    assert 0 not in eng.slow_nodes
    assert not eng.force_unslow(0)                 # not slowed: no-op
    eng.drain()
    assert eng.done


def test_reclaim_node_preempts_at_spot_cost():
    eng = two_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=50_000.0)])
    eng.step(100.0)
    hit = eng.reclaim_node(0, SPOT_RECLAMATION_COST)
    assert hit == 1
    assert eng.reclaimed_jobs == 1 and eng.preemptions == 1
    assert eng.cluster.node_down[0]
    # harsher economics: a real restore penalty was booked for the resume
    eng.force_recover(0)
    eng.reschedule(at=eng.now)
    eng.drain()
    assert eng.done and len(eng.completed) == 1
    assert eng.resume_penalty_gpu_s > 0.0


def test_spot_wave_resolves_sku_and_self_closes():
    eng = two_node_engine()
    eng.submit([mk_job(0, gpus=8, gpu_type="P100", runtime=50_000.0)])
    eng.step(50.0)
    inj = ChaosInjector(ChaosSchedule().add_spot_wave(
        100.0, sku="P100", count=1, down_for=400.0))
    acts = inj.control(eng, 100.0)
    assert acts[0].kind == "reclaim" and acts[0].nodes == (0,)
    assert acts[0].jobs_hit == 1
    # the paired recover was queued internally — the wave self-closes
    assert inj.next_time() == 500.0
    eng.step(500.0)
    inj.control(eng, 500.0)
    assert not eng.cluster.node_down[0]
    assert inj.action_counts() == {"reclaim": 1, "recover": 1}
    eng.drain()
    assert eng.done


# ------------------------------------------------------- degradation ladder ----


def test_zero_budget_trips_milp_fallbacks():
    run = get_scenario("steady").build(80, 0)
    deg = DegradationPolicy(milp_budget_s=0.0, trip_after=1,
                            reset_after_decisions=8)
    sr = run_scenario(run, allocator="milp", degradation=deg)
    assert len(sr.batch.jobs) == 80
    assert sr.engine.milp_fallbacks > 0
    assert sr.engine.snapshot().milp_fallback_ratio > 0.0


def test_zero_window_deadline_degrades_to_fcfs_windows():
    run = get_scenario("steady").build(80, 0)
    deg = DegradationPolicy(window_deadline_s=0.0, fcfs_windows=2)
    sr = run_scenario(run, allocator="pack", degradation=deg)
    assert len(sr.batch.jobs) == 80
    assert sr.engine.degraded_windows > 0
    assert sr.engine.degraded_s > 0.0
    assert 0.0 < sr.telemetry.degraded_fraction() <= 1.0


def test_generous_budget_never_degrades():
    run = get_scenario("steady").build(60, 0)
    deg = DegradationPolicy(milp_budget_s=1e9, window_deadline_s=1e9)
    sr = run_scenario(run, allocator="milp", degradation=deg)
    base = run_scenario(get_scenario("steady").build(60, 0),
                        allocator="milp")
    assert sr.engine.milp_fallbacks == 0
    assert sr.engine.degraded_windows == 0
    # an un-tripped ladder is pure observation: identical schedule
    assert job_tuples(sr.batch.jobs) == job_tuples(base.batch.jobs)


def test_snapshot_ratios_are_zero_division_safe():
    snap = EngineSnapshot(now=0.0, submitted=0, num_pending=0, num_running=0,
                          num_completed=0, free_gpus=0, utilization=0.0,
                          fragmentation=0.0, decisions=0, milp_calls=0,
                          backfills=0, restarts=0)
    assert snap.down_ratio == 0.0
    assert snap.milp_fallback_ratio == 0.0


# ---------------------------------------------------------------- scenarios ----


def test_chaos_storm_scenario_registered():
    assert "chaos-storm" in list_scenarios()
    run = get_scenario("chaos-storm").build(60, 0)
    assert run.chaos is not None and run.fault_model is not None
    kinds = [e.kind for e in run.chaos.events]
    assert kinds.count("fail") == kinds.count("recover") == 2
    assert kinds.count("slow") == kinds.count("unslow") == 1
    assert kinds.count("reclaim") == 2
    # determinism: same seed, same timeline and jobs
    again = get_scenario("chaos-storm").build(60, 0)
    assert [(e.time, e.kind) for e in again.chaos.events] == \
        [(e.time, e.kind) for e in run.chaos.events]
    assert [(j.job_id, j.submit_time) for j in again.jobs] == \
        [(j.job_id, j.submit_time) for j in run.jobs]


def test_chaos_storm_completes_and_closes_all_outages():
    sr = run_scenario("chaos-storm", num_jobs=150, seed=0, allocator="pack")
    assert len(sr.batch.jobs) == 150
    eng = sr.engine
    assert not (eng.cluster.node_down & ~eng.cluster.retired).any()
    assert eng.reclaimed_jobs >= 0 and eng.restarts > 0
    counts = {a.kind: True for a in sr.telemetry.chaos_events}
    assert "fail" in counts and "recover" in counts
    assert sr.telemetry.peak_nodes_down() >= 4        # a whole rack at once


def test_chaos_off_is_bit_identical_across_scenarios():
    """chaos=False must reproduce the plain chaos-free stream exactly on
    every registered scenario — the chaos plumbing is observational until
    a schedule is attached."""
    for name in list_scenarios():
        plain = run_scenario(
            dataclasses.replace(get_scenario(name).build(40, 0), chaos=None),
            allocator="pack")
        off = run_scenario(get_scenario(name).build(40, 0),
                           allocator="pack", chaos=False)
        assert job_tuples(off.batch.jobs) == job_tuples(plain.batch.jobs), name
        assert off.engine.decisions == plain.engine.decisions, name
        assert off.engine.backfills == plain.engine.backfills, name


# --------------------------------------------------------------- federation ----


def _duo_fleet():
    a100 = ClusterSpec([NodeSpec(i, "A100", 8, 96, 1024.0, 3.0)
                        for i in range(2)], name="a100")
    v100 = ClusterSpec([NodeSpec(i, "V100", 8, 64, 512.0, 1.5)
                        for i in range(2)], name="v100")
    return a100, v100


def test_blackout_member_masks_routing_and_restores():
    fed = FederatedScheduler(_duo_fleet(), router="jsq")
    downed = fed.blackout_member(0, at=0.0)
    assert downed == [0, 1] and fed.offline == {0}
    assert fed._routing_views()[0].info.total_gpus == 0
    # "any" jobs route around the dark member
    fed.submit([mk_job(i, gpus=4, submit=0.0, runtime=500.0)
                for i in range(4)])
    assert fed.engines[0].submitted == 0
    assert fed.engines[1].submitted == 4
    restored = fed.restore_member(0, at=10.0)
    assert restored == [0, 1] and not fed.offline
    fed.step()
    assert fed.done


def test_blackout_defers_sku_bound_jobs_until_restore():
    """Jobs only the dark member can serve park in the deferred heap and
    drain with backoff once the member returns."""
    a100, v100 = _duo_fleet()
    jobs = [mk_job(i, gpus=4, gpu_type="V100", submit=60.0 * i,
                   runtime=400.0) for i in range(4)]
    jobs += [mk_job(10 + i, gpus=8, gpu_type="A100", submit=2000.0 + 60.0 * i,
                    runtime=600.0) for i in range(3)]
    jobs.sort(key=lambda j: j.submit_time)
    run = FleetRun(name="duo-blackout", clusters=(a100, v100), jobs=jobs,
                   fault_models=(None, None),
                   chaos=ChaosSchedule().add_blackout(1000.0, cluster=0,
                                                      duration=6000.0))
    sr = run_fleet(run, router="jsq")
    fed = sr.fed
    assert fed.done and not fed._deferred
    assert fed.deferrals >= 3                 # every A100 job parked at least once
    assert len(sr.result.jobs) == len(jobs)
    assert {a.kind for a in fed.chaos_actions} == {"blackout", "restore"}
    # the A100 jobs landed on the restored member, not force-routed early
    assert sr.result.routed[0] >= 3
    for j in sr.result.jobs:
        if j.gpu_type == "A100":
            assert j.start_time >= 7000.0     # after the 1000+6000 restore


def test_fleet_blackout_scenario_registered_and_completes():
    assert "fleet-blackout" in list_fleet_scenarios()
    run = get_fleet_scenario("fleet-blackout").build(90, 0)
    assert run.chaos is not None
    sr = run_fleet(run, router="jsq", allocator="pack")
    assert sr.fed.done and len(sr.result.jobs) == 90
    counts = {}
    for a in sr.fed.chaos_actions:
        counts[a.kind] = counts.get(a.kind, 0) + 1
    assert counts == {"blackout": 1, "restore": 1}
    # all capacity back up at the end — the blackout closed
    for eng in sr.fed.engines:
        assert not (eng.cluster.node_down & ~eng.cluster.retired).any()


def test_fleet_chaos_dispatches_engine_events_to_members():
    run = get_fleet_scenario("fleet-steady").build(60, 0)
    sched = (ChaosSchedule()
             .add_rack_burst(600.0, [0, 1], 1800.0, cluster=1)
             .add_spot_wave(900.0, sku="P100", count=1, down_for=1200.0,
                            cluster=2))
    sr = run_fleet(dataclasses.replace(run, chaos=sched), router="jsq",
                   allocator="pack")
    assert sr.fed.done and len(sr.result.jobs) == 60
    kinds = {}
    for a in sr.fed.chaos_actions:
        kinds.setdefault(a.kind, []).append(a.cluster)
    assert kinds["fail"] == [1]
    assert kinds["reclaim"] == [2]
    # both the burst recover and the wave's self-closing recover fired
    assert sorted(kinds["recover"]) == [1, 2]
    for eng in sr.fed.engines:
        assert not (eng.cluster.node_down & ~eng.cluster.retired).any()


def test_fleet_chaos_off_is_bit_identical():
    run = get_fleet_scenario("fleet-blackout").build(60, 0)
    off = run_fleet(run, router="jsq", allocator="pack", chaos=False)
    plain = run_fleet(dataclasses.replace(run, chaos=None), router="jsq",
                      allocator="pack")
    assert job_tuples(off.result.jobs) == job_tuples(plain.result.jobs)
    assert off.result.routed == plain.result.routed
    assert off.fed.deferrals == plain.fed.deferrals == 0


# ------------------------------------------------------------------ tooling ----


def test_bench_chaos_smoke(tmp_path):
    """The registered chaos bench must run end-to-end in --smoke mode and
    emit a well-formed acceptance block (benches can't silently rot)."""
    json_path = tmp_path / "BENCH_chaos.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_BENCH_CHAOS_JOBS"] = "120"
    env["REPRO_BENCH_CHAOS_JSON"] = str(json_path)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_chaos", "--smoke"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    doc = json.loads(json_path.read_text())
    assert doc["bench"] == "chaos" and doc["num_jobs"] == 120
    assert doc["scale"] == "smoke"
    acc = doc["acceptance"]
    assert "wait_within_band" in acc and "ladder_fired" in acc
    assert acc["milp_fallbacks"] > 0
    for row in doc["results"].values():
        assert row["completed"] == 120
        for v in row.values():
            if isinstance(v, float):
                assert math.isfinite(v)


def test_bench_chaos_registered():
    import benchmarks.run as brun
    assert "chaos" in brun.MODULES


@pytest.mark.slow
def test_chaos_soak_storm_with_degradation():
    """Long chaos soak: the full storm at 600 jobs under the strict ladder
    still completes every job and closes every outage."""
    deg = DegradationPolicy(milp_budget_s=0.0, trip_after=1,
                            reset_after_decisions=16, window_deadline_s=0.0)
    sr = run_scenario("chaos-storm", num_jobs=600, seed=1, allocator="milp",
                      degradation=deg)
    assert len(sr.batch.jobs) == 600
    eng = sr.engine
    assert eng.milp_fallbacks > 0 and eng.degraded_windows > 0
    assert not (eng.cluster.node_down & ~eng.cluster.retired).any()
