import numpy as np
from conftest import hypothesis_or_stubs

given, settings, st = hypothesis_or_stubs()

from repro.core import ClusterState, Job, make_cluster


def mk_job(i, gpus, gpu_type="any"):
    return Job(job_id=i, user=0, submit_time=0.0, runtime=100.0,
               est_runtime=100.0, num_gpus=gpus, gpu_type=gpu_type)


def test_placement_modes():
    c = ClusterState(make_cluster("helios"))
    j = mk_job(0, 4)
    pack = c.find_placement(j, "pack")
    spread = c.find_placement(j, "spread")
    assert sum(pack.values()) == 4 and sum(spread.values()) == 4


def test_gang_across_nodes():
    c = ClusterState(make_cluster("helios"))
    j = mk_job(0, 20)  # > one node (8 GPUs)
    p = c.find_placement(j, "pack")
    assert p is not None and sum(p.values()) == 20 and len(p) >= 3


def test_type_constraint():
    c = ClusterState(make_cluster("helios"))
    j = mk_job(0, 8, gpu_type="V100")
    p = c.find_placement(j, "pack")
    assert all(c.gpu_types[i] == "V100" for i in p)


def test_fragmentation_bounds():
    c = ClusterState(make_cluster("helios"))
    f0 = c.fragmentation()
    assert 0.0 <= f0 <= 1.0
    # drain almost everything from one node -> fragmentation changes
    j = mk_job(0, 7)
    c.allocate(j, {0: 7})
    assert 0.0 <= c.fragmentation() <= 1.0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=8), min_size=1,
                max_size=20), st.randoms(use_true_random=False))
def test_alloc_release_invariants(sizes, rnd):
    """No oversubscription ever; full release restores the initial state."""
    c = ClusterState(make_cluster("helios"))
    total0 = c.free_gpus.copy()
    cpus0 = c.free_cpus.copy()
    mem0 = c.free_mem.copy()
    live = []
    for i, g in enumerate(sizes):
        j = mk_job(i, g)
        p = c.find_placement(j, "pack" if rnd.random() < 0.5 else "spread")
        if p is None:
            continue
        c.allocate(j, p)
        live.append((j, p))
        assert (c.free_gpus >= 0).all()
        assert (c.free_cpus >= 0).all()
        assert (c.free_mem >= -1e-6).all()
    for j, p in live:
        c.release(j, p)
    np.testing.assert_array_equal(c.free_gpus, total0)
    np.testing.assert_array_equal(c.free_cpus, cpus0)
    np.testing.assert_allclose(c.free_mem, mem0, atol=1e-6)


def test_failure_excludes_node():
    c = ClusterState(make_cluster("helios"))
    c.fail_node(0)
    j = mk_job(0, 8)
    p = c.find_placement(j, "pack")
    assert p is not None and 0 not in p
    c.recover_node(0)
    assert not c.node_down.any()


def test_num_ways():
    c = ClusterState(make_cluster("helios"))
    assert c.num_ways_to_schedule(mk_job(0, 4)) >= 1
    big = mk_job(1, 10_000)
    assert c.num_ways_to_schedule(big) == 0
    assert not c.can_schedule_now(big)


def test_ratios_finite_on_degenerate_clusters():
    """Bugfix pin: utilization/fragmentation never divide by vanished
    capacity — all-nodes-failed and empty clusters read finite ratios."""
    import math

    from repro.core.types import ClusterSpec

    c = ClusterState(make_cluster("helios"))
    for node in range(len(c.spec.nodes)):
        c.fail_node(node)
    for up_only in (False, True):
        assert math.isfinite(c.utilization(up_only=up_only))
        assert math.isfinite(c.fragmentation(up_only=up_only))
    # up-only views ignore free GPUs stranded on down nodes entirely
    assert c.utilization(up_only=True) == 0.0
    assert c.fragmentation(up_only=True) == 0.0
    assert c.free_gpu_tallies()[0] == 0

    empty = ClusterState(ClusterSpec(nodes=[], name="empty"))
    assert empty.utilization() == 0.0 == empty.utilization(up_only=True)
    assert empty.fragmentation() == 0.0 == empty.fragmentation(up_only=True)
