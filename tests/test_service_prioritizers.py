"""Coverage for the tenancy prioritizer wrappers in repro.sched.service:
the SLA bypass lane (previously untested) and the incremental VC-quota gate
(differential-pinned against its O(running) recompute reference)."""
import pytest

from repro.core import PolicyPrioritizer, make_cluster, make_policy
from repro.core.types import Job
from repro.sched import (EngineHooks, QuotaPrioritizer, SlaLanePrioritizer,
                         get_scenario, run_stream, wrap_tenancy)


def _job(jid, *, user=0, vc=0, submit=0.0, runtime=100.0, gpus=1):
    return Job(job_id=jid, user=user, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, vc=vc)


@pytest.fixture()
def cluster_state():
    from repro.core.cluster import ClusterState
    return ClusterState(make_cluster("helios"))


# ---------------------------------------------------------------- SLA lane ----


def test_sla_jobs_bypass_to_front(cluster_state):
    """SLA-bound users' jobs rank before every best-effort job, regardless
    of what the base policy would prefer."""
    jobs = [
        _job(0, user=1, submit=0.0, runtime=10.0),     # best-effort, tiny
        _job(1, user=9, submit=50.0, runtime=9000.0),  # SLA, huge
        _job(2, user=2, submit=10.0, runtime=20.0),    # best-effort
        _job(3, user=9, submit=5.0, runtime=8000.0),   # SLA
    ]
    pri = SlaLanePrioritizer(PolicyPrioritizer(make_policy("sjf")),
                             frozenset({9}))
    order = pri.rank(jobs, cluster_state, now=100.0)
    assert order[:2] == [3, 1]          # SLA first...
    assert set(order[2:]) == {0, 2}     # ...then everyone else


def test_sla_jobs_fcfs_among_themselves(cluster_state):
    """Inside the SLA lane, ordering is FCFS by (submit_time, job_id) even
    when the base policy (SJF) would invert it."""
    jobs = [
        _job(0, user=5, submit=30.0, runtime=1.0),    # SLA, latest, shortest
        _job(1, user=5, submit=10.0, runtime=500.0),  # SLA, earliest, longest
        _job(2, user=5, submit=20.0, runtime=50.0),   # SLA, middle
    ]
    pri = SlaLanePrioritizer(PolicyPrioritizer(make_policy("sjf")),
                             frozenset({5}))
    assert pri.rank(jobs, cluster_state, now=40.0) == [1, 2, 0]


def test_sla_lane_preserves_base_order_for_best_effort(cluster_state):
    """Best-effort jobs keep exactly the base prioritizer's relative order
    behind the SLA lane."""
    jobs = [
        _job(0, user=1, runtime=300.0),
        _job(1, user=7, runtime=5.0),      # SLA
        _job(2, user=2, runtime=10.0),
        _job(3, user=3, runtime=100.0),
    ]
    base = PolicyPrioritizer(make_policy("sjf"))
    pri = SlaLanePrioritizer(base, frozenset({7}))
    order = pri.rank(jobs, cluster_state, now=0.0)
    rest = [jobs[i] for i in order if jobs[i].user != 7]
    base_rest = [j for j in jobs if j.user != 7]
    base_order = base.rank(base_rest, cluster_state, now=0.0)
    assert rest == [base_rest[i] for i in base_order]   # SJF: 2, 3, 0
    assert [j.job_id for j in rest] == [2, 3, 0]


def test_sla_lane_no_sla_users_is_transparent(cluster_state):
    jobs = [_job(0, runtime=300.0), _job(1, runtime=5.0)]
    base = PolicyPrioritizer(make_policy("sjf"))
    pri = SlaLanePrioritizer(base, frozenset())
    assert pri.rank(jobs, cluster_state, 0.0) == \
        base.rank(jobs, cluster_state, 0.0)
    assert pri.use_estimates == base.use_estimates


# -------------------------------------------------------------- quota gate ----


def test_quota_demotes_over_quota_vcs(cluster_state):
    """Jobs from a VC whose hook-fed usage exceeds its quota are demoted
    behind every under-quota job."""
    pri = QuotaPrioritizer(PolicyPrioritizer(make_policy("fcfs")),
                           {0: 0.10, 1: 0.90})
    # simulate engine hooks: VC 0 holds 200 of 400 GPUs (over a 10% quota)
    pri.on_start(_job(90, vc=0, gpus=200), now=0.0)
    jobs = [_job(0, vc=0, submit=0.0), _job(1, vc=1, submit=1.0),
            _job(2, vc=0, submit=2.0), _job(3, vc=1, submit=3.0)]
    assert pri.rank(jobs, cluster_state, 10.0) == [1, 3, 0, 2]
    # once the hog finishes, FCFS order is restored
    pri.on_finish(_job(90, vc=0, gpus=200), now=5.0)
    assert pri.rank(jobs, cluster_state, 10.0) == [0, 1, 2, 3]


def test_quota_usage_tracks_start_finish_requeue():
    pri = QuotaPrioritizer(PolicyPrioritizer(make_policy("fcfs")), {0: 0.5})
    a, b = _job(0, vc=2, gpus=8), _job(1, vc=2, gpus=4)
    pri.on_start(a, 0.0)
    pri.on_start(b, 0.0)
    assert pri._usage == {2: 12}
    pri.on_requeue(a, 1.0)      # fault kill re-queues: usage drops
    assert pri._usage == {2: 4}
    pri.on_finish(b, 2.0)
    assert pri._usage == {}     # empty VCs are dropped, not left at 0
    pri.reset_usage()
    assert pri._usage == {}


class _UsageAuditor(EngineHooks):
    """Asserts, at every engine tick, that the hook-fed incremental usage
    equals a fresh O(running) recompute from the engine's running set."""

    def __init__(self, pri):
        self.pri = pri
        self.checked = 0

    def on_tick(self, now, engine):
        expect = {}
        for job, *_ in engine.running.values():
            expect[job.vc] = expect.get(job.vc, 0) + job.num_gpus
        assert self.pri._usage == expect
        self.checked += 1


def test_quota_incremental_matches_recompute_every_tick():
    """The incremental usage dict equals the O(running) recompute after
    every processed event batch, including fault-driven requeues."""
    run = get_scenario("fault-storm").build(64, seed=2)
    pri = QuotaPrioritizer(PolicyPrioritizer(make_policy("fcfs")),
                           {0: 0.25, 1: 0.25, 2: 0.25, 3: 0.25})
    auditor = _UsageAuditor(pri)
    run_stream(run.spec, [j.clone_pending() for j in run.jobs], pri,
               allocator="pack", fault_model=run.fault_model,
               hooks=(auditor,))
    assert auditor.checked > 0


@pytest.mark.parametrize("scenario", ["multi-tenant", "fault-storm"])
def test_quota_incremental_differential(scenario):
    """Equivalence pin (ROADMAP perf round-2 item c): the incremental gate
    schedules bit-identically to the O(running)-per-rank recompute path."""
    run = get_scenario(scenario).build(120, seed=9)
    quotas = run.vc_quotas or {0: 0.25, 1: 0.25, 2: 0.25, 3: 0.25}
    outs = []
    for incremental in (True, False):
        pri = QuotaPrioritizer(PolicyPrioritizer(make_policy("fcfs")),
                               quotas, incremental=incremental)
        sr = run_stream(run.spec, [j.clone_pending() for j in run.jobs],
                        pri, allocator="pack", fault_model=run.fault_model,
                        chunked_submit=True)
        outs.append({j.job_id: (j.start_time, j.finish_time, j.restarts)
                     for j in sr.batch.jobs})
    assert outs[0] == outs[1]


def test_wrap_tenancy_composition():
    base = PolicyPrioritizer(make_policy("fcfs"))
    assert wrap_tenancy(base) is base
    sla = wrap_tenancy(base, frozenset({1}))
    assert isinstance(sla, SlaLanePrioritizer)
    both = wrap_tenancy(base, frozenset({1}), {0: 0.5})
    assert isinstance(both, QuotaPrioritizer)
    assert isinstance(both.base, SlaLanePrioritizer)
    assert isinstance(wrap_tenancy(base, vc_quotas={0: 0.5},
                                   enforce_quotas=False),
                      PolicyPrioritizer)
