import numpy as np
import pytest

from repro.core import BASE_POLICIES, Job, make_policy


def mk(i, submit, runtime, gpus, user=0):
    return Job(job_id=i, user=user, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus)


def test_fcfs_orders_by_submit():
    p = make_policy("fcfs")
    a, b = mk(0, 10, 100, 1), mk(1, 5, 100, 1)
    assert p.score(b, 20) < p.score(a, 20)


def test_sjf_prefers_short():
    p = make_policy("sjf")
    assert p.score(mk(0, 0, 50, 1), 0) < p.score(mk(1, 0, 500, 1), 0)


def test_wfp3_prefers_long_waiters():
    p = make_policy("wfp3")
    waited = mk(0, 0, 100, 2)
    fresh = mk(1, 990, 100, 2)
    assert p.score(waited, 1000) < p.score(fresh, 1000)


def test_unicep_penalizes_size():
    p = make_policy("unicep")
    small = mk(0, 0, 100, 2)
    big = mk(1, 0, 100, 32)
    assert p.score(small, 500) < p.score(big, 500)


def test_f1_uses_logs():
    p = make_policy("f1")
    s = p.score(mk(0, 100, 100, 4), 200)
    assert np.isfinite(s)


def test_qssf_learns_history():
    p = make_policy("qssf")
    j = mk(0, 0, 5000, 2, user=7)
    cold = p.score(j, 0)
    done = mk(1, 0, 10.0, 1, user=7)
    done.start_time, done.finish_time = 0.0, 10.0
    p.observe_finish(done)
    warm = p.score(j, 0)
    assert warm < cold  # history says user 7 runs short jobs


def test_slurm_multifactor_fairshare():
    p = make_policy("slurm-mf")
    heavy, light = 1, 2
    done = mk(9, 0, 1e6, 8, user=heavy)
    p.observe_finish(done)
    s_heavy = p.score(mk(0, 0, 100, 1, user=heavy), 10)
    s_light = p.score(mk(1, 0, 100, 1, user=light), 10)
    assert s_light < s_heavy  # light user gets priority


def test_registry_all():
    for name in BASE_POLICIES:
        p = make_policy(name)
        assert np.isfinite(p.score(mk(0, 1, 100, 2), 50))


def test_estimates_mode():
    p = make_policy("sjf", use_estimates=True)
    j = mk(0, 0, 100, 1)
    j.est_runtime = 10_000.0
    assert p.score(j, 0) == 10_000.0


def _random_jobs(seed, n=256):
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n):
        j = Job(job_id=i, user=int(rng.integers(0, 9)),
                submit_time=float(rng.uniform(0, 1e6)),
                runtime=float(rng.lognormal(6, 2)) + 1.0,
                est_runtime=float(rng.lognormal(6, 2)) + 1.0,
                num_gpus=int(rng.integers(1, 65)),
                vc=int(rng.integers(0, 8)))
        jobs.append(j)
    return jobs


@pytest.mark.parametrize("name", BASE_POLICIES)
@pytest.mark.parametrize("use_estimates", [False, True])
def test_score_batch_bit_identical(name, use_estimates):
    """score_batch must equal the scalar score loop BITWISE — numpy
    transcendentals differ from math.* by ulps on SIMD builds, and a 1-ulp
    score difference can flip an argsort and change the schedule."""
    p = make_policy(name, use_estimates=use_estimates)
    # give stateful policies (qssf, slurm-mf) some history first
    for k in range(12):
        p.observe_finish(mk(1000 + k, 0, float(10 ** (k % 5 + 1)), k % 4 + 1,
                            user=k % 5))
    for seed, now in ((0, 0.0), (1, 3600.0), (2, 2.5e6)):
        jobs = _random_jobs(seed)
        batch = p.score_batch(jobs, now)
        scalar = np.asarray([p.score(j, now) for j in jobs])
        assert batch.dtype == np.float64
        np.testing.assert_array_equal(
            batch, scalar,
            err_msg=f"{name} score_batch diverges from scalar score")


def test_score_batch_empty_window():
    for name in BASE_POLICIES:
        out = make_policy(name).score_batch([], 0.0)
        assert len(out) == 0


@pytest.mark.parametrize("name", BASE_POLICIES)
def test_score_batch_fields_path_identical(name):
    """The engine-maintained contiguous-field path must score exactly like
    the attribute-gathering path (and hence like the scalar loop)."""
    from repro.core.prioritizer import WindowFields
    p = make_policy(name)
    for k in range(8):
        p.observe_finish(mk(500 + k, 0, 50.0 * (k + 1), k % 3 + 1, user=k % 4))
    jobs = _random_jobs(3)
    fields = WindowFields.from_jobs(jobs)
    for now in (0.0, 7e5):
        np.testing.assert_array_equal(p.score_batch(jobs, now, fields),
                                      p.score_batch(jobs, now))
