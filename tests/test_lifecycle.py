"""Tests for repro.lifecycle + the engine/federation plumbing underneath it:
the enforced job state machine, checkpoint-restore preemption (penalty
accounting pinned against the ckpt-floor math), pause/resume, elastic
resize, SLO-lane deadline eviction, cross-cluster migration, the
preemption-off / migration-off bit-identity pins, and the fault-kill
requeue path staying hook-for-hook unchanged."""
import json
import math
import os
import subprocess
import sys

import pytest
from conftest import REPO, SRC

from repro.core import PolicyPrioritizer, make_cluster, make_policy
from repro.core.types import ClusterSpec, Job, JobState, NodeSpec
from repro.fed import run_fleet
from repro.lifecycle import (LEGAL_TRANSITIONS, CkptCostModel,
                             ElasticGangPolicy, IllegalTransition,
                             PreemptionController, QueueImbalanceMigration,
                             SloDeadlinePolicy, check, transition)
from repro.sched import (EngineHooks, SchedulerEngine, get_scenario,
                         list_scenarios, run_scenario)


def mk_job(i, gpus=1, gpu_type="any", submit=0.0, runtime=1000.0, **kw):
    return Job(job_id=i, user=0, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, gpu_type=gpu_type, **kw)


def one_node_engine(gpus=8, speed=1.0, hooks=()):
    spec = ClusterSpec([NodeSpec(0, "P100", gpus, 4 * gpus * 4,
                                 32.0 * gpus * 4, speed)], name="uni")
    return SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                           allocator="pack", hooks=hooks)


class Recorder(EngineHooks):
    """Ordered log of every lifecycle-relevant hook firing."""

    def __init__(self):
        self.log = []

    def on_start(self, job, now):
        self.log.append(("start", job.job_id, now))

    def on_requeue(self, job, now):
        self.log.append(("requeue", job.job_id, now))

    def on_preempt(self, job, now, penalty_s):
        self.log.append(("preempt", job.job_id, now, penalty_s))

    def on_resume(self, job, now):
        self.log.append(("resume", job.job_id, now))

    def of(self, kind):
        return [e for e in self.log if e[0] == kind]


# ------------------------------------------------------------ state machine ----


def test_transition_map_is_exhaustively_enforced():
    """Every (src, dst) pair either transitions or raises — no silent
    assignment path survives outside the map."""
    for src in JobState:
        for dst in JobState:
            j = mk_job(0)
            j.state = src
            if dst in LEGAL_TRANSITIONS[src]:
                check(src, dst)
                assert transition(j, dst) is j and j.state is dst
            else:
                with pytest.raises(IllegalTransition):
                    check(src, dst)
                with pytest.raises(IllegalTransition):
                    transition(j, dst)
                assert j.state is src               # unchanged on refusal


def test_terminal_states_have_no_exits():
    assert LEGAL_TRANSITIONS[JobState.COMPLETED] == frozenset()
    assert LEGAL_TRANSITIONS[JobState.FAILED] == frozenset()
    with pytest.raises(IllegalTransition, match="COMPLETED"):
        check(JobState.COMPLETED, JobState.PENDING)


def test_illegal_transition_message_lists_legal_targets():
    with pytest.raises(IllegalTransition, match="RUNNING"):
        check(JobState.PENDING, JobState.PAUSED)


# ------------------------------------------------ wait_time / jct satellites ----


def test_wait_time_and_jct_raise_informatively_before_start():
    j = mk_job(3, submit=50.0)
    with pytest.raises(RuntimeError, match="job 3 never started"):
        _ = j.wait_time
    with pytest.raises(RuntimeError, match="job 3 never finished"):
        _ = j.jct
    with pytest.raises(RuntimeError, match="never finished"):
        j.bsld()


def test_first_start_time_survives_preempt_restart():
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=10_000.0)])
    eng.step(0.0)
    eng.step(500.0)
    eng.preempt_job(0)
    job = eng.pending[0]
    assert job.first_start_time == 0.0
    eng.reschedule(at=500.0)                       # immediate restart
    assert job.start_time == 0.0 and job.first_start_time == 0.0
    assert job.wait_time == 0.0                    # not reset by the requeue
    eng.drain()
    assert job.state is JobState.COMPLETED


# ----------------------------------------------- preempt / resume + penalty ----


def test_preempt_resume_penalty_matches_ckpt_floor_math():
    """The acceptance pin: surviving progress floors to the ckpt grid,
    ``progress_at_ckpt`` reflects the floored work *before* the resume
    penalty, and the penalty lands in both remaining work and the
    GPU-second overhead counter."""
    rec = Recorder()
    eng = one_node_engine(hooks=(rec,))
    cost = CkptCostModel(ckpt_interval=1800.0, restore_s=120.0,
                         per_gpu_restore_s=2.0)
    eng.submit([mk_job(0, gpus=4, runtime=10_000.0)])
    eng.step(0.0)
    eng.advance_to(5000.0)
    eng.preempt_job(0, cost)

    # elapsed 5000s at speed 1.0 -> 2 whole 1800s intervals survive
    floored = 2 * 1800.0 * 1.0
    left = 10_000.0 - floored
    penalty = 120.0 + 2.0 * 4
    job = eng.pending[0]
    assert job.state is JobState.PENDING and job.restarts == 1
    assert job.progress_at_ckpt == pytest.approx(floored / 10_000.0)
    assert eng.remaining[0] == pytest.approx(left + penalty)
    assert eng.resume_penalty_gpu_s == pytest.approx(penalty * 4)
    assert eng.preemptions == 1
    assert eng.snapshot().preemptions == 1
    # hook order: preempt (with the charged penalty) before requeue
    assert rec.log[-2:] == [("preempt", 0, 5000.0, penalty),
                            ("requeue", 0, 5000.0)]

    eng.reschedule(at=5000.0)
    assert rec.log[-2:] == [("start", 0, 5000.0), ("resume", 0, 5000.0)]
    eng.drain()
    assert job.finish_time == pytest.approx(5000.0 + left + penalty)


def test_preempt_without_cost_model_is_penalty_free():
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=2, runtime=4000.0)])
    eng.step(0.0)
    eng.advance_to(1000.0)
    eng.preempt_job(0)                             # no injector: no floor
    assert eng.remaining[0] == pytest.approx(3000.0)
    assert eng.resume_penalty_gpu_s == 0.0
    with pytest.raises(KeyError, match="not running"):
        eng.preempt_job(0)                         # already evicted


def test_pause_holds_job_outside_queue_until_resume():
    rec = Recorder()
    eng = one_node_engine(hooks=(rec,))
    eng.submit([mk_job(0, gpus=8, runtime=6000.0)])
    eng.step(0.0)
    eng.advance_to(1000.0)
    eng.pause_job(0)
    job = eng.paused[0]
    assert job.state is JobState.PAUSED
    assert eng.snapshot().paused == 1 and not eng.pending
    assert not rec.of("preempt")                   # pause is not a preemption
    eng.reschedule(at=2000.0)
    assert not eng.running                         # paused work is invisible
    eng.resume_job(0)
    eng.reschedule(at=2000.0)
    assert rec.of("resume") == [("resume", 0, 2000.0)]
    eng.drain()
    assert job.state is JobState.COMPLETED
    assert job.finish_time == pytest.approx(2000.0 + 5000.0)
    with pytest.raises(KeyError, match="not paused"):
        eng.resume_job(0)


# ------------------------------------------------------------ elastic resize ----


def test_resize_scales_speed_with_gang_size():
    rec = Recorder()
    eng = one_node_engine(hooks=(rec,))
    eng.submit([mk_job(0, gpus=4, runtime=8000.0, min_gpus=2, max_gpus=8)])
    eng.step(0.0)
    eng.advance_to(2000.0)
    assert eng.resize_job(0, 2) is True
    job, _, st, fin, speed = eng.running[0]
    assert job.num_gpus == 2 and job.base_gpus == 4
    assert job.req_cpus == 8 and job.req_mem_gb == 64.0
    assert speed == pytest.approx(0.5)             # half the gang, half rate
    assert st == 2000.0 and fin == pytest.approx(2000.0 + 6000.0 / 0.5)
    assert rec.of("preempt") and rec.of("resume")  # resize is ckpt-restart
    assert eng.preemptions == 1
    eng.drain()
    assert job.finish_time == pytest.approx(14_000.0)


def test_resize_reverts_when_target_size_cannot_fit():
    eng = one_node_engine(gpus=8)
    eng.submit([mk_job(0, gpus=4, runtime=9000.0, min_gpus=2, max_gpus=8),
                mk_job(1, gpus=4, runtime=9000.0)])
    eng.step(0.0)
    assert len(eng.running) == 2
    assert eng.resize_job(0, 8) is False           # only 4 GPUs reachable
    job = eng.running[0][0]
    assert job.num_gpus == 4 and job.state is JobState.RUNNING
    eng.drain()
    assert all(j.state is JobState.COMPLETED
               for j in (job, eng.running.get(1, [None])[0]) if j)


def test_resize_refuses_non_elastic_and_noop_targets():
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=4, runtime=5000.0)])
    eng.step(0.0)
    assert eng.resize_job(0, 8) is False           # not elastic: untouched
    assert eng.running[0][0].num_gpus == 4 and eng.preemptions == 0
    eng2 = one_node_engine()
    eng2.submit([mk_job(0, gpus=4, runtime=5000.0, min_gpus=4, max_gpus=8)])
    eng2.step(0.0)
    assert eng2.resize_job(0, 2) is False          # clamps to min == current
    assert eng2.preemptions == 0


# ------------------------------------------------------ SLO deadline policy ----


def test_slo_policy_evicts_best_effort_for_deadline_job():
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=50_000.0),
                mk_job(1, gpus=8, runtime=1000.0, submit=100.0,
                       deadline=2000.0)])
    eng.step(600.0)
    assert 0 in eng.running and eng.pending        # 1 starved behind 0
    ctl = PreemptionController([SloDeadlinePolicy()])
    ctl.control(eng, 600.0)
    kinds = [e.action for e in ctl.events]
    assert kinds == ["preempt", "deadline-start"]
    assert ctl.events[0].job_id == 0 and ctl.events[1].job_id == 1
    assert ctl.events[0].penalty_s > 0.0           # charged, not free
    assert 1 in eng.running                        # deadline job on GPUs now
    assert eng.running[1][0].state is JobState.RUNNING
    eng.drain()
    jobs = {j.job_id: j for j in eng.completed}
    assert jobs[1].finish_time <= 2000.0           # deadline made
    assert jobs[0].restarts == 1
    assert ctl.event_counts() == {"preempt": 1, "deadline-start": 1}


def test_slo_policy_starts_second_urgent_job_on_freed_capacity():
    """One eviction frees more than the first deadline job needs: the
    second urgent job takes the free-capacity fast path (no extra
    victim), and the controller advances the clock to the window edge."""
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=50_000.0),
                mk_job(1, gpus=4, runtime=1000.0, submit=50.0,
                       deadline=2000.0),
                mk_job(2, gpus=4, runtime=1000.0, submit=60.0,
                       deadline=2100.0)])
    eng.step(600.0)
    ctl = PreemptionController([SloDeadlinePolicy()])
    ctl.control(eng, 600.0)
    assert eng.now == 600.0
    assert [e.action for e in ctl.events] == \
        ["preempt", "deadline-start", "deadline-start"]
    assert "free capacity" in ctl.events[2].reason
    assert eng.preemptions == 1 and {1, 2} <= set(eng.running)


def test_elastic_policy_shrinks_under_backlog_and_grows_when_idle():
    eng = one_node_engine(gpus=8)
    eng.submit([mk_job(0, gpus=8, runtime=40_000.0, min_gpus=2, max_gpus=8),
                mk_job(1, gpus=4, runtime=1000.0, submit=10.0)])
    eng.step(60.0)
    pol = ElasticGangPolicy()
    ev = pol.tick(eng, 60.0, CkptCostModel())
    assert [e.action for e in ev] == ["shrink"]
    assert eng.running[0][0].num_gpus == 4         # 8 -> max(2, 8//2)
    eng.reschedule(at=60.0)
    assert 1 in eng.running                        # backlog admitted
    eng.step(20_000.0)                             # small job long gone
    ev2 = pol.tick(eng, 20_000.0, CkptCostModel())
    assert [e.action for e in ev2] == ["grow"]
    assert eng.running[0][0].num_gpus == 8
    eng.drain()
    assert eng.done


# ----------------------------------------------------------------- migration ----


def test_withdraw_admit_preserves_progress_across_clusters():
    rec = Recorder()
    src = one_node_engine()
    dst = one_node_engine(hooks=(rec,))
    src.submit([mk_job(0, gpus=4, runtime=10_000.0)])
    src.step(0.0)
    src.advance_to(2000.0)
    src.pause_job(0)                               # 8000s of work left
    job, remaining = src.withdraw_pending(0)
    assert job.state is JobState.MIGRATING
    assert remaining == pytest.approx(8000.0)
    assert src.submitted == 0 and 0 not in src.remaining

    dst.advance_to(2000.0)                         # fleet clocks in lockstep
    dst.admit_migrated(job, remaining)
    assert job.state is JobState.PENDING
    dst.step(2000.0)
    assert 0 in dst.running
    assert rec.of("resume")                        # restored, not fresh
    dst.drain()
    assert job.state is JobState.COMPLETED
    assert job.finish_time == pytest.approx(2000.0 + 8000.0)


def test_withdraw_pending_takes_queued_jobs_too():
    eng = one_node_engine()
    eng.submit([mk_job(0, gpus=8, runtime=9000.0),
                mk_job(1, gpus=8, runtime=9000.0)])
    eng.step(0.0)
    job, remaining = eng.withdraw_pending(1)       # still queued, never ran
    assert job.state is JobState.MIGRATING
    assert remaining == pytest.approx(9000.0)
    assert not eng.pending
    with pytest.raises(KeyError, match="neither pending nor paused"):
        eng.withdraw_pending(1)


def test_fleet_migration_drains_queue_behind_fault_storm():
    mig = QueueImbalanceMigration(min_advantage=2, max_moves_per_window=8)
    sr = run_fleet("fleet-fault-migration", 90, seed=1, router="jsq",
                   allocator="pack", rescan_interval=300.0, migration=mig)
    assert len(sr.result.jobs) == 90               # nothing lost in transit
    assert sr.fed.migrations                       # the storm forced moves
    for mv in sr.fed.migrations:
        assert mv.src != mv.dst
    # routing tables track the final home of each migrated job
    last = {}
    for mv in sr.fed.migrations:
        last[mv.job_id] = mv.dst
    for jid, dst in last.items():
        assert sr.fed.routes[jid] == dst
    # telemetry on both sides saw every move
    tin = sum(t.migrations_in for t in sr.telemetries)
    tout = sum(t.migrations_out for t in sr.telemetries)
    assert tin == tout == len(sr.fed.migrations)


def test_migration_off_fleet_bit_identical():
    """A migration policy that can never clear its hysteresis threshold
    must be unobservable — same pin idiom as the frozen autoscaler."""
    base = run_fleet("fleet-fault-storm", 48, seed=5, router="jsq",
                     allocator="pack", rescan_interval=300.0)
    inert = run_fleet("fleet-fault-storm", 48, seed=5, router="jsq",
                      allocator="pack", rescan_interval=300.0,
                      migration=QueueImbalanceMigration(
                          min_advantage=10 ** 9))
    a = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in base.result.jobs}
    b = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in inert.result.jobs}
    assert a == b
    assert not inert.fed.migrations


# ---------------------------------------------- disabled == bit-identical ----


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_disabled_preemption_bit_identical(name):
    """Acceptance pin: an attached controller with no policies (and the
    ``preemption=...`` service plumbing) must be bit-identical to
    ``preemption=None`` on every registered scenario."""
    base = run_scenario(get_scenario(name).build(64, seed=5),
                        allocator="pack", rescan_interval=300.0)
    inert = run_scenario(get_scenario(name).build(64, seed=5),
                         allocator="pack", rescan_interval=300.0,
                         preemption=PreemptionController(policies=[]))
    a = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in base.batch.jobs}
    b = {j.job_id: (j.start_time, j.finish_time, j.restarts)
         for j in inert.batch.jobs}
    assert a == b
    assert base.batch.decisions == inert.batch.decisions
    assert base.batch.backfills == inert.batch.backfills


def test_fault_kill_requeue_path_unchanged():
    """Fault evictions ride the same _kill_job core but must stay exactly
    what they were: on_requeue fires, on_preempt does NOT, and the
    preemption counters stay untouched."""
    run = get_scenario("fault-storm").build(48, seed=3)
    rec = Recorder()
    eng = SchedulerEngine(run.spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="pack", fault_model=run.fault_model,
                          hooks=(rec,))
    eng.submit([j.clone_pending() for j in run.jobs])
    eng.drain()
    assert len(eng.completed) == 48
    assert rec.of("requeue")                       # the storm did evict
    assert not rec.of("preempt") and not rec.of("resume")
    assert eng.preemptions == 0
    assert eng.resume_penalty_gpu_s == 0.0


# ------------------------------------------------------- stream integration ----


def test_slo_lanes_stream_with_full_controller():
    """slo-lanes end-to-end through run_scenario: the controller acts, all
    jobs still complete, and the engine/telemetry preemption counters
    agree with each other."""
    off = run_scenario("slo-lanes", num_jobs=120, seed=0, allocator="pack",
                       rescan_interval=60.0)
    ctl = PreemptionController([SloDeadlinePolicy(), ElasticGangPolicy()])
    on = run_scenario("slo-lanes", num_jobs=120, seed=0, allocator="pack",
                      rescan_interval=60.0, preemption=ctl)
    assert len(off.batch.jobs) == len(on.batch.jobs) == 120
    assert ctl.events and on.engine.preemptions > 0
    tel = on.telemetry
    assert tel.preempt_count == on.engine.preemptions
    assert tel.resume_count == tel.preempt_count   # every eviction resumed
    assert tel.resume_penalty_gpu_s == \
        pytest.approx(on.engine.resume_penalty_gpu_s)
    assert tel.preemption_events == ctl.events

    def hit_rate(jobs):
        dl = [j for j in jobs if j.has_deadline]
        return sum(1 for j in dl if j.finish_time <= j.deadline) / len(dl)

    assert hit_rate(on.batch.jobs) >= hit_rate(off.batch.jobs)


def test_slo_lanes_scenario_shape():
    run = get_scenario("slo-lanes").build(100, 0)
    dl = [j for j in run.jobs if j.has_deadline]
    el = [j for j in run.jobs if j.elastic]
    assert dl and el and len(dl) < 100
    for j in dl:
        assert j.deadline > j.submit_time
    for j in el:
        assert 0 < j.min_gpus < j.num_gpus * 2 + 1 and j.max_gpus > j.min_gpus
    again = get_scenario("slo-lanes").build(100, 0)
    assert [(j.deadline, j.min_gpus, j.max_gpus) for j in run.jobs] == \
        [(j.deadline, j.min_gpus, j.max_gpus) for j in again.jobs]


# ----------------------------------------------------------------- tooling ----


def test_bench_preemption_smoke(tmp_path):
    """The registered preemption bench must run end-to-end in --smoke mode
    and emit a well-formed acceptance block."""
    json_path = tmp_path / "BENCH_preemption.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_BENCH_PREEMPT_JOBS"] = "120"
    env["REPRO_BENCH_PREEMPT_JSON"] = str(json_path)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_preemption", "--smoke"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    doc = json.loads(json_path.read_text())
    assert doc["bench"] == "preemption" and doc["num_jobs"] == 120
    assert doc["scale"] == "smoke"
    acc = doc["acceptance"]
    assert "slo_lanes_improves_hit_rate" in acc
    assert "slo_lanes_wait_within_band" in acc
    for row in doc["results"].values():
        assert row["completed"] == 120
        for v in row.values():
            if isinstance(v, float):
                assert math.isfinite(v)


def test_bench_preemption_registered():
    import benchmarks.run as brun
    assert "preemption" in brun.MODULES
