"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("B,H,KV,L,D", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),
    (1, 8, 2, 128, 128),
    (2, 2, 1, 256, 80),     # non-128 head dim exercises lane padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(B, H, KV, L, D, dtype, window):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, L, D), dtype)
    k = rand(ks[1], (B, KV, L, D), dtype)
    v = rand(ks[2], (B, KV, L, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    kr = jnp.repeat(k, H // KV, axis=1).reshape(B * H, L, D)
    vr = jnp.repeat(v, H // KV, axis=1).reshape(B * H, L, D)
    want = ref.flash_attention_ref(q.reshape(B * H, L, D), kr, vr,
                                   causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32).reshape(B * H, L, D),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (1, 64, 2, 16, 32, 16),
    (2, 128, 4, 32, 64, 32),
    (1, 256, 2, 64, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, L, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    xh = rand(ks[0], (B, L, H, P), dtype, 0.5)
    dt = jax.nn.softplus(rand(ks[1], (B, L, H)))
    A = -jnp.exp(rand(ks[2], (H,), scale=0.3))
    Bs = rand(ks[3], (B, L, N), scale=0.3)
    Cs = rand(ks[4], (B, L, N), scale=0.3)
    y, S = ops.ssd_scan(xh, dt, A, Bs, Cs, chunk=chunk)
    want = ref.ssd_scan_ref(
        xh.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1)[..., None], A, Bs, Cs
    ).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)
    assert S.shape == (B, H, P, N) and np.isfinite(np.asarray(S)).all()


def test_ssd_init_state_consistency():
    """Running [first half; second half with carried state] == full run."""
    ks = jax.random.split(KEY, 5)
    B, L, H, P, N = 1, 128, 2, 16, 32
    xh = rand(ks[0], (B, L, H, P), scale=0.5)
    dt = jax.nn.softplus(rand(ks[1], (B, L, H)))
    A = -jnp.exp(rand(ks[2], (H,), scale=0.3))
    Bs = rand(ks[3], (B, L, N), scale=0.3)
    Cs = rand(ks[4], (B, L, N), scale=0.3)
    y_full, S_full = ops.ssd_scan(xh, dt, A, Bs, Cs, chunk=32)
    h = L // 2
    y1, S1 = ops.ssd_scan(xh[:, :h], dt[:, :h], A, Bs[:, :h], Cs[:, :h],
                          chunk=32)
    y2, S2 = ops.ssd_scan(xh[:, h:], dt[:, h:], A, Bs[:, h:], Cs[:, h:],
                          chunk=32, init_state=S1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, h:]),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=2e-3,
                               rtol=2e-3)


@pytest.mark.parametrize("Q,F,H1,H2", [(256, 8, 64, 32), (128, 8, 32, 16)])
def test_policy_mlp_sweep(Q, F, H1, H2):
    ks = jax.random.split(KEY, 7)
    x = rand(ks[0], (Q, F))
    params = [{"w": rand(ks[1], (F, H1)), "b": rand(ks[2], (H1,))},
              {"w": rand(ks[3], (H1, H2)), "b": rand(ks[4], (H2,))},
              {"w": rand(ks[5], (H2, 1)), "b": rand(ks[6], (1,))}]
    mask = (jnp.arange(Q) < Q // 2).astype(jnp.float32)
    got = ops.policy_mlp(x, params, mask)
    want = ref.policy_mlp_ref(x, params[0]["w"], params[0]["b"],
                              params[1]["w"], params[1]["b"],
                              params[2]["w"], params[2]["b"], mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("T,d,E,k", [(256, 64, 16, 2), (512, 32, 8, 4),
                                     (512, 128, 64, 8)])
def test_moe_router_sweep(T, d, E, k):
    ks = jax.random.split(KEY, 2)
    x = rand(ks[0], (T, d))
    w = rand(ks[1], (d, E), scale=0.1)
    gw, gi = ops.moe_router(x, w, k)
    ww, wi = ref.moe_router_ref(x, w, k)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ww), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_model_flash_vs_xla_path():
    """LM forward with impl.attn='flash' (interpret) equals the XLA path."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.lm import ModelImpl
    cfg = get_config("yi-6b", smoke=True)
    m_x = build_model(cfg, impl=ModelImpl(attn="xla"))
    m_f = build_model(cfg, impl=ModelImpl(attn="flash"))
    params = m_x.init(KEY)
    toks = jax.random.randint(KEY, (2, 128), 0, cfg.vocab_size)
    lx = m_x.forward(params, toks)
    lf = m_f.forward(params, toks)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lf), atol=0.1,
                               rtol=0.1)


def test_chunked_attention_equals_full():
    """XLA chunked q-block attention == full-matrix attention."""
    from repro.models.attention import _sdpa_chunked, _sdpa_full, causal_mask
    ks = jax.random.split(KEY, 3)
    B, H, KV, L, D = 1, 4, 2, 1024, 32
    q = rand(ks[0], (B, H, L, D))
    k = rand(ks[1], (B, KV, L, D))
    v = rand(ks[2], (B, KV, L, D))
    for win in (0, 128):
        got = _sdpa_chunked(q, k, v, causal=True, window=win, block_q=256)
        mask = causal_mask(L, L, win)[:, :, 0]
        want = _sdpa_full(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
