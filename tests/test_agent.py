import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import (PPOAgent, PPOConfig, actor_logits, greedy_step,
                              init_params, policy_step)
from repro.core.features import CV_SIZE, MAX_QUEUE_SIZE, OV_SIZE


def _state(n=16, seed=0):
    rng = np.random.default_rng(seed)
    ov = np.zeros((MAX_QUEUE_SIZE, OV_SIZE), np.float32)
    cv = np.zeros((MAX_QUEUE_SIZE, CV_SIZE), np.float32)
    ov[:n] = rng.random((n, OV_SIZE))
    cv[:n] = rng.random((n, CV_SIZE))
    mask = np.zeros((MAX_QUEUE_SIZE,), np.float32)
    mask[:n] = 1
    return ov, cv, mask


def test_masked_actions_never_selected():
    agent = PPOAgent(PPOConfig(seed=0))
    ov, cv, mask = _state(5)
    for _ in range(20):
        a, logits = agent.act(ov, cv, mask, explore=True, record=False)
        assert a < 5
    assert (logits[5:] < -1e8).all()


def test_greedy_is_argsort():
    params = init_params(PPOConfig())
    ov, cv, mask = _state(8)
    order = np.asarray(greedy_step(params, jnp.asarray(ov), jnp.asarray(mask)))
    lg = np.asarray(actor_logits(params, jnp.asarray(ov), jnp.asarray(mask)))
    assert order[0] == int(np.argmax(lg))


def test_logp_matches_softmax():
    params = init_params(PPOConfig())
    ov, cv, mask = _state(6)
    out = policy_step(params, jnp.asarray(ov), jnp.asarray(cv),
                      jnp.asarray(mask), jax.random.PRNGKey(0))
    lg = actor_logits(params, jnp.asarray(ov), jnp.asarray(mask))
    want = jax.nn.log_softmax(lg)[out["action"]]
    assert abs(float(out["logp"] - want)) < 1e-5


def test_ppo_update_changes_params():
    agent = PPOAgent(PPOConfig(seed=1))
    before = jax.tree.map(np.array, agent.params)
    ov, cv, mask = _state(10)
    for _ in range(8):
        agent.act(ov, cv, mask, explore=True, record=True)
    stats = agent.finish_episode(reward=1.0)
    assert stats["steps"] == 8
    after = agent.params
    diffs = jax.tree.map(lambda a, b: float(np.abs(a - b).max()), before, after)
    assert max(jax.tree.leaves(diffs)) > 0


def test_positive_reward_reinforces_actions():
    """Positive-reward episodes on action 2 must raise its probability.
    (Episodes where other actions were sampled are dropped, isolating the
    reinforcement property from Adam's sign-noise under per-episode updates.)"""
    agent = PPOAgent(PPOConfig(seed=2, lr=3e-3, entropy_coef=0.0))
    ov, cv, mask = _state(4, seed=3)
    lg0 = actor_logits(agent.params, jnp.asarray(ov), jnp.asarray(mask))
    p0 = float(np.asarray(jax.nn.softmax(lg0))[2])
    updates = 0
    while updates < 12:
        agent.reset_buffer()
        a, _ = agent.act(ov, cv, mask, explore=True, record=True)
        if a == 2:
            agent.finish_episode(reward=1.0)
            updates += 1
        else:
            agent.reset_buffer()
    lg = actor_logits(agent.params, jnp.asarray(ov), jnp.asarray(mask))
    probs = np.asarray(jax.nn.softmax(lg))[:4]
    assert probs[2] > p0, (p0, probs)
    assert probs[2] == probs.max()


def test_state_dict_roundtrip():
    a = PPOAgent(PPOConfig(seed=0))
    b = PPOAgent(PPOConfig(seed=9))
    b.load_state_dict(a.state_dict())
    ov, cv, mask = _state(5)
    la = actor_logits(a.params, jnp.asarray(ov), jnp.asarray(mask))
    lb = actor_logits(b.params, jnp.asarray(ov), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb))


def test_episodes_per_update_pooling():
    """With episodes_per_update=3, updates trigger only every 3rd episode."""
    agent = PPOAgent(PPOConfig(seed=5, episodes_per_update=3))
    ov, cv, mask = _state(6)
    updated = []
    for ep in range(7):
        agent.reset_buffer()
        for _ in range(3):
            agent.act(ov, cv, mask, explore=True, record=True)
        st = agent.finish_episode(reward=0.5)
        updated.append(st["updated"])
    assert updated == [0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0]
