"""Direct unit tests for repro.core.faults (FaultModel / FaultInjector):
seeded determinism, down->up transition ordering, checkpoint math, and the
interaction between fault events and cordoned/draining nodes."""
import math

import pytest

from repro.core import PolicyPrioritizer, make_cluster, make_policy
from repro.core.faults import FaultInjector, FaultModel
from repro.core.types import Job
from repro.sched import SchedulerEngine


def _model(**kw):
    base = dict(mtbf_per_node=6 * 3600.0, repair_time=600.0,
                straggler_prob=0.0, ckpt_interval=900.0, seed=7)
    base.update(kw)
    return FaultModel(**base)


def mk_job(i, gpus=1, submit=0.0, runtime=1000.0, gpu_type="any"):
    return Job(job_id=i, user=0, submit_time=submit, runtime=runtime,
               est_runtime=runtime, num_gpus=gpus, gpu_type=gpu_type)


# ------------------------------------------------------------- determinism ----


def test_injector_seeded_determinism():
    a = FaultInjector(_model(), num_nodes=8, horizon=30 * 86400.0)
    b = FaultInjector(_model(), num_nodes=8, horizon=30 * 86400.0)
    assert a.events == b.events and a.events
    c = FaultInjector(_model(seed=8), num_nodes=8, horizon=30 * 86400.0)
    assert a.events != c.events


def test_events_respect_horizon_and_mtbf_scale():
    horizon = 30 * 86400.0
    inj = FaultInjector(_model(), num_nodes=6, horizon=horizon)
    fails = [t for (t, kind, _) in inj.events if kind == "fail"]
    assert fails and all(t < horizon for t in fails)
    # ~ horizon/mtbf failures per node on average; allow wide slack
    expected = horizon / (6 * 3600.0)
    per_node = len(fails) / 6
    assert expected / 2 <= per_node <= expected * 2


# ---------------------------------------------------- down->up transitions ----


def test_fail_recover_pairing_and_ordering():
    """Every fail has exactly one matching recover, repair_time later (the
    exponential draw may re-fail a node before its repair lands, so the
    sequence need not strictly alternate); pop_due returns events in
    nondecreasing time order."""
    inj = FaultInjector(_model(), num_nodes=4, horizon=60 * 86400.0)
    per_node: dict[int, dict[str, list]] = {}
    last_t = -math.inf
    while inj.events:
        t = inj.next_event_time()
        for (ft, kind, node) in inj.pop_due(t):
            assert ft >= last_t - 1e-9
            last_t = ft
            per_node.setdefault(node, {}).setdefault(kind, []).append(ft)
    for node, by_kind in per_node.items():
        fails = sorted(by_kind.get("fail", []))
        recs = sorted(by_kind.get("recover", []))
        assert fails and len(fails) == len(recs)
        for t_fail, t_rec in zip(fails, recs):
            assert t_rec == pytest.approx(t_fail + 600.0)


def test_straggler_pairing():
    inj = FaultInjector(_model(straggler_prob=1.0, straggler_duration=500.0),
                        num_nodes=2, horizon=30 * 86400.0)
    kinds = {k for (_, k, _) in inj.events}
    assert kinds == {"slow", "unslow"}
    slows = sorted((t, n) for (t, k, n) in inj.events if k == "slow")
    unslows = sorted((t, n) for (t, k, n) in inj.events if k == "unslow")
    for (ts, ns), (tu, nu) in zip(slows, unslows):
        assert nu == ns and tu == pytest.approx(ts + 500.0)


def test_pop_due_is_monotonic_prefix():
    inj = FaultInjector(_model(), num_nodes=4, horizon=30 * 86400.0)
    total = len(inj.events)
    mid = inj.events[total // 2][0]
    due = inj.pop_due(mid)
    assert all(t <= mid + 1e-9 for (t, _, _) in due)
    assert inj.next_event_time() > mid
    assert len(due) + len(inj.events) == total


def test_checkpointed_progress_boundaries():
    inj = FaultInjector(_model(), num_nodes=1, horizon=1.0)
    assert inj.checkpointed_progress(0.0, 1000.0) == 0.0
    assert inj.checkpointed_progress(899.0, 1000.0) == 0.0   # before 1st ckpt
    assert inj.checkpointed_progress(900.0, 1000.0) == pytest.approx(0.9)
    assert inj.checkpointed_progress(5000.0, 1000.0) == 1.0  # clamped
    assert inj.checkpointed_progress(100.0, 0.0) == 0.0      # degenerate


# ------------------------------------------- faults vs cordoned/draining ----


def test_fault_kill_on_cordoned_node_completes_the_drain():
    """A cordoned node whose job is killed by a failure has no allocations
    left — the drain must complete (auto-retire), and the later recover
    event must not resurrect the retired slot."""
    spec = make_cluster("helios")
    eng = SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                          allocator="pack",
                          fault_model=_model(mtbf_per_node=2 * 3600.0,
                                             repair_time=600.0))
    eng.submit([mk_job(i, gpus=8, runtime=30 * 3600.0) for i in range(10)])
    eng.step(1.0)
    assert eng.snapshot().num_running == 10
    # cordon a busy node, then let the fault storm roll
    victim_jid, rec = next(iter(eng.running.items()))
    (node, _), = rec[1].items()
    assert eng.cluster.remove_node(node) is False
    assert bool(eng.cluster.cordoned[node])
    eng.drain()
    assert eng.done
    assert bool(eng.cluster.retired[node])
    assert not bool(eng.cluster.cordoned[node])
    # recover events on the retired slot may have fired; capacity stayed out
    assert not eng.cluster.eligible_mask("any")[node]


def test_pair_close_pushes_companions_past_horizon():
    """Only the *failure draw* is horizon-bounded: a fail landing just
    inside the horizon still pushes its recover companion even when the
    repair completes past it, so a node can never end a run permanently
    failed (or slowed) by timeline truncation."""
    inj = FaultInjector(_model(repair_time=1e9), num_nodes=6,
                        horizon=60 * 86400.0)
    fails = [t for (t, k, _) in inj.events if k == "fail"]
    recs = [t for (t, k, _) in inj.events if k == "recover"]
    assert fails and len(fails) == len(recs)
    assert all(t > inj.horizon for t in recs)      # every repair lands late
    slow_inj = FaultInjector(_model(straggler_prob=1.0,
                                    straggler_duration=1e9),
                             num_nodes=6, horizon=60 * 86400.0)
    slows = [t for (t, k, _) in slow_inj.events if k == "slow"]
    unslows = [t for (t, k, _) in slow_inj.events if k == "unslow"]
    assert slows and len(slows) == len(unslows)
    assert all(t > slow_inj.horizon for t in unslows)


# --------------------------------------------------- runtime-added capacity ----


def test_extend_node_is_deterministic_and_pair_closed():
    inj = FaultInjector(_model(mtbf_per_node=1800.0), num_nodes=2,
                        horizon=10 * 86400.0)
    drawn = inj.extend_node(2, start=5000.0)
    assert drawn and all(n == 2 for (_, _, n) in drawn)
    assert all(t > 5000.0 for (t, _, _) in drawn)
    assert inj.num_nodes == 3
    fails = [t for (t, k, _) in drawn if k == "fail"]
    recs = [t for (t, k, _) in drawn if k == "recover"]
    assert fails and len(fails) == len(recs)
    # independent of the construction-time RNG's consumption: a fresh
    # injector over a *different* initial node count draws the same
    # timeline for the same (seed, node, start)
    other = FaultInjector(_model(mtbf_per_node=1800.0), num_nodes=1,
                          horizon=10 * 86400.0)
    assert other.extend_node(2, start=5000.0) == drawn
    # and the heap is exactly base timelines + the extension
    base = FaultInjector(_model(mtbf_per_node=1800.0), num_nodes=2,
                         horizon=10 * 86400.0)
    assert sorted(inj.events) == sorted(base.events + drawn)


def test_autoscaler_added_capacity_gets_a_fault_timeline():
    """Nodes added at runtime are seeded a deterministic timeline the next
    time the engine reschedules (the autoscaler's post-add kick), closing
    the documented added-capacity-is-fault-immune gap."""
    from repro.core.types import NodeSpec

    def grown_engine():
        spec = make_cluster("slurm-testbed")   # add_node mutates spec.nodes
        eng = SchedulerEngine(spec, PolicyPrioritizer(make_policy("fcfs")),
                              allocator="pack",
                              fault_model=_model(mtbf_per_node=1800.0,
                                                 repair_time=300.0))
        eng.submit([mk_job(0, gpus=1, runtime=10.0)])
        # bounded step: draining would roll the clock through the whole
        # fault timeline, past the horizon, leaving nothing to extend
        eng.step(600.0)
        assert eng.done
        return eng

    eng = grown_engine()
    n0 = eng._injector.num_nodes
    assert all(n < n0 for (_, _, n) in eng._injector.events)
    nid = eng.cluster.add_node(NodeSpec(0, "P100", 4, 32, 256.0, 1.0))
    assert nid == n0
    eng.reschedule(at=eng.now)
    new_events = [e for e in eng._injector.events if e[2] == n0]
    assert new_events, "added node must carry a fault timeline"
    assert all(t > eng.now for (t, _, _) in new_events)
    # marker events mirrored onto the engine heap so the clock reaches them
    marked = [t for (t, _, kind, node) in eng._events
              if kind == "fault" and node == n0]
    assert len(marked) == len(new_events)
    # deterministic: a second engine grown the same way draws identically
    eng2 = grown_engine()
    eng2.cluster.add_node(NodeSpec(0, "P100", 4, 32, 256.0, 1.0))
    eng2.reschedule(at=eng2.now)
    assert sorted(e for e in eng2._injector.events if e[2] == n0) \
        == sorted(new_events)
