"""Scale-out pins: parallel federation stepping, the MILP solution cache,
compact completed-summary mode, and the shape-bucketed deep-window scorer.

Everything here guards the "Raw speed, round 3" contract: every fast path
is opt-in and bit-identical to its serial/uncached/default reference —
identical job tuples AND identical decision counters, not just identical
aggregates.
"""
import numpy as np
import pytest

from repro.core import PolicyPrioritizer, make_policy
from repro.core.agent import PPOAgent
from repro.core.cluster import ClusterState
from repro.core.env import RLPrioritizer
from repro.core.milp import choose_allocation
from repro.core.types import ClusterSpec, Job, NodeSpec
from repro.fed import run_fleet
from repro.fed.scenarios import FLEET_SCENARIOS
from repro.kernels.batch_score import BucketedScorer, bucket_for
from repro.sched import SchedulerEngine, get_scenario


# ------------------------------------------------- parallel federation ----


def _fleet_sig(sr):
    """Bit-identity signature: completed job tuples + per-member decision
    counters + routing counts + fleet aggregates."""
    jobs = tuple(sorted((j.job_id, j.submit_time, j.first_start_time,
                         j.finish_time, j.num_gpus, j.vc)
                        for j in sr.result.jobs))
    eng = sr.fed.engines
    return (jobs,
            tuple(e.decisions for e in eng),
            tuple(e.backfills for e in eng),
            tuple(sr.fed.routed),
            sr.fed.deferrals,
            len(sr.fed.migrations))


@pytest.mark.parametrize("name", sorted(FLEET_SCENARIOS))
def test_parallel_federation_bit_identical(name):
    """parallel=True must replay every registered fleet scenario —
    fault-storm and blackout chaos included — bit-identically to the
    serial path: same job tuples, same decisions/backfills per member,
    same routing and deferral counts."""
    serial = _fleet_sig(run_fleet(name, num_jobs=150, seed=3))
    par = _fleet_sig(run_fleet(name, num_jobs=150, seed=3, parallel=True))
    assert serial == par


def test_parallel_federation_pool_lifecycle():
    """The stepping pool is lazy, reused across windows, and close() is
    idempotent (a closed federation re-creates it on the next step)."""
    from repro.fed.federation import FederatedScheduler
    run = FLEET_SCENARIOS["fleet-steady"].build(60, 1)
    fed = FederatedScheduler(run.clusters, "jsq",
                             fault_models=run.fault_models, parallel=True)
    assert fed._pool is None          # lazy: no threads before stepping
    fed.submit(run.jobs)
    fed.step(run.jobs[0].submit_time + 3600.0)
    assert fed._pool is not None
    fed.close()
    assert fed._pool is None
    fed.close()                       # idempotent
    fed.run_until_complete()          # re-creates the pool transparently
    assert fed.done
    fed.close()


# ---------------------------------------------------- MILP solve cache ----


def _fragmented_cluster() -> ClusterState:
    spec = ClusterSpec(nodes=[NodeSpec(node_id=i, gpu_type="V100",
                                       num_gpus=8, num_cpus=96, mem_gb=768.0)
                              for i in range(16)])
    cluster = ClusterState(spec)
    for i in range(8):   # fragment: spread and pack become distinct ways
        filler = Job(job_id=900 + i, user=0, submit_time=0.0,
                     runtime=86400.0, est_runtime=86400.0, num_gpus=4,
                     gpu_type="V100")
        cluster.allocate(filler, {i: 4})
    return cluster


def _probe(jid: int, gpus: int) -> Job:
    return Job(job_id=jid, user=0, submit_time=0.0, runtime=3600.0,
               est_runtime=3600.0, num_gpus=gpus, gpu_type="V100")


def test_milp_solution_cache_differential():
    """Cached and uncached paths return identical results for every probe
    shape, and repeats on an unchanged cluster are served from the cache
    (same object, no re-solve)."""
    cluster = _fragmented_cluster()
    for gpus in (8, 12, 16, 24):
        job = _probe(gpus, gpus)
        ways = cluster.candidate_ways(job)
        assert len(ways) >= 2, gpus
        look = [_probe(100 + gpus + i, 8) for i in range(3)]
        uncached = choose_allocation(cluster, job, ways, look,
                                     solution_cache=False)
        first = choose_allocation(cluster, job, ways, look)
        again = choose_allocation(cluster, job, ways, look)
        assert (uncached.placement, uncached.way_index) \
            == (first.placement, first.way_index)
        assert again is first           # dict hit, not a re-solve


def test_milp_solution_cache_invalidated_on_version_bump():
    """Any cluster mutation bumps the version and must bypass (and reset)
    the solution cache — a stale placement for the old free-GPU state
    would corrupt the allocator."""
    cluster = _fragmented_cluster()
    job = _probe(1, 8)
    ways = cluster.candidate_ways(job)
    first = choose_allocation(cluster, job, ways, [])
    ver0, store0 = cluster._milp_sol_cache
    assert store0                      # populated at the current version

    # mutate: allocate 4 more GPUs -> version bump, fresh ways
    blocker = _probe(2, 4)
    cluster.allocate(blocker, {8: 4})
    ways2 = cluster.candidate_ways(job)
    second = choose_allocation(cluster, job, ways2, [])
    ver1, store1 = cluster._milp_sol_cache
    assert ver1 != ver0                # keyed to the new version...
    assert second is not first         # ...and genuinely re-solved
    assert len(store1) == 1            # old version's entries dropped


def test_milp_skeletons_thread_local():
    """_SKELETONS is thread-local: concurrent federation stepping must
    never share (or corrupt) the mutable skeleton arrays."""
    import threading

    from repro.core.milp import _SKELETONS, _skeleton

    _skeleton(4, 8, 2)
    main_len = len(_SKELETONS)
    assert main_len >= 1
    seen: dict = {}

    def worker():
        seen["before"] = len(_SKELETONS)
        _skeleton(4, 8, 2)
        seen["after"] = len(_SKELETONS)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert seen["before"] == 0         # fresh store in the new thread
    assert seen["after"] == 1
    assert len(_SKELETONS) == main_len  # main thread's store untouched


# ------------------------------------------- compact completed summary ----


def _stream(engine, jobs):
    jobs = [j.clone_pending() for j in jobs]
    feed = 0
    while True:
        nxt = engine.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt == float("inf"):
            break
        horizon = max(engine.now, nxt) + 3600.0
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= horizon:
            hi += 1
        if hi > feed:
            engine.submit(jobs[feed:hi])
            feed = hi
        engine.step(horizon)
    return engine


def test_compact_completed_mode_pinned_to_default():
    """completed_summary=True must change only the bookkeeping: identical
    decisions, backfills, completed counts, aggregate stats, and result()
    makespan/avg-JCT — while the full Job list is dropped and the ring
    stays bounded."""
    run = get_scenario("flash-crowd").build(600, seed=0)

    def build(compact):
        pri = PolicyPrioritizer(make_policy("fcfs"))
        return SchedulerEngine(run.spec, pri, allocator="pack",
                               fault_model=run.fault_model,
                               queue_window=256, completed_summary=compact,
                               completed_keep=32)

    full = _stream(build(False), run.jobs)
    compact = _stream(build(True), run.jobs)

    assert compact.completed_count == full.completed_count == 600
    assert compact.decisions == full.decisions
    assert compact.backfills == full.backfills
    assert len(compact.completed) == 0          # jobs not retained
    assert len(compact.completed_ring) == 32    # bounded ring
    assert len(full.completed) == 600

    sf, sc = full.completed_stats(), compact.completed_stats()
    assert sc["completed"] == sf["completed"]
    assert sc["mean_jct_s"] == pytest.approx(sf["mean_jct_s"])
    assert sc["mean_wait_s"] == pytest.approx(sf["mean_wait_s"])

    rf, rc = full.result(), compact.result()
    assert rc.makespan == rf.makespan
    assert rc.gpu_seconds_used == rf.gpu_seconds_used
    assert rc.decisions == rf.decisions
    # per-job averages in compact mode come from completed_stats() (the
    # result() docstring's contract — result().jobs is intentionally empty)
    assert sc["mean_jct_s"] == pytest.approx(rf.avg_jct)
    assert sc["mean_wait_s"] == pytest.approx(rf.avg_wait)

    # snapshots agree on the headline counters too
    assert compact.snapshot().num_completed == full.snapshot().num_completed


def test_compact_ring_holds_most_recent_tuples():
    run = get_scenario("steady").build(100, seed=0)
    pri = PolicyPrioritizer(make_policy("fcfs"))
    eng = _stream(SchedulerEngine(run.spec, pri, allocator="pack",
                                  fault_model=run.fault_model,
                                  completed_summary=True, completed_keep=10),
                  run.jobs)
    assert eng.completed_count == 100
    ring = list(eng.completed_ring)
    assert len(ring) == 10
    # tuples are (job_id, submit, first_start, finish, num_gpus, vc) in
    # finish order — the tail of the stream
    finishes = [r[3] for r in ring]
    assert finishes == sorted(finishes)


# --------------------------------------------- bucketed deep-window scorer ----


def test_bucket_ladder():
    assert bucket_for(1) == 256
    assert bucket_for(256) == 256
    assert bucket_for(257) == 512
    assert bucket_for(5000) == 8192
    assert bucket_for(10 ** 6) == 16384     # clamped at the cap


def _mk_cluster():
    spec = ClusterSpec(nodes=[NodeSpec(node_id=i, gpu_type="V100",
                                       num_gpus=8, num_cpus=64, mem_gb=512.0)
                              for i in range(8)])
    return ClusterState(spec)


def _mk_jobs(n):
    rng = np.random.default_rng(0)
    return [Job(job_id=i, user=i % 5, submit_time=float(i),
                runtime=600.0 + 10 * i, est_runtime=600.0 + 10 * i,
                num_gpus=int(rng.integers(1, 8)), gpu_type="V100", vc=i % 3)
            for i in range(n)]


def test_bucketed_scorer_matches_reference_mlp():
    """The Pallas batch scorer must match a plain numpy forward pass of
    the same actor MLP (tanh-tanh-linear) on every row."""
    agent = PPOAgent()
    sc = BucketedScorer(agent.params["actor"])
    rng = np.random.default_rng(1)
    x = rng.normal(size=(300, 8)).astype(np.float32)
    got = sc.score(x)
    h = x.astype(np.float64)
    for i, lyr in enumerate(agent.params["actor"]):
        h = h @ np.asarray(lyr["w"], dtype=np.float64) \
            + np.asarray(lyr["b"], dtype=np.float64)
        if i < 2:
            h = np.tanh(h)
    assert got.shape == (300,)
    np.testing.assert_allclose(got, h[:, 0], rtol=1e-4, atol=1e-5)
    assert sc.compiled_buckets == (512,)
    # a second nearby size reuses the same bucket — no new compilation
    sc.score(rng.normal(size=(400, 8)).astype(np.float32))
    assert sc.compiled_buckets == (512,)


def test_deep_window_head_identical_tail_policy_ordered():
    """deep_scorer changes ONLY the FIFO tail beyond MAX_QUEUE_SIZE: the
    actor-window head ranking stays bit-identical, the tail becomes a
    permutation ordered by the bucketed logits."""
    cluster = _mk_cluster()
    jobs = _mk_jobs(400)

    base = RLPrioritizer(PPOAgent(), explore=False)
    order_base = base.rank(jobs, cluster, now=500.0)

    agent = PPOAgent()
    deep = RLPrioritizer(agent, explore=False,
                         deep_scorer=BucketedScorer(agent.params["actor"]))
    order_deep = deep.rank(jobs, cluster, now=500.0)

    assert order_base[:256] == order_deep[:256]
    assert sorted(order_deep) == list(range(400))
    assert order_base[256:] == list(range(256, 400))   # default stays FIFO
    assert order_deep[256:] != list(range(256, 400))   # deep mode reorders


def test_deep_scorer_inert_below_window():
    """Queues that fit in the actor window never touch the scorer."""
    cluster = _mk_cluster()
    jobs = _mk_jobs(64)
    agent = PPOAgent()
    sc = BucketedScorer(agent.params["actor"])
    deep = RLPrioritizer(agent, explore=False, deep_scorer=sc)
    base = RLPrioritizer(PPOAgent(), explore=False)
    assert deep.rank(jobs, cluster, now=100.0) \
        == base.rank(jobs, cluster, now=100.0)
    assert sc.compiled_buckets == ()


# ------------------------------------------------- deep lookahead shrink ----


def test_deep_lookahead_inert_below_threshold():
    """deep_lookahead_k only engages beyond deep_queue_threshold pending
    jobs: a shallow stream is bit-identical with and without it."""
    run = get_scenario("steady").build(300, seed=0)

    def sig(**kw):
        pri = PolicyPrioritizer(make_policy("fcfs"))
        eng = _stream(SchedulerEngine(run.spec, pri, allocator="pack",
                                      fault_model=run.fault_model, **kw),
                      run.jobs)
        return (tuple(sorted((j.job_id, j.finish_time)
                             for j in eng.completed)),
                eng.decisions, eng.backfills)

    assert sig() == sig(deep_lookahead_k=2, deep_queue_threshold=4096)
