import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import OptConfig, opt_init, opt_update, schedule


def _params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (16, 8), jnp.bfloat16),
            "norm": {"scale": jnp.ones((8,), jnp.float32)}}


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    end = float(schedule(cfg, jnp.asarray(100)))
    assert abs(end - 1e-4) < 1e-6          # decays to min_lr_frac * lr
    mid = float(schedule(cfg, jnp.asarray(55)))
    assert 1e-4 < mid < 1e-3


def test_update_moves_params_and_states():
    params = _params()
    state = opt_init(params)
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.0)
    p2, s2, stats = opt_update(params, grads, state, cfg)
    assert int(s2["step"]) == 1
    assert float(stats["gnorm"]) > 0
    assert float(jnp.abs(p2["w"].astype(jnp.float32)
                         - params["w"].astype(jnp.float32)).max()) > 0
    # moments are fp32 regardless of param dtype
    assert s2["m"]["w"].dtype == jnp.float32


def test_no_weight_decay_on_norm_scales():
    params = _params()
    state = opt_init(params)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.5)
    p2, _, _ = opt_update(params, zeros, state, cfg)
    # zero grads: decayed leaves shrink, norm scales must not
    np.testing.assert_allclose(np.asarray(p2["norm"]["scale"]),
                               np.ones(8), atol=1e-6)
    assert float(jnp.abs(p2["w"]).astype(jnp.float32).max()) < \
        float(jnp.abs(params["w"]).astype(jnp.float32).max())


def test_grad_clip_bounds_update():
    params = _params()
    state = opt_init(params)
    huge = jax.tree.map(lambda p: jnp.full(p.shape, 1e6, jnp.float32), params)
    cfg = OptConfig(lr=1e-2, warmup_steps=0, total_steps=10, clip_norm=1.0,
                    weight_decay=0.0)
    p2, _, stats = opt_update(params, huge, state, cfg)
    # post-clip first Adam step magnitude is bounded by ~lr
    delta = float(jnp.abs(p2["w"].astype(jnp.float32)
                          - params["w"].astype(jnp.float32)).max())
    assert delta < 0.3
