"""repro.predict — online runtime prediction for scheduling decisions.

See ``repro.predict.predictor`` for the model and training loop, and
``docs/ARCHITECTURE.md`` ("Prediction layer") for how the estimates feed
EASY backfill reservations, MILP lookahead durations, and autoscaler
demand forecasts.
"""
from repro.predict.predictor import (CONTEXT_NAMES, NUM_CONTEXT,
                                     PREDICT_FEATURES, RESID_CLAMP,
                                     OverrunPolicy, QuantileMLP,
                                     RunningMeanBaseline, RuntimePredictor)

__all__ = [
    "CONTEXT_NAMES", "NUM_CONTEXT", "PREDICT_FEATURES", "RESID_CLAMP",
    "OverrunPolicy", "QuantileMLP", "RunningMeanBaseline",
    "RuntimePredictor",
]
