"""Online runtime prediction (`repro.predict`) — learned estimates for
EASY backfill reservations, MILP lookahead durations, and autoscaler
demand forecasts.

The paper's application-agnostic constraint is respected: the predictor
learns **only from observed telemetry** — the engine hook stream of
submissions and completions — with no per-job offline profiling.  Each
completed job contributes one online SGD step; each pending job can be
scored at any time.

Model: a small quantile-head MLP (numpy forward/backward here, with the
fused Pallas kernel in ``repro.kernels.predict_mlp`` as the batched
inference path) over the existing 17 ``repro.core.features`` job features
plus 4 cluster-context features.  The heads predict **log-runtime
residuals over a debiased estimate anchor**:

    anchor(job)  = est_runtime * exp(bias[user, gpus-bucket])
    q_tau(job)   = anchor(job) * exp(f_tau(x)),   tau in {0.5, 0.9}

where ``bias`` is the running mean of observed ``log(actual / est)`` per
(user, gpus-bucket) — the per-cohort *systematic* mis-estimation (users
who habitually pad their walltime request, or habitually lowball it) —
and the MLP heads, trained with the pinball (quantile) loss, capture the
residual quantiles on top of the corrected anchor.  The split matters:
cohort identity is a lookup, not something a tiny MLP can carve out of a
scalar user-id feature, while the remaining noise *is* feature-shaped.
All tables start empty and every head initializes to zero, so the
*untrained* predictor reproduces the declared estimate exactly — assist
mode can be enabled from the first job without a cold-start cliff.  A trivial per-(user, gpus-bucket) running-mean
baseline is trained alongside from the same stream; the MLP's prequential
MAPE must beat it (gated in ``benchmarks/bench_prediction.py``).

Shadow mode (``assist=False``) trains from the hook stream but is never
consulted by the engine — pinned bit-identical to ``predictor=None`` on
every registered scenario, the same off-path discipline as
obs/chaos/autoscaler-off.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

import numpy as np

from repro.core.features import NUM_FEATURES, build_features
from repro.sched.engine import EngineHooks
from repro.core.types import Job

#: cluster-context features appended to the 17 core job features
CONTEXT_NAMES = ("utilization", "pending_norm", "running_norm", "free_frac")
NUM_CONTEXT = len(CONTEXT_NAMES)
PREDICT_FEATURES = NUM_FEATURES + NUM_CONTEXT

#: log-residual clamp: e^4 ~ 55x either way — far wider than any real
#: mis-estimation pattern, tight enough that one bad SGD step can never
#: emit an inf/NaN reservation
RESID_CLAMP = 4.0


def _gpu_bucket(num_gpus: int) -> int:
    """Power-of-two GPU-count bucket (1, 2, 3-4, 5-8, ...)."""
    return max(int(num_gpus), 0).bit_length()


class QuantileMLP:
    """Tiny tanh MLP with one linear head per quantile, trained online with
    the pinball loss by manual numpy backprop (single-sample SGD).

    The head layer initializes to zero so the untrained network outputs a
    zero log-residual for every input — predictions start exactly at the
    anchor.  Parameter layout matches the fused Pallas kernel
    (``repro.kernels.predict_mlp``): w1/b1/w2/b2/w3/b3, float32.
    """

    def __init__(self, num_features: int = PREDICT_FEATURES,
                 hidden: tuple[int, int] = (24, 12),
                 quantiles: tuple[float, ...] = (0.5, 0.9),
                 lr: float = 0.05, seed: int = 0):
        h1, h2 = hidden
        q = len(quantiles)
        rng = np.random.default_rng(seed)
        self.quantiles = tuple(float(t) for t in quantiles)
        self.lr = float(lr)
        self.params = {
            "w1": (rng.standard_normal((num_features, h1))
                   / math.sqrt(num_features)).astype(np.float32),
            "b1": np.zeros(h1, np.float32),
            "w2": (rng.standard_normal((h1, h2))
                   / math.sqrt(h1)).astype(np.float32),
            "b2": np.zeros(h2, np.float32),
            "w3": np.zeros((h2, q), np.float32),
            "b3": np.zeros(q, np.float32),
        }

    def forward(self, x: np.ndarray) -> np.ndarray:
        """(n, F) -> (n, Q) log-runtime residuals, float32."""
        p = self.params
        h1 = np.tanh(x @ p["w1"] + p["b1"])
        h2 = np.tanh(h1 @ p["w2"] + p["b2"])
        return h2 @ p["w3"] + p["b3"]

    def sgd_step(self, x: np.ndarray, y: float) -> float:
        """One pinball-loss SGD step on a single (features, log-residual)
        pair; returns the summed pinball loss before the update."""
        p = self.params
        x = np.asarray(x, np.float32)
        h1 = np.tanh(x @ p["w1"] + p["b1"])
        h2 = np.tanh(h1 @ p["w2"] + p["b2"])
        q = h2 @ p["w3"] + p["b3"]
        taus = np.asarray(self.quantiles, np.float32)
        diff = np.float32(y) - q
        loss = float(np.sum(np.maximum(taus * diff, (taus - 1.0) * diff)))
        # dL/dq per head: (1 - tau) when over-predicting, -tau when under
        g = np.where(q >= y, 1.0 - taus, -taus).astype(np.float32)
        dw3 = np.outer(h2, g)
        dh2 = p["w3"] @ g
        dz2 = dh2 * (1.0 - h2 * h2)
        dw2 = np.outer(h1, dz2)
        dh1 = p["w2"] @ dz2
        dz1 = dh1 * (1.0 - h1 * h1)
        dw1 = np.outer(x, dz1)
        lr = self.lr
        p["w3"] -= lr * dw3
        p["b3"] -= lr * g
        p["w2"] -= lr * dw2
        p["b2"] -= lr * dz2
        p["w1"] -= lr * dw1
        p["b1"] -= lr * dz1
        return loss


class RunningMeanBaseline:
    """Trivial per-(user, gpus-bucket) running mean of observed runtimes —
    the floor the MLP must beat on MAPE.  Falls back to the global mean,
    then to the declared estimate, when a key has no observations yet."""

    def __init__(self):
        self._sum: dict[tuple[int, int], float] = {}
        self._n: dict[tuple[int, int], int] = {}
        self._gsum = 0.0
        self._gn = 0

    def predict(self, job: Job) -> float:
        key = (job.user, _gpu_bucket(job.num_gpus))
        n = self._n.get(key, 0)
        if n:
            return self._sum[key] / n
        if self._gn:
            return self._gsum / self._gn
        return max(float(job.est_runtime), 1.0)

    def observe(self, job: Job, runtime: float) -> None:
        key = (job.user, _gpu_bucket(job.num_gpus))
        self._sum[key] = self._sum.get(key, 0.0) + runtime
        self._n[key] = self._n.get(key, 0) + 1
        self._gsum += runtime
        self._gn += 1


@dataclasses.dataclass
class OverrunPolicy:
    """Checkpoint economics for reservation overruns.  Duck-type-compatible
    with ``CkptCostModel`` (``ckpt_interval`` + ``resume_penalty``), so the
    engine charges the overrun through the normal ``preempt_job`` path."""

    grace_s: float = 60.0          # slack past the deadline before eviction
    ckpt_interval: float = 900.0   # progress floors to this grid
    penalty_s: float = 600.0       # replayed restore work, in work-seconds

    def resume_penalty(self, job: Job) -> float:
        return self.penalty_s


class RuntimePredictor(EngineHooks):
    """Online quantile runtime predictor, attached as an engine hook.

    Subclassing ``EngineHooks`` matters twice over: under a ``MultiHooks``
    the dispatch filter skips every inherited no-op (only ``on_submit`` /
    ``on_finish`` count as defined), and when ``load_state`` re-attaches
    the pickled predictor *directly* to ``engine.hooks`` the inherited
    no-ops absorb the rest of the hook surface.

    Training loop (no profiling, observed telemetry only):

    - ``on_submit`` caches the job's 17-dim feature row (cluster state at
      submission) keyed by job id;
    - ``on_finish`` pairs it with the observed runtime, records the
      *prequential* MLP/baseline errors (predict-then-update, so reported
      MAPE is honest out-of-sample error), takes one pinball SGD step per
      quantile head, folds ``log(actual / est)`` into the per-(user,
      gpus-bucket) anchor bias, feeds the running-mean baseline, and
      evicts the row.

    Consumers (engine-driven, all read-only):

    - ``reserve_batch`` / ``reserve_runtime``: p90 reservations for EASY
      backfill gating;
    - ``lookahead_durations``: p50 durations for the MILP lookahead terms;
    - ``pending_gpu_hours``: predicted GPU-hours of the pending window for
      autoscaler demand forecasts.

    ``assist=False`` is shadow mode: the hooks train, the engine never
    consults the model (bit-identity pinned).  ``use_kernel=True`` routes
    batched forwards through the fused Pallas kernel.
    """

    def __init__(self, *, assist: bool = True,
                 quantiles: tuple[float, float] = (0.5, 0.9),
                 hidden: tuple[int, int] = (24, 12), lr: float = 0.05,
                 seed: int = 0, overrun: OverrunPolicy | None = None,
                 use_kernel: bool = False, window: int = 512,
                 max_cached: int = 262_144):
        self.assist = bool(assist)
        self.mlp = QuantileMLP(PREDICT_FEATURES, hidden, quantiles,
                               lr=lr, seed=seed)
        self.baseline = RunningMeanBaseline()
        self.overrun = overrun if overrun is not None else OverrunPolicy()
        self.use_kernel = bool(use_kernel)
        self.engine = None
        self.train_steps = 0
        self.max_cached = int(max_cached)
        self._cache: dict[int, np.ndarray] = {}   # job_id -> feature row
        #: per-(user, gpus-bucket) running mean of log(actual / declared
        #: est) — the systematic cohort bias folded into the anchor
        self._bias_sum: dict[tuple[int, int], float] = {}
        self._bias_n: dict[tuple[int, int], int] = {}
        self._err_mlp: deque[float] = deque(maxlen=window)
        self._err_base: deque[float] = deque(maxlen=window)
        self._sum_err_mlp = 0.0
        self._sum_err_base = 0.0
        self._n_err = 0
        #: reservation-slack samples (t_res - predicted finish) at backfill
        #: commit time; ``reservations`` is the cumulative count so metric
        #: observers can consume only the new tail (``recent_slacks``)
        self.reservation_slacks: deque[float] = deque(maxlen=4096)
        self.reservations = 0
        self._ctx = np.zeros(NUM_CONTEXT, np.float32)
        self._ctx_key: tuple | None = None

    # ------------------------------------------------------------ plumbing --
    def bind(self, engine) -> None:
        """Attach the engine whose cluster state feeds feature rows.  The
        engine calls this from its constructor (and again on
        ``load_state``); the back-reference is dropped for pickling."""
        self.engine = engine

    def __getstate__(self):
        state = self.__dict__.copy()
        state["engine"] = None          # rebound by SchedulerEngine.load_state
        return state

    def _context(self, engine) -> np.ndarray:
        """4 cluster-context features, memoized per (cluster version,
        queue/running population) so batch scoring pays for it once."""
        cluster = engine.cluster
        key = (getattr(cluster, "version", -1), len(engine.pending),
               len(engine.running), engine.now)
        if key == self._ctx_key:
            return self._ctx
        free, _ = cluster.free_gpu_tallies()
        total, _ = cluster.provisioned_gpu_totals()
        npend, nrun = len(engine.pending), len(engine.running)
        self._ctx = np.array([
            cluster.utilization(up_only=True),
            npend / (npend + 32.0),
            nrun / (nrun + 32.0),
            free / max(total, 1),
        ], np.float32)
        self._ctx_key = key
        return self._ctx

    def _job_row(self, job: Job, engine, now: float) -> np.ndarray:
        if engine is not None:
            return build_features([job], engine.cluster, now,
                                  use_estimates=True)[0]
        return np.zeros(NUM_FEATURES, np.float32)

    def _anchor(self, job: Job) -> float:
        est = float(job.est_runtime)
        if not math.isfinite(est) or est <= 0.0:
            # unknown-duration jobs (see trace.load_trace_csv) are served
            # entirely by the learned model via the baseline anchor (which
            # is already an observed-runtime mean — no debias on top)
            return max(self.baseline.predict(job), 1.0)
        key = (job.user, _gpu_bucket(job.num_gpus))
        n = self._bias_n.get(key, 0)
        if n:
            b = self._bias_sum[key] / n
            est *= math.exp(min(max(b, -RESID_CLAMP), RESID_CLAMP))
        return max(est, 1.0)

    def _rows(self, jobs: list[Job], engine) -> np.ndarray:
        X = np.empty((len(jobs), PREDICT_FEATURES), np.float32)
        cache = self._cache
        missing: list[int] = []
        for k, j in enumerate(jobs):
            row = cache.get(j.job_id)
            if row is None:
                missing.append(k)
            else:
                X[k, :NUM_FEATURES] = row
        if missing:
            if engine is not None:
                feats = build_features([jobs[k] for k in missing],
                                       engine.cluster, engine.now,
                                       use_estimates=True)
            else:       # unbound (offline scoring): zero rows, est anchor
                feats = np.zeros((len(missing), NUM_FEATURES), np.float32)
            for m, k in enumerate(missing):
                X[k, :NUM_FEATURES] = feats[m]
        X[:, NUM_FEATURES:] = (self._context(engine) if engine is not None
                               else self._ctx)
        return X

    def _forward(self, X: np.ndarray) -> np.ndarray:
        if self.use_kernel:
            try:
                from repro.kernels.ops import predict_mlp as _kernel
                return np.asarray(_kernel(X, self.mlp.params))
            except Exception:  # noqa: BLE001 — no jax: numpy path is exact
                self.use_kernel = False
        return self.mlp.forward(X)

    # ---------------------------------------------------------- prediction --
    def predict_quantiles(self, jobs: list[Job],
                          engine=None) -> tuple[np.ndarray, np.ndarray]:
        """Batched (p50, p90) runtime predictions in seconds, each
        ``>= 1.0`` with ``p90 >= p50`` enforced."""
        engine = engine if engine is not None else self.engine
        n = len(jobs)
        if n == 0:
            return np.zeros(0), np.zeros(0)
        anchors = np.array([self._anchor(j) for j in jobs], np.float64)
        r = self._forward(self._rows(jobs, engine)).astype(np.float64)
        r = np.clip(r, -RESID_CLAMP, RESID_CLAMP)
        p50 = np.maximum(anchors * np.exp(r[:, 0]), 1.0)
        p90 = np.maximum(anchors * np.exp(r[:, 1]), p50)
        return p50, p90

    def reserve_batch(self, jobs: list[Job], engine=None) -> np.ndarray:
        """p90 reservations for a backfill window (conservative gate)."""
        return self.predict_quantiles(jobs, engine)[1]

    def reserve_runtime(self, job: Job, engine=None) -> float:
        return float(self.reserve_batch([job], engine)[0])

    def predict_runtime(self, job: Job, engine=None) -> float:
        return float(self.predict_quantiles([job], engine)[0][0])

    def lookahead_durations(self, jobs: list[Job], engine=None) -> list[float]:
        """p50 durations for the MILP lookahead jobs (replaces the
        declared-duration assumption in ``core.milp``)."""
        return [float(v) for v in self.predict_quantiles(jobs, engine)[0]]

    def pending_gpu_hours(self, engine=None, cap: int = 512) -> float:
        """Predicted GPU-hours queued in the pending window — the demand
        forecast the autoscaler hysteresis controllers consume.  Windows
        deeper than ``cap`` are scored on the head and extrapolated."""
        engine = engine if engine is not None else self.engine
        pending = engine.pending
        if not pending:
            return 0.0
        window = pending[:cap]
        p50, _ = self.predict_quantiles(window, engine)
        gh = float(np.dot([j.num_gpus for j in window], p50)) / 3600.0
        if len(pending) > len(window):
            gh *= len(pending) / len(window)
        return gh

    # ------------------------------------------------------------- training --
    def on_submit(self, job: Job, now: float) -> None:
        if len(self._cache) >= self.max_cached:
            self._cache.pop(next(iter(self._cache)))
        self._cache[job.job_id] = self._job_row(job, self.engine, now)

    def on_finish(self, job: Job, now: float) -> None:
        actual = max(float(job.runtime), 1.0)
        anchor = self._anchor(job)
        row = self._cache.pop(job.job_id, None)
        if row is None:
            row = self._job_row(job, self.engine, now)
        x = np.empty(PREDICT_FEATURES, np.float32)
        x[:NUM_FEATURES] = row
        x[NUM_FEATURES:] = (self._context(self.engine)
                            if self.engine is not None else self._ctx)
        # prequential errors: predict with the *current* model, then update
        r = float(np.clip(self.mlp.forward(x[None, :])[0, 0],
                          -RESID_CLAMP, RESID_CLAMP))
        p50 = max(anchor * math.exp(r), 1.0)
        base = max(self.baseline.predict(job), 1.0)
        e_mlp = abs(p50 - actual) / actual
        e_base = abs(base - actual) / actual
        self._err_mlp.append(e_mlp)
        self._err_base.append(e_base)
        self._sum_err_mlp += e_mlp
        self._sum_err_base += e_base
        self._n_err += 1
        y = min(max(math.log(actual / anchor), -RESID_CLAMP), RESID_CLAMP)
        self.mlp.sgd_step(x, y)
        est = float(job.est_runtime)
        if math.isfinite(est) and est > 0.0:
            # cohort bias is measured against the *declared* estimate (the
            # debiased anchor would feed back on itself)
            yb = min(max(math.log(actual / max(est, 1.0)),
                         -RESID_CLAMP), RESID_CLAMP)
            key = (job.user, _gpu_bucket(job.num_gpus))
            self._bias_sum[key] = self._bias_sum.get(key, 0.0) + yb
            self._bias_n[key] = self._bias_n.get(key, 0) + 1
        self.baseline.observe(job, actual)
        self.train_steps += 1

    # ------------------------------------------------------------ reporting --
    def note_reservation(self, slack_s: float) -> None:
        """Engine callback at predictor-gated backfill commit:
        ``slack_s = t_res - (now + p90)`` (how much headroom the
        reservation left)."""
        self.reservations += 1
        self.reservation_slacks.append(float(slack_s))

    def recent_slacks(self, cursor: int) -> tuple[list[float], int]:
        """Slack samples recorded since ``cursor`` (a previous return
        value), oldest first, capped at the ring length."""
        new = self.reservations - cursor
        if new <= 0:
            return [], self.reservations
        avail = min(new, len(self.reservation_slacks))
        return list(self.reservation_slacks)[-avail:], self.reservations

    def rolling_mape(self) -> float:
        """Windowed prequential MAPE of the MLP p50 head (0.0 until the
        first completion — zero-division-safe)."""
        return float(np.mean(self._err_mlp)) if self._err_mlp else 0.0

    def baseline_rolling_mape(self) -> float:
        return float(np.mean(self._err_base)) if self._err_base else 0.0

    def mape(self) -> float:
        """Cumulative prequential MAPE of the MLP p50 head."""
        return self._sum_err_mlp / max(self._n_err, 1)

    def baseline_mape(self) -> float:
        return self._sum_err_base / max(self._n_err, 1)
