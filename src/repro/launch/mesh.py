"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device state;
the dry-run sets XLA_FLAGS before any jax import to fake 512 host devices.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
