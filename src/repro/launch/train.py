"""Production training driver.

Runs any assigned arch on any mesh (production 16x16 / 2x16x16 or a local
host mesh), with: deterministic restart-safe data, periodic async
checkpoints, crash restore (elastic: restores onto whatever mesh is
available), gradient-accumulation microbatching, and step-time logging.

Smoke mode (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import LM, ModelImpl
from repro.sharding.specs import DEFAULT_RULES, logical_spec, sanitize_tree
from repro.train.optimizer import OptConfig, opt_init, opt_specs
from repro.train.step import make_train_step


def shard_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, PS))


def train_loop(arch: str, *, smoke: bool = False, steps: int = 50,
               batch: int = 8, seq: int = 128, microbatches: int = 1,
               ckpt_dir: str | None = None, ckpt_interval: int = 20,
               mesh=None, log_every: int = 10, lr: float = 3e-4,
               resume: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = LM(cfg, impl=ModelImpl())
    mesh = mesh or make_host_mesh()
    rules = DEFAULT_RULES

    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 10, 5),
                        total_steps=steps)
    step_fn = make_train_step(model, opt_cfg, microbatches=microbatches)

    abstract_params = model.abstract_params()
    pspecs = sanitize_tree(model.param_specs(rules, mesh), abstract_params,
                           mesh)
    ospecs = opt_specs(pspecs)
    data_spec = logical_spec(("batch", "seq"), rules, mesh)

    ds = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=0)
    mgr = CheckpointManager(ckpt_dir, interval=ckpt_interval) if ckpt_dir \
        else None

    with mesh:
        params = jax.jit(
            model.init, out_shardings=shard_tree(mesh, pspecs)
        )(jax.random.PRNGKey(0))
        opt_state = jax.jit(
            opt_init, out_shardings=shard_tree(mesh, ospecs))(params)
        start_step = 0
        if mgr is not None and resume:
            restored, at = mgr.restore(
                {"params": params, "opt": opt_state},
                mesh, {"params": pspecs, "opt": ospecs})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                start_step = int(at)
                print(f"[train] restored checkpoint at step {start_step}")

        jit_step = jax.jit(
            step_fn,
            in_shardings=(shard_tree(mesh, pspecs), shard_tree(mesh, ospecs),
                          NamedSharding(mesh, data_spec)),
            out_shardings=(shard_tree(mesh, pspecs),
                           shard_tree(mesh, ospecs), None),
            donate_argnums=(0, 1))

        losses = []
        t0 = time.time()
        for step in range(start_step, steps):
            hbatch = ds.batch_at(step)
            dbatch = {k: jax.device_put(v, NamedSharding(mesh, data_spec))
                      for k, v in hbatch.items()}
            params, opt_state, metrics = jit_step(params, opt_state, dbatch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if mgr is not None:
                mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
            if log_every and (step + 1) % log_every == 0:
                dt = (time.time() - t0) / max(step + 1 - start_step, 1)
                print(f"[train] step {step + 1}/{steps} loss={loss:.4f} "
                      f"gnorm={float(metrics['gnorm']):.3f} "
                      f"{dt * 1e3:.0f} ms/step", flush=True)
        if mgr is not None:
            mgr.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    mesh = None
    if args.production_mesh or args.multi_pod:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    out = train_loop(args.arch, smoke=args.smoke, steps=args.steps,
                     batch=args.batch, seq=args.seq,
                     microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                     ckpt_interval=args.ckpt_interval, mesh=mesh, lr=args.lr)
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
