"""Roofline-term derivation from compiled dry-run artifacts.

All three terms are PER-CHIP seconds (per-chip work / per-chip rate):

  compute    = flops_per_chip / 197e12         (TPU v5e bf16 peak)
  memory     = hbm_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9 (per ICI link)

Sources, and why there are two of each:

- The compiled program is the SPMD-partitioned per-device module, so
  `cost_analysis()` flops/bytes and HLO shapes are per-chip.  BUT XLA counts
  a while-loop body ONCE, so rolled layer/microbatch scans undercount by
  their trip counts.  We therefore (a) parse the HLO call graph and multiply
  collective bytes by enclosing while trip counts, and (b) compute an
  ANALYTIC flops/bytes model from the config as the primary compute/memory
  source (validated against fully-unrolled accounting compiles on the small
  archs — see EXPERIMENTS.md §Roofline).
- `collective_bytes` uses each collective's output-shape bytes as the
  per-chip traffic proxy (all-gather: bytes received; all-reduce: ~2x(N-1)/N
  of that — we keep the raw proxy and note it).
"""
from __future__ import annotations

import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
# header params may be nested tuples -> greedy paren match
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)"
                      r"%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _parse_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _entry_name(hlo_text: str) -> str | None:
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = _COMP_RE.match(s)
            if m:
                return m.group(1)
    return None


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the largest s32 constant in the while condition."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-chip collective bytes, corrected by enclosing while trip counts."""
    comps = _parse_computations(hlo_text)
    entry = _entry_name(hlo_text)

    # direct collective bytes + calls per computation
    direct: dict[str, dict[str, int]] = {}
    counts: dict[str, dict[str, int]] = {}
    whiles: dict[str, list[tuple[str, int]]] = {}   # comp -> [(body, trips)]
    calls: dict[str, list[str]] = {}
    for name, lines in comps.items():
        d = {k: 0 for k in _COLLECTIVES}
        c = {k: 0 for k in _COLLECTIVES}
        w: list[tuple[str, int]] = []
        cl: list[str] = []
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(ln)   # prefer XLA's own trip count
                trips = int(tm.group(1)) if tm else \
                    _trip_count(comps.get(cond, []))
                w.append((body, trips))
                cl.append(cond)
                continue
            for kind in _COLLECTIVES:
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    if "-done(" in ln:
                        continue
                    shape_part = ln.split("=", 1)[1].split(kind)[0] if "=" in ln \
                        else ln
                    d[kind] += sum(_bytes_of_shape(dt, dims)
                                   for dt, dims in _SHAPE_RE.findall(shape_part))
                    c[kind] += 1
                    break
            for grp in _CALL_RE.findall(ln):
                for g in grp.split(","):
                    cl.append(g.strip().lstrip("%"))
        direct[name], counts[name], whiles[name], calls[name] = d, c, w, cl

    # propagate multipliers down the call graph from ENTRY
    mult: dict[str, int] = {}

    def visit(name: str, m: int) -> None:
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0), m)
        for body, trips in whiles.get(name, []):
            visit(body, m * max(trips, 1))
        for callee in calls.get(name, []):
            if callee in comps and callee not in [b for b, _ in whiles.get(name, [])]:
                visit(callee, m)

    if entry:
        visit(entry, 1)
    else:  # fallback: everything multiplier 1
        for name in comps:
            mult[name] = 1

    out = {k: 0 for k in _COLLECTIVES}
    cnt = {k: 0 for k in _COLLECTIVES}
    for name in comps:
        m = mult.get(name, 1)
        for kind in _COLLECTIVES:
            out[kind] += direct[name][kind] * m
            cnt[kind] += counts[name][kind] * m
    out["_counts"] = cnt  # type: ignore[assignment]
    return out


# ------------------------------------------------------------- analytic model ---


def analytic_cost(cfg, shape, *, microbatches: int = 1, remat: bool = True,
                  chips: int = 256, model=None) -> dict[str, float]:
    """First-principles flops (global) + HBM bytes (per chip) for a step.

    Formulas (B=global batch, L=seq, d=d_model, per layer):
      attn proj flops = 2*d*hd*(H + 2*KV + H) * tokens
      attn score/av   = 2 * 2 * H*hd * L_kv * tokens      (causal: x0.5)
      mlp             = 2*d*ff*(3 gated | 2) * tokens
      moe             = (2*d*E + k*3*2*d*F) * tokens
      ssd             = (2*(2di+2N+H)*d + 2*K*cd + 2*Q*(N+H*P) + 8*H*P*N
                         + 2*di*d) * tokens
      logits          = 2*d*Vp * tokens
    train: x3 (fwd+bwd), x4 with full remat.  Memory: weights traffic x
    microbatches, optimizer r/w, activation r/w estimate, logits, KV cache.
    """
    from repro.configs.base import SHAPES, padded_vocab
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, L = shape.global_batch, shape.seq_len
    d, hd = cfg.d_model, cfg.head_dim_
    H, KV = cfg.num_heads, cfg.num_kv_heads
    Vp = padded_vocab(cfg.vocab_size)
    kind = shape.kind
    decode = kind == "decode"
    tokens = B * (1 if decode else L)
    L_kv = L                                 # decode: context length
    win = cfg.window or 0

    # ---- per-layer flops per token, by layer type ----
    def attn_flops(causal: bool) -> float:
        proj = 2 * d * hd * (2 * H + 2 * KV)
        ctx = min(win, L_kv) if win else L_kv
        score = 2 * 2 * H * hd * ctx * (0.5 if (causal and not decode) else 1.0)
        return proj + score

    def mlp_flops() -> float:
        mult = 3 if cfg.activation in ("silu", "gelu") else 2
        return 2 * d * cfg.d_ff * mult

    def moe_flops() -> float:
        F = cfg.moe_d_ff or cfg.d_ff
        return 2 * d * cfg.num_experts + cfg.experts_per_token * 3 * 2 * d * F

    def ssd_flops() -> float:
        di = cfg.ssm_expand * d
        Hs = di // cfg.ssm_head_dim
        P, N, K = cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
        cd = di + 2 * N
        Q = 1 if decode else min(cfg.ssm_chunk, L)
        return (2 * d * (2 * di + 2 * N + Hs) + 2 * K * cd
                + 2 * Q * (N + Hs * P) + 8 * Hs * P * N + 2 * di * d)

    per_tok = 0.0
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        per_layer = attn_flops(True) + (moe_flops() if fam == "moe"
                                        else mlp_flops())
        per_tok += cfg.num_layers * per_layer
    elif fam == "ssm":
        per_tok += cfg.num_layers * ssd_flops()
    elif fam == "hybrid":
        per = cfg.attn_period
        n_attn = cfg.num_layers // per
        n_mamba = cfg.num_layers - n_attn
        n_moe = cfg.num_layers // max(cfg.moe_period, 1)
        n_mlp = cfg.num_layers - n_moe
        per_tok += (n_attn * attn_flops(True) + n_mamba * ssd_flops()
                    + n_moe * moe_flops() + n_mlp * mlp_flops())
    elif fam == "audio":
        dec = cfg.num_layers * (attn_flops(True) + mlp_flops()
                                + attn_flops(False))  # self + mlp + cross
        per_tok += dec
    per_tok += 2 * d * Vp                               # logits
    fwd = per_tok * tokens
    if fam == "audio" and not decode:
        enc_tokens = B * cfg.encoder_frames
        fwd += enc_tokens * cfg.encoder_layers * (attn_flops(False) + mlp_flops())

    if kind == "train":
        flops = fwd * (4.0 if remat else 3.0)
    else:
        flops = fwd

    # ---- per-chip HBM bytes ----
    if model is not None:
        P_total = model.param_count()
        P_active = model.active_param_count()
    else:
        P_total = P_active = 0
    pb = 2.0 * P_total / chips                      # param shard bytes (bf16)
    act_unit = tokens * cfg.num_layers * d * 2.0 / chips   # one act tensor
    if kind == "train":
        weights = 3.0 * microbatches * pb           # fwd+bwd+remat, per mb
        optimizer = (4 + 4 + 4 + 4 + 2 + 2) * P_total / chips
        acts = act_unit * 24.0                      # ~12 r/w pairs per layer
        logits_b = tokens * Vp * 8.0 / chips
        hbm = weights + optimizer + acts + logits_b
    elif kind == "prefill":
        hbm = pb + act_unit * 8.0 + tokens * Vp * 4.0 / chips
    else:  # decode
        kv_bytes = 0.0
        if fam in ("dense", "vlm", "moe", "audio"):
            S_eff = min(win, L) if win else L
            kv_bytes = cfg.num_layers * B * KV * S_eff * hd * 2 * 2.0
        elif fam == "hybrid":
            n_attn = cfg.num_layers // cfg.attn_period
            kv_bytes = n_attn * B * KV * L * hd * 2 * 2.0
            di = cfg.ssm_expand * d
            Hs = di // cfg.ssm_head_dim
            kv_bytes += (cfg.num_layers - n_attn) * B * Hs * cfg.ssm_head_dim \
                * cfg.ssm_state * 4.0
        elif fam == "ssm":
            di = cfg.ssm_expand * d
            Hs = di // cfg.ssm_head_dim
            kv_bytes = cfg.num_layers * B * Hs * cfg.ssm_head_dim \
                * cfg.ssm_state * 4.0
        hbm = 2.0 * P_active / chips + kv_bytes / chips + tokens * Vp * 4.0 / chips

    return {"flops_global": flops, "hbm_bytes_per_chip": hbm,
            "flops_per_chip": flops / chips}


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS_BF16
    memory = hbm_bytes_per_chip / HBM_BW
    collective = coll_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    return {**terms, "dominant": dominant,
            "roofline_fraction": compute / bound if bound > 0 else 0.0}


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); forward-only shapes
    use 2*N*D; decode: D = batch tokens."""
    from repro.configs.base import SHAPES
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.kind == "train":
        return 6.0 * active_params * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active_params * shape.global_batch * shape.seq_len
    return 2.0 * active_params * shape.global_batch
