"""Serving driver: batched requests through prefill + continuous decode.

Smoke mode (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM, ModelImpl
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg, impl=ModelImpl())
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=args.batch)

    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    prompt=list(rng.integers(1, cfg.vocab_size,
                                             size=args.prompt_len)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req{r.req_id}: {r.output}")


if __name__ == "__main__":
    main()
