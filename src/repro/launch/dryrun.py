import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent on the production
mesh (16x16 single-pod, 2x16x16 multi-pod) without hardware: the jit step is
lowered from ShapeDtypeStructs (no allocation), compiled, and its
memory_analysis / cost_analysis / collective schedule recorded as JSON under
benchmarks/artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as PS  # noqa: E402

from repro.configs import (ALL_ARCHS, SHAPES, get_config, input_specs,  # noqa: E402
                           shape_applicable)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (analytic_cost, collective_bytes,  # noqa: E402
                                   model_flops, roofline_terms)
from repro.models.lm import LM, ModelImpl  # noqa: E402
from repro.sharding.specs import (DEFAULT_RULES, logical_spec,  # noqa: E402
                                  sanitize_spec, sanitize_tree)
from repro.train.optimizer import OptConfig, abstract_opt_state, opt_specs  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

# per-arch train-step microbatching (activation memory control at batch 256)
TRAIN_MICROBATCHES = {
    "qwen3-moe-235b-a22b": 16,
    "jamba-v0.1-52b": 8,
    "nemotron-4-15b": 8,
    "yi-6b": 4,
    "internvl2-2b": 2,
    "h2o-danube-1.8b": 2,
    "stablelm-1.6b": 2,
    "granite-moe-1b-a400m": 2,
    "mamba2-780m": 2,
    "whisper-tiny": 1,
}
LOSS_CHUNK = {"nemotron-4-15b": 512, "qwen3-moe-235b-a22b": 512}


def _sharding(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, PS))


def _batch_specs(cfg, shape, mesh, rules):
    """PartitionSpecs for the input batch dict (divisibility-sanitized)."""
    specs = {}
    for key, sds in input_specs(cfg, shape).items():
        if key in ("tokens", "labels"):
            lg = ("batch", "seq")
        elif key == "patch_embeds":
            lg = ("batch", "seq", "embed_act")
        else:  # audio_frames
            lg = ("batch", "frames", "embed_act")
        specs[key] = sanitize_spec(logical_spec(lg[:len(sds.shape)], rules, mesh),
                                   sds.shape, mesh)
    return specs


def lower_cell(arch: str, shape_name: str, mesh, rules=None,
               impl: ModelImpl | None = None, microbatches: int | None = None):
    """Lower + compile one cell; returns (record dict, compiled)."""
    rules = rules or DEFAULT_RULES
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    impl = impl or ModelImpl(loss_chunk=LOSS_CHUNK.get(arch, 0))
    model = LM(cfg, impl=impl, rules=rules)
    chips = mesh.size

    abstract_params = model.abstract_params()
    pspecs = sanitize_tree(model.param_specs(rules, mesh), abstract_params, mesh)
    in_specs = _batch_specs(cfg, shape, mesh, rules)
    abstract_batch = input_specs(cfg, shape)
    from repro.configs.base import padded_vocab
    Vp = padded_vocab(cfg.vocab_size)

    with mesh:
        if shape.kind == "train":
            mb = microbatches if microbatches is not None else \
                TRAIN_MICROBATCHES.get(arch, 1)
            step = make_train_step(model, OptConfig(), microbatches=mb)
            ospecs = opt_specs(pspecs)
            fn = jax.jit(
                step,
                in_shardings=(_sharding(mesh, pspecs), _sharding(mesh, ospecs),
                              _sharding(mesh, in_specs)),
                out_shardings=(_sharding(mesh, pspecs),
                               _sharding(mesh, ospecs), None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(abstract_params,
                               abstract_opt_state(abstract_params),
                               abstract_batch)
        elif shape.kind == "prefill":
            cache_sp = sanitize_tree(
                model.cache_specs(shape.global_batch, shape.seq_len, rules,
                                  mesh),
                model.abstract_cache(shape.global_batch, shape.seq_len), mesh)
            logits_sp = sanitize_spec(
                logical_spec(("batch", "vocab"), rules, mesh),
                (shape.global_batch, Vp), mesh)

            def prefill(params, batch):
                return model.prefill(
                    params, batch["tokens"],
                    patch_embeds=batch.get("patch_embeds"),
                    audio_frames=batch.get("audio_frames"))

            fn = jax.jit(
                prefill,
                in_shardings=(_sharding(mesh, pspecs), _sharding(mesh, in_specs)),
                out_shardings=(NamedSharding(mesh, logits_sp),
                               _sharding(mesh, cache_sp)),
            )
            lowered = fn.lower(abstract_params, abstract_batch)
        else:  # decode
            S = shape.seq_len
            abstract_cache = model.abstract_cache(shape.global_batch, S)
            cache_sp = sanitize_tree(
                model.cache_specs(shape.global_batch, S, rules, mesh),
                abstract_cache, mesh)
            logits_sp = sanitize_spec(
                logical_spec(("batch", "vocab"), rules, mesh),
                (shape.global_batch, Vp), mesh)
            tok_sp = sanitize_spec(logical_spec(("batch", "seq"), rules, mesh),
                                   (shape.global_batch, 1), mesh)

            def decode(params, tokens, cache):
                return model.decode_step(params, tokens, cache)

            fn = jax.jit(
                decode,
                in_shardings=(_sharding(mesh, pspecs),
                              NamedSharding(mesh, tok_sp),
                              _sharding(mesh, cache_sp)),
                out_shardings=(NamedSharding(mesh, logits_sp),
                               _sharding(mesh, cache_sp)),
                donate_argnums=(2,),
            )
            lowered = fn.lower(abstract_params, abstract_batch["tokens"],
                               abstract_cache)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: list with one dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # per-chip collective bytes, while-trip-count corrected
    coll = collective_bytes(hlo)
    counts = coll.pop("_counts")
    coll_total = sum(coll.values())
    # per-chip HLO numbers (partitioned module; rolled scans count body once)
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    # analytic primary source (validated vs unrolled compiles; see roofline.py)
    mb = microbatches if microbatches is not None else \
        (TRAIN_MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1)
    ana = analytic_cost(cfg, shape, microbatches=mb,
                        remat=impl.remat, chips=chips, model=model)
    terms = roofline_terms(ana["flops_per_chip"], ana["hbm_bytes_per_chip"],
                           coll_total)
    mflops = model_flops(cfg, shape, model.active_param_count())

    record = {
        "arch": arch, "shape": shape_name, "mesh": list(mesh.devices.shape),
        "chips": chips, "compile_s": round(compile_s, 2),
        "microbatches": mb,
        "flops_per_chip": ana["flops_per_chip"],
        "flops_global": ana["flops_global"],
        "hbm_bytes_per_chip": ana["hbm_bytes_per_chip"],
        "hlo_flops_per_chip": hlo_flops,
        "hlo_bytes_per_chip": hlo_bytes,
        "collective_bytes": coll, "collective_counts": counts,
        "collective_total": coll_total,
        "model_flops": mflops,
        "useful_flops_frac": mflops / ana["flops_global"]
        if ana["flops_global"] else 0.0,
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        **terms,
    }
    return record, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(ARTIFACT_DIR)
    os.makedirs(out_dir, exist_ok=True)

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_tag = "multipod" if multi_pod else "singlepod"
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                if not shape_applicable(cfg, shape_name):
                    print(f"[skip] {arch} x {shape_name} "
                          f"(full attention; see DESIGN.md)")
                    continue
                tag = f"{mesh_tag}/{arch}__{shape_name}"
                path = os.path.join(out_dir, mesh_tag)
                os.makedirs(path, exist_ok=True)
                fpath = os.path.join(path, f"{arch}__{shape_name}.json")
                t0 = time.time()
                try:
                    rec, compiled = lower_cell(arch, shape_name, mesh,
                                               microbatches=args.microbatches)
                    print(f"[ok]   {tag}: compile={rec['compile_s']}s "
                          f"mem/dev={rec['memory']['bytes_per_device']/2**30:.2f}GiB "
                          f"compute={rec['compute_s']*1e3:.1f}ms "
                          f"mem={rec['memory_s']*1e3:.1f}ms "
                          f"coll={rec['collective_s']*1e3:.1f}ms "
                          f"dom={rec['dominant']}", flush=True)
                    with open(fpath, "w") as f:
                        json.dump(rec, f, indent=1)
                    del compiled
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag} after {time.time()-t0:.0f}s: {e}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
