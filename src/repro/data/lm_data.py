"""Deterministic synthetic LM data pipeline.

Tokens are generated from a seeded counter-based generator (Philox via
numpy), so step `k` always yields the same batch — restart-safe (a job that
restarts from a checkpoint at step k resumes the exact data stream) and
host-shardable (each host materializes only its slice of the global batch).

A light Markov structure makes the stream learnable (examples/train_lm.py
shows loss going down), not just uniform noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    markov_order: bool = True

    def __post_init__(self) -> None:
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        # fixed random transition offsets: token_{t+1} ~ f(token_t) + noise
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        self._jump = rng.integers(1, self.vocab_size,
                                  size=(256,), dtype=np.int64)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """{tokens, labels} of shape (local_batch, seq_len), deterministic."""
        rng = np.random.default_rng(
            (np.int64(self.seed) << 20) + np.int64(step) * self.num_hosts
            + self.host_id)
        B, L, V = self.local_batch, self.seq_len, self.vocab_size
        noise = rng.integers(0, V, size=(B, L + 1), dtype=np.int64)
        if self.markov_order:
            toks = np.empty((B, L + 1), dtype=np.int64)
            toks[:, 0] = noise[:, 0]
            mix = rng.random((B, L)) < 0.85
            for t in range(L):
                nxt = (toks[:, t] + self._jump[toks[:, t] % 256]) % V
                toks[:, t + 1] = np.where(mix[:, t], nxt, noise[:, t + 1])
        else:
            toks = noise
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_for(cfg, shape, *, step: int = 0, seed: int = 0,
              num_hosts: int = 1, host_id: int = 0) -> dict[str, np.ndarray]:
    """Concrete batch matching `input_specs(cfg, shape)` (for runnable tests)."""
    from repro.configs.base import SHAPES, input_specs
    if isinstance(shape, str):
        shape = SHAPES[shape]
    specs = input_specs(cfg, shape)
    tok_shape = specs["tokens"].shape
    ds = SyntheticLMDataset(cfg.vocab_size, tok_shape[1], tok_shape[0],
                            seed=seed, num_hosts=num_hosts, host_id=host_id)
    batch = dict(ds.batch_at(step))
    if "labels" not in specs:
        batch.pop("labels")
    rng = np.random.default_rng(seed + 17)
    for key in ("patch_embeds", "audio_frames"):
        if key in specs:
            s = specs[key]
            local = (s.shape[0] // num_hosts,) + s.shape[1:]
            batch[key] = (rng.standard_normal(local) * 0.02).astype("float32")
    return batch
