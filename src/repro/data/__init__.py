from repro.data.lm_data import SyntheticLMDataset, batch_for

__all__ = ["SyntheticLMDataset", "batch_for"]
