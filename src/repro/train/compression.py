"""Gradient compression for cross-pod reduction.

int8 quantization with per-tensor scale: grads are quantized before the
slow cross-pod all-reduce and dequantized after, cutting pod-interconnect
bytes 4x (bf16->int8 is 2x; fp32 accumulators->int8 is 4x).  Exposed as a
shard_map-level reducer over the `pod` axis; within-pod reductions stay
full precision (ICI is fast, DCN between pods is the bottleneck).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads):
    return jax.tree.map(lambda g: quantize_int8(g.astype(jnp.float32)), grads,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def decompress_tree(qtree):
    return jax.tree.map(lambda qs: dequantize_int8(*qs), qtree,
                        is_leaf=lambda x: isinstance(x, tuple))


def pod_allreduce_compressed(grads, axis_name: str = "pod"):
    """Inside shard_map: int8 all-reduce over the pod axis.

    Quantize -> psum int32 -> dequantize with the max scale.  Using the max
    scale across pods keeps the estimate unbiased up to rounding; error is
    bounded by scale/2 per element per pod.
    """
    def reduce_one(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis_name)       # common scale
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total.astype(jnp.float32) * scale / n

    return jax.tree.map(reduce_one, grads)
