from repro.train.optimizer import OptConfig, opt_init, opt_update
from repro.train.step import make_eval_step, make_train_step

__all__ = ["OptConfig", "opt_init", "opt_update", "make_train_step",
           "make_eval_step"]
