"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Used when the `pod` axis is repurposed as a pipeline axis: each pod holds a
contiguous slice of layers; microbatches stream through stages with
`jax.lax.ppermute` hand-offs.  The steady-state schedule keeps all stages
busy except the (S-1)-bubble at the ends, the classic GPipe trade-off.

This module is self-contained (works on any mesh axis); tests exercise it on
a small host-device mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def pipeline_forward(stage_fn: Callable, h: jax.Array, stage_params,
                     *, axis_name: str, num_stages: int,
                     num_microbatches: int) -> jax.Array:
    """Run inside shard_map: h (M, mb, L, d) microbatched activations.

    stage_fn(params, x) -> x applies THIS device's layer slice.
    stage_params: this stage's parameter slice.
    Returns outputs in original microbatch order (valid on the last stage,
    broadcast back to all stages for loss symmetry).
    """
    M, S = num_microbatches, num_stages
    stage = jax.lax.axis_index(axis_name)
    T = M + S - 1                      # total pipeline ticks

    def tick(carry, t):
        buf, outs = carry              # buf: (mb, L, d) in-flight activation
        # stage 0 injects microbatch t (if any remain)
        inject = jnp.where(t < M, t, M - 1)
        x0 = jax.lax.dynamic_index_in_dim(h, inject, axis=0, keepdims=False)
        x_in = jnp.where(stage == 0, x0, buf)
        y = stage_fn(stage_params, x_in)
        # last stage records its finished microbatch (t - (S-1))
        out_idx = jnp.clip(t - (S - 1), 0, M - 1)
        record = jnp.logical_and(stage == S - 1, t >= S - 1)
        outs = jax.lax.cond(
            record,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o, outs)
        # hand activation to the next stage
        buf = jax.lax.ppermute(y, axis_name,
                               [(i, (i + 1) % S) for i in range(S)])
        return (buf, outs), None

    buf0 = jnp.zeros_like(h[0])
    outs0 = jnp.zeros_like(h)
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
    # broadcast final outputs from the last stage to every stage
    outs = jax.lax.ppermute(outs, axis_name,
                            [((S - 1 + i) % S, i) for i in range(S)])
    return outs


def make_pipelined_apply(stage_fn: Callable, mesh, *, axis_name: str = "pod",
                         num_microbatches: int = 4):
    """Wrap a per-stage layer fn into a full pipelined apply via shard_map."""
    from jax.experimental.shard_map import shard_map

    S = mesh.shape[axis_name]

    def apply(stacked_params, h):
        # h: (M, mb, L, d) replicated; params stacked (S, ...) sharded on axis
        def inner(params_slice, h_rep):
            params_slice = jax.tree.map(lambda x: x[0], params_slice)
            return pipeline_forward(stage_fn, h_rep, params_slice,
                                    axis_name=axis_name, num_stages=S,
                                    num_microbatches=num_microbatches)

        pspec = jax.tree.map(lambda _: PS(axis_name), stacked_params)
        return shard_map(inner, mesh=mesh,
                         in_specs=(pspec, PS()), out_specs=PS(),
                         check_rep=False)(stacked_params, h)

    return apply
