"""train_step / eval_step factories.

- microbatched gradient accumulation (`lax.scan` over microbatches, fp32
  accumulators) — the overlap-friendly structure XLA pipelines against the
  FSDP all-gathers;
- donation of params/opt state (in-place update, halves peak memory);
- sharding: in/out specs derived from the model's logical schema.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.train.optimizer import OptConfig, opt_update


def _split_microbatches(batch: dict, k: int) -> dict:
    return jax.tree.map(lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                        batch)


def make_train_step(model: LM, opt_cfg: OptConfig, *, microbatches: int = 1
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Not yet jitted — callers wrap with jax.jit + shardings."""

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        return loss, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def body(carry, microbatch):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, microbatch)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grad_sum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grad_sum)
        else:
            loss, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        params, opt_state, stats = opt_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: LM) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step


def jit_train_step(model: LM, train_step: Callable, mesh, rules=None,
                   batch_spec: dict[str, Any] | None = None):
    """jit with explicit in/out shardings + donation on (params, opt_state)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as PS

    from repro.sharding.specs import logical_spec
    from repro.train.optimizer import opt_specs

    pspecs = model.param_specs(rules, mesh)
    ospecs = opt_specs(pspecs)
    bspec = batch_spec or {}
    data_ps = logical_spec(("batch", "seq"), rules, mesh)

    def shard(tree_spec):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                            is_leaf=lambda x: isinstance(x, PS))

    in_sh = (shard(pspecs), shard(ospecs), None)
    out_sh = (shard(pspecs), shard(ospecs), None)
    return jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1)), data_ps
