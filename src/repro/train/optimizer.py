"""AdamW with FSDP-friendly state layout.

Memory design for the 235B-on-256-chip case: params live in bf16; Adam
moments are fp32 and sharded exactly like the params (2D: embed->data,
tp-axis->model); there is NO separate fp32 master copy — the update is
computed in fp32 from the bf16 param and cast back (≈12 bytes/param total
state, fully sharded).  lr schedule: linear warmup + cosine decay.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def opt_init(params):
    """Moments in fp32, same tree/sharding as params; step counter scalar."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params):
    zeros = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                         abstract_params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                             jnp.float32),
                              abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_specs(param_specs):
    """Moments share the params' PartitionSpecs (fully sharded states)."""
    from jax.sharding import PartitionSpec as PS
    return {"m": param_specs, "v": param_specs, "step": PS()}


def _decay_mask(path: tuple) -> bool:
    """No weight decay on norms/biases/scalars (1-D leaves)."""
    leaf_name = str(path[-1]) if path else ""
    return not any(s in leaf_name for s in ("scale", "bias", "A_log", "D",
                                            "dt_bias"))


def opt_update(params, grads, state, cfg: OptConfig):
    """One AdamW step. grads: fp32 (or castable). Returns (params, state, stats)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    gflat, _ = jax.tree.flatten(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in gflat))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    params_flat, treedef = jax.tree.flatten(params)
    grads_flat = jax.tree.leaves(grads)
    m_flat = jax.tree.leaves(state["m"])
    v_flat = jax.tree.leaves(state["v"])

    new_p, new_m, new_v = [], [], []
    for path, p, g, m, v in zip(paths, params_flat, grads_flat, m_flat, v_flat):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * pf
        new_p.append((pf - lr * upd).astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)

    params = jax.tree.unflatten(treedef, new_p)
    state = {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v), "step": step}
    return params, state, {"gnorm": gnorm, "lr": lr}
