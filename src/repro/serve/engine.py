"""Batched serving engine: continuous batching over a request queue.

Requests (prompt token lists) are grouped into fixed-size decode batches;
finished sequences are retired and their slots refilled from the queue
(continuous batching).  Prefill runs per-request (padded to the bucket
size), decode runs one fused step for the whole batch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.serve.step import make_decode_step


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: LM, params, *, batch_size: int = 4,
                 max_len: int = 256, eos_id: int = -1):
        self.model = model
        self.params = params
        self.B = batch_size
        self.S = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(make_decode_step(model))
        self._forward_prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, pad_to=self.S))

    def _prefill_batch(self, reqs: list[Request]):
        """Left-pad prompts to a common length, prefill, return cache+last tok."""
        assert len(reqs) == self.B
        L = max(len(r.prompt) for r in reqs)
        toks = np.zeros((self.B, L), np.int32)
        for i, r in enumerate(reqs):
            toks[i, L - len(r.prompt):] = r.prompt     # left-pad with 0
        logits, cache = self._forward_prefill(self.params, jnp.asarray(toks))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve all requests to completion; returns them with outputs."""
        queue = list(requests)
        done: list[Request] = []
        while queue:
            batch = queue[:self.B]
            queue = queue[self.B:]
            while len(batch) < self.B:            # pad with a dummy request
                batch.append(Request(req_id=-1, prompt=[0], max_new_tokens=1))
            tok, cache = self._prefill_batch(batch)
            for i, r in enumerate(batch):
                if r.req_id >= 0:
                    r.output.append(int(tok[i, 0]))
            steps = max(r.max_new_tokens for r in batch) - 1
            for _ in range(max(steps, 0)):
                tok, _, cache = self._decode(self.params, tok, cache)
                for i, r in enumerate(batch):
                    if r.req_id < 0 or r.done:
                        continue
                    t = int(tok[i, 0])
                    if len(r.output) < r.max_new_tokens:
                        r.output.append(t)
                    if t == self.eos_id or len(r.output) >= r.max_new_tokens:
                        r.done = True
            done.extend(r for r in batch if r.req_id >= 0)
        return done
