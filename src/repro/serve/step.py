"""Serving step factories: prefill (full forward + cache build) and decode
(one token against the cache).  decode_* / long_* dry-run shapes lower these,
not train_step."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.models.lm import LM


def make_prefill_step(model: LM) -> Callable:
    def prefill_step(params, batch: dict):
        return model.prefill(params, batch["tokens"],
                             patch_embeds=batch.get("patch_embeds"),
                             audio_frames=batch.get("audio_frames"))
    return prefill_step


def make_decode_step(model: LM, *, greedy: bool = True) -> Callable:
    def decode_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache
    return decode_step
