from repro.serve.step import make_decode_step, make_prefill_step
from repro.serve.engine import ServeEngine

__all__ = ["make_decode_step", "make_prefill_step", "ServeEngine"]
