"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is scatter-based (Megablocks-style adapted to TPU/XLA): tokens are
grouped by the batch dim, each group scatter-adds its tokens into per-expert
capacity buffers, experts run batched GEMMs over (group, expert, cap, d), and
a gather+weighted-sum combines results.  This avoids materializing the
(tokens x experts x capacity) one-hot of the classic einsum formulation —
at 1M-token prefill that tensor would be >10 TB.

Expert parallelism: the expert dim is sharded over `model`, groups over
`(pod, data)`; GSPMD inserts the all-to-alls at the group<->expert transpose.
Capacity-dropped tokens fall through the residual (Switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, activation_fn
from repro.sharding.specs import AxisRules, with_logical_constraint


def moe_schema(cfg: ModelConfig) -> dict:
    d, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    dt = cfg.dtype
    return {
        "router": ParamSpec((d, E), ("embed", "experts"), jnp.float32, scale=0.1),
        "w_gate": ParamSpec((E, d, F), ("experts", "embed", "ffn"), dt),
        "w_up": ParamSpec((E, d, F), ("experts", "embed", "ffn"), dt),
        "w_down": ParamSpec((E, F, d), ("experts", "ffn", "embed"), dt),
    }


def router_topk(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """(..., E) -> (weights (..., k), indices (..., k)); softmax over the k."""
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, idx


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = max(int(cfg.capacity_factor * tokens_per_group * k / E), k)
    if cap >= 128:  # MXU-friendly rounding once buffers are big enough
        cap = (cap + 127) // 128 * 128
    return min(cap, tokens_per_group * k)


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig,
              rules: AxisRules | None = None, impl: str = "xla") -> jax.Array:
    """x: (B, L, d) -> (B, L, d).  B is the dispatch group dim."""
    B, L, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(cfg, L)

    if impl == "fused":
        from repro.kernels.ops import moe_router
        weights, experts = moe_router(x.reshape(B * L, d), p["router"], k)
        weights = weights.reshape(B, L, k)
        experts = experts.reshape(B, L, k)
    else:
        logits = x.astype(jnp.float32) @ p["router"]          # (B, L, E)
        weights, experts = router_topk(logits, k)             # (B, L, k)

    # position of each (token, choice) in its expert's buffer, per group
    flat_e = experts.reshape(B, L * k)                        # choice-major per token
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (B, L*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot             # (B, L*k, E)
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    pos = pos.reshape(B, L, k)
    keep = (pos < cap)
    weights = weights * keep.astype(weights.dtype)
    pos = jnp.where(keep, pos, cap - 1)  # clamp; dropped tokens masked anyway

    # scatter-add tokens into expert buffers, one scatter per routing choice
    buf = jnp.zeros((B, E, cap, d), dtype=x.dtype)
    b_idx = jnp.arange(B)[:, None]
    for j in range(k):
        contrib = x * keep[:, :, j, None].astype(x.dtype)
        buf = buf.at[b_idx, experts[:, :, j], pos[:, :, j]].add(
            contrib, mode="drop")
    buf = with_logical_constraint(buf, ("batch", "experts", "expert_cap",
                                        "embed_act"), rules)

    # expert FFN: batched over (group, expert)
    act = activation_fn(cfg.activation)
    hidden = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * \
        jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["w_down"])
    out_buf = with_logical_constraint(out_buf, ("batch", "experts", "expert_cap",
                                                "embed_act"), rules)

    # gather back + weighted combine
    out = jnp.zeros((B, L, d), dtype=jnp.float32)
    for j in range(k):
        gathered = out_buf[b_idx, experts[:, :, j], pos[:, :, j]]   # (B, L, d)
        out = out + gathered.astype(jnp.float32) * weights[:, :, j, None]
    return out.astype(x.dtype)


def moe_aux_loss(router_logits: jax.Array, experts: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balancing loss (mean prob x mean top-1 assignment)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    probs = probs.reshape(-1, E)
    top1 = experts.reshape(-1, experts.shape[-1])[:, 0]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    return E * jnp.sum(me * ce)
