"""Mamba2 (SSD — state-space duality) mixer block.

Chunked SSD forward for train/prefill (quadratic within chunks, linear state
carry across chunks, `lax.scan` over chunks) and an O(1)-state decode step.
The inner/head dim is sharded over `model` (tensor parallelism); B/C are
single-group (G=1), shared across heads, per the Mamba2 default.

Jamba's mamba layers reuse this block with their own (smaller) state size —
Jamba ships Mamba-1; we adapt it to the SSD formulation (TPU-friendly:
chunk-level matmuls hit the MXU instead of a length-L sequential scan), noted
in DESIGN.md as a hardware adaptation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, rmsnorm
from repro.sharding.specs import AxisRules, with_logical_constraint


def mamba_dims(cfg: ModelConfig) -> dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N          # x, B, C share the causal conv (G=1)
    return dict(d_inner=d_inner, H=H, P=cfg.ssm_head_dim, N=N, conv_dim=conv_dim)


def mamba_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dims = mamba_dims(cfg)
    di, H, N, cd = dims["d_inner"], dims["H"], dims["N"], dims["conv_dim"]
    dt = cfg.dtype
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * N + H), ("embed", "ssm_inner"), dt),
        "conv_w": ParamSpec((cd, cfg.ssm_conv), ("ssm_inner", "conv"), dt,
                            scale=0.5),
        "conv_b": ParamSpec((cd,), ("ssm_inner",), dt, "zeros"),
        "A_log": ParamSpec((H,), ("ssm_inner",), jnp.float32, "ones"),
        "D": ParamSpec((H,), ("ssm_inner",), jnp.float32, "ones"),
        "dt_bias": ParamSpec((H,), ("ssm_inner",), jnp.float32, "zeros"),
        "norm_scale": ParamSpec((di,), ("ssm_inner",), jnp.float32, "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), dt),
    }


def _split_proj(p: dict, x: jax.Array, cfg: ModelConfig):
    dims = mamba_dims(cfg)
    di, H, N = dims["d_inner"], dims["H"], dims["N"]
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over seq. xBC: (B, L, C); w: (C, K)."""
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = state                                  # (B, K-1, C)
    xp = jnp.concatenate([pad, xBC], axis=1)         # (B, L+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1], :] * w[:, i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bs: jax.Array,
                Cs: jax.Array, chunk: int,
                init_state: jax.Array | None = None,
                impl: str = "xla") -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B, L, H, P) head inputs; dt: (B, L, H) step sizes (post-softplus);
    A: (H,) negative decay rates; Bs/Cs: (B, L, N) single-group state in/out.
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    if impl == "pallas":
        from repro.kernels.ops import ssd_scan
        return ssd_scan(xh, dt, A, Bs, Cs, chunk=chunk, init_state=init_state)

    B, L, H, P = xh.shape
    N = Bs.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xc = xh.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bs.reshape(B, nc, Q, N)
    Cc = Cs.reshape(B, nc, Q, N)
    a = dtc * A[None, None, None, :]                 # (B, nc, Q, H) log-decay
    cs = jnp.cumsum(a, axis=2)                        # inclusive cumsum

    S0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(S, inp):
        xq, dtq, Bq, Cq, aq, csq = inp               # per-chunk slices
        # intra-chunk (quadratic within the chunk)
        decay = jnp.exp(csq[:, :, None, :] - csq[:, None, :, :])   # (B,Q,Q,H)
        ii, jj = jnp.meshgrid(jnp.arange(Q), jnp.arange(Q), indexing="ij")
        tri = (jj <= ii)[None, :, :, None]
        G = jnp.einsum("bin,bjn->bij", Cq.astype(jnp.float32),
                       Bq.astype(jnp.float32))        # (B,Q,Q)
        W = jnp.where(tri, G[..., None] * decay, 0.0) # (B,Q,Q,H)
        xdt = xq.astype(jnp.float32) * dtq[..., None] # (B,Q,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xdt)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cq.astype(jnp.float32), S,
                             jnp.exp(csq))
        # state update
        total = csq[:, -1, :]                         # (B,H)
        carry_decay = jnp.exp(total[:, None, :] - csq)  # (B,Q,H)
        dS = jnp.einsum("bjn,bjhp,bjh->bhpn", Bq.astype(jnp.float32), xdt,
                        carry_decay)
        S_new = S * jnp.exp(total)[:, :, None, None] + dS
        return S_new, (y_intra + y_inter)

    inputs = (xc.swapaxes(0, 1), dtc.swapaxes(0, 1), Bc.swapaxes(0, 1),
              Cc.swapaxes(0, 1), a.swapaxes(0, 1), cs.swapaxes(0, 1))
    S_final, ys = jax.lax.scan(body, S0, inputs)
    y = ys.swapaxes(0, 1).reshape(B, L, H, P).astype(xh.dtype)
    return y, S_final.astype(jnp.float32)


def mamba_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  rules: AxisRules | None = None, impl: str = "xla",
                  conv_state: jax.Array | None = None,
                  ssm_state: jax.Array | None = None,
                  return_state: bool = False):
    """Full-sequence mamba mixer. x: (B, L, d) -> (B, L, d)."""
    dims = mamba_dims(cfg)
    di, H, P, N = dims["d_inner"], dims["H"], dims["P"], dims["N"]
    B, L, _ = x.shape
    z, xBC_raw, dt = _split_proj(p, x, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"], conv_state)
    xs, Bs, Cs = xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]
    xh = xs.reshape(B, L, H, P)
    xh = with_logical_constraint(xh, ("batch", "seq", "ssm_inner", None), rules)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, S = ssd_chunked(xh, dt, A, Bs, Cs, cfg.ssm_chunk, ssm_state, impl)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, L, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_scale"])
    out = y @ p["out_proj"]
    out = with_logical_constraint(out, ("batch", "seq", "embed_act"), rules)
    if return_state:
        # conv state for prefill->decode handoff: last K-1 *raw* conv inputs
        K = cfg.ssm_conv
        pad = jnp.zeros((B, K - 1, dims["conv_dim"]), x.dtype)
        conv_tail = jnp.concatenate([pad, xBC_raw.astype(x.dtype)],
                                    axis=1)[:, -(K - 1):, :]
        return out, (conv_tail, S)
    return out


def mamba_decode_step(p: dict, x: jax.Array, conv_state: jax.Array,
                      ssm_state: jax.Array, cfg: ModelConfig,
                      rules: AxisRules | None = None):
    """One-token decode. x: (B, 1, d); conv_state: (B, K-1, conv_dim);
    ssm_state: (B, H, P, N).  Returns (out, new_conv_state, new_ssm_state)."""
    dims = mamba_dims(cfg)
    di, H, P, N = dims["d_inner"], dims["H"], dims["P"], dims["N"]
    B = x.shape[0]
    z, xBC, dt = _split_proj(p, x, cfg)               # xBC: (B, 1, conv_dim)
    window = jnp.concatenate([conv_state, xBC], axis=1)   # (B, K, conv_dim)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xs = conv_out[:, :di]
    Bs = conv_out[:, di:di + N]
    Cs = conv_out[:, di + N:]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt1 = dt[:, 0, :]                                 # (B, H)
    dA = jnp.exp(dt1 * A[None, :])                    # (B, H)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bs.astype(jnp.float32), xh, dt1)
    S = ssm_state.astype(jnp.float32) * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cs.astype(jnp.float32), S)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_scale"])
    out = y @ p["out_proj"]
    return out, window[:, 1:, :], S.astype(ssm_state.dtype)
