"""GQA attention with RoPE, causal / sliding-window masks, cross-attention,
and KV-cache support.  Default impl is einsum (XLA) — used for dry-runs and
CPU tests; `impl="flash"` switches to the Pallas flash kernel on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamSpec, apply_rope
from repro.sharding.specs import AxisRules, with_logical_constraint


def attn_schema(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = cfg.dtype
    sch = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), dt, "zeros")
        sch["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), dt, "zeros")
        sch["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), dt, "zeros")
    return sch


def _project_qkv(p: dict, x: jax.Array, x_kv: jax.Array, cfg: ModelConfig,
                 rules: AxisRules | None):
    q = jnp.einsum("bld,dhk->bhlk", x, p["wq"])
    k = jnp.einsum("bld,dhk->bhlk", x_kv, p["wk"])
    v = jnp.einsum("bld,dhk->bhlk", x_kv, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    q = with_logical_constraint(q, ("batch", "heads", "seq", "head_dim"), rules)
    return q, k, v


def _sdpa_full(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: jax.Array | None,
               rules: AxisRules | None = None) -> jax.Array:
    """Full-sequence attention. q: (B,H,Lq,hd); k,v: (B,KV,Lk,hd).

    KV heads are broadcast (repeated) to H so every tensor — including the
    (B,H,Lq,Lk) score matrix — stays sharded on heads->model.  The grouped
    (B,KV,G,Lq,Lk) form leaves scores replicated over heads when KV doesn't
    divide the model axis, which blows per-device temp memory at seq 4k+.
    """
    B, H, Lq, hd = q.shape
    KV = k.shape[1]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=1)
        v = jnp.repeat(v, H // KV, axis=1)
    k = with_logical_constraint(k, ("batch", "heads", "seq", "head_dim"), rules)
    v = with_logical_constraint(v, ("batch", "heads", "seq", "head_dim"), rules)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = with_logical_constraint(logits, ("batch", "heads", None, None),
                                     rules)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                  window: int, block_q: int = 512,
                  rules: AxisRules | None = None) -> jax.Array:
    """Flash-style chunked attention on the XLA path: scan over q blocks so
    only a (B,H,bq,Lk) score slab is ever live — 64x less temp memory than
    the full (B,H,L,L) matrix at 32k.  Numerically identical to _sdpa_full
    (per-row softmax computed on the full kv extent of each block)."""
    B, H, L, hd = q.shape
    KV = k.shape[1]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=1)
        v = jnp.repeat(v, H // KV, axis=1)
    k = with_logical_constraint(k, ("batch", "heads", "seq", "head_dim"), rules)
    v = with_logical_constraint(v, ("batch", "heads", "seq", "head_dim"), rules)
    block_q = min(block_q, L)
    assert L % block_q == 0
    nq = L // block_q
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(k.shape[2])

    def body(_, iq):
        q_blk = jax.lax.dynamic_slice_in_dim(q, iq * block_q, block_q, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32), kf) * scale
        qpos = iq * block_q + jnp.arange(block_q)
        m = jnp.ones((block_q, k.shape[2]), bool)
        if causal:
            m &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            m &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(m[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(v.dtype)
        return None, o

    _, blocks = jax.lax.scan(body, None, jnp.arange(nq))
    # (nq, B, H, bq, hd) -> (B, H, L, hd)
    return blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, L, hd)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
          kv_logical: str | None = None, rules: AxisRules | None = None) -> jax.Array:
    """Grouped GQA attention (decode path: Lq=1, scores stay small).
    q: (B,H,Lq,hd); k,v: (B,KV,Lk,hd); mask broadcastable to (B,KV,G,Lq,Lk)."""
    B, H, Lq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, Lq, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bkth->bkgqt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, Lq, hd).astype(v.dtype)


def causal_mask(Lq: int, Lk: int, window: int = 0, offset: int = 0) -> jax.Array:
    """(1,1,1,Lq,Lk) boolean; offset = absolute position of query 0."""
    qpos = jnp.arange(Lq)[:, None] + offset
    kpos = jnp.arange(Lk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None, None]


def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    window: int = 0,
    x_kv: jax.Array | None = None,       # cross-attention source
    use_rope: bool = True,
    rules: AxisRules | None = None,
    impl: str = "xla",
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill). x: (B, L, d)."""
    B, L, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(p, x, x_kv, cfg, rules)
    if use_rope and x_kv is x:
        pos = positions if positions is not None else jnp.arange(L)
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_pct)
    if impl == "flash" and causal and x_kv is x:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=True, window=window)
    elif x_kv is x and (impl == "xla_chunked"
                        or (impl == "xla" and L >= 8192 and L % 512 == 0)):
        # long sequences: chunked q-block attention (see _sdpa_chunked)
        out = _sdpa_chunked(q, k, v, causal=causal, window=window, rules=rules)
    else:
        mask = causal_mask(L, k.shape[2], window) if (causal and x_kv is x) else None
        if mask is not None:
            mask = mask[:, :, 0]   # (1,1,Lq,Lk) for the full (repeat) form
        out = _sdpa_full(q, k, v, mask, rules=rules)
    out = jnp.einsum("bhlk,hkd->bld", out, p["wo"])
    out = with_logical_constraint(out, ("batch", "seq", "embed_act"), rules)
    if return_kv:
        return out, (k, v)
    return out


def cross_decode(p: dict, x: jax.Array, xk: jax.Array, xv: jax.Array,
                 cfg: ModelConfig, rules: AxisRules | None = None) -> jax.Array:
    """Decode-time cross-attention over a precomputed (frames) KV cache."""
    q = jnp.einsum("bld,dhk->bhlk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
    out = _sdpa(q, xk, xv, None, rules=rules)
    out = jnp.einsum("bhlk,hkd->bld", out, p["wo"])
    return with_logical_constraint(out, ("batch", "seq", "embed_act"), rules)


# ------------------------------------------------------------ decode (cached) ---


def decode_attention(
    p: dict,
    x: jax.Array,                 # (B, 1, d)
    cache_k: jax.Array,           # (B, KV, S, hd)
    cache_v: jax.Array,
    cache_len: jax.Array,         # scalar int32: tokens already in cache
    cfg: ModelConfig,
    *,
    window: int = 0,
    use_rope: bool = True,
    rules: AxisRules | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: returns (out (B,1,d), new_k, new_v)."""
    B, _, _ = x.shape
    S = cache_k.shape[2]
    q, k, v = _project_qkv(p, x, x, cfg, rules)
    if use_rope:
        pos = jnp.asarray(cache_len)[None]
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_pct)
    # ring-buffer write for SWA, append otherwise
    slot = jnp.mod(cache_len, S) if window > 0 else jnp.minimum(cache_len, S - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                  slot, axis=2)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                  slot, axis=2)
    kpos = jnp.arange(S)
    if window > 0:
        valid = (kpos < jnp.minimum(cache_len + 1, S))
    else:
        valid = kpos <= jnp.minimum(cache_len, S - 1)
    mask = valid[None, None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, mask, rules=rules)
    out = jnp.einsum("bhlk,hkd->bld", out, p["wo"])
    return (with_logical_constraint(out, ("batch", "seq", "embed_act"), rules),
            cache_k, cache_v)
