"""Model construction from configs."""
from __future__ import annotations

from repro.configs.base import ModelConfig, get_config
from repro.models.lm import LM, ModelImpl
from repro.sharding.specs import AxisRules


def build_model(cfg: ModelConfig | str, impl: ModelImpl | None = None,
                rules: AxisRules | None = None, smoke: bool = False) -> LM:
    if isinstance(cfg, str):
        cfg = get_config(cfg, smoke=smoke)
    return LM(cfg, impl=impl, rules=rules)
