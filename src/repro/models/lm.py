"""Model assembly for all assigned families.

- dense / moe / vlm : decoder-only transformer (GQA, optional SWA, MoE FFN)
- ssm               : Mamba2 stack (no FFN)
- hybrid            : Jamba superblocks (7 mamba + 1 attn per 8 layers,
                      MoE on odd layers), scanned over superblocks
- audio             : whisper-style encoder-decoder (frontends are stubs)

Layers are scanned with stacked params (compile time O(1) in depth) and
rematerialized.  Every apply mode is supported: `forward` (train),
`prefill` (forward + cache out), `decode_step` (1 token, cache in/out).

Positional encoding is RoPE everywhere; whisper's learned/sinusoidal
embeddings are replaced by RoPE (documented deviation — keeps the synthetic
32k decode shapes well-defined).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (ParamSpec, abstract_from_schema, apply_norm,
                                 embed_apply, embed_schema, init_from_schema,
                                 is_spec, mlp_apply, mlp_schema, norm_schema,
                                 param_count, specs_from_schema, stack_schema,
                                 unembed_apply)
from repro.sharding.specs import AxisRules, with_logical_constraint


@dataclasses.dataclass(frozen=True)
class ModelImpl:
    attn: str = "xla"        # xla | flash
    ssd: str = "xla"         # xla | pallas
    moe: str = "xla"         # xla | fused
    remat: bool = True
    remat_policy: str = "full"   # full | dots | none
    loss_chunk: int = 0      # 0 = unchunked cross-entropy
    scan_unroll: bool = False  # unroll layer scans (accounting mode: makes
    #                            cost_analysis count every layer's flops)


def _remat(fn, impl: ModelImpl):
    if not impl.remat or impl.remat_policy == "none":
        return fn
    if impl.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ================================================================== blocks ======

def _scan(impl: ModelImpl, body, init, xs):
    return jax.lax.scan(body, init, xs,
                        unroll=True if impl.scan_unroll else 1)




class Block:
    """One transformer layer: mixer (attn | mamba | cross) + optional FFN."""

    def __init__(self, cfg: ModelConfig, impl: ModelImpl, *, mixer: str,
                 ffn: str, causal: bool = True, cross: bool = False,
                 rules: AxisRules | None = None):
        self.cfg, self.impl, self.rules = cfg, impl, rules
        self.mixer, self.ffn, self.causal, self.cross = mixer, ffn, causal, cross

    # ----------------------------------------------------------- schema -----
    def schema(self) -> dict:
        cfg = self.cfg
        sch: dict[str, Any] = {"norm1": norm_schema(cfg.d_model, cfg.norm)}
        if self.mixer == "attn":
            sch["attn"] = attn_mod.attn_schema(cfg)
        else:
            sch["mamba"] = mamba_mod.mamba_schema(cfg)
        if self.cross:
            sch["norm_x"] = norm_schema(cfg.d_model, cfg.norm)
            sch["cross"] = attn_mod.attn_schema(cfg)
        if self.ffn != "none":
            sch["norm2"] = norm_schema(cfg.d_model, cfg.norm)
            sch["ffn"] = (moe_mod.moe_schema(cfg) if self.ffn == "moe"
                          else mlp_schema(cfg.d_model, cfg.d_ff,
                                          cfg.activation, cfg.dtype))
        return sch

    def cache_schema(self, B: int, S: int) -> dict:
        cfg = self.cfg
        out: dict[str, Any] = {}
        if self.mixer == "attn":
            KV, hd = cfg.num_kv_heads, cfg.head_dim_
            Sw = min(S, cfg.window) if cfg.window > 0 else S
            # shard KV heads over `model` only when they tile it (PRODUCTION_TP);
            # otherwise give the axis to the cache length (kv_seq) so decode
            # caches of GQA models still shard 512 ways
            from repro.sharding.specs import PRODUCTION_TP
            kvh = "kv_heads" if KV % PRODUCTION_TP == 0 else None
            kv = ("batch", kvh, "kv_seq", "head_dim")
            out["k"] = ParamSpec((B, KV, Sw, hd), kv, cfg.dtype, "zeros")
            out["v"] = ParamSpec((B, KV, Sw, hd), kv, cfg.dtype, "zeros")
        else:
            dims = mamba_mod.mamba_dims(cfg)
            out["conv"] = ParamSpec((B, cfg.ssm_conv - 1, dims["conv_dim"]),
                                    ("batch", None, "ssm_inner"), cfg.dtype,
                                    "zeros")
            out["ssm"] = ParamSpec((B, dims["H"], dims["P"], dims["N"]),
                                   ("batch", "ssm_inner", None, "ssm_state"),
                                   jnp.float32, "zeros")
        if self.cross:
            KV, hd = cfg.num_kv_heads, cfg.head_dim_
            kv = ("batch", "kv_heads", "frames", "head_dim")
            F = cfg.encoder_frames
            out["xk"] = ParamSpec((B, KV, F, hd), kv, cfg.dtype, "zeros")
            out["xv"] = ParamSpec((B, KV, F, hd), kv, cfg.dtype, "zeros")
        return out

    # ------------------------------------------------------------- apply ----
    def _ffn_apply(self, p: dict, h: jax.Array) -> tuple[jax.Array, jax.Array]:
        cfg, aux = self.cfg, jnp.zeros((), jnp.float32)
        if self.ffn == "none":
            return h, aux
        hn = apply_norm(p["norm2"], h, cfg.norm)
        if self.ffn == "moe":
            logits = hn.astype(jnp.float32) @ p["ffn"]["router"]
            _, experts = moe_mod.router_topk(logits, cfg.experts_per_token)
            aux = moe_mod.moe_aux_loss(logits, experts, cfg.num_experts)
            out = moe_mod.moe_apply(p["ffn"], hn, cfg, self.rules, self.impl.moe)
        else:
            out = mlp_apply(p["ffn"], hn, cfg.activation)
        return h + out, aux

    def full(self, p: dict, h: jax.Array, *, enc: jax.Array | None = None,
             positions: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
        """Full-sequence apply (train). Returns (h, moe_aux)."""
        cfg = self.cfg
        hn = apply_norm(p["norm1"], h, cfg.norm)
        if self.mixer == "attn":
            mix = attn_mod.attention(p["attn"], hn, cfg, causal=self.causal,
                                     window=cfg.window, positions=positions,
                                     rules=self.rules, impl=self.impl.attn)
        else:
            mix = mamba_mod.mamba_forward(p["mamba"], hn, cfg, self.rules,
                                          self.impl.ssd)
        h = h + mix
        if self.cross:
            hx = apply_norm(p["norm_x"], h, cfg.norm)
            h = h + attn_mod.attention(p["cross"], hx, cfg, causal=False,
                                       x_kv=enc, use_rope=False,
                                       rules=self.rules, impl="xla")
        return self._ffn_apply(p, h)

    def prefill(self, p: dict, h: jax.Array, *, enc: jax.Array | None = None,
                pad_to: int = 0) -> tuple[jax.Array, dict]:
        """Full-sequence apply that also emits this layer's decode cache.
        pad_to: allocate this many cache slots (> L leaves room to decode)."""
        cfg = self.cfg
        B, L, _ = h.shape
        cache: dict[str, jax.Array] = {}
        hn = apply_norm(p["norm1"], h, cfg.norm)
        if self.mixer == "attn":
            mix, (ks, vs) = attn_mod.attention(
                p["attn"], hn, cfg, causal=self.causal, window=cfg.window,
                rules=self.rules, impl=self.impl.attn, return_kv=True)
            S_tot = max(pad_to, L)
            S = min(S_tot, cfg.window) if cfg.window > 0 else S_tot
            if cfg.window > 0 and L >= S:
                idx = jnp.arange(L - S, L) % S
                ring_k = jnp.zeros(ks.shape[:2] + (S,) + ks.shape[3:],
                                   ks.dtype).at[:, :, idx].set(ks[:, :, L - S:])
                ring_v = jnp.zeros_like(ring_k).at[:, :, idx].set(
                    vs[:, :, L - S:])
                cache["k"], cache["v"] = ring_k, ring_v
            elif cfg.window > 0:  # L < window: place at slots (pos % S)
                idx = jnp.arange(L) % S
                ring_k = jnp.zeros(ks.shape[:2] + (S,) + ks.shape[3:],
                                   ks.dtype).at[:, :, idx].set(ks)
                ring_v = jnp.zeros_like(ring_k).at[:, :, idx].set(vs)
                cache["k"], cache["v"] = ring_k, ring_v
            else:
                pad = S_tot - L
                cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0)))
                cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0)))
            h = h + mix
        else:
            mix, (conv_tail, S_state) = mamba_mod.mamba_forward(
                p["mamba"], hn, cfg, self.rules, self.impl.ssd,
                return_state=True)
            cache["conv"], cache["ssm"] = conv_tail, S_state
            h = h + mix
        if self.cross:
            hx = apply_norm(p["norm_x"], h, cfg.norm)
            mix, (xk, xv) = attn_mod.attention(
                p["cross"], hx, cfg, causal=False, x_kv=enc, use_rope=False,
                rules=self.rules, return_kv=True)
            cache["xk"], cache["xv"] = xk, xv
            h = h + mix
        h, _ = self._ffn_apply(p, h)
        return h, cache

    def decode(self, p: dict, h: jax.Array, cache: dict, cache_len: jax.Array
               ) -> tuple[jax.Array, dict]:
        """One-token apply. h: (B, 1, d)."""
        cfg = self.cfg
        new_cache = dict(cache)
        hn = apply_norm(p["norm1"], h, cfg.norm)
        if self.mixer == "attn":
            mix, k2, v2 = attn_mod.decode_attention(
                p["attn"], hn, cache["k"], cache["v"], cache_len, cfg,
                window=cfg.window, rules=self.rules)
            new_cache["k"], new_cache["v"] = k2, v2
        else:
            mix, conv2, ssm2 = mamba_mod.mamba_decode_step(
                p["mamba"], hn, cache["conv"], cache["ssm"], cfg, self.rules)
            new_cache["conv"], new_cache["ssm"] = conv2, ssm2
        h = h + mix
        if self.cross:
            hx = apply_norm(p["norm_x"], h, cfg.norm)
            out = attn_mod.cross_decode(p["cross"], hx, cache["xk"],
                                        cache["xv"], cfg, rules=self.rules)
            h = h + out
        h, _ = self._ffn_apply(p, h)
        return h, new_cache


# =================================================================== model ======


def _hybrid_layout(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(mixer, ffn) per layer inside one hybrid superblock."""
    period = cfg.attn_period
    out = []
    for j in range(period):
        mixer = "attn" if j == cfg.attn_offset else "mamba"
        ffn = "moe" if (cfg.moe_period and j % cfg.moe_period == 1) else "mlp"
        out.append((mixer, ffn))
    return out


class LM:
    """Decoder LM / enc-dec wrapper over scanned Block stacks."""

    def __init__(self, cfg: ModelConfig, impl: ModelImpl | None = None,
                 rules: AxisRules | None = None):
        self.cfg = cfg
        self.impl = impl or ModelImpl()
        self.rules = rules
        fam = cfg.family
        mk = functools.partial(Block, cfg, self.impl, rules=rules)
        if fam in ("dense", "vlm"):
            self.blocks = [mk(mixer="attn", ffn="mlp")]
            self.n_stack = cfg.num_layers
        elif fam == "moe":
            self.blocks = [mk(mixer="attn", ffn="moe")]
            self.n_stack = cfg.num_layers
        elif fam == "ssm":
            self.blocks = [mk(mixer="mamba", ffn="none")]
            self.n_stack = cfg.num_layers
        elif fam == "hybrid":
            assert cfg.num_layers % cfg.attn_period == 0
            self.blocks = [mk(mixer=m, ffn=f) for m, f in _hybrid_layout(cfg)]
            self.n_stack = cfg.num_layers // cfg.attn_period
        elif fam == "audio":
            self.enc_block = mk(mixer="attn", ffn="mlp", causal=False)
            self.blocks = [mk(mixer="attn", ffn="mlp", cross=True)]
            self.n_stack = cfg.num_layers
        else:
            raise ValueError(fam)

    # ---------------------------------------------------------- schema ------
    def schema(self) -> dict:
        cfg = self.cfg
        if len(self.blocks) == 1:
            blocks = stack_schema(self.blocks[0].schema(), self.n_stack)
        else:  # hybrid superblock: dict of distinct layers, stacked
            sup = {f"l{j}": b.schema() for j, b in enumerate(self.blocks)}
            blocks = stack_schema(sup, self.n_stack)
        from repro.configs.base import padded_vocab
        Vp = padded_vocab(cfg.vocab_size)
        sch: dict[str, Any] = {
            "embed": embed_schema(Vp, cfg.d_model, cfg.dtype),
            "blocks": blocks,
            "final_norm": norm_schema(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            sch["unembed"] = ParamSpec((Vp, cfg.d_model),
                                       ("vocab", "embed_table"), cfg.dtype)
        if cfg.family == "audio":
            sch["encoder"] = {
                "blocks": stack_schema(self.enc_block.schema(),
                                       cfg.encoder_layers),
                "final_norm": norm_schema(cfg.d_model, cfg.norm),
            }
        return sch

    def init(self, key: jax.Array):
        return init_from_schema(key, self.schema())

    def abstract_params(self):
        return abstract_from_schema(self.schema())

    def param_specs(self, rules: AxisRules | None = None, mesh=None):
        return specs_from_schema(self.schema(), rules or self.rules, mesh)

    def param_count(self) -> int:
        return param_count(self.schema())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.num_experts and cfg.experts_per_token:
            F = cfg.moe_d_ff or cfg.d_ff
            per_expert = 3 * cfg.d_model * F
            n_moe = self._num_moe_layers()
            inactive = n_moe * (cfg.num_experts - cfg.experts_per_token) * per_expert
            return total - inactive
        return total

    def _num_moe_layers(self) -> int:
        cfg = self.cfg
        if cfg.family == "moe":
            return cfg.num_layers
        if cfg.family == "hybrid":
            return sum(f == "moe" for _, f in _hybrid_layout(cfg)) * self.n_stack
        return 0

    # --------------------------------------------------------- embedding ----
    def _embed_in(self, params, tokens, patch_embeds=None, audio=False):
        h = embed_apply(params["embed"], tokens).astype(self.cfg.dtype)
        if self.cfg.family == "vlm" and patch_embeds is not None:
            h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
        return with_logical_constraint(h, ("batch", "seq", "embed_act"),
                                       self.rules)

    def _unembed(self, params, h):
        table = params.get("unembed", params["embed"]["table"])
        logits = unembed_apply(table, h, self.cfg.vocab_size)
        return with_logical_constraint(logits, ("batch", "seq", "vocab"),
                                       self.rules)

    # ----------------------------------------------------------- encoder ----
    def _encode(self, params, audio_frames):
        h = audio_frames.astype(self.cfg.dtype)
        blk = self.enc_block

        def body(carry, p):
            out, _ = blk.full(p, carry)
            return out, None

        h, _ = _scan(self.impl, _remat(body, self.impl), h, params["encoder"]["blocks"])
        return apply_norm(params["encoder"]["final_norm"], h, self.cfg.norm)

    # ------------------------------------------------------------ forward ---
    def hidden_states(self, params, tokens, *, patch_embeds=None,
                      audio_frames=None) -> tuple[jax.Array, jax.Array]:
        """Returns (h_final (B, L, d), total moe aux loss)."""
        cfg = self.cfg
        enc = self._encode(params, audio_frames) if cfg.family == "audio" else None
        h = self._embed_in(params, tokens, patch_embeds)

        if len(self.blocks) == 1:
            blk = self.blocks[0]

            def body(carry, p):
                out, aux = blk.full(p, carry, enc=enc)
                return out, aux

            h, auxs = _scan(self.impl, _remat(body, self.impl), h, params["blocks"])
            aux = jnp.sum(auxs)
        else:
            blocks = self.blocks

            def body(carry, p):
                out, aux = carry, jnp.zeros((), jnp.float32)
                for j, b in enumerate(blocks):
                    out, a = b.full(p[f"l{j}"], out)
                    aux = aux + a
                return out, aux

            h, auxs = _scan(self.impl, _remat(body, self.impl), h, params["blocks"])
            aux = jnp.sum(auxs)
        h = apply_norm(params["final_norm"], h, cfg.norm)
        return h, aux

    def forward(self, params, tokens, *, patch_embeds=None, audio_frames=None
                ) -> jax.Array:
        """Full logits (B, L_text, vocab); vlm: logits for text positions."""
        h, _ = self.hidden_states(params, tokens, patch_embeds=patch_embeds,
                                  audio_frames=audio_frames)
        if self.cfg.family == "vlm" and patch_embeds is not None:
            h = h[:, patch_embeds.shape[1]:, :]
        return self._unembed(params, h)

    def loss(self, params, batch: dict) -> jax.Array:
        """Next-token cross-entropy (+ MoE aux).  labels = targets per pos."""
        cfg = self.cfg
        h, aux = self.hidden_states(
            params, batch["tokens"], patch_embeds=batch.get("patch_embeds"),
            audio_frames=batch.get("audio_frames"))
        if cfg.family == "vlm" and "patch_embeds" in batch:
            h = h[:, batch["patch_embeds"].shape[1]:, :]
        labels = batch["labels"]
        table = params.get("unembed", params["embed"]["table"])

        def xent(hc, lc):
            logits = unembed_apply(table, hc, cfg.vocab_size)
            logits = with_logical_constraint(logits, ("batch", "seq", "vocab"),
                                             self.rules)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return jnp.sum(lse - gold)

        C = self.impl.loss_chunk
        B, L, _ = h.shape
        if C and L % C == 0 and L > C:
            hc = h.reshape(B, L // C, C, -1).swapaxes(0, 1)
            lc = labels.reshape(B, L // C, C).swapaxes(0, 1)

            def body(tot, inp):
                return tot + xent(*inp), None

            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
        else:
            total = xent(h, labels)
        ntok = jnp.asarray(labels.size, jnp.float32)
        return total / ntok + 0.01 * aux

    # ------------------------------------------------------------- caches ---
    def cache_schema(self, B: int, S: int) -> dict:
        sch: dict[str, Any] = {"len": ParamSpec((), (), jnp.int32, "zeros")}
        if len(self.blocks) == 1:
            sch["blocks"] = stack_schema(self.blocks[0].cache_schema(B, S),
                                         self.n_stack)
        else:
            sup = {f"l{j}": b.cache_schema(B, S)
                   for j, b in enumerate(self.blocks)}
            sch["blocks"] = stack_schema(sup, self.n_stack)
        return sch

    def abstract_cache(self, B: int, S: int):
        return abstract_from_schema(self.cache_schema(B, S))

    def cache_specs(self, B: int, S: int, rules: AxisRules | None = None,
                    mesh=None):
        return specs_from_schema(self.cache_schema(B, S), rules or self.rules,
                                 mesh)

    def init_cache(self, B: int, S: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_schema(B, S),
            is_leaf=is_spec)

    # ------------------------------------------------------------ prefill ---
    def prefill(self, params, tokens, *, patch_embeds=None, audio_frames=None,
                pad_to: int = 0) -> tuple[jax.Array, dict]:
        """Returns (last-token logits (B, vocab), cache).  pad_to: total
        cache slots to allocate (> prompt length leaves decode room)."""
        cfg = self.cfg
        enc = self._encode(params, audio_frames) if cfg.family == "audio" else None
        h = self._embed_in(params, tokens, patch_embeds)
        L_total = h.shape[1]

        if len(self.blocks) == 1:
            blk = self.blocks[0]

            def body(carry, p):
                out, cache = blk.prefill(p, carry, enc=enc, pad_to=pad_to)
                return out, cache

            h, caches = _scan(self.impl, _remat(body, self.impl), h, params["blocks"])
        else:
            blocks = self.blocks

            def body(carry, p):
                out = carry
                caches = {}
                for j, b in enumerate(blocks):
                    out, c = b.prefill(p[f"l{j}"], out, pad_to=pad_to)
                    caches[f"l{j}"] = c
                return out, caches

            h, caches = _scan(self.impl, _remat(body, self.impl), h, params["blocks"])
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = self._unembed(params, h[:, -1:, :])[:, 0, :]
        cache = {"blocks": caches, "len": jnp.asarray(L_total, jnp.int32)}
        return logits, cache

    # ------------------------------------------------------------- decode ---
    def decode_step(self, params, tokens, cache) -> tuple[jax.Array, dict]:
        """tokens: (B, 1) -> (logits (B, vocab), new cache)."""
        cfg = self.cfg
        h = self._embed_in(params, tokens)
        cache_len = cache["len"]

        if len(self.blocks) == 1:
            blk = self.blocks[0]

            def body(carry, inp):
                p, c = inp
                out, c2 = blk.decode(p, carry, c, cache_len)
                return out, c2

            h, new_caches = _scan(self.impl, body, h, (params["blocks"], cache["blocks"]))
        else:
            blocks = self.blocks

            def body(carry, inp):
                p, c = inp
                out = carry
                c2 = {}
                for j, b in enumerate(blocks):
                    out, cj = b.decode(p[f"l{j}"], out, c[f"l{j}"], cache_len)
                    c2[f"l{j}"] = cj
                return out, c2

            h, new_caches = _scan(self.impl, body, h, (params["blocks"], cache["blocks"]))
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = self._unembed(params, h)[:, 0, :]
        return logits, {"blocks": new_caches, "len": cache_len + 1}
