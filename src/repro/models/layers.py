"""Parameter schema machinery + elementary layers (norms, RoPE, MLP, embeds).

Params are plain nested dicts of arrays.  Every leaf is declared once as a
`ParamSpec(shape, logical, ...)`; from the schema we derive random inits,
abstract ShapeDtypeStructs (dry-run), and PartitionSpecs (sharding rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.specs import AxisRules, logical_spec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0        # stddev multiplier (normal: 1/sqrt(fan_in) base)

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_from_schema(key: jax.Array, schema) -> Any:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def init_one(k: jax.Array, s: ParamSpec) -> jax.Array:
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
        std = s.scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(s.dtype)

    return jax.tree.unflatten(treedef, [init_one(k, s) for k, s in zip(keys, leaves)])


def abstract_from_schema(schema) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema,
                        is_leaf=is_spec)


def specs_from_schema(schema, rules: AxisRules | None = None, mesh=None) -> Any:
    return jax.tree.map(lambda s: logical_spec(s.logical, rules, mesh), schema,
                        is_leaf=is_spec)


def stack_schema(schema, n: int) -> Any:
    """Prepend a stacked-layers dim (for scan-over-layers parameter stacking)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype,
                            s.init, s.scale),
        schema, is_leaf=is_spec)


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


# ------------------------------------------------------------------- layers -----


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_schema(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), (None,), jnp.float32, "ones")}
    return {"scale": ParamSpec((d,), (None,), jnp.float32, "ones"),
            "bias": ParamSpec((d,), (None,), jnp.float32, "zeros")}


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# --- RoPE -------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, rope_pct: float = 1.0) -> jax.Array:
    rot = int(head_dim * rope_pct) // 2 * 2
    exponents = jnp.arange(0, rot, 2, dtype=jnp.float32) / max(rot, 1)
    return 1.0 / (theta ** exponents)            # (rot/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_pct: float = 1.0) -> jax.Array:
    """x: (..., seq, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta, rope_pct)
    rot = freqs.shape[0] * 2
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, rot/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(*x.shape[:-1], rot)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)


# --- MLP ----------------------------------------------------------------------


def mlp_schema(d_model: int, d_ff: int, activation: str, dtype) -> dict:
    gated = activation in ("silu", "gelu")
    sch = {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype),
        "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed"), dtype),
    }
    if gated:
        sch["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype)
    return sch


def mlp_apply(p: dict, x: jax.Array, activation: str) -> jax.Array:
    act = activation_fn(activation)
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = up * act(x @ p["w_gate"])
    else:
        up = act(up)
    return up @ p["w_down"]


# --- Embedding ------------------------------------------------------------------


def embed_schema(vocab: int, d_model: int, dtype) -> dict:
    # NOTE: the table's embed dim deliberately has its own logical axis
    # ("embed_table" -> None): FSDP-sharding it over `data` makes the token
    # gather reshard through an involuntary full replication in GSPMD.
    # vocab stays on `model` (TP); the gather lowers to mask+psum.
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed_table"),
                               dtype, scale=1.0)}


def embed_apply(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_apply(table: jax.Array, h: jax.Array,
                  real_vocab: int | None = None) -> jax.Array:
    """h: (..., d); table: (padded_vocab, d) -> logits in fp32; columns past
    `real_vocab` are masked to -inf (vocab padding, see configs.base)."""
    logits = jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                        table.astype(jnp.float32))
    V = table.shape[0]
    if real_vocab is not None and real_vocab < V:
        mask = jnp.arange(V) < real_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits
