"""repro.scale: telemetry-driven autoscaling — elastic capacity controllers
closing the loop from rolling telemetry to cluster size (see
docs/ARCHITECTURE.md "Autoscaling layer")."""
from repro.scale.autoscaler import (AUTOSCALERS, Autoscaler, PoolSpec,
                                    QueuePressureAutoscaler, ScaleEvent,
                                    TargetUtilizationAutoscaler,
                                    list_autoscalers, make_autoscaler,
                                    pools_from_spec)

__all__ = [
    "AUTOSCALERS", "Autoscaler", "PoolSpec", "QueuePressureAutoscaler",
    "ScaleEvent", "TargetUtilizationAutoscaler", "list_autoscalers",
    "make_autoscaler", "pools_from_spec",
]
