"""Telemetry-driven autoscaling controllers (elastic cluster capacity).

The scheduler so far only reordered a queue against *fixed* capacity; this
module closes the loop the ROADMAP calls for: rolling telemetry (utilization
timeline, wait-p99) drives node add/remove events consumed by the
rescan-interval service loop.  The design follows the survey observation
(Gao et al., "Deep Learning Workload Scheduling in GPU Datacenters") that
elastic capacity is the lever queue-ordering schedulers leave on the table —
and the source paper's utilization objective is exactly the controller
input our rolling telemetry already computes.

Mechanics
---------
A controller manages **per-SKU pools** (``PoolSpec``: node template plus
min/max node bounds) and, once per processed rescan window, reads the
engine's ``EngineSnapshot`` and — when attached — ``RollingTelemetry``, then
emits at most one scaling action subject to:

- **hysteresis**: two thresholds (band / dual watermark) so the signal must
  cross distinct levels to scale up vs. down — no flapping on noise;
- **cooldown**: a minimum simulated-time gap between actions;
- **bounds**: per-pool min/max active node counts.

Scale-up re-admits a draining (cordoned) node of the target SKU before
paying for a fresh one; scale-down prefers idle nodes and otherwise cordons
the least-busy node, which the cluster auto-retires once it drains (see
``ClusterState`` drain semantics).  Every action is logged as a
``ScaleEvent`` and forwarded to telemetry for provisioning-cost accounting.

A **stall override** lets the service loop force a scale-up evaluation
(ignoring cooldown and the signal) when the queue is starved and the event
heap has run dry — without it, a too-aggressive scale-down could strand
pending jobs forever.  The override still respects pool max bounds, so a
genuinely unplaceable job terminates the run instead of looping.

Controllers hold no reference to cluster internals beyond the public
``ClusterState`` arrays and mutators; with ``autoscaler=None`` every code
path in the engine/service is bit-identical to the pre-autoscaling system
(pinned by tests).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.types import ClusterSpec, NodeSpec


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One capacity action taken by a controller."""

    time: float
    action: str          # "add" | "uncordon" | "cordon" | "retire"
    node_id: int
    gpu_type: str
    reason: str


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One elastic per-SKU pool: the node template scale-up clones and the
    active-node bounds the controller must respect.  ``preemptible`` marks
    the pool as spot capacity — cheap but reclaimable: ``repro.chaos`` spot-
    reclamation waves (``ChaosSchedule.spot_waves_for_pools``) target only
    pools that opt in."""

    gpu_type: str
    template: NodeSpec
    min_nodes: int
    max_nodes: int
    preemptible: bool = False


def pools_from_spec(spec: ClusterSpec, *, min_frac: float = 0.25,
                    max_frac: float = 1.0) -> dict[str, PoolSpec]:
    """Derive per-SKU pools from a cluster spec: the template is the SKU's
    first node, ``min_nodes = max(1, ceil(min_frac * count))`` and
    ``max_nodes = max(count, ceil(max_frac * count))`` — with the defaults a
    controller may shrink to a quarter of each pool but never grow past the
    provisioned peak (the static-capacity baseline)."""
    by_sku: dict[str, list[NodeSpec]] = {}
    for nd in spec.nodes:
        by_sku.setdefault(nd.gpu_type, []).append(nd)
    pools = {}
    for sku, nodes in by_sku.items():
        count = len(nodes)
        pools[sku] = PoolSpec(
            gpu_type=sku, template=nodes[0],
            min_nodes=max(1, math.ceil(min_frac * count)),
            max_nodes=max(count, math.ceil(max_frac * count)))
    return pools


class Autoscaler:
    """Base controller: pool bookkeeping, hysteresis plumbing, cooldown,
    bounds, and the add/uncordon/cordon action mechanics.  Subclasses
    implement :meth:`desired_direction`."""

    name = "base"

    def __init__(self, pools: dict[str, PoolSpec], *,
                 cooldown_s: float = 1800.0, step_nodes: int = 1):
        if not pools:
            raise ValueError("an autoscaler needs at least one pool")
        self.pools = dict(pools)
        self.cooldown_s = cooldown_s
        self.step_nodes = max(1, int(step_nodes))
        self.events: list[ScaleEvent] = []
        self._last_action_t = -math.inf

    @classmethod
    def from_spec(cls, spec: ClusterSpec, *, min_frac: float = 0.25,
                  max_frac: float = 1.0, **kw) -> "Autoscaler":
        return cls(pools_from_spec(spec, min_frac=min_frac,
                                   max_frac=max_frac), **kw)

    # ------------------------------------------------------------ subclass API --
    def desired_direction(self, engine, now: float,
                          telemetry) -> tuple[int, str]:
        """``(direction, reason)``: +1 scale up, -1 scale down, 0 hold."""
        raise NotImplementedError

    # --------------------------------------------------------------- control ----
    def control(self, engine, now: float, telemetry=None,
                stalled: bool = False) -> list[ScaleEvent]:
        """One controller tick.  Reads signals, maybe emits one bounded
        action, applies it to ``engine.cluster``, and kicks the engine so a
        newly feasible queue schedules immediately.  ``stalled=True`` is
        the service loop's starvation override: force a scale-up attempt
        regardless of cooldown or signal."""
        if stalled:
            direction, reason = 1, "stall: pending jobs with no feasible event"
        elif now - self._last_action_t < self.cooldown_s:
            return []
        else:
            direction, reason = self.desired_direction(engine, now, telemetry)
        if direction == 0:
            return []
        if direction > 0:
            events = self._scale_up(engine, now, reason)
        else:
            events = self._scale_down(engine, now, reason)
        if events:
            self._last_action_t = now
            self.events.extend(events)
            if telemetry is not None:
                telemetry.note_scale_events(events)
            engine.reschedule(at=now)
        return events

    # -------------------------------------------------------------- forecast ----
    def _forecast_gpu_hours(self, engine) -> float | None:
        """Predicted GPU-hours of the pending window, when the engine carries
        an assisting runtime predictor (``repro.predict``).  ``None`` when no
        predictor is attached or it runs in shadow mode — controllers must
        then fall back to their reactive signals, keeping the predictor-off
        path bit-identical."""
        pred = getattr(engine, "predictor", None)
        if pred is None or not getattr(pred, "assist", False):
            return None
        fn = getattr(pred, "pending_gpu_hours", None)
        if fn is None:
            return None
        return float(fn(engine))

    # ------------------------------------------------------------- pool state ---
    def _active_count(self, cluster, sku: str) -> int:
        """Nodes of the pool the bounds govern: not retired, not draining
        (down-but-repairing nodes still count — they come back)."""
        m = cluster.sku_mask(sku)
        return int((m & ~cluster.retired & ~cluster.cordoned).sum())

    def _pending_demand(self, engine, cap: int = 512) -> dict[str, int]:
        """Pending GPU demand per SKU over the queue head (bounded scan);
        flexible ("any") demand is credited to every pool."""
        demand: dict[str, int] = {sku: 0 for sku in self.pools}
        for j in engine.pending[:cap]:
            if j.gpu_type == "any":
                for sku in demand:
                    demand[sku] += j.num_gpus
            elif j.gpu_type in demand:
                demand[j.gpu_type] += j.num_gpus
        return demand

    def _pools_by_up_preference(self, engine) -> list[str]:
        """Pools ordered by scale-up priority: unmet pending demand first,
        then per-SKU busy fraction; deterministic tie-break on SKU name."""
        cluster = engine.cluster
        demand = self._pending_demand(engine)
        _, free_by_type = cluster.free_gpu_tallies()
        _, prov_by_type = cluster.provisioned_gpu_totals()

        def busy_frac(sku: str) -> float:
            prov = prov_by_type.get(sku, 0)
            return 1.0 - free_by_type.get(sku, 0) / prov if prov else 0.0

        return sorted(self.pools,
                      key=lambda sku: (-demand.get(sku, 0),
                                       -busy_frac(sku), sku))

    def _scale_up(self, engine, now: float, reason: str) -> list[ScaleEvent]:
        cluster = engine.cluster
        events: list[ScaleEvent] = []
        order = self._pools_by_up_preference(engine)
        for _ in range(self.step_nodes):
            sku = next((s for s in order
                        if self._active_count(cluster, s)
                        < self.pools[s].max_nodes), None)
            if sku is None:
                break
            pool = self.pools[sku]
            # re-admit a draining node before paying for a fresh one
            cand = np.flatnonzero(cluster.sku_mask(sku) & cluster.cordoned)
            if cand.size:
                nid = int(cand[0])
                cluster.uncordon_node(nid)
                events.append(ScaleEvent(now, "uncordon", nid, sku, reason))
            else:
                nid = cluster.add_node(pool.template)
                events.append(ScaleEvent(now, "add", nid, sku, reason))
        return events

    def _scale_down(self, engine, now: float, reason: str) -> list[ScaleEvent]:
        cluster = engine.cluster
        events: list[ScaleEvent] = []
        for _ in range(self.step_nodes):
            # pool with the most idle placeable GPUs sheds first
            placeable = cluster.placeable_mask()
            best, best_idle = None, -1
            for sku, pool in sorted(self.pools.items()):
                if self._active_count(cluster, sku) <= pool.min_nodes:
                    continue
                idle = int(cluster.free_gpus[cluster.sku_mask(sku)
                                             & placeable].sum())
                if idle > best_idle:
                    best, best_idle = sku, idle
            if best is None:
                break
            m = cluster.sku_mask(best) & ~cluster.retired & ~cluster.cordoned
            cand = np.flatnonzero(m)
            # least busy first; ties retire the newest node
            busy = (cluster.total_gpus[cand] - cluster.free_gpus[cand])
            nid = int(cand[np.lexsort((-cand, busy))[0]])
            retired = cluster.remove_node(nid)
            events.append(ScaleEvent(now, "retire" if retired else "cordon",
                                     nid, best, reason))
        return events

    # ------------------------------------------------------------- reporting ----
    def event_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for e in self.events:
            counts[e.action] = counts.get(e.action, 0) + 1
        return counts


class TargetUtilizationAutoscaler(Autoscaler):
    """Keep rolling GPU utilization inside ``[util_low, util_high]``: above
    the band adds capacity, below it (with an empty-enough queue) drains
    capacity.  The band *is* the hysteresis — the two watermarks must be
    separated for the controller to hold steady between them."""

    name = "target-util"

    def __init__(self, pools: dict[str, PoolSpec], *,
                 util_low: float = 0.35, util_high: float = 0.85,
                 max_pending_for_down: int = 0,
                 forecast_hold_gpu_hours: float = 8.0, **kw):
        if not 0.0 <= util_low < util_high <= 1.0:
            raise ValueError(f"need 0 <= util_low < util_high <= 1, got "
                             f"[{util_low}, {util_high}]")
        super().__init__(pools, **kw)
        self.util_low = util_low
        self.util_high = util_high
        self.max_pending_for_down = max_pending_for_down
        self.forecast_hold_gpu_hours = forecast_hold_gpu_hours

    def desired_direction(self, engine, now, telemetry) -> tuple[int, str]:
        snap = engine.snapshot()
        if telemetry is not None:
            util = telemetry.probe(now, engine).utilization
            src = "rolling"
        else:
            util = snap.utilization
            src = "instant"
        if util > self.util_high:
            return 1, f"{src} util {util:.2f} > {self.util_high:.2f}"
        if util < self.util_low and snap.num_pending <= self.max_pending_for_down:
            # predicted demand holds capacity that instantaneous utilization
            # would drain — the forecast sees pending work the utilization
            # signal has not absorbed yet
            fc = self._forecast_gpu_hours(engine)
            if fc is not None and fc >= self.forecast_hold_gpu_hours:
                return 0, (f"hold: forecast {fc:.1f} GPU-h >= "
                           f"{self.forecast_hold_gpu_hours:.1f}")
            return -1, f"{src} util {util:.2f} < {self.util_low:.2f}"
        return 0, "in band"


class QueuePressureAutoscaler(Autoscaler):
    """Scale on queueing delay: rolling wait-p99 above ``wait_up_s`` adds
    capacity; wait-p99 below ``wait_down_s`` with an idle-enough cluster
    drains it.  The dual watermark (``wait_down_s`` well under
    ``wait_up_s``) is the hysteresis."""

    name = "queue-pressure"

    def __init__(self, pools: dict[str, PoolSpec], *,
                 wait_up_s: float = 1800.0, wait_down_s: float = 300.0,
                 util_down: float = 0.5,
                 forecast_up_gpu_hours: float = 64.0, **kw):
        if not 0.0 <= wait_down_s < wait_up_s:
            raise ValueError(f"need 0 <= wait_down_s < wait_up_s, got "
                             f"[{wait_down_s}, {wait_up_s}]")
        super().__init__(pools, **kw)
        self.wait_up_s = wait_up_s
        self.wait_down_s = wait_down_s
        self.util_down = util_down
        self.forecast_up_gpu_hours = forecast_up_gpu_hours

    def desired_direction(self, engine, now, telemetry) -> tuple[int, str]:
        snap = engine.snapshot()
        if telemetry is not None:
            sample = telemetry.probe(now, engine)
            wait_p99, util = sample.wait_p99, sample.utilization
        else:
            wait_p99, util = 0.0, snap.utilization
        if wait_p99 > self.wait_up_s:
            return 1, f"wait p99 {wait_p99:.0f}s > {self.wait_up_s:.0f}s"
        if snap.num_pending > 0:
            # forecast lead: predicted backlog GPU-hours trip the up
            # watermark before the rolling wait percentile reacts
            fc = self._forecast_gpu_hours(engine)
            if fc is not None and fc >= self.forecast_up_gpu_hours:
                return 1, (f"forecast {fc:.1f} GPU-h >= "
                           f"{self.forecast_up_gpu_hours:.1f}")
        if snap.num_pending > 0 and snap.free_gpus == 0:
            # backlog against a fully busy cluster: do not wait for the
            # rolling percentile to catch up
            return 1, "backlog with zero free GPUs"
        if wait_p99 < self.wait_down_s and snap.num_pending == 0 \
                and util < self.util_down:
            return -1, f"wait p99 {wait_p99:.0f}s < {self.wait_down_s:.0f}s"
        return 0, "between watermarks"


AUTOSCALERS: dict[str, type] = {
    "target-util": TargetUtilizationAutoscaler,
    "queue-pressure": QueuePressureAutoscaler,
}


def make_autoscaler(name: str, spec: ClusterSpec, **kw) -> Autoscaler:
    """Build a registered controller with pools derived from ``spec``.
    ``min_frac``/``max_frac`` pass through to :func:`pools_from_spec`;
    everything else goes to the controller."""
    if name not in AUTOSCALERS:
        raise KeyError(f"unknown autoscaler {name!r}; "
                       f"registered: {', '.join(sorted(AUTOSCALERS))}")
    pool_kw = {k: kw.pop(k) for k in ("min_frac", "max_frac") if k in kw}
    return AUTOSCALERS[name](pools_from_spec(spec, **pool_kw), **kw)


def list_autoscalers() -> list[str]:
    return sorted(AUTOSCALERS)
