"""Fault-tolerant checkpointing: msgpack + compressed shards (zstd when
available, stdlib zlib otherwise), atomic commit, elastic restore (reshard
onto a different mesh).

Layout:  <dir>/step_<N>.tmp/  ->  rename  ->  <dir>/step_<N>/
           manifest.msgpack            {key: {shape, dtype, file}}
           <leaf-id>.bin               zstd(raw bytes, C-order)

Restore reads host-side numpy and `jax.device_put`s with the *target* mesh's
NamedSharding — the saved layout is mesh-independent, so a checkpoint written
on (16,16) restores onto (2,16,16) or a single CPU device (elastic rescale).
Async save: a snapshot is copied to host, then written by a worker thread.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as _zstd
except ImportError:          # minimal install: fall back to stdlib zlib
    _zstd = None
import zlib as _zlib


def _compress(raw: bytes) -> bytes:
    if _zstd is not None:
        return _zstd.ZstdCompressor(level=3).compress(raw)
    return _zlib.compress(raw, 3)


def _decompress(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if _zstd is None:
            raise RuntimeError(
                "checkpoint was written with zstd; install the [compress] extra")
        return _zstd.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return _zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


_CODEC = "zstd" if _zstd is not None else "zlib"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, step: int, tree) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    flat = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fname = f"{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(_compress(np.ascontiguousarray(arr).tobytes()))
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                         "file": fname}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb({"step": step, "codec": _CODEC,
                               "leaves": manifest}))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(path: str, target_tree, step: int | None = None,
                    mesh=None, spec_tree=None):
    """Restore into the structure of `target_tree` (values or abstract).

    With (mesh, spec_tree) given, leaves are device_put with the target
    sharding — elastic restore onto any mesh.  Missing keys raise; extra
    keys in the checkpoint are ignored.
    """
    step = latest_step(path) if step is None else step
    assert step is not None, f"no checkpoint under {path}"
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_meta = manifest["leaves"]
    codec = manifest.get("codec", "zstd")   # pre-fallback checkpoints: zstd

    paths_leaves = jax.tree_util.tree_flatten_with_path(target_tree)[0]
    specs_flat = (jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        if spec_tree is not None else [None] * len(paths_leaves))
    out = []
    for (path_keys, leaf), spec in zip(paths_leaves, specs_flat):
        key = "/".join(_path_str(p) for p in path_keys)
        meta = leaves_meta.get(key)
        assert meta is not None, f"checkpoint missing leaf {key}"
        with open(os.path.join(d, meta["file"]), "rb") as f:
            raw = _decompress(f.read(), codec)
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if mesh is not None and spec is not None:
            sh = jax.sharding.NamedSharding(mesh, spec)
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


class CheckpointManager:
    """Periodic async checkpointing with retention + crash-safe restore."""

    def __init__(self, path: str, *, interval: int = 100, keep: int = 3):
        self.path = path
        self.interval = interval
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None
        os.makedirs(path, exist_ok=True)

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.interval != 0:
            return False
        self.wait()
        flat_snapshot = _flatten(tree)          # host copy before async write

        def _write():
            # re-wrap as a flat dict tree; manifest keys stay identical
            save_checkpoint(self.path, step, flat_snapshot)
            self._gc()

        self._pending = self._pool.submit(_write)
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore(self, target_tree, mesh=None, spec_tree=None):
        self.wait()
        step = latest_step(self.path)
        if step is None:
            return None, None
        return load_checkpoint(self.path, target_tree, step, mesh, spec_tree)
