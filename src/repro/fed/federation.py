"""Multi-cluster federation: a meta-scheduler over per-cluster engines.

``FederatedScheduler`` owns N independent ``SchedulerEngine`` instances —
one per cluster, each with its own ``ClusterSpec``, prioritizer, allocator,
and fault model — and routes every arriving job to exactly one engine at
submit time.  After routing, clusters never interact: engines advance in
**lockstep rescan windows** (``step(until)`` steps every engine to the same
time bound, the ``service.py`` windowed-stepping contract), so a fleet of N
clusters behaves like N independent streams stitched together by the router.

Two invariants make the layer cheap and predictable:

- **Snapshot-only routing** (see ``repro.fed.router``): the router reads
  static ``ClusterInfo`` plus the latest ``EngineSnapshot`` per cluster —
  O(N) per job, independent of queue depth or cluster size.  The federation
  refreshes the routed cluster's snapshot after each accepted job, so
  burst arrivals within one window see their own effect on queue loads.
- **Window-edge equivalence**: engines only advance inside ``step`` /
  ``drain``, and scheduling happens at event instants, so *given a fixed
  routing assignment* lockstep windowed stepping is exactly equivalent to
  draining each engine independently.  A single-cluster federation with the
  stateless ``hash`` router is therefore bit-identical to a bare
  ``SchedulerEngine`` (pinned by differential tests).  Load-aware routers
  legitimately route differently under different rescan cadences — the
  snapshots they read evolve with the windows.

Observability: each engine carries its own ``RollingTelemetry`` hook;
``FleetSnapshot`` aggregates O(1) per-cluster snapshots (fleet utilization,
cross-cluster Jain fairness, routed-job distribution) and ``result()``
folds completed jobs into a ``FleetResult`` with fleet-wide JCT / wait
percentiles and per-cluster ``BatchResult``s.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.metrics import BatchResult
from repro.core.policies import make_policy
from repro.core.prioritizer import PolicyPrioritizer, Prioritizer
from repro.core.types import ClusterSpec, Job
from repro.fed.router import ClusterInfo, ClusterView, Router, make_router
from repro.fed.scenarios import FleetRun, get_fleet_scenario
from repro.sched.engine import MultiHooks, SchedulerEngine
from repro.sched.service import QuotaPrioritizer, wrap_tenancy
from repro.sched.telemetry import RollingTelemetry, jain_index


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    """O(1) fleet-wide view: per-cluster snapshots plus aggregates.

    ``utilization`` is the capacity-weighted mean of per-cluster (up-node)
    utilizations and ``fairness`` is Jain's index over them; both are
    guarded so zero-GPU fleets and all-failed members yield finite values.
    """

    now: float
    clusters: tuple
    routed: tuple
    submitted: int
    num_pending: int
    num_running: int
    num_completed: int
    free_gpus: int
    utilization: float
    fairness: float

    @property
    def in_flight(self) -> int:
        return self.num_pending + self.num_running


@dataclasses.dataclass
class FleetResult:
    """End-of-run fleet aggregate over everything completed so far."""

    per_cluster: list[BatchResult]
    routed: list[int]
    jobs: list[Job]                    # completed, fleet-wide
    makespan: float
    gpu_seconds_used: float
    utilization: float                 # used / (fleet GPUs * makespan)
    avg_jct: float
    avg_wait: float
    jct_p50: float
    jct_p99: float
    wait_p50: float
    wait_p99: float
    fairness: float                    # Jain over per-cluster GPU-seconds/GPU


def _pct(arr: np.ndarray | None, q: float) -> float:
    return float(np.percentile(arr, q)) if arr is not None and arr.size else 0.0


#: Deferred-route retry backoff: first retry after DEFER_BASE_S, doubling
#: per failed attempt up to DEFER_MAX_S; after DEFER_MAX_ATTEMPTS the job is
#: force-routed onto the best surviving member even if nominally too big
#: for any of them (it then waits in that member's queue like any other
#: temporarily-unplaceable job).
DEFER_BASE_S = 60.0
DEFER_MAX_S = 3600.0
DEFER_MAX_ATTEMPTS = 8


class FederatedScheduler:
    """Meta-scheduler routing a shared job stream across per-cluster engines.

    ``prioritizer_factory(i)`` builds cluster ``i``'s prioritizer — engines
    must never share prioritizer state (a ``QuotaPrioritizer``'s usage
    tracking is per engine, so the factory is called once per cluster).
    ``QuotaPrioritizer`` instances are wired exactly like ``run_stream``
    does: attached as the engine's hook (incremental usage) and handed the
    engine reference for the recompute reference path.
    """

    def __init__(
        self,
        clusters: Sequence[ClusterSpec],
        router: Router | str = "jsq",
        *,
        prioritizer_factory: Callable[[int], Prioritizer] | None = None,
        allocator: str = "milp",
        backfill: bool = True,
        lookahead_k: int = 8,
        fault_models: Sequence | None = None,
        queue_window: int | None = None,
        telemetry: bool = True,
        telemetry_window: float = 6 * 3600.0,
        sample_interval: float = 600.0,
        router_seed: int = 0,
        optimized: bool = True,
        autoscalers: Sequence | None = None,
        migration=None,
        obs=None,
        parallel: bool = False,
        predictors: Sequence | None = None,
    ):
        if not clusters:
            raise ValueError("a federation needs at least one cluster")
        #: fleet-level observability bundle (repro.obs.Observability):
        #: members get per-cluster child bundles (disjoint trace pids, own
        #: metric labels) and routing / deferral / migration / blackout
        #: decisions count on the fleet registry.  None = bit-identical to
        #: the un-instrumented federation (pinned by tests).
        self.obs = obs
        fms = list(fault_models) if fault_models is not None \
            else [None] * len(clusters)
        if len(fms) != len(clusters):
            raise ValueError(f"{len(clusters)} clusters but {len(fms)} "
                             f"fault models")
        self.autoscalers = list(autoscalers) if autoscalers is not None \
            else [None] * len(clusters)
        if len(self.autoscalers) != len(clusters):
            raise ValueError(f"{len(clusters)} clusters but "
                             f"{len(self.autoscalers)} autoscalers")
        #: per-member runtime predictors (repro.predict.RuntimePredictor):
        #: engines must never share predictor state (online training and the
        #: feature cache are per engine).  None entries leave that member
        #: bit-identical to the predictor-less engine (pinned by tests).
        self.predictors = list(predictors) if predictors is not None \
            else [None] * len(clusters)
        if len(self.predictors) != len(clusters):
            raise ValueError(f"{len(clusters)} clusters but "
                             f"{len(self.predictors)} predictors")
        # scale-ups append to each member's spec.nodes: autoscaled members
        # get their own spec copy so caller-held fleet runs stay replayable
        clusters = [ClusterSpec(nodes=list(s.nodes), name=s.name)
                    if a is not None else s
                    for s, a in zip(clusters, self.autoscalers)]
        self.router = make_router(router, seed=router_seed)
        factory = prioritizer_factory or \
            (lambda i: PolicyPrioritizer(make_policy("fcfs")))
        self.engines: list[SchedulerEngine] = []
        self.telemetries: list[RollingTelemetry | None] = []
        for i, spec in enumerate(clusters):
            pri = factory(i)
            hooks: list = []
            tel = None
            if telemetry:
                tel = RollingTelemetry(window=telemetry_window,
                                       sample_interval=sample_interval)
                hooks.append(tel)
            if obs is not None:
                mobs = obs.member(i, name=spec.name or f"cluster{i}")
                hooks.extend(mobs.hooks())
            if self.predictors[i] is not None:
                hooks.append(self.predictors[i])
            if isinstance(pri, QuotaPrioritizer) and pri.incremental:
                pri.reset_usage()
                hooks.append(pri)
            # one MultiHooks per engine: duck-typed observers get the full
            # surface and a raising one cannot corrupt the member's window
            hooks = [MultiHooks(*hooks)] if hooks else []
            engine = SchedulerEngine(
                spec, pri, allocator=allocator, backfill=backfill,
                lookahead_k=lookahead_k, fault_model=fms[i],
                queue_window=queue_window, hooks=hooks, optimized=optimized,
                predictor=self.predictors[i])
            if isinstance(pri, QuotaPrioritizer):
                pri.engine = engine
            self.engines.append(engine)
            self.telemetries.append(tel)
        self.infos = [ClusterInfo.from_spec(i, spec)
                      for i, spec in enumerate(clusters)]
        self._views = [ClusterView(info, eng.snapshot())
                       for info, eng in zip(self.infos, self.engines)]
        self.routed = [0] * len(self.engines)
        self.routes: dict[int, int] = {}        # job_id -> cluster index
        #: cross-cluster migration policy (repro.lifecycle.migration duck
        #: type: pick(fed, now) -> [MigrationEvent]); None = one-shot
        #: routing only, bit-identical to the pre-lifecycle federation
        self.migration = migration
        self.migrations: list = []              # executed MigrationEvents
        #: members currently blacked out by chaos (every node down): routing
        #: masks them with zero-capacity views — substitution, never list
        #: filtering, because routers index ``views[i]`` positionally
        self.offline: set[int] = set()
        self._blackout_downed: dict[int, list[int]] = {}
        #: jobs whose route found no *online* capable member, parked for
        #: retry with exponential backoff: (retry_at, seq, attempts, job)
        self._deferred: list[tuple[float, int, int, Job]] = []
        self._defer_seq = itertools.count()
        self.deferrals = 0                      # total defer decisions
        self.chaos_actions: list = []           # fleet ChaosActions applied
        #: opt-in threaded member stepping (see ``_step_members``): engines
        #: share no mutable state between window edges, so stepping them
        #: concurrently and summing in member order is decision-for-decision
        #: identical to the serial loop (pinned by differential tests).
        #: Forced serial under ``obs`` — member bundles count on the shared
        #: fleet registry, whose counters are not thread-safe.
        self.parallel = bool(parallel)
        self._pool: ThreadPoolExecutor | None = None

    # ------------------------------------------------------------- ingest ----
    def _routing_views(self) -> list[ClusterView]:
        """The views routers actually see: blacked-out members are masked
        by *substituting* a zero-capacity ``ClusterInfo`` (routers index
        ``views[i]`` positionally, so the list shape must never change) —
        the capable-cluster filter then degrades to the surviving set."""
        if not self.offline:
            return self._views
        views = list(self._views)
        for i in self.offline:
            v = views[i]
            views[i] = ClusterView(
                ClusterInfo(index=i, name=v.info.name, total_gpus=0,
                            total_by_type={}), v.snap)
        return views

    def _any_online_capable(self, job: Job) -> bool:
        return any(v.info.capacity_for(job.gpu_type) >= job.num_gpus
                   for i, v in enumerate(self._views)
                   if i not in self.offline)

    def _route_one(self, job: Job, *, force: bool = False) -> bool:
        """Route one job onto an engine; returns False when no online
        member could ever place it (caller defers).  ``force`` skips the
        capability check — the post-backoff escape hatch — but still
        routes on the online-masked views."""
        views = self._routing_views()
        if self.offline and not force and not self._any_online_capable(job):
            return False
        idx = self.router.route(job, views)
        if not 0 <= idx < len(self.engines):
            raise RuntimeError(
                f"router {self.router.name!r} returned cluster {idx} "
                f"for job {job.job_id} (fleet has {len(self.engines)})")
        self.engines[idx].submit((job,))
        self.routed[idx] += 1
        self.routes[job.job_id] = idx
        # refresh only the routed cluster's view: O(1), and the next
        # job's routing sees this one in the queue load
        self._views[idx] = ClusterView(self.infos[idx],
                                       self.engines[idx].snapshot())
        if self.obs is not None:
            self.obs.count("repro_fed_routed_total",
                           "jobs routed per member",
                           cluster=self.infos[idx].name or str(idx))
            if force:
                self.obs.count("repro_fed_forced_routes_total",
                               "post-backoff forced routes")
        return True

    def _defer(self, job: Job, now: float, attempts: int) -> None:
        delay = min(DEFER_BASE_S * 2 ** attempts, DEFER_MAX_S)
        heapq.heappush(self._deferred,
                       (now + delay, next(self._defer_seq), attempts + 1,
                        job))
        self.deferrals += 1
        if self.obs is not None:
            self.obs.count("repro_fed_deferrals_total",
                           "routes parked for backoff retry")

    def _retry_deferred(self, now: float, *, all_parked: bool = False) -> int:
        """Re-attempt parked routes due by ``now`` (``all_parked`` retries
        everything regardless of backoff — the member-restore path, where
        capacity just changed fundamentally); failures back off again, and
        a job out of attempts force-routes onto the best surviving member
        (or keeps waiting while the whole fleet is dark).  Returns how many
        jobs got routed."""
        due = []
        while self._deferred and (all_parked
                                  or self._deferred[0][0] <= now + 1e-9):
            due.append(heapq.heappop(self._deferred))
        routed = 0
        for _, _, attempts, job in due:
            force = (attempts >= DEFER_MAX_ATTEMPTS
                     and len(self.offline) < len(self.engines))
            if self._route_one(job, force=force):
                routed += 1
            else:
                self._defer(job, now, attempts)
        return routed

    def submit(self, jobs: Iterable[Job]) -> int:
        """Route each job to one engine at submit time (snapshot-only,
        O(N clusters) per job).  Jobs are ingested in submit-time order —
        the same normalization a single engine applies to a batch.  Jobs
        no *online* member could ever place (mid-blackout arrivals needing
        a dark member's SKU) are parked and retried with backoff."""
        batch = sorted(jobs, key=lambda j: j.submit_time)
        for job in batch:
            if not self._route_one(job):
                self._defer(job, job.submit_time, attempts=0)
        return len(batch)

    # ------------------------------------------------------------ queries ----
    @property
    def done(self) -> bool:
        return not self._deferred and all(e.done for e in self.engines)

    def next_event_time(self) -> float:
        nxt = min(e.next_event_time() for e in self.engines)
        if self._deferred:
            nxt = min(nxt, self._deferred[0][0])
        return nxt

    def snapshot(self) -> FleetSnapshot:
        snaps = tuple(e.snapshot() for e in self.engines)
        total_cap = sum(info.total_gpus for info in self.infos)
        util = 0.0
        if total_cap > 0:
            util = sum(s.utilization * info.total_gpus
                       for s, info in zip(snaps, self.infos)) / total_cap
        return FleetSnapshot(
            now=max(e.now for e in self.engines),
            clusters=snaps,
            routed=tuple(self.routed),
            submitted=sum(s.submitted for s in snaps),
            num_pending=sum(s.num_pending for s in snaps),
            num_running=sum(s.num_running for s in snaps),
            num_completed=sum(s.num_completed for s in snaps),
            free_gpus=sum(s.free_gpus for s in snaps),
            utilization=util,
            fairness=jain_index([s.utilization for s in snaps]),
        )

    # ----------------------------------------------------------- stepping ----
    def _step_members(self, until: float) -> int:
        """Step every member engine to ``until`` and return the summed
        event-batch count.  With ``parallel=True`` the per-member calls run
        in a lazily created thread pool: members are fully independent
        between window edges (routing, control, migration, and view
        refreshes all happen serially *after* this barrier), so the only
        shared state inside a step is each engine's own.  The pool's
        ``map`` preserves member order, and integer summation is
        order-insensitive anyway — outputs are bit-identical to the serial
        loop.  Wall-clock wins depend on members releasing the GIL (numpy
        percentile/sort paths do) and scale with member count, not jobs."""
        engines = self.engines
        if not self.parallel or len(engines) < 2 or self.obs is not None:
            return sum(e.step(until) for e in engines)
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=min(len(engines), os.cpu_count() or 1),
                thread_name_prefix="fed-step")
        return sum(self._pool.map(lambda e: e.step(until), engines))

    def close(self) -> None:
        """Release the stepping thread pool (no-op for serial federations).
        Safe to call repeatedly; the pool is re-created on the next
        parallel step if the federation keeps running."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def step(self, until: float = math.inf) -> int:
        """Advance every engine in lockstep to ``until`` (one rescan
        window); returns total event batches processed.  Per-member
        autoscalers get their control tick at the window edge, *before* the
        view refresh — routers see scaled capacity through the refreshed
        snapshots immediately."""
        processed = self._step_members(until)
        if until != math.inf:
            self._control(until)
        self._refresh_views()
        if self._deferred and until != math.inf:
            if self._retry_deferred(until):
                self._refresh_views()
        if self.migration is not None and until != math.inf:
            if self._migrate(until):
                self._refresh_views()
        return processed

    def _migrate(self, now: float) -> int:
        """Execute the migration policy's moves for this window edge:
        drain from the source (``withdraw_pending`` → MIGRATING), resubmit
        on the destination with preserved remaining work
        (``admit_migrated``), and step the destination to the same edge so
        the arrival is ingested — and possibly scheduled — at the instant
        of the move.  Telemetry on both sides records the migration."""
        moves = self.migration.pick(self, now)
        for mv in moves:
            job, remaining = self.engines[mv.src].withdraw_pending(mv.job_id)
            dst = self.engines[mv.dst]
            if now > dst.now:
                dst.advance_to(now)       # arrivals land at the window edge
            dst.admit_migrated(job, remaining)
            dst.step(now)
            self.routed[mv.src] -= 1
            self.routed[mv.dst] += 1
            self.routes[mv.job_id] = mv.dst
            self.migrations.append(mv)
            for idx, kind in ((mv.src, "out"), (mv.dst, "in")):
                tel = self.telemetries[idx]
                note = getattr(tel, "note_migration", None)
                if note is not None:
                    note(kind)
            if self.obs is not None:
                self.obs.count(
                    "repro_fed_migrations_total",
                    "cross-cluster migrations executed",
                    src=self.infos[mv.src].name or str(mv.src),
                    dst=self.infos[mv.dst].name or str(mv.dst))
        return len(moves)

    def _control(self, now: float, stalled: bool = False) -> int:
        """Run every attached autoscaler's control tick; returns the number
        of scale events emitted fleet-wide."""
        acted = 0
        for i, (eng, scaler, tel) in enumerate(zip(self.engines,
                                                   self.autoscalers,
                                                   self.telemetries)):
            if scaler is None:
                continue
            if stalled and (eng.done or eng.next_event_time() != math.inf):
                continue   # only starved members get the override
            if self.obs is None:
                acted += len(scaler.control(eng, now, tel, stalled=stalled))
                continue
            t0 = time.perf_counter()
            events = scaler.control(eng, now, tel, stalled=stalled)
            self.obs.member(i).note_controller(
                "autoscaler", len(events), time.perf_counter() - t0, now)
            acted += len(events)
        return acted

    def control_stalled(self, now: float) -> int:
        """Stall override (see ``service.run_stream``): force a scale-up
        evaluation on members whose queues are starved with a dry event
        heap.  Refreshes views when anything changed."""
        acted = self._control(now, stalled=True)
        if acted:
            self._refresh_views()
        return acted

    def drain(self) -> int:
        """Process every queued event on every engine (batch semantics) —
        engines are independent after routing, so sequential drains equal
        lockstep stepping."""
        processed = sum(e.drain() for e in self.engines)
        self._refresh_views()
        return processed

    def run_until_complete(self) -> int:
        processed = 0
        while not self.done and self.next_event_time() != math.inf:
            processed += self.step(self.next_event_time())
        return processed

    def _refresh_views(self) -> None:
        for i, eng in enumerate(self.engines):
            snap = eng.snapshot()
            info = self.infos[i]
            # capacity staleness guard: the capable-cluster filter reads
            # static ClusterInfo, so autoscaled capacity must rebuild it —
            # a job sized for a scaled-up member would otherwise be deemed
            # unplaceable from pre-scaling totals (and vice versa)
            if (info.total_gpus != snap.total_gpus
                    or info.total_by_type != snap.total_gpus_by_type):
                info = ClusterInfo(index=i, name=info.name,
                                   total_gpus=snap.total_gpus,
                                   total_by_type=dict(snap.total_gpus_by_type))
                self.infos[i] = info
            self._views[i] = ClusterView(info, snap)

    # -------------------------------------------------------------- chaos ----
    def blackout_member(self, idx: int, at: float) -> list[int]:
        """Take every up node of member ``idx`` down at once (federation
        blackout): running gangs checkpoint-kill into the member's own
        queue, the member is marked offline, and routing degrades to the
        surviving capable set.  Returns the node ids actually downed (the
        set :meth:`restore_member` brings back — organically-failed nodes
        keep their own repair timelines)."""
        eng = self.engines[idx]
        if at > eng.now:
            eng.advance_to(at)
        cluster = eng.cluster
        downed: list[int] = []
        for node in range(len(cluster.total_gpus)):
            if not cluster.retired[node] and not cluster.node_down[node]:
                eng.force_fail(node)
                downed.append(node)
        self._blackout_downed[idx] = downed
        self.offline.add(idx)
        self._refresh_views()
        if self.obs is not None:
            self.obs.count("repro_fed_blackouts_total",
                           "member blackouts applied",
                           cluster=self.infos[idx].name or str(idx))
        return downed

    def restore_member(self, idx: int, at: float) -> list[int]:
        """Bring a blacked-out member back: recover exactly the nodes the
        blackout downed, reschedule its queue, and immediately retry every
        parked route (the member's capacity is visible again).  Returns
        the recovered node ids."""
        eng = self.engines[idx]
        if at > eng.now:
            eng.advance_to(at)
        downed = self._blackout_downed.pop(idx, [])
        for node in downed:
            eng.force_recover(node)
        eng.reschedule(at=at)
        self.offline.discard(idx)
        self._refresh_views()
        self._retry_deferred(at, all_parked=True)
        return downed

    def note_chaos(self, actions, now: float) -> None:
        """Record fleet chaos actions and forward each to its member's
        telemetry; refreshes views so the next routing decision sees the
        post-chaos capacity."""
        self.chaos_actions.extend(actions)
        for a in actions:
            if 0 <= a.cluster < len(self.telemetries):
                tel = self.telemetries[a.cluster]
                note = getattr(tel, "note_chaos_events", None)
                if note is not None:
                    note([a])
            if self.obs is not None:
                self.obs.count("repro_chaos_actions_total",
                               "fleet chaos actions applied", kind=a.kind)
        self._refresh_views()

    # ------------------------------------------------------------- result ----
    def finalize_telemetry(self) -> None:
        """Force an end-of-run sample on every cluster's telemetry."""
        for tel, eng in zip(self.telemetries, self.engines):
            if tel is not None:
                tel.final(eng)

    def result(self) -> FleetResult:
        per = [e.result() for e in self.engines]
        jobs = [j for e in self.engines for j in e.completed]
        jcts = np.array([j.jct for j in jobs]) if jobs else None
        waits = np.array([j.wait_time for j in jobs]) if jobs else None
        t0 = min((e.t0 for e in self.engines if e.t0 is not None),
                 default=0.0)
        t_end = max((j.finish_time for j in jobs), default=t0)
        makespan = t_end - t0
        cap_gpus = sum(info.total_gpus for info in self.infos)
        capacity = cap_gpus * max(makespan, 1e-9)
        used = sum(r.gpu_seconds_used for r in per)
        return FleetResult(
            per_cluster=per, routed=list(self.routed), jobs=jobs,
            makespan=makespan, gpu_seconds_used=used,
            utilization=used / capacity if capacity > 0 else 0.0,
            avg_jct=float(jcts.mean()) if jcts is not None else 0.0,
            avg_wait=float(waits.mean()) if waits is not None else 0.0,
            jct_p50=_pct(jcts, 50), jct_p99=_pct(jcts, 99),
            wait_p50=_pct(waits, 50), wait_p99=_pct(waits, 99),
            fairness=jain_index(
                [r.gpu_seconds_used / max(info.total_gpus, 1)
                 for r, info in zip(per, self.infos)]),
        )


# ----------------------------------------------------------------- drivers ----


@dataclasses.dataclass
class FleetStreamResult:
    """Outcome of replaying a fleet stream through the federation."""

    result: FleetResult
    snapshot: FleetSnapshot
    telemetries: list
    windows: int
    fed: FederatedScheduler
    obs: object | None = None


def run_fleet(
    run: FleetRun | str,
    num_jobs: int = 1000,
    seed: int = 0,
    *,
    router: Router | str = "jsq",
    rescan_interval: float = 60.0,
    allocator: str = "milp",
    backfill: bool = True,
    policy: str = "fcfs",
    prioritizer_factory: Callable[[int], Prioritizer] | None = None,
    queue_window: int | None = None,
    telemetry_window: float = 6 * 3600.0,
    sample_interval: float = 600.0,
    router_seed: int = 0,
    optimized: bool = True,
    autoscaler_factory: Callable | None = None,
    migration=None,
    chaos=None,
    obs=None,
    parallel: bool = False,
    predictor_factory: Callable | None = None,
) -> FleetStreamResult:
    """Replay a fleet scenario (or a prebuilt ``FleetRun``) through a fresh
    federation in lockstep rescan windows: each window's arrivals are routed
    as the window opens, then every engine steps to the window edge.  Empty
    multi-window gaps are hopped in one grid-aligned jump (same contract as
    ``service.run_stream``).  The fleet's tenant metadata (SLA users, VC
    quotas) wraps every cluster's prioritizer via ``wrap_tenancy``.

    ``autoscaler_factory(i, spec)`` builds member ``i``'s ``repro.scale``
    controller (return ``None`` for fixed-capacity members); controllers
    tick at every lockstep window edge and routers see scaled capacity
    through the refreshed views.

    ``predictor_factory(i, spec)`` builds member ``i``'s
    ``repro.predict.RuntimePredictor`` (return ``None`` for predictor-less
    members) — predictors train per member from that engine's completion
    hooks and must never be shared across members.

    ``migration`` attaches a ``repro.lifecycle.migration`` policy: waiting
    jobs re-route between members at every window edge when fresh snapshots
    show a sufficiently better home (``migration=None`` keeps the one-shot
    routing, bit-identical to the pre-lifecycle federation).

    ``chaos`` attaches a ``repro.chaos.FleetChaosInjector`` (ticking first
    at every window edge, like ``service.run_stream``): ``None`` wraps the
    fleet run's own ``ChaosSchedule`` if it declares one, ``False`` forces
    chaos off, anything else is used directly.

    ``obs`` attaches a fleet-level ``repro.obs.Observability``: each member
    engine gets its own child tracer/metrics/audit hooks (distinct trace
    pids), control-plane ticks are timed, and the bundle is finalized
    before the result is returned.  ``obs=None`` keeps the run bit-identical
    to an unobserved fleet.

    ``parallel=True`` steps member engines through a thread pool inside
    every lockstep window (outputs pinned bit-identical to the serial
    path, see ``FederatedScheduler._step_members``); the pool is released
    before the result is returned."""
    if isinstance(run, str):
        run = get_fleet_scenario(run).build(num_jobs, seed)
    run_chaos = getattr(run, "chaos", None)
    if chaos is None and run_chaos is not None:
        from repro.chaos import FleetChaosInjector
        chaos = FleetChaosInjector(run_chaos)
    elif chaos is False:
        chaos = None
    factory = prioritizer_factory or (
        lambda i: wrap_tenancy(PolicyPrioritizer(make_policy(policy)),
                               run.sla_users, run.vc_quotas))
    autoscalers = None
    if autoscaler_factory is not None:
        autoscalers = [autoscaler_factory(i, spec)
                       for i, spec in enumerate(run.clusters)]
    predictors = None
    if predictor_factory is not None:
        predictors = [predictor_factory(i, spec)
                      for i, spec in enumerate(run.clusters)]
    fed = FederatedScheduler(
        run.clusters, router, prioritizer_factory=factory,
        allocator=allocator, backfill=backfill,
        fault_models=run.fault_models, queue_window=queue_window,
        telemetry_window=telemetry_window, sample_interval=sample_interval,
        router_seed=router_seed, optimized=optimized,
        autoscalers=autoscalers, migration=migration, obs=obs,
        parallel=parallel, predictors=predictors)

    def _chaos_tick(now):
        if obs is None:
            return chaos.control(fed, now)
        t0_w = time.perf_counter()
        applied = chaos.control(fed, now)
        obs.note_controller("fleet-chaos", len(applied),
                            time.perf_counter() - t0_w, now)
        return applied

    jobs = sorted((j.clone_pending() for j in run.jobs),
                  key=lambda j: j.submit_time)
    iv = max(rescan_interval, 1e-6)
    t0 = jobs[0].submit_time if jobs else 0.0
    t = t0
    feed = 0
    windows = 0
    while True:
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= t + iv:
            hi += 1
        if hi > feed:
            fed.submit(jobs[feed:hi])
            feed = hi
        if feed >= len(jobs) and (fed.done
                                  or fed.next_event_time() == math.inf):
            if not fed.done and chaos is not None \
                    and chaos.next_time() < math.inf:
                # dry heaps with work still queued (or parked routes): only
                # a chaos event — e.g. the restore ending a blackout — can
                # unblock them; hop to its window edge and tick
                t = t0 + math.ceil((chaos.next_time() - t0) / iv) * iv
                fed.step(t)
                _chaos_tick(t)
                continue
            if fed.done or autoscalers is None:
                break
            # starved member(s) with dry heaps: only added capacity can
            # unblock them (same stall override as service.run_stream)
            t += iv
            if not fed.control_stalled(t) \
                    and fed.next_event_time() == math.inf:
                break
            continue
        nxt = fed.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if chaos is not None:
            nxt = min(nxt, chaos.next_time())
        if nxt > t + iv:
            t = t0 + math.floor((nxt - t0) / iv) * iv
            continue
        if obs is not None:
            t_step = time.perf_counter()
            fed.step(t + iv)
            obs.note_window(t, time.perf_counter() - t_step, 0)
        else:
            fed.step(t + iv)
        t += iv
        windows += 1
        if chaos is not None:
            _chaos_tick(t)
    fed.finalize_telemetry()
    fed.close()
    if obs is not None:
        obs.finalize_fleet(fed)
    return FleetStreamResult(result=fed.result(), snapshot=fed.snapshot(),
                             telemetries=fed.telemetries, windows=windows,
                             fed=fed, obs=obs)
