"""Multi-cluster federation: a meta-scheduler over per-cluster engines.

``FederatedScheduler`` owns N independent ``SchedulerEngine`` instances —
one per cluster, each with its own ``ClusterSpec``, prioritizer, allocator,
and fault model — and routes every arriving job to exactly one engine at
submit time.  After routing, clusters never interact: engines advance in
**lockstep rescan windows** (``step(until)`` steps every engine to the same
time bound, the ``service.py`` windowed-stepping contract), so a fleet of N
clusters behaves like N independent streams stitched together by the router.

Two invariants make the layer cheap and predictable:

- **Snapshot-only routing** (see ``repro.fed.router``): the router reads
  static ``ClusterInfo`` plus the latest ``EngineSnapshot`` per cluster —
  O(N) per job, independent of queue depth or cluster size.  The federation
  refreshes the routed cluster's snapshot after each accepted job, so
  burst arrivals within one window see their own effect on queue loads.
- **Window-edge equivalence**: engines only advance inside ``step`` /
  ``drain``, and scheduling happens at event instants, so *given a fixed
  routing assignment* lockstep windowed stepping is exactly equivalent to
  draining each engine independently.  A single-cluster federation with the
  stateless ``hash`` router is therefore bit-identical to a bare
  ``SchedulerEngine`` (pinned by differential tests).  Load-aware routers
  legitimately route differently under different rescan cadences — the
  snapshots they read evolve with the windows.

Observability: each engine carries its own ``RollingTelemetry`` hook;
``FleetSnapshot`` aggregates O(1) per-cluster snapshots (fleet utilization,
cross-cluster Jain fairness, routed-job distribution) and ``result()``
folds completed jobs into a ``FleetResult`` with fleet-wide JCT / wait
percentiles and per-cluster ``BatchResult``s.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.metrics import BatchResult
from repro.core.policies import make_policy
from repro.core.prioritizer import PolicyPrioritizer, Prioritizer
from repro.core.types import ClusterSpec, Job
from repro.fed.router import ClusterInfo, ClusterView, Router, make_router
from repro.fed.scenarios import FleetRun, get_fleet_scenario
from repro.sched.engine import SchedulerEngine
from repro.sched.service import QuotaPrioritizer, wrap_tenancy
from repro.sched.telemetry import RollingTelemetry, jain_index


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    """O(1) fleet-wide view: per-cluster snapshots plus aggregates.

    ``utilization`` is the capacity-weighted mean of per-cluster (up-node)
    utilizations and ``fairness`` is Jain's index over them; both are
    guarded so zero-GPU fleets and all-failed members yield finite values.
    """

    now: float
    clusters: tuple
    routed: tuple
    submitted: int
    num_pending: int
    num_running: int
    num_completed: int
    free_gpus: int
    utilization: float
    fairness: float

    @property
    def in_flight(self) -> int:
        return self.num_pending + self.num_running


@dataclasses.dataclass
class FleetResult:
    """End-of-run fleet aggregate over everything completed so far."""

    per_cluster: list[BatchResult]
    routed: list[int]
    jobs: list[Job]                    # completed, fleet-wide
    makespan: float
    gpu_seconds_used: float
    utilization: float                 # used / (fleet GPUs * makespan)
    avg_jct: float
    avg_wait: float
    jct_p50: float
    jct_p99: float
    wait_p50: float
    wait_p99: float
    fairness: float                    # Jain over per-cluster GPU-seconds/GPU


def _pct(arr: np.ndarray | None, q: float) -> float:
    return float(np.percentile(arr, q)) if arr is not None and arr.size else 0.0


class FederatedScheduler:
    """Meta-scheduler routing a shared job stream across per-cluster engines.

    ``prioritizer_factory(i)`` builds cluster ``i``'s prioritizer — engines
    must never share prioritizer state (a ``QuotaPrioritizer``'s usage
    tracking is per engine, so the factory is called once per cluster).
    ``QuotaPrioritizer`` instances are wired exactly like ``run_stream``
    does: attached as the engine's hook (incremental usage) and handed the
    engine reference for the recompute reference path.
    """

    def __init__(
        self,
        clusters: Sequence[ClusterSpec],
        router: Router | str = "jsq",
        *,
        prioritizer_factory: Callable[[int], Prioritizer] | None = None,
        allocator: str = "milp",
        backfill: bool = True,
        lookahead_k: int = 8,
        fault_models: Sequence | None = None,
        queue_window: int | None = None,
        telemetry: bool = True,
        telemetry_window: float = 6 * 3600.0,
        sample_interval: float = 600.0,
        router_seed: int = 0,
        optimized: bool = True,
        autoscalers: Sequence | None = None,
        migration=None,
    ):
        if not clusters:
            raise ValueError("a federation needs at least one cluster")
        fms = list(fault_models) if fault_models is not None \
            else [None] * len(clusters)
        if len(fms) != len(clusters):
            raise ValueError(f"{len(clusters)} clusters but {len(fms)} "
                             f"fault models")
        self.autoscalers = list(autoscalers) if autoscalers is not None \
            else [None] * len(clusters)
        if len(self.autoscalers) != len(clusters):
            raise ValueError(f"{len(clusters)} clusters but "
                             f"{len(self.autoscalers)} autoscalers")
        # scale-ups append to each member's spec.nodes: autoscaled members
        # get their own spec copy so caller-held fleet runs stay replayable
        clusters = [ClusterSpec(nodes=list(s.nodes), name=s.name)
                    if a is not None else s
                    for s, a in zip(clusters, self.autoscalers)]
        self.router = make_router(router, seed=router_seed)
        factory = prioritizer_factory or \
            (lambda i: PolicyPrioritizer(make_policy("fcfs")))
        self.engines: list[SchedulerEngine] = []
        self.telemetries: list[RollingTelemetry | None] = []
        for i, spec in enumerate(clusters):
            pri = factory(i)
            hooks: list = []
            tel = None
            if telemetry:
                tel = RollingTelemetry(window=telemetry_window,
                                       sample_interval=sample_interval)
                hooks.append(tel)
            if isinstance(pri, QuotaPrioritizer) and pri.incremental:
                pri.reset_usage()
                hooks.append(pri)
            engine = SchedulerEngine(
                spec, pri, allocator=allocator, backfill=backfill,
                lookahead_k=lookahead_k, fault_model=fms[i],
                queue_window=queue_window, hooks=hooks, optimized=optimized)
            if isinstance(pri, QuotaPrioritizer):
                pri.engine = engine
            self.engines.append(engine)
            self.telemetries.append(tel)
        self.infos = [ClusterInfo.from_spec(i, spec)
                      for i, spec in enumerate(clusters)]
        self._views = [ClusterView(info, eng.snapshot())
                       for info, eng in zip(self.infos, self.engines)]
        self.routed = [0] * len(self.engines)
        self.routes: dict[int, int] = {}        # job_id -> cluster index
        #: cross-cluster migration policy (repro.lifecycle.migration duck
        #: type: pick(fed, now) -> [MigrationEvent]); None = one-shot
        #: routing only, bit-identical to the pre-lifecycle federation
        self.migration = migration
        self.migrations: list = []              # executed MigrationEvents

    # ------------------------------------------------------------- ingest ----
    def submit(self, jobs: Iterable[Job]) -> int:
        """Route each job to one engine at submit time (snapshot-only,
        O(N clusters) per job).  Jobs are ingested in submit-time order —
        the same normalization a single engine applies to a batch."""
        batch = sorted(jobs, key=lambda j: j.submit_time)
        for job in batch:
            idx = self.router.route(job, self._views)
            if not 0 <= idx < len(self.engines):
                raise RuntimeError(
                    f"router {self.router.name!r} returned cluster {idx} "
                    f"for job {job.job_id} (fleet has {len(self.engines)})")
            self.engines[idx].submit((job,))
            self.routed[idx] += 1
            self.routes[job.job_id] = idx
            # refresh only the routed cluster's view: O(1), and the next
            # job's routing sees this one in the queue load
            self._views[idx] = ClusterView(self.infos[idx],
                                           self.engines[idx].snapshot())
        return len(batch)

    # ------------------------------------------------------------ queries ----
    @property
    def done(self) -> bool:
        return all(e.done for e in self.engines)

    def next_event_time(self) -> float:
        return min(e.next_event_time() for e in self.engines)

    def snapshot(self) -> FleetSnapshot:
        snaps = tuple(e.snapshot() for e in self.engines)
        total_cap = sum(info.total_gpus for info in self.infos)
        util = 0.0
        if total_cap > 0:
            util = sum(s.utilization * info.total_gpus
                       for s, info in zip(snaps, self.infos)) / total_cap
        return FleetSnapshot(
            now=max(e.now for e in self.engines),
            clusters=snaps,
            routed=tuple(self.routed),
            submitted=sum(s.submitted for s in snaps),
            num_pending=sum(s.num_pending for s in snaps),
            num_running=sum(s.num_running for s in snaps),
            num_completed=sum(s.num_completed for s in snaps),
            free_gpus=sum(s.free_gpus for s in snaps),
            utilization=util,
            fairness=jain_index([s.utilization for s in snaps]),
        )

    # ----------------------------------------------------------- stepping ----
    def step(self, until: float = math.inf) -> int:
        """Advance every engine in lockstep to ``until`` (one rescan
        window); returns total event batches processed.  Per-member
        autoscalers get their control tick at the window edge, *before* the
        view refresh — routers see scaled capacity through the refreshed
        snapshots immediately."""
        processed = sum(e.step(until) for e in self.engines)
        if until != math.inf:
            self._control(until)
        self._refresh_views()
        if self.migration is not None and until != math.inf:
            if self._migrate(until):
                self._refresh_views()
        return processed

    def _migrate(self, now: float) -> int:
        """Execute the migration policy's moves for this window edge:
        drain from the source (``withdraw_pending`` → MIGRATING), resubmit
        on the destination with preserved remaining work
        (``admit_migrated``), and step the destination to the same edge so
        the arrival is ingested — and possibly scheduled — at the instant
        of the move.  Telemetry on both sides records the migration."""
        moves = self.migration.pick(self, now)
        for mv in moves:
            job, remaining = self.engines[mv.src].withdraw_pending(mv.job_id)
            dst = self.engines[mv.dst]
            if now > dst.now:
                dst.advance_to(now)       # arrivals land at the window edge
            dst.admit_migrated(job, remaining)
            dst.step(now)
            self.routed[mv.src] -= 1
            self.routed[mv.dst] += 1
            self.routes[mv.job_id] = mv.dst
            self.migrations.append(mv)
            for idx, kind in ((mv.src, "out"), (mv.dst, "in")):
                tel = self.telemetries[idx]
                note = getattr(tel, "note_migration", None)
                if note is not None:
                    note(kind)
        return len(moves)

    def _control(self, now: float, stalled: bool = False) -> int:
        """Run every attached autoscaler's control tick; returns the number
        of scale events emitted fleet-wide."""
        acted = 0
        for eng, scaler, tel in zip(self.engines, self.autoscalers,
                                    self.telemetries):
            if scaler is None:
                continue
            if stalled and (eng.done or eng.next_event_time() != math.inf):
                continue   # only starved members get the override
            acted += len(scaler.control(eng, now, tel, stalled=stalled))
        return acted

    def control_stalled(self, now: float) -> int:
        """Stall override (see ``service.run_stream``): force a scale-up
        evaluation on members whose queues are starved with a dry event
        heap.  Refreshes views when anything changed."""
        acted = self._control(now, stalled=True)
        if acted:
            self._refresh_views()
        return acted

    def drain(self) -> int:
        """Process every queued event on every engine (batch semantics) —
        engines are independent after routing, so sequential drains equal
        lockstep stepping."""
        processed = sum(e.drain() for e in self.engines)
        self._refresh_views()
        return processed

    def run_until_complete(self) -> int:
        processed = 0
        while not self.done and self.next_event_time() != math.inf:
            processed += self.step(self.next_event_time())
        return processed

    def _refresh_views(self) -> None:
        for i, eng in enumerate(self.engines):
            snap = eng.snapshot()
            info = self.infos[i]
            # capacity staleness guard: the capable-cluster filter reads
            # static ClusterInfo, so autoscaled capacity must rebuild it —
            # a job sized for a scaled-up member would otherwise be deemed
            # unplaceable from pre-scaling totals (and vice versa)
            if (info.total_gpus != snap.total_gpus
                    or info.total_by_type != snap.total_gpus_by_type):
                info = ClusterInfo(index=i, name=info.name,
                                   total_gpus=snap.total_gpus,
                                   total_by_type=dict(snap.total_gpus_by_type))
                self.infos[i] = info
            self._views[i] = ClusterView(info, snap)

    # ------------------------------------------------------------- result ----
    def finalize_telemetry(self) -> None:
        """Force an end-of-run sample on every cluster's telemetry."""
        for tel, eng in zip(self.telemetries, self.engines):
            if tel is not None:
                tel.final(eng)

    def result(self) -> FleetResult:
        per = [e.result() for e in self.engines]
        jobs = [j for e in self.engines for j in e.completed]
        jcts = np.array([j.jct for j in jobs]) if jobs else None
        waits = np.array([j.wait_time for j in jobs]) if jobs else None
        t0 = min((e.t0 for e in self.engines if e.t0 is not None),
                 default=0.0)
        t_end = max((j.finish_time for j in jobs), default=t0)
        makespan = t_end - t0
        cap_gpus = sum(info.total_gpus for info in self.infos)
        capacity = cap_gpus * max(makespan, 1e-9)
        used = sum(r.gpu_seconds_used for r in per)
        return FleetResult(
            per_cluster=per, routed=list(self.routed), jobs=jobs,
            makespan=makespan, gpu_seconds_used=used,
            utilization=used / capacity if capacity > 0 else 0.0,
            avg_jct=float(jcts.mean()) if jcts is not None else 0.0,
            avg_wait=float(waits.mean()) if waits is not None else 0.0,
            jct_p50=_pct(jcts, 50), jct_p99=_pct(jcts, 99),
            wait_p50=_pct(waits, 50), wait_p99=_pct(waits, 99),
            fairness=jain_index(
                [r.gpu_seconds_used / max(info.total_gpus, 1)
                 for r, info in zip(per, self.infos)]),
        )


# ----------------------------------------------------------------- drivers ----


@dataclasses.dataclass
class FleetStreamResult:
    """Outcome of replaying a fleet stream through the federation."""

    result: FleetResult
    snapshot: FleetSnapshot
    telemetries: list
    windows: int
    fed: FederatedScheduler


def run_fleet(
    run: FleetRun | str,
    num_jobs: int = 1000,
    seed: int = 0,
    *,
    router: Router | str = "jsq",
    rescan_interval: float = 60.0,
    allocator: str = "milp",
    backfill: bool = True,
    policy: str = "fcfs",
    prioritizer_factory: Callable[[int], Prioritizer] | None = None,
    queue_window: int | None = None,
    telemetry_window: float = 6 * 3600.0,
    sample_interval: float = 600.0,
    router_seed: int = 0,
    optimized: bool = True,
    autoscaler_factory: Callable | None = None,
    migration=None,
) -> FleetStreamResult:
    """Replay a fleet scenario (or a prebuilt ``FleetRun``) through a fresh
    federation in lockstep rescan windows: each window's arrivals are routed
    as the window opens, then every engine steps to the window edge.  Empty
    multi-window gaps are hopped in one grid-aligned jump (same contract as
    ``service.run_stream``).  The fleet's tenant metadata (SLA users, VC
    quotas) wraps every cluster's prioritizer via ``wrap_tenancy``.

    ``autoscaler_factory(i, spec)`` builds member ``i``'s ``repro.scale``
    controller (return ``None`` for fixed-capacity members); controllers
    tick at every lockstep window edge and routers see scaled capacity
    through the refreshed views.

    ``migration`` attaches a ``repro.lifecycle.migration`` policy: waiting
    jobs re-route between members at every window edge when fresh snapshots
    show a sufficiently better home (``migration=None`` keeps the one-shot
    routing, bit-identical to the pre-lifecycle federation)."""
    if isinstance(run, str):
        run = get_fleet_scenario(run).build(num_jobs, seed)
    factory = prioritizer_factory or (
        lambda i: wrap_tenancy(PolicyPrioritizer(make_policy(policy)),
                               run.sla_users, run.vc_quotas))
    autoscalers = None
    if autoscaler_factory is not None:
        autoscalers = [autoscaler_factory(i, spec)
                       for i, spec in enumerate(run.clusters)]
    fed = FederatedScheduler(
        run.clusters, router, prioritizer_factory=factory,
        allocator=allocator, backfill=backfill,
        fault_models=run.fault_models, queue_window=queue_window,
        telemetry_window=telemetry_window, sample_interval=sample_interval,
        router_seed=router_seed, optimized=optimized,
        autoscalers=autoscalers, migration=migration)

    jobs = sorted((j.clone_pending() for j in run.jobs),
                  key=lambda j: j.submit_time)
    iv = max(rescan_interval, 1e-6)
    t0 = jobs[0].submit_time if jobs else 0.0
    t = t0
    feed = 0
    windows = 0
    while True:
        hi = feed
        while hi < len(jobs) and jobs[hi].submit_time <= t + iv:
            hi += 1
        if hi > feed:
            fed.submit(jobs[feed:hi])
            feed = hi
        if feed >= len(jobs) and (fed.done
                                  or fed.next_event_time() == math.inf):
            if fed.done or autoscalers is None:
                break
            # starved member(s) with dry heaps: only added capacity can
            # unblock them (same stall override as service.run_stream)
            t += iv
            if not fed.control_stalled(t) \
                    and fed.next_event_time() == math.inf:
                break
            continue
        nxt = fed.next_event_time()
        if feed < len(jobs):
            nxt = min(nxt, jobs[feed].submit_time)
        if nxt > t + iv:
            t = t0 + math.floor((nxt - t0) / iv) * iv
            continue
        fed.step(t + iv)
        t += iv
        windows += 1
    fed.finalize_telemetry()
    return FleetStreamResult(result=fed.result(), snapshot=fed.snapshot(),
                             telemetries=fed.telemetries, windows=windows,
                             fed=fed)
