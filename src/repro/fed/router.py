"""Snapshot-driven routing policies for the multi-cluster federation layer.

A ``Router`` decides, at submit time, which cluster's ``SchedulerEngine``
receives an arriving job.  The **snapshot-only routing invariant**: a router
sees exactly two things per cluster —

- ``ClusterInfo``: static capacity (total GPUs, per-SKU totals), computed
  once from the ``ClusterSpec``;
- the latest ``EngineSnapshot``: the O(1) view the engine already exports
  (queue depth, free GPUs overall and per SKU, utilization, ...).

Routers never touch engine internals, never enumerate placements, and never
profile jobs — exactly the cheap-rolling-signal regime online schedulers
like PADS argue for — so routing one job is O(N) in the number of clusters
regardless of cluster size or queue depth.

All routers restrict their choice to *capable* clusters (enough total GPUs
of the requested SKU that the job could ever be placed there); a job no
cluster can ever run degrades to the largest-capacity cluster for its SKU
instead of crashing the router.  Snapshot-derived ratios arrive pre-hardened
(see ``EngineSnapshot``): a fleet member whose nodes have all failed reads
zero free GPUs and finite utilization, never NaN.

Registered policies (``ROUTERS`` / ``make_router``):

- ``jsq``             — join-shortest-queue on jobs in the system.
- ``free-gpus``       — most free GPUs on up nodes right now.
- ``sku-affinity``    — prefer clusters whose SKU mix can serve the job's
                        GPU request *now* (most free GPUs of that SKU);
                        falls back to shortest-queue among capable clusters
                        when no cluster currently has the SKU free.
- ``weighted-random`` — random, weighted by static cluster capacity
                        (deterministic in its seed).
- ``hash``            — stateless multiplicative hash of the job id; the
                        baseline every stateful policy must beat.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence

import numpy as np

from repro.core.types import ClusterSpec, Job
from repro.sched.engine import EngineSnapshot

#: Knuth's multiplicative hashing constant (2^32 / phi), used by the
#: stateless ``hash`` router to spread sequential job ids uniformly.
_KNUTH = 2654435761


@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    """Static, routing-visible description of one fleet member."""

    index: int
    name: str
    total_gpus: int
    total_by_type: dict

    @classmethod
    def from_spec(cls, index: int, spec: ClusterSpec) -> "ClusterInfo":
        return cls(index=index, name=spec.name, total_gpus=spec.total_gpus,
                   total_by_type={t: spec.gpus_of_type(t)
                                  for t in spec.gpu_types})

    def capacity_for(self, gpu_type: str) -> int:
        """Total GPUs this cluster could ever offer the requested SKU."""
        if gpu_type == "any":
            return self.total_gpus
        return self.total_by_type.get(gpu_type, 0)


@dataclasses.dataclass
class ClusterView:
    """What the router sees for one cluster: static info + latest snapshot.

    The federation refreshes the routed cluster's snapshot after every
    accepted job, so ``snap.submitted`` already counts jobs routed earlier
    in the same batch."""

    info: ClusterInfo
    snap: EngineSnapshot

    @property
    def queue_load(self) -> int:
        """Jobs currently in this cluster's system: pending + running +
        routed-but-not-yet-arrived.  Equals ``EngineSnapshot.in_flight`` at
        every rescan-window edge (once the engine has stepped past the
        arrivals); between edges it additionally counts jobs routed here
        since the engine last stepped — without it, JSQ would dump a whole
        burst on whichever cluster looked shortest at the window open."""
        return self.snap.submitted - self.snap.num_completed

    def free_for(self, gpu_type: str) -> int:
        """Free GPUs on up nodes satisfying the requested SKU, right now."""
        if gpu_type == "any":
            return self.snap.free_gpus
        return self.snap.free_gpus_by_type.get(gpu_type, 0)


class Router(Protocol):
    """Routing policy: pick the cluster index an arriving job is sent to.

    ``views[i].info.index == i`` — the federation passes views in cluster
    order, and the returned index addresses that same list."""

    name: str

    def route(self, job: Job, views: Sequence[ClusterView]) -> int: ...


def capable_clusters(job: Job, views: Sequence[ClusterView]) -> list[int]:
    """Indices of clusters that could EVER place the job (enough total GPUs
    of the requested SKU).  When none qualifies, degrade to the single
    largest-capacity cluster for that SKU (ties: overall capacity, then
    lowest index) — a mis-sized job turns into one hot queue, not a crash."""
    cap = [v.info.index for v in views
           if v.info.capacity_for(job.gpu_type) >= job.num_gpus]
    if cap:
        return cap
    best = max(views, key=lambda v: (v.info.capacity_for(job.gpu_type),
                                     v.info.total_gpus, -v.info.index))
    return [best.info.index]


class HashRouter:
    """Stateless baseline: multiplicative hash of the job id over the
    capable set.  Uniform regardless of cluster size or load — exactly the
    blindness the stateful policies are benchmarked against."""

    name = "hash"

    def route(self, job: Job, views: Sequence[ClusterView]) -> int:
        cap = capable_clusters(job, views)
        return cap[((job.job_id * _KNUTH) & 0xFFFFFFFF) % len(cap)]


class JSQRouter:
    """Join-shortest-queue on jobs in the system (ties: lowest index)."""

    name = "jsq"

    def route(self, job: Job, views: Sequence[ClusterView]) -> int:
        cap = capable_clusters(job, views)
        return min(cap, key=lambda i: (views[i].queue_load, i))


class FreeGpusRouter:
    """Most free GPUs on up nodes right now (ties: lowest index)."""

    name = "free-gpus"

    def route(self, job: Job, views: Sequence[ClusterView]) -> int:
        cap = capable_clusters(job, views)
        return min(cap, key=lambda i: (-views[i].snap.free_gpus, i))


class SkuAffinityRouter:
    """Prefer clusters whose SKU mix serves the request *now*: among capable
    clusters with >= num_gpus of the requested SKU free, take the one with
    the most free (ties: lowest index).  When no cluster currently has the
    SKU free — the job will queue wherever it lands — fall back to the
    shortest queue among capable clusters."""

    name = "sku-affinity"

    def route(self, job: Job, views: Sequence[ClusterView]) -> int:
        cap = capable_clusters(job, views)
        fit = [i for i in cap if views[i].free_for(job.gpu_type) >= job.num_gpus]
        if fit:
            return min(fit, key=lambda i: (-views[i].free_for(job.gpu_type), i))
        return min(cap, key=lambda i: (views[i].queue_load, i))


class WeightedRandomRouter:
    """Random over capable clusters, weighted by static total capacity;
    deterministic in ``seed``.  Zero/degenerate weights fall back to a
    uniform draw (an all-zero fleet must not produce NaN probabilities)."""

    name = "weighted-random"

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def route(self, job: Job, views: Sequence[ClusterView]) -> int:
        cap = capable_clusters(job, views)
        if len(cap) == 1:
            return cap[0]
        w = np.array([views[i].info.total_gpus for i in cap], dtype=np.float64)
        tot = float(w.sum())
        if not np.isfinite(tot) or tot <= 0.0:
            return cap[int(self._rng.integers(len(cap)))]
        return cap[int(self._rng.choice(len(cap), p=w / tot))]


ROUTERS: dict[str, type] = {
    "hash": HashRouter,
    "jsq": JSQRouter,
    "free-gpus": FreeGpusRouter,
    "sku-affinity": SkuAffinityRouter,
    "weighted-random": WeightedRandomRouter,
}


def list_routers() -> list[str]:
    return sorted(ROUTERS)


def make_router(router: Router | str, seed: int = 0) -> Router:
    """Resolve a router by registry name (pass-through for instances)."""
    if not isinstance(router, str):
        return router
    if router not in ROUTERS:
        raise KeyError(f"unknown router {router!r}; "
                       f"registered: {', '.join(sorted(ROUTERS))}")
    cls = ROUTERS[router]
    return cls(seed=seed) if cls is WeightedRandomRouter else cls()
