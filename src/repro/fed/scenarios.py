"""Fleet scenarios: heterogeneous multi-cluster workloads for the federation.

Each fleet scenario composes the existing registered single-cluster
scenarios (``repro.sched.scenarios``) over a *fleet*: per-cluster specs and
fault models, plus one merged arrival stream the meta-scheduler routes.
Builders are deterministic in ``seed`` (same contract as the single-cluster
registry).

Registry: ``FLEET_SCENARIOS`` maps name -> ``FleetScenario``; use
``get_fleet_scenario(name)`` / ``list_fleet_scenarios()``.  Registered:

- ``fleet-steady``       — three identical clusters, merged steady streams
                           (control: any sane router ties here).
- ``fleet-skewed-flash`` — three size-skewed clusters (~0.5x / 1x / 2x)
                           serving merged flash-crowd streams; uniform
                           (hash) routing drowns the small cluster.
- ``fleet-fault-storm``  — one cluster in fault-storm while two stay
                           steady; load-aware routers drain around the
                           failing member.
- ``fleet-sku-split``    — a small fast A100 island next to a large V100
                           pool with SKU-skewed demand (affinity stress).
- ``fleet-multi-tenant`` — two clusters with skewed per-VC demand against
                           even quotas (exercises the per-engine VC-quota
                           gate across the fleet).
- ``fleet-fault-migration`` — a *harsh* storm (2h MTBF, 30-minute repairs)
                           on one member beside two healthy neighbours:
                           the queue piles up behind the storm, the case
                           ``repro.lifecycle`` cross-cluster migration
                           exists to drain.
- ``fleet-blackout``     — one member loses *all* nodes mid-run for 15% of
                           the horizon (``repro.chaos`` blackout): routers
                           degrade to the survivors, parked routes retry
                           with backoff when the member returns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.faults import FaultModel
from repro.core.trace import generate_trace, make_cluster
from repro.core.types import ClusterSpec, Job, NodeSpec
from repro.sched.scenarios import ScenarioRun, get_scenario


@dataclasses.dataclass(frozen=True)
class FleetRun:
    """A concrete, replayable fleet workload: clusters + merged job stream
    + per-cluster fault models + tenant metadata."""

    name: str
    clusters: tuple[ClusterSpec, ...]
    jobs: list[Job]
    fault_models: tuple
    sla_users: frozenset = frozenset()
    vc_quotas: dict | None = None
    #: optional fleet chaos timeline (a ``repro.chaos.ChaosSchedule`` whose
    #: events carry member indices; duck-typed — ``run_fleet`` wraps it in
    #: a fresh ``FleetChaosInjector`` per run)
    chaos: object | None = None

    @classmethod
    def from_scenario(cls, run: ScenarioRun) -> "FleetRun":
        """Wrap a single-cluster ``ScenarioRun`` as a one-member fleet
        (the degenerate federation used by the differential tests)."""
        return cls(name=run.name, clusters=(run.spec,), jobs=run.jobs,
                   fault_models=(run.fault_model,), sla_users=run.sla_users,
                   vc_quotas=run.vc_quotas, chaos=run.chaos)

    @property
    def total_gpus(self) -> int:
        return sum(c.total_gpus for c in self.clusters)


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """A named fleet scenario: deterministic builder of FleetRuns."""

    name: str
    description: str
    build: Callable[[int, int], FleetRun]       # (num_jobs, seed) -> run


FLEET_SCENARIOS: dict[str, FleetScenario] = {}


def register_fleet(name: str, description: str):
    def deco(fn: Callable[[int, int], FleetRun]):
        FLEET_SCENARIOS[name] = FleetScenario(name=name,
                                              description=description,
                                              build=fn)
        return fn
    return deco


def get_fleet_scenario(name: str) -> FleetScenario:
    if name not in FLEET_SCENARIOS:
        raise KeyError(f"unknown fleet scenario {name!r}; registered: "
                       f"{', '.join(sorted(FLEET_SCENARIOS))}")
    return FLEET_SCENARIOS[name]


def list_fleet_scenarios() -> list[str]:
    return sorted(FLEET_SCENARIOS)


# ----------------------------------------------------------------- helpers ----


def merge_streams(streams: list[list[Job]]) -> list[Job]:
    """Merge per-scenario job streams into one fleet arrival stream: clones
    every job, orders by submit time (ties broken by stream position so the
    merge is deterministic), and re-ids jobs 0..n-1 so ids are unique
    fleet-wide (routing tables key on job_id)."""
    tagged = []
    for s_idx, stream in enumerate(streams):
        for j in stream:
            tagged.append((j.submit_time, s_idx, j.job_id, j.clone_pending()))
    tagged.sort(key=lambda t: t[:3])
    merged = []
    for i, (_, _, _, j) in enumerate(tagged):
        j.job_id = i
        merged.append(j)
    return merged


def _split(num_jobs: int, k: int) -> list[int]:
    """Split a job budget across k per-cluster streams (earlier streams get
    the remainder)."""
    base, rem = divmod(num_jobs, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


def _rename(spec: ClusterSpec, name: str) -> ClusterSpec:
    spec.name = name
    return spec


def _helios_like(n_p100: int, n_v100: int, name: str) -> ClusterSpec:
    """A helios-class cluster scaled to an arbitrary node count (same SKUs
    and node shapes as ``make_cluster('helios')``)."""
    nodes = []
    for i in range(n_p100):
        nodes.append(NodeSpec(i, "P100", 8, 64, 512.0, 1.0))
    for i in range(n_v100):
        nodes.append(NodeSpec(n_p100 + i, "V100", 8, 64, 512.0, 1.5))
    return ClusterSpec(nodes=nodes, name=name)


# --------------------------------------------------------------- scenarios ----


@register_fleet("fleet-steady",
                "Three identical helios clusters serving merged steady "
                "streams — the control fleet where any sane router ties.")
def _fleet_steady(num_jobs: int, seed: int) -> FleetRun:
    k = 3
    clusters = tuple(_rename(make_cluster("helios"), f"helios-{i}")
                     for i in range(k))
    streams = [get_scenario("steady").build(n, seed + 17 * i).jobs
               for i, n in enumerate(_split(num_jobs, k))]
    return FleetRun(name="fleet-steady", clusters=clusters,
                    jobs=merge_streams(streams), fault_models=(None,) * k)


@register_fleet("fleet-skewed-flash",
                "Three size-skewed helios-class clusters (5/10/20 nodes) "
                "serving merged flash-crowd streams: uniform routing drowns "
                "the small cluster, load-aware routing must not.")
def _fleet_skewed_flash(num_jobs: int, seed: int) -> FleetRun:
    clusters = (_helios_like(2, 3, "helios-small"),
                _helios_like(5, 5, "helios-mid"),
                _helios_like(12, 8, "helios-large"))
    streams = [get_scenario("flash-crowd").build(n, seed + 31 * i).jobs
               for i, n in enumerate(_split(num_jobs, 3))]
    return FleetRun(name="fleet-skewed-flash", clusters=clusters,
                    jobs=merge_streams(streams), fault_models=(None,) * 3)


@register_fleet("fleet-fault-storm",
                "One philly cluster under fault-storm failure rates while "
                "two identical neighbours stay healthy — routers that read "
                "snapshots drain around the failing member.")
def _fleet_fault_storm(num_jobs: int, seed: int) -> FleetRun:
    runs = [get_scenario("fault-storm").build(n, seed + 7 * i)
            for i, n in enumerate(_split(num_jobs, 3))]
    clusters = tuple(_rename(runs[i].spec, f"philly-{i}") for i in range(3))
    # only cluster 0 actually suffers the storm; the others run fault-free
    return FleetRun(name="fleet-fault-storm", clusters=clusters,
                    jobs=merge_streams([r.jobs for r in runs]),
                    fault_models=(runs[0].fault_model, None, None))


@register_fleet("fleet-fault-migration",
                "A harsher fault storm on one member (2h MTBF, 30-minute "
                "repairs, heavy stragglers) beside two healthy neighbours — "
                "one-shot routing strands queued work behind the storm; "
                "cross-cluster migration re-homes it.")
def _fleet_fault_migration(num_jobs: int, seed: int) -> FleetRun:
    runs = [get_scenario("fault-storm").build(n, seed + 23 * i)
            for i, n in enumerate(_split(num_jobs, 3))]
    clusters = tuple(_rename(runs[i].spec, f"philly-{i}") for i in range(3))
    storm = FaultModel(mtbf_per_node=2 * 3600.0, repair_time=1800.0,
                       straggler_prob=0.4, straggler_slowdown=0.4,
                       ckpt_interval=900.0, seed=seed + 808)
    return FleetRun(name="fleet-fault-migration", clusters=clusters,
                    jobs=merge_streams([r.jobs for r in runs]),
                    fault_models=(storm, None, None))


@register_fleet("fleet-blackout",
                "Three helios-like members; member 0 blacks out entirely at "
                "35% of the horizon and returns 15% later — the federation "
                "chaos stress (offline routing + deferred-route backoff).")
def _fleet_blackout(num_jobs: int, seed: int) -> FleetRun:
    from repro.chaos import ChaosSchedule
    k = 3
    clusters = tuple(_helios_like(3, 3, f"helios-bo-{i}") for i in range(k))
    streams = [get_scenario("steady").build(n, seed + 41 * i).jobs
               for i, n in enumerate(_split(num_jobs, k))]
    jobs = merge_streams(streams)
    horizon = jobs[-1].submit_time if jobs else 86400.0
    chaos = ChaosSchedule().add_blackout(0.35 * horizon, cluster=0,
                                         duration=0.15 * horizon)
    return FleetRun(name="fleet-blackout", clusters=clusters, jobs=jobs,
                    fault_models=(None,) * k, chaos=chaos)


@register_fleet("fleet-sku-split",
                "A small fast A100 island (3 nodes) next to a large V100 "
                "pool (16 nodes); 20% of demand asks for A100, 45% V100, "
                "35% flexible — SKU-affinity stress.")
def _fleet_sku_split(num_jobs: int, seed: int) -> FleetRun:
    a100 = ClusterSpec([NodeSpec(i, "A100", 8, 96, 1024.0, 3.0)
                        for i in range(3)], name="a100-island")
    v100 = ClusterSpec([NodeSpec(i, "V100", 8, 64, 512.0, 1.5)
                        for i in range(16)], name="v100-pool")
    streams = [generate_trace("alibaba", n, seed=seed + 13 * i)
               for i, n in enumerate(_split(num_jobs, 2))]
    jobs = merge_streams(streams)
    rng = np.random.default_rng(seed + 606)
    draws = rng.random(len(jobs))
    for j, u in zip(jobs, draws):
        j.gpu_type = "A100" if u < 0.20 else ("V100" if u < 0.65 else "any")
    return FleetRun(name="fleet-sku-split", clusters=(a100, v100), jobs=jobs,
                    fault_models=(None, None))


@register_fleet("fleet-multi-tenant",
                "Two alibaba clusters with skewed per-VC demand "
                "(55/25/12/8%) against even 25% quotas: every engine runs "
                "its own incremental VC-quota gate.")
def _fleet_multi_tenant(num_jobs: int, seed: int) -> FleetRun:
    runs = [get_scenario("multi-tenant").build(n, seed + 11 * i)
            for i, n in enumerate(_split(num_jobs, 2))]
    clusters = tuple(_rename(runs[i].spec, f"alibaba-{i}") for i in range(2))
    return FleetRun(name="fleet-multi-tenant", clusters=clusters,
                    jobs=merge_streams([r.jobs for r in runs]),
                    fault_models=(None, None), vc_quotas=runs[0].vc_quotas)
