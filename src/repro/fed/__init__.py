"""repro.fed: multi-cluster federation — a meta-scheduler routing streaming
jobs across per-cluster SchedulerEngines via snapshot-only routing policies
(see docs/ARCHITECTURE.md, "Federation layer")."""
from repro.fed.federation import (FederatedScheduler, FleetResult,
                                  FleetSnapshot, FleetStreamResult, run_fleet)
from repro.fed.router import (ROUTERS, ClusterInfo, ClusterView, Router,
                              capable_clusters, list_routers, make_router)
from repro.fed.scenarios import (FLEET_SCENARIOS, FleetRun, FleetScenario,
                                 get_fleet_scenario, list_fleet_scenarios,
                                 merge_streams, register_fleet)

__all__ = [
    "FederatedScheduler", "FleetResult", "FleetSnapshot", "FleetStreamResult",
    "run_fleet", "ROUTERS", "ClusterInfo", "ClusterView", "Router",
    "capable_clusters", "list_routers", "make_router", "FLEET_SCENARIOS",
    "FleetRun", "FleetScenario", "get_fleet_scenario", "list_fleet_scenarios",
    "merge_streams", "register_fleet",
]
