"""Episode cutting + dense reward shaping over the streaming engine.

The legacy trainer learns on idle-cluster 256-job batches with one sparse
terminal reward — a regime a production scheduler never sees.  Here,
``EpisodeCutter`` slices a *running* ``SchedulerEngine`` into fixed-horizon
PPO episodes: it observes the engine through the standard hook interface
(start/finish/requeue/tick feed an internal ``RollingTelemetry``; the
per-decision hook aligns policy steps), and at every rescan-window boundary
(the service driver's ``on_window`` callback) converts the **delta** of
rolling service metrics into a dense shaped reward:

    r_window = - w_wait    * Δ wait_p99  / wait_scale
               + w_util    * Δ utilization
               - w_backlog * Δ backlog   / backlog_scale      (clipped)

The window reward is split evenly over the decisions recorded in that
window (so a window's contribution is invariant to how many decisions it
took); windows with no decisions carry their reward into the next decision-
bearing window (folded into the episode's last step if the cut arrives
first).  After ``horizon`` windows the episode is closed and handed
to ``PPOAgent.finish_episode_dense`` — GAE(gamma, lambda) advantages, with
the critic's last value as the bootstrap for truncated episodes.
Consecutive episodes are cut from the same stream, so later episodes start
from a genuinely congested cluster.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.agent import PPOAgent
from repro.sched.engine import EngineHooks, SchedulerEngine
from repro.sched.telemetry import RollingTelemetry
from repro.core.types import Job


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    """Shaped-reward weights and scales (deltas between rescan windows)."""

    wait_p99: float = 1.0        # weight on rolling wait-p99 movement
    utilization: float = 0.5     # weight on windowed-utilization movement
    backlog: float = 1.0         # weight on pending-queue-depth movement
    wait_scale: float = 3600.0   # 1 h of wait-p99 movement ~ 1 reward unit
    backlog_scale: float = 64.0  # jobs of backlog movement ~ 1 reward unit
    clip: float = 5.0            # per-window reward clip


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """Rolling-telemetry probe at one rescan-window boundary."""

    time: float
    wait_p99: float
    utilization: float
    backlog: int


_IDLE = WindowStats(time=0.0, wait_p99=0.0, utilization=0.0, backlog=0)


def shaped_reward(prev: WindowStats, cur: WindowStats,
                  w: RewardWeights) -> float:
    """Dense per-window reward from rolling-telemetry deltas.  Negative when
    the queue is deteriorating (wait-p99 / backlog growing), positive when
    the policy is draining it or lifting utilization."""
    r = (-w.wait_p99 * (cur.wait_p99 - prev.wait_p99) / w.wait_scale
         + w.utilization * (cur.utilization - prev.utilization)
         - w.backlog * (cur.backlog - prev.backlog) / w.backlog_scale)
    return float(np.clip(r, -w.clip, w.clip))


@dataclasses.dataclass
class EpisodeStats:
    """Outcome of one cut episode."""

    steps: int                  # recorded PPO decisions
    windows: int                # rescan windows in the episode
    reward_sum: float
    loss: float
    updated: bool               # False while episodes_per_update pools
    terminal: bool              # stream drained (vs. horizon truncation)
    scenario: str = ""


class EpisodeCutter(EngineHooks):
    """Cuts fixed-horizon PPO episodes from a running ``SchedulerEngine``.

    Attach as an engine hook *and* as the service driver's ``on_window``
    callback; call :meth:`flush` once the stream drains.  The prioritizer
    must be the engine's (recording) ``RLPrioritizer`` — its ``record``
    flag is held off for the first ``warmup_windows`` windows so episodes
    start from a warm, congested cluster instead of the idle transient.
    """

    def __init__(self, agent: PPOAgent, prioritizer, *, horizon: int = 12,
                 weights: RewardWeights | None = None,
                 warmup_windows: int = 0,
                 telemetry_window: float = 6 * 3600.0,
                 scenario: str = ""):
        self.agent = agent
        self.pri = prioritizer
        self.horizon = max(int(horizon), 1)
        self.weights = weights or RewardWeights()
        self.warmup_windows = max(int(warmup_windows), 0)
        self.scenario = scenario
        # internal rolling telemetry: never samples on its own (inf
        # interval) — the cutter probes it at window boundaries
        self.telemetry = RollingTelemetry(window=telemetry_window,
                                          sample_interval=math.inf)
        self.episodes: list[EpisodeStats] = []
        self.decisions = 0            # via the engine's per-decision hook
        self._windows_seen = 0        # processed windows incl. warm-up
        self._ep_windows = 0
        self._rewards: list[float] = []   # one entry per recorded step
        self._mark = 0                # rollout length at last boundary
        self._carry = 0.0             # reward from decision-less windows
        self._prev: WindowStats | None = None
        if self.warmup_windows > 0:
            self.pri.record = False

    # ------------------------------------------------------- engine hooks ----
    def on_submit(self, job: Job, now: float) -> None:
        self.telemetry.on_submit(job, now)

    def on_start(self, job: Job, now: float) -> None:
        self.telemetry.on_start(job, now)

    def on_finish(self, job: Job, now: float) -> None:
        self.telemetry.on_finish(job, now)

    def on_requeue(self, job: Job, now: float) -> None:
        self.telemetry.on_requeue(job, now)

    def on_tick(self, now: float, engine: SchedulerEngine) -> None:
        self.telemetry.on_tick(now, engine)

    def on_decision(self, jobs, order, now, engine) -> None:
        self.decisions += 1

    # ------------------------------------------------------------- probing ----
    def _probe(self, engine: SchedulerEngine) -> WindowStats:
        s = self.telemetry.probe(engine.now, engine)
        return WindowStats(time=s.time, wait_p99=s.wait_p99,
                           utilization=s.utilization, backlog=s.queue_len)

    # ------------------------------------------------------------- cutting ----
    def on_window(self, engine: SchedulerEngine, t: float,
                  windows: int) -> None:
        """Service-driver callback: one processed rescan window ended."""
        stats = self._probe(engine)
        self._windows_seen += 1
        if self._windows_seen <= self.warmup_windows:
            if self._windows_seen == self.warmup_windows:
                # warm-up over: start recording from a congested baseline
                self.pri.record = True
                self._prev = stats
                self._mark = self.agent.rollout_len
            return
        prev = self._prev if self._prev is not None else _IDLE
        r = shaped_reward(prev, stats, self.weights) + self._carry
        self._prev = stats
        n_new = self.agent.rollout_len - self._mark
        if n_new > 0:
            self._rewards.extend([r / n_new] * n_new)
            self._mark = self.agent.rollout_len
            self._carry = 0.0
        else:
            self._carry = r    # no decisions this window: defer the reward
        self._ep_windows += 1
        if self._ep_windows >= self.horizon:
            self.cut(terminal=False)

    def cut(self, terminal: bool) -> EpisodeStats | None:
        """Close the current episode and hand it to the agent (GAE update).
        Returns the episode stats, or None if nothing was recorded."""
        T = self.agent.rollout_len
        if T > len(self._rewards):
            # trailing decisions past the last boundary get the carried
            # reward (0.0 if none was pending)
            n = T - len(self._rewards)
            self._rewards.extend([self._carry / n] * n)
            self._carry = 0.0
        elif self._carry and T > 0:
            # decision-less windows at the episode tail: credit their
            # deferred reward to the last recorded step (the most recent
            # decisions produced those windows' outcome) rather than
            # silently dropping it at the cut
            self._rewards[T - 1] += self._carry
            self._carry = 0.0
        windows = self._ep_windows
        if T == 0:
            # nothing recorded: keep any pending carry for the next
            # decision-bearing window (episode numbering just moves on)
            self._reset_episode()
            return None
        rewards = np.asarray(self._rewards[:T], dtype=np.float32)
        boot = 0.0
        if not terminal:
            vals = self.agent.rollout_values
            boot = float(vals[-1]) if vals else 0.0
        upd = self.agent.finish_episode_dense(rewards, bootstrap_value=boot)
        st = EpisodeStats(steps=T, windows=windows,
                          reward_sum=float(rewards.sum()),
                          loss=upd["loss"], updated=bool(upd["updated"]),
                          terminal=terminal, scenario=self.scenario)
        self.episodes.append(st)
        self._reset_episode()
        return st

    def flush(self) -> EpisodeStats | None:
        """Close the trailing partial episode once the stream has drained."""
        if self.agent.rollout_len or self._rewards or self._ep_windows:
            return self.cut(terminal=True)
        return None

    def _reset_episode(self) -> None:
        # NOTE: _carry deliberately survives the reset — a cut() with zero
        # recorded steps must not discard reward deferred from decision-less
        # windows (cuts with steps fold it into the last step first)
        self._rewards = []
        self._mark = 0
        self._ep_windows = 0
