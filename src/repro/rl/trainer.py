"""Streaming PPO training on SchedulerEngine episodes.

``StreamingTrainer`` samples scenario streams from the registered scenario
distribution (``repro.sched.scenarios``), replays each through the
rescan-interval service driver with a recording ``RLPrioritizer``, and lets
an ``EpisodeCutter`` slice the run into fixed-horizon episodes with dense
shaped rewards (see ``repro.rl.episodes``).  The first ``warmup_windows``
windows of every stream run un-recorded, so episodes train on warm,
congested clusters — the non-stationary regime of the paper's Fig. 6 —
rather than the idle-cluster transient the legacy batch trainer sees.

Evaluation is greedy through ``service.run_stream`` against any base
policies on the same scenario builds (identical job copies / faults), so
streaming-trained, batch-trained, and heuristic schedulers are directly
comparable (``benchmarks/bench_rl_streaming.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.agent import PPOAgent, PPOConfig
from repro.core.env import RLPrioritizer
from repro.core.policies import make_policy
from repro.core.prioritizer import PolicyPrioritizer, Prioritizer
from repro.rl.episodes import EpisodeCutter, EpisodeStats, RewardWeights
from repro.sched.scenarios import ScenarioRun, get_scenario
from repro.sched.service import run_stream


@dataclasses.dataclass
class StreamingConfig:
    """Streaming-trainer knobs.  ``scenarios`` is the episode distribution;
    each sampled stream is cut into ``ceil(windows / horizon)`` episodes."""

    scenarios: tuple[str, ...] = ("steady", "flash-crowd", "sku-skew")
    num_jobs: int = 160             # jobs per sampled stream
    streams: int = 8                # streams per train() call
    horizon: int = 12               # rescan windows per episode
    rescan_interval: float = 300.0
    warmup_windows: int = 4         # un-recorded windows per stream
    allocator: str = "pack"
    queue_window: int = 512
    use_estimates: bool = False
    reward: RewardWeights = dataclasses.field(default_factory=RewardWeights)
    seed: int = 0
    ppo: PPOConfig = dataclasses.field(default_factory=PPOConfig)


class StreamingTrainer:
    """Trains a PPO agent on streaming episodes; evaluates greedily.

    Pass an existing ``agent`` (e.g. one batch-trained by ``RLTuneTrainer``)
    to fine-tune or to evaluate it under the streaming harness.
    """

    def __init__(self, cfg: StreamingConfig | None = None,
                 agent: PPOAgent | None = None):
        self.cfg = cfg or StreamingConfig()
        self.agent = agent or PPOAgent(self.cfg.ppo)
        self.history: list[EpisodeStats] = []

    # ----------------------------------------------------------------- train ----
    def train_stream(self, scenario: str | ScenarioRun,
                     seed: int = 0) -> list[EpisodeStats]:
        """Replay one scenario stream, cutting episodes as it runs."""
        cfg = self.cfg
        run = get_scenario(scenario).build(cfg.num_jobs, seed) \
            if isinstance(scenario, str) else scenario
        pri = RLPrioritizer(self.agent, explore=True,
                            use_estimates=cfg.use_estimates, streaming=True)
        cutter = EpisodeCutter(self.agent, pri, horizon=cfg.horizon,
                               weights=cfg.reward,
                               warmup_windows=cfg.warmup_windows,
                               scenario=run.name)
        run_stream(run.spec, [j.clone_pending() for j in run.jobs], pri,
                   rescan_interval=cfg.rescan_interval,
                   allocator=cfg.allocator, fault_model=run.fault_model,
                   queue_window=cfg.queue_window, chunked_submit=True,
                   hooks=(cutter,), on_window=cutter.on_window)
        cutter.flush()
        eps = list(cutter.episodes)
        self.history.extend(eps)
        return eps

    def train(self, streams: int | None = None,
              log_every: int = 0) -> list[EpisodeStats]:
        """Sample ``streams`` scenario streams from the distribution and
        train on every episode cut from them."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 17)
        out: list[EpisodeStats] = []
        for si in range(streams if streams is not None else cfg.streams):
            name = cfg.scenarios[int(rng.integers(len(cfg.scenarios)))]
            eps = self.train_stream(name, seed=int(rng.integers(1_000_000)))
            out.extend(eps)
            if log_every and (si + 1) % log_every == 0:
                recent = [e.reward_sum for e in out[-8:]]
                print(f"[stream {si + 1}] {name}: {len(eps)} episodes, "
                      f"recent reward {np.mean(recent):+.3f}")
        return out

    # ------------------------------------------------------------------ eval ----
    def evaluate(self, scenarios: tuple[str, ...] | None = None,
                 num_jobs: int | None = None, seed: int = 1234,
                 baselines: tuple[str, ...] = ("fcfs",)) -> dict:
        """Greedy evaluation through ``service.run_stream``: the RL agent
        vs. ``baselines`` on identical scenario builds.  Returns
        ``{scenario: {"rl": metrics, <baseline>: metrics, ...}}``."""
        cfg = self.cfg
        out: dict[str, dict[str, dict[str, float]]] = {}
        for name in scenarios or cfg.scenarios:
            run = get_scenario(name).build(num_jobs or cfg.num_jobs, seed)
            row = {"rl": self._eval_once(
                run, RLPrioritizer(self.agent, explore=False,
                                   use_estimates=cfg.use_estimates,
                                   streaming=True))}
            for b in baselines:
                row[b] = self._eval_once(
                    run, PolicyPrioritizer(make_policy(b, cfg.use_estimates)))
            out[name] = row
        return out

    def _eval_once(self, run: ScenarioRun,
                   prioritizer: Prioritizer) -> dict[str, float]:
        cfg = self.cfg
        sr = run_stream(run.spec, [j.clone_pending() for j in run.jobs],
                        prioritizer, rescan_interval=cfg.rescan_interval,
                        allocator=cfg.allocator, fault_model=run.fault_model,
                        queue_window=cfg.queue_window, chunked_submit=True)
        b = sr.batch
        return {"mean_wait": b.avg_wait, "mean_jct": b.avg_jct,
                "bsld": b.avg_bsld, "utilization": b.utilization,
                "completed": float(len(b.jobs))}
