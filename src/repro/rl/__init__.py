"""repro.rl: the RL training stack, lifted out of ``repro.core.trainer`` /
``repro.core.env`` and refactored onto the streaming scheduler engine.

- ``repro.rl.episodes`` — ``EpisodeCutter`` slices a running
  ``SchedulerEngine`` into fixed-horizon PPO episodes with dense shaped
  rewards from rolling-telemetry deltas.
- ``repro.rl.trainer`` — ``StreamingTrainer`` samples episodes from the
  registered scenario distribution and evaluates greedily through
  ``service.run_stream``.
- ``repro.rl.batch`` — the legacy batch-pair trainer (``RLTuneTrainer``),
  the terminal-reward special case; re-exported by ``repro.core.trainer``
  and pinned bit-identical on fixed seeds.
"""
from repro.rl.batch import (EpochStats, RLTuneTrainer, TrainerConfig,
                            improvement)
from repro.rl.episodes import (EpisodeCutter, EpisodeStats, RewardWeights,
                               WindowStats, shaped_reward)
from repro.rl.trainer import StreamingConfig, StreamingTrainer

__all__ = [
    "EpochStats", "RLTuneTrainer", "TrainerConfig", "improvement",
    "EpisodeCutter", "EpisodeStats", "RewardWeights", "WindowStats",
    "shaped_reward", "StreamingConfig", "StreamingTrainer",
]
