"""Legacy batch-pair RLTune training & evaluation (paper Sec. 3.1).

The batch pipeline is the terminal-reward special case of the ``repro.rl``
machinery: one "episode" = one 256-job batch drained to completion from an
idle cluster (``Simulator.run_batch``, itself a thin wrapper over the
streaming ``SchedulerEngine``), rewarded once with the normalized
base-vs-RL score gap through ``PPOAgent.finish_episode`` (the pinned sparse
pathway).  ``repro.core.trainer`` re-exports this module, and the seed
goldens in ``tests/test_system.py`` pin it bit-identical on fixed seeds.

Training: each batch flows through two pipelines — the base policy pipeline
and the RL pipeline — on identical job copies and an identical idle
cluster.  One epoch = ``batches_per_epoch`` batches (paper: 100).

Evaluation: both pipelines run with user runtime estimates (noisy) and the
RL pipeline acts greedily.

For training on *streaming* episodes cut from a live engine (dense shaped
rewards, GAE), see ``repro.rl.trainer.StreamingTrainer``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.agent import PPOAgent, PPOConfig
from repro.core.env import InspectorPrioritizer, RLPrioritizer
from repro.core.metrics import BatchResult, reward_from_scores
from repro.core.policies import make_policy
from repro.core.simulator import PolicyPrioritizer, Simulator
from repro.core.trace import PROFILES, generate_trace, make_cluster, train_eval_split
from repro.core.types import ClusterSpec, Job


@dataclasses.dataclass
class TrainerConfig:
    trace: str = "helios"
    base_policy: str = "fcfs"
    metric: str = "wait"            # wait | jct | bsld | util
    batch_size: int = 256
    batches_per_epoch: int = 100
    epochs: int = 1
    variant: str = "pro"            # pro | naive | inspector
    base_allocator: str = "pack"    # Slurm-like default for the base pipeline
    use_estimates_eval: bool = True
    lookahead_k: int = 8
    seed: int = 0
    ppo: PPOConfig = dataclasses.field(default_factory=PPOConfig)


@dataclasses.dataclass
class EpochStats:
    rewards: list[float]
    losses: list[float]
    abs_scores: list[float]
    ars_scores: list[float]

    @property
    def mean_reward(self) -> float:
        return float(np.mean(self.rewards)) if self.rewards else 0.0


class RLTuneTrainer:
    """Trains a PPO agent against a base policy on one trace."""

    def __init__(self, cfg: TrainerConfig, cluster: ClusterSpec | None = None,
                 jobs: list[Job] | None = None):
        self.cfg = cfg
        self.cluster = cluster or make_cluster(cfg.trace)
        total = cfg.batch_size * cfg.batches_per_epoch * max(cfg.epochs, 1)
        total = int(total / 0.9) + cfg.batch_size   # leave the 10% eval split
        jobs = jobs or generate_trace(PROFILES[cfg.trace], total, seed=cfg.seed)
        self.train_jobs, self.eval_jobs = train_eval_split(jobs, 0.9)
        self.agent = PPOAgent(cfg.ppo)
        rl_alloc = "milp" if cfg.variant == "pro" else "pack"
        self.rl_sim = Simulator(self.cluster, allocator=rl_alloc,
                                lookahead_k=cfg.lookahead_k)
        self.base_sim = Simulator(self.cluster, allocator=cfg.base_allocator)

    # ----------------------------------------------------------------- train ----
    def _rl_prioritizer(self, explore: bool, use_estimates: bool):
        cfg = self.cfg
        if cfg.variant == "inspector":
            return InspectorPrioritizer(self.agent, make_policy(cfg.base_policy,
                                                                use_estimates),
                                        explore=explore, use_estimates=use_estimates)
        raw = cfg.variant == "naive"
        return RLPrioritizer(self.agent, explore=explore,
                             use_estimates=use_estimates, raw_features=raw)

    def _batches(self, jobs: list[Job], n: int, batch_size: int,
                 rng: np.random.Generator) -> list[list[Job]]:
        """n random contiguous windows of batch_size jobs (paper: random
        sequences of jobs per experiment run)."""
        out = []
        hi = max(len(jobs) - batch_size, 0)
        for _ in range(n):
            s = int(rng.integers(0, hi + 1))
            out.append(jobs[s:s + batch_size])
        return out

    def run_batch_pair(self, batch: list[Job], *, explore: bool,
                       use_estimates: bool) -> tuple[BatchResult, BatchResult]:
        """Run base and RL pipelines on identical copies of one batch."""
        cfg = self.cfg
        base_jobs = [j.clone_pending() for j in batch]
        rl_jobs = [j.clone_pending() for j in batch]
        base_pol = PolicyPrioritizer(make_policy(cfg.base_policy, use_estimates))
        base_res = self.base_sim.run_batch(base_jobs, base_pol)
        rl_res = self.rl_sim.run_batch(rl_jobs,
                                       self._rl_prioritizer(explore, use_estimates))
        return base_res, rl_res

    def train(self, epochs: int | None = None, batches_per_epoch: int | None = None,
              log_every: int = 0) -> list[EpochStats]:
        cfg = self.cfg
        epochs = epochs or cfg.epochs
        bpe = batches_per_epoch or cfg.batches_per_epoch
        rng = np.random.default_rng(cfg.seed + 7)
        history: list[EpochStats] = []
        for ep in range(epochs):
            stats = EpochStats([], [], [], [])
            for bi, batch in enumerate(self._batches(self.train_jobs, bpe,
                                                     cfg.batch_size, rng)):
                self.agent.reset_buffer()
                base_res, rl_res = self.run_batch_pair(batch, explore=True,
                                                       use_estimates=False)
                abs_s = base_res.score(cfg.metric)
                ars_s = rl_res.score(cfg.metric)
                reward = reward_from_scores(abs_s, ars_s)
                upd = self.agent.finish_episode(reward)
                stats.rewards.append(reward)
                stats.losses.append(upd["loss"])
                stats.abs_scores.append(abs_s)
                stats.ars_scores.append(ars_s)
                if log_every and (bi + 1) % log_every == 0:
                    print(f"[epoch {ep} batch {bi + 1}/{bpe}] "
                          f"reward={np.mean(stats.rewards[-log_every:]):+.4f}")
            history.append(stats)
        return history

    # ------------------------------------------------------------------ eval ----
    def evaluate(self, num_batches: int = 10, batch_size: int | None = None,
                 seed: int = 1234) -> dict[str, dict[str, float]]:
        """Paper Sec. 5.2: random job sequences, RL greedy, noisy estimates."""
        cfg = self.cfg
        batch_size = batch_size or cfg.batch_size
        rng = np.random.default_rng(seed)
        pool = self.eval_jobs if len(self.eval_jobs) >= batch_size else self.train_jobs
        agg = {"base": {m: [] for m in ("wait", "jct", "bsld", "util")},
               "rl": {m: [] for m in ("wait", "jct", "bsld", "util")}}
        for batch in self._batches(pool, num_batches, batch_size, rng):
            base_res, rl_res = self.run_batch_pair(
                batch, explore=False, use_estimates=cfg.use_estimates_eval)
            for name, res in (("base", base_res), ("rl", rl_res)):
                agg[name]["wait"].append(res.avg_wait)
                agg[name]["jct"].append(res.avg_jct)
                agg[name]["bsld"].append(res.avg_bsld)
                agg[name]["util"].append(res.utilization)
        return {side: {m: float(np.mean(v)) for m, v in d.items()}
                for side, d in agg.items()}


def improvement(base: float, rl: float, lower_is_better: bool = True) -> float:
    """Percent improvement of RL over base."""
    if base == 0:
        return 0.0
    gain = (base - rl) / abs(base) if lower_is_better else (rl - base) / abs(base)
    return 100.0 * gain
