"""Control-plane degradation ladder.

The engine's decision loop assumes it never runs late: every rescan may
solve a MILP per queued job, and ranking walks the whole window.  Under
chaos (mass requeues after a rack burst, reclamation waves) the queue
balloons and a real control plane would blow its decision deadline.  The
ladder trades decision *quality* for decision *latency*, rung by rung:

1. **MILP budget** — each ``choose_allocation`` solver call is timed; a
   streak of ``trip_after`` consecutive over-budget solves opens a circuit
   breaker and the next ``reset_after_decisions`` decisions take the
   greedy heuristic path instead (counted as ``milp_fallbacks``).
2. **FCFS windows** — scheduling-pass wall time is accumulated into
   sim-time buckets of ``window_s``; a bucket exceeding
   ``window_deadline_s`` forces the next ``fcfs_windows`` buckets to rank
   the queue FCFS (arrival order) instead of calling the prioritizer
   (counted as ``degraded_windows`` / ``degraded_s``).

A ``degradation=None`` engine never reads the clock — the pinned
bit-identical default.  The policy object is duck-typed by the engine
(``repro.sched`` never imports this package).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Budgets for the two degradation rungs.  All wall-clock seconds."""

    milp_budget_s: float = 0.05        # per-solve budget for the MILP path
    trip_after: int = 3                # consecutive over-budget solves to trip
    reset_after_decisions: int = 64    # greedy decisions before retrying MILP
    window_s: float = 60.0             # sim-time bucket for pass wall time
    window_deadline_s: float = 0.5     # wall budget per bucket
    fcfs_windows: int = 2              # buckets ranked FCFS after a blown one
