"""Correlated chaos schedules: rack bursts, spot waves, storms, blackouts.

The seed-era ``FaultModel`` draws *independent* per-node Poisson failures;
real clusters fail in correlated bursts — a rack PDU trips, a spot pool is
reclaimed in one sweep, a top-of-rack switch degrades a whole row, an
entire member cluster drops off the federation.  ``ChaosSchedule`` is the
deterministic description of such events; the injectors apply them through
the engine's forced-fault entry points at rescan-window edges (the same
controller contract as ``repro.scale.Autoscaler`` and
``repro.lifecycle.PreemptionController``), so a chaos run is replayable
and a ``chaos=None`` run touches zero engine code paths (pinned
bit-identical by tests).

Event semantics:

- ``fail`` / ``recover``   — rack/pool burst: the node set goes down
  together (running gangs checkpoint-kill and requeue) and comes back
  together.  Builders always emit the closing ``recover`` so a burst can
  never permanently strand capacity.
- ``reclaim``              — spot-reclamation wave against a preemptible
  pool: jobs on the reclaimed nodes are *preempted* (``preempt_job`` with
  the harsher ``SPOT_RECLAMATION_COST``, per the PR-6 follow-on) instead of
  fault-killed, then the nodes leave until the paired ``recover``.
- ``slow`` / ``unslow``    — straggler storm: a node set degrades to a
  fractional speed together (checkpoint-migration rules apply as usual).
- ``blackout`` / ``restore`` — federation member outage: every up node of
  one member fails at once; routers degrade to the surviving capable set
  and queued routes retry with backoff until the member returns (see
  ``repro.fed.federation``).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable, Sequence

from repro.lifecycle.costs import CkptCostModel

#: Harsher-than-default checkpoint economics for spot reclamation: coarser
#: checkpoint grid (more lost work) and a heavier restore, modelling a
#: reclaimed instance whose state must be rehydrated on fresh capacity.
SPOT_RECLAMATION_COST = CkptCostModel(ckpt_interval=3600.0, restore_s=600.0,
                                      per_gpu_restore_s=8.0)


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled chaos action.  ``nodes`` targets engine-level events;
    ``sku``/``count`` select the reclaimed pool for ``reclaim`` (resolved
    against live capacity at apply time); ``cluster`` addresses the
    federation member for fleet schedules."""

    time: float
    kind: str                      # fail|recover|slow|unslow|reclaim|blackout|restore
    nodes: tuple[int, ...] = ()
    cluster: int = 0
    sku: str = "any"               # reclaim: preemptible pool SKU
    count: int = 0                 # reclaim: nodes reclaimed per wave
    down_for: float = 0.0          # reclaim: outage span (recover follows)
    slowdown: float = 0.5          # slow: speed multiplier
    note: str = ""


class ChaosSchedule:
    """Deterministic, composable list of chaos events.

    Builders append matched open/close pairs (``fail``+``recover``,
    ``slow``+``unslow``, ``blackout``+``restore``) so every injected
    outage closes even when the close lands past the stream's natural end —
    mirroring the ``FaultInjector`` pair-close invariant."""

    def __init__(self) -> None:
        self.events: list[ChaosEvent] = []

    def add(self, event: ChaosEvent) -> "ChaosSchedule":
        self.events.append(event)
        return self

    def add_rack_burst(self, at: float, nodes: Iterable[int],
                       down_for: float, *, cluster: int = 0,
                       note: str = "rack-burst") -> "ChaosSchedule":
        """Correlated outage: ``nodes`` fail together at ``at`` and recover
        together ``down_for`` seconds later."""
        nodes = tuple(int(n) for n in nodes)
        self.add(ChaosEvent(at, "fail", nodes=nodes, cluster=cluster,
                            note=note))
        self.add(ChaosEvent(at + down_for, "recover", nodes=nodes,
                            cluster=cluster, note=note))
        return self

    def add_spot_wave(self, at: float, *, sku: str = "any", count: int = 1,
                      down_for: float, cluster: int = 0,
                      note: str = "spot-wave") -> "ChaosSchedule":
        """Spot-reclamation wave: ``count`` up nodes of ``sku`` (lowest ids
        first, resolved at apply time) have their jobs preempted at the
        harsher reclamation cost, then leave for ``down_for`` seconds."""
        self.add(ChaosEvent(at, "reclaim", cluster=cluster, sku=sku,
                            count=int(count), down_for=float(down_for),
                            note=note))
        return self

    def add_straggler_storm(self, at: float, nodes: Iterable[int],
                            duration: float, *, slowdown: float = 0.5,
                            cluster: int = 0,
                            note: str = "straggler-storm") -> "ChaosSchedule":
        """Correlated slowdown: ``nodes`` degrade to ``slowdown`` speed
        together for ``duration`` seconds."""
        nodes = tuple(int(n) for n in nodes)
        self.add(ChaosEvent(at, "slow", nodes=nodes, cluster=cluster,
                            slowdown=float(slowdown), note=note))
        self.add(ChaosEvent(at + duration, "unslow", nodes=nodes,
                            cluster=cluster, note=note))
        return self

    def add_blackout(self, at: float, cluster: int,
                     duration: float, *,
                     note: str = "member-blackout") -> "ChaosSchedule":
        """Federation member outage: every up node of member ``cluster``
        fails at ``at``; the member restores ``duration`` seconds later."""
        self.add(ChaosEvent(at, "blackout", cluster=cluster, note=note))
        self.add(ChaosEvent(at + duration, "restore", cluster=cluster,
                            note=note))
        return self

    def spot_waves_for_pools(self, pools, times: Sequence[float], *,
                             frac: float = 0.5, down_for: float,
                             cluster: int = 0) -> "ChaosSchedule":
        """One reclamation wave per ``times`` entry against every pool
        flagged ``preemptible`` in a ``repro.scale`` pool map, reclaiming
        ``ceil(frac * max_nodes)`` nodes of the pool's SKU per wave."""
        for sku, pool in sorted(pools.items()):
            if not getattr(pool, "preemptible", False):
                continue
            count = max(1, math.ceil(frac * pool.max_nodes))
            for at in times:
                self.add_spot_wave(at, sku=sku, count=count,
                                   down_for=down_for, cluster=cluster,
                                   note=f"spot-wave:{sku}")
        return self

    def sorted_events(self) -> list[tuple[float, int, ChaosEvent]]:
        """Events as ``(time, insertion_seq, event)`` triples — the stable
        ordering the injectors consume."""
        return sorted((e.time, i, e) for i, e in enumerate(self.events))


@dataclasses.dataclass(frozen=True)
class ChaosAction:
    """One chaos event as actually applied (telemetry record)."""

    time: float
    kind: str
    cluster: int
    nodes: tuple[int, ...]
    jobs_hit: int
    note: str


class ChaosInjector:
    """Applies a ``ChaosSchedule`` to one ``SchedulerEngine`` at rescan-
    window edges (service-loop controller contract: ``control(engine, now,
    telemetry)`` once per processed window).  Spot reclamations resolve
    their node set against live capacity and queue their own paired
    ``recover`` internally, so waves self-close like every other event."""

    def __init__(self, schedule: ChaosSchedule, *,
                 reclamation_cost: CkptCostModel | None = None):
        self._queue: list[tuple[float, int, ChaosEvent]] = \
            schedule.sorted_events()
        heapq.heapify(self._queue)
        self._seq = len(self._queue)
        self.cost = reclamation_cost if reclamation_cost is not None \
            else SPOT_RECLAMATION_COST
        self.actions: list[ChaosAction] = []

    # ------------------------------------------------------------ queries ----
    def next_time(self) -> float:
        return self._queue[0][0] if self._queue else math.inf

    def action_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for a in self.actions:
            counts[a.kind] = counts.get(a.kind, 0) + 1
        return counts

    # ------------------------------------------------------------- control ----
    def _push(self, event: ChaosEvent) -> None:
        heapq.heappush(self._queue, (event.time, self._seq, event))
        self._seq += 1

    def _pop_due(self, now: float) -> list[ChaosEvent]:
        due = []
        while self._queue and self._queue[0][0] <= now + 1e-9:
            due.append(heapq.heappop(self._queue)[2])
        return due

    def control(self, engine, now: float, telemetry=None) \
            -> list[ChaosAction]:
        due = self._pop_due(now)
        if not due:
            return []
        if now > engine.now:
            engine.advance_to(now)
        applied = [self._apply(engine, e, now) for e in due]
        self.actions.extend(applied)
        if telemetry is not None:
            note = getattr(telemetry, "note_chaos_events", None)
            if note is not None:
                note(applied)
        engine.reschedule(at=now)
        return applied

    def _apply(self, engine, e: ChaosEvent, now: float) -> ChaosAction:
        hit = 0
        nodes = e.nodes
        if e.kind == "fail":
            for n in nodes:
                hit += engine.force_fail(n)
        elif e.kind == "recover":
            for n in nodes:
                engine.force_recover(n)
        elif e.kind == "slow":
            for n in nodes:
                engine.force_slow(n, e.slowdown)
        elif e.kind == "unslow":
            for n in nodes:
                engine.force_unslow(n)
        elif e.kind == "reclaim":
            nodes = self._resolve_spot_nodes(engine, e)
            for n in nodes:
                hit += engine.reclaim_node(n, self.cost)
            if nodes and e.down_for > 0:
                self._push(ChaosEvent(now + e.down_for, "recover",
                                      nodes=nodes, cluster=e.cluster,
                                      note=e.note))
        else:
            raise ValueError(
                f"chaos event kind {e.kind!r} targets the federation; "
                f"use FleetChaosInjector")
        return ChaosAction(time=now, kind=e.kind, cluster=e.cluster,
                           nodes=tuple(nodes), jobs_hit=hit, note=e.note)

    @staticmethod
    def _resolve_spot_nodes(engine, e: ChaosEvent) -> tuple[int, ...]:
        """Lowest-id up nodes matching the wave's SKU — deterministic, and
        biased toward the same pool prefix wave after wave (a realistic
        reclamation pattern: providers drain pools from one edge)."""
        cluster = engine.cluster
        up = cluster.placeable_mask()
        chosen = []
        for i in range(len(cluster.gpu_types)):
            if len(chosen) >= e.count:
                break
            if up[i] and (e.sku == "any" or str(cluster.gpu_types[i]) == e.sku):
                chosen.append(i)
        return tuple(chosen)


class FleetChaosInjector:
    """Applies a fleet ``ChaosSchedule`` across a ``FederatedScheduler``:
    engine-level events dispatch to ``fed.engines[event.cluster]`` (same
    semantics as ``ChaosInjector``), ``blackout``/``restore`` toggle whole
    members through the federation's offline-routing machinery."""

    def __init__(self, schedule: ChaosSchedule, *,
                 reclamation_cost: CkptCostModel | None = None):
        self._queue: list[tuple[float, int, ChaosEvent]] = \
            schedule.sorted_events()
        heapq.heapify(self._queue)
        self._seq = len(self._queue)
        self.cost = reclamation_cost if reclamation_cost is not None \
            else SPOT_RECLAMATION_COST
        self.actions: list[ChaosAction] = []

    def next_time(self) -> float:
        return self._queue[0][0] if self._queue else math.inf

    def action_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for a in self.actions:
            counts[a.kind] = counts.get(a.kind, 0) + 1
        return counts

    def _push(self, event: ChaosEvent) -> None:
        heapq.heappush(self._queue, (event.time, self._seq, event))
        self._seq += 1

    def control(self, fed, now: float) -> list[ChaosAction]:
        due = []
        while self._queue and self._queue[0][0] <= now + 1e-9:
            due.append(heapq.heappop(self._queue)[2])
        if not due:
            return []
        applied = []
        touched: set[int] = set()
        for e in due:
            if e.kind == "blackout":
                downed = fed.blackout_member(e.cluster, at=now)
                applied.append(ChaosAction(
                    time=now, kind=e.kind, cluster=e.cluster,
                    nodes=tuple(downed), jobs_hit=len(downed), note=e.note))
            elif e.kind == "restore":
                restored = fed.restore_member(e.cluster, at=now)
                applied.append(ChaosAction(
                    time=now, kind=e.kind, cluster=e.cluster,
                    nodes=tuple(restored), jobs_hit=len(restored),
                    note=e.note))
            else:
                eng = fed.engines[e.cluster]
                if now > eng.now:
                    eng.advance_to(now)
                sub = ChaosInjector.__new__(ChaosInjector)
                sub._queue, sub._seq, sub.cost, sub.actions = \
                    [], 0, self.cost, []
                act = sub._apply(eng, e, now)
                # a reclaim's paired recover lands back on *this* queue
                for (t, _, follow) in sub._queue:
                    self._push(dataclasses.replace(follow, cluster=e.cluster))
                applied.append(dataclasses.replace(act, cluster=e.cluster))
                touched.add(e.cluster)
        for idx in sorted(touched):
            fed.engines[idx].reschedule(at=now)
        fed.note_chaos(applied, now)
        self.actions.extend(applied)
        return applied
