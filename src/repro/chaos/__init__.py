"""Chaos fault injection and graceful control-plane degradation.

Correlated failure bursts, spot-reclamation waves, straggler storms and
federation blackouts (``ChaosSchedule`` + injectors), plus the degradation
ladder the control plane falls down when decision latency blows its
budget (``DegradationPolicy``).  This package imports only ``repro.core``
and ``repro.lifecycle`` — never ``repro.sched``/``repro.fed``, which
import *it* — so the engine stays chaos-agnostic behind duck-typed hooks.
"""
from repro.chaos.degradation import DegradationPolicy
from repro.chaos.schedule import (
    SPOT_RECLAMATION_COST,
    ChaosAction,
    ChaosEvent,
    ChaosInjector,
    ChaosSchedule,
    FleetChaosInjector,
)

__all__ = [
    "SPOT_RECLAMATION_COST",
    "ChaosAction",
    "ChaosEvent",
    "ChaosInjector",
    "ChaosSchedule",
    "DegradationPolicy",
    "FleetChaosInjector",
]
