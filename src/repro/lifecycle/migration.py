"""Cross-cluster job migration policy for the federation layer.

``FederatedScheduler`` routes each job once, at submit time; under skewed
load (a fault storm taking half a member's nodes down, a burst landing on
one cluster) that one-shot assignment goes stale.  A migration policy runs
at every lockstep window edge, after the autoscaler ticks and view refresh:
it re-routes *waiting* work — PENDING queue entries and PAUSED jobs, never
running gangs — through the federation's own router against fresh snapshots
and proposes moves whose load advantage clears a hysteresis threshold.

The federation executes each move as drain + resubmit with preserved
progress: ``engine.withdraw_pending`` (→ MIGRATING) on the source,
``engine.admit_migrated`` (→ PENDING, remaining work carried over) on the
destination, with a ``MigrationEvent`` recorded and telemetry on both sides
updated.  Policies are duck-typed: anything with
``pick(fed, now) -> list[MigrationEvent]``.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import JobState


@dataclasses.dataclass(frozen=True)
class MigrationEvent:
    """One cross-cluster move decided at a window edge."""

    time: float
    job_id: int
    src: int
    dst: int
    reason: str


class QueueImbalanceMigration:
    """Move queued/paused jobs from overloaded members to better homes.

    A job migrates only when the federation's router, shown current views,
    would place it elsewhere AND the source's queue load exceeds the
    destination's by at least ``min_advantage`` jobs (hysteresis — without
    it, near-balanced fleets would shuttle jobs every window).
    ``max_moves_per_window`` bounds churn; ``scan`` bounds the per-source
    pending-prefix examined.  Proposed loads are updated move-by-move so a
    single window cannot dogpile one destination.
    """

    name = "queue-imbalance"

    def __init__(self, *, min_advantage: int = 8,
                 max_moves_per_window: int = 4, scan: int = 64):
        self.min_advantage = min_advantage
        self.max_moves_per_window = max_moves_per_window
        self.scan = scan

    def pick(self, fed, now: float) -> list[MigrationEvent]:
        views = fed._views
        if len(views) < 2:
            return []
        loads = [v.queue_load for v in views]
        moves: list[MigrationEvent] = []
        budget = self.max_moves_per_window
        order = sorted(range(len(views)), key=lambda i: (-loads[i], i))
        for src in order:
            if budget <= 0:
                break
            eng = fed.engines[src]
            waiting = [j for j in eng.pending[:self.scan]
                       if j.state is JobState.PENDING]
            waiting += [eng.paused[jid] for jid in sorted(eng.paused)]
            for job in waiting:
                if budget <= 0:
                    break
                dst = fed.router.route(job, views)
                if dst == src:
                    continue
                if loads[src] - loads[dst] < self.min_advantage:
                    continue
                moves.append(MigrationEvent(
                    now, job.job_id, src, dst,
                    f"queue load {loads[src]} vs {loads[dst]} "
                    f"(router: {fed.router.name})"))
                loads[src] -= 1
                loads[dst] += 1
                budget -= 1
        return moves
