"""Checkpoint-restore cost model for preemption and migration.

Preempting a running job is not free: the next start replays work since the
last checkpoint (handled by the engine's ckpt-floor arithmetic, identical to
the fault path) *and* pays a restore penalty — container restart, checkpoint
download, optimizer-state resharding — that grows with gang size.  The
constants mirror the ``repro.ckpt`` layer: ``ckpt_interval`` matches
``FaultModel.ckpt_interval`` / ``CheckpointManager(interval=...)`` so
preemption and fault kills floor progress to the same checkpoint grid.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import Job


@dataclasses.dataclass(frozen=True)
class CkptCostModel:
    """Cost constants charged when a job is preempted / migrated.

    ``resume_penalty`` is expressed in *work seconds at reference speed*
    (the unit of ``Job.runtime`` / ``engine.remaining``): it is added to the
    job's remaining work, so a slow SKU stretches it like any other work.
    """

    ckpt_interval: float = 1800.0       # periodic checkpoint cadence (s)
    restore_s: float = 120.0            # fixed restart cost per resume
    per_gpu_restore_s: float = 2.0      # resharding cost per gang GPU

    def resume_penalty(self, job: Job) -> float:
        """Work-seconds charged when ``job`` next resumes."""
        return self.restore_s + self.per_gpu_restore_s * job.num_gpus
