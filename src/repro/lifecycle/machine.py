"""Enforced job state machine.

Every lifecycle-aware mutation of ``Job.state`` — engine start/finish/kill
paths, the preemption controller, and cross-cluster migration — goes through
:func:`transition`, which validates the move against :data:`LEGAL_TRANSITIONS`
and raises :class:`IllegalTransition` instead of silently corrupting scheduler
state.  The map mirrors the lifecycle in the paper's service mode plus the
preemption extensions:

    PENDING ──────────────► RUNNING ────► COMPLETED
       │  ▲                 │  │ │
       │  │ (requeue/resume)│  │ └──────► FAILED
       │  └──── PREEMPTED ◄─┘  │
       │  ▲                    └────────► PAUSED
       │  └─────────────────────────────────┘
       └──► MIGRATING ──► PENDING   (admitted on the destination cluster)

``PREEMPTED`` and ``MIGRATING`` are transient: a preempted job is immediately
requeued (``RUNNING → PREEMPTED → PENDING`` in one controller action) because
the backfill loop only considers ``PENDING`` queue entries, and a migrating
job is ``PENDING`` again the instant the destination engine admits it.
``COMPLETED`` / ``FAILED`` are terminal.
"""
from __future__ import annotations

from repro.core.types import Job, JobState

_S = JobState

#: Legal moves.  Keys are source states; values the set of allowed targets.
LEGAL_TRANSITIONS: dict[JobState, frozenset[JobState]] = {
    _S.PENDING:   frozenset({_S.RUNNING, _S.MIGRATING, _S.FAILED}),
    _S.RUNNING:   frozenset({_S.COMPLETED, _S.FAILED, _S.PENDING,
                             _S.PAUSED, _S.PREEMPTED}),
    _S.PAUSED:    frozenset({_S.RUNNING, _S.PENDING, _S.MIGRATING,
                             _S.FAILED}),
    _S.PREEMPTED: frozenset({_S.PENDING, _S.RUNNING, _S.FAILED}),
    _S.MIGRATING: frozenset({_S.PENDING, _S.FAILED}),
    _S.COMPLETED: frozenset(),
    _S.FAILED:    frozenset(),
}


class IllegalTransition(RuntimeError):
    """Raised when a lifecycle move is not in :data:`LEGAL_TRANSITIONS`."""


def check(src: JobState, dst: JobState) -> None:
    """Validate ``src -> dst`` without touching any job."""
    if dst not in LEGAL_TRANSITIONS[src]:
        raise IllegalTransition(
            f"illegal job transition {src.name} -> {dst.name} "
            f"(legal from {src.name}: "
            f"{sorted(s.name for s in LEGAL_TRANSITIONS[src]) or 'none'})")


def transition(job: Job, dst: JobState) -> Job:
    """Validate and apply one state move; returns the job for chaining."""
    check(job.state, dst)
    job.state = dst
    return job
