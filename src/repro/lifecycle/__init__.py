"""repro.lifecycle — preemptive job lifecycle.

Enforced state machine (``machine``), checkpoint-restore cost model
(``costs``), the per-window preemption controller and its policies
(``preemption``), and cross-cluster migration policies (``migration``).
The engine's pause/resume/preempt/resize/migrate entry points live on
``repro.sched.SchedulerEngine``; this package supplies the rules and the
controllers that drive them.
"""
from repro.lifecycle.costs import CkptCostModel
from repro.lifecycle.machine import (LEGAL_TRANSITIONS, IllegalTransition,
                                     check, transition)
from repro.lifecycle.migration import MigrationEvent, QueueImbalanceMigration
from repro.lifecycle.preemption import (ElasticGangPolicy, PreemptionController,
                                        PreemptionEvent, SloDeadlinePolicy)

__all__ = [
    "CkptCostModel",
    "LEGAL_TRANSITIONS",
    "IllegalTransition",
    "check",
    "transition",
    "MigrationEvent",
    "QueueImbalanceMigration",
    "ElasticGangPolicy",
    "PreemptionController",
    "PreemptionEvent",
    "SloDeadlinePolicy",
]
