"""Preemption controller: one lifecycle tick per rescan window.

Mirrors the autoscaler contract (``repro.scale.Autoscaler.control``): the
service loop calls :meth:`PreemptionController.control` once per *processed*
rescan window, after the autoscaler tick.  The controller advances the engine
clock to the window edge, lets each policy act through the engine's lifecycle
entry points (``preempt_job`` / ``resize_job`` / ``start_now`` — every one a
checkpoint-restore move charged by the shared :class:`CkptCostModel`), and
kicks ``engine.reschedule`` so freed capacity is reused at the same instant.

With no controller configured (``preemption=None``) the service loop touches
zero engine code paths — pinned bit-identical by tests, like the
autoscaler-off path.

Policies are duck-typed: anything with
``tick(engine, now, cost) -> list[PreemptionEvent]``.

- :class:`SloDeadlinePolicy` — SLO-lane deadline enforcement.  A pending
  deadline job that can no longer wait (``now + est_runtime + slack >=
  deadline``) is force-started; when the cluster is full, the policy evicts
  the cheapest set of best-effort victims (least checkpoint-lost work first)
  whose release makes the gang fit, verified on a scratch ``ClusterState``
  before any real eviction.
- :class:`ElasticGangPolicy` — grow/shrink for jobs flagged elastic
  (``0 < min_gpus < max_gpus``): backlog pressure shrinks the largest
  elastic gangs toward ``min_gpus`` to admit queued work; an idle cluster
  grows the smallest gangs back toward ``max_gpus``.
"""
from __future__ import annotations

import dataclasses

from repro.core.cluster import ClusterState
from repro.core.types import Job
from repro.lifecycle.costs import CkptCostModel


@dataclasses.dataclass(frozen=True)
class PreemptionEvent:
    """One lifecycle action taken by a controller policy."""

    time: float
    action: str        # "preempt" | "deadline-start" | "shrink" | "grow"
    job_id: int
    reason: str
    penalty_s: float = 0.0


class SloDeadlinePolicy:
    """Evict best-effort work so deadline jobs start in time.

    ``slack_s`` is the safety margin subtracted from the latest feasible
    start; ``max_victims_per_tick`` bounds collateral damage per window;
    ``scan`` bounds the pending-queue prefix examined (the queue is
    submit-ordered, so deadline storms are near the head in practice).
    """

    def __init__(self, *, slack_s: float = 600.0,
                 max_victims_per_tick: int = 8, scan: int = 256):
        self.slack_s = slack_s
        self.max_victims_per_tick = max_victims_per_tick
        self.scan = scan

    def _urgent(self, job: Job, now: float) -> bool:
        est = max(job.est_runtime, 1.0)
        return now + est + self.slack_s >= job.deadline

    def tick(self, engine, now: float, cost: CkptCostModel) \
            -> list[PreemptionEvent]:
        events: list[PreemptionEvent] = []
        victims_left = self.max_victims_per_tick
        urgent = [j for j in engine.pending[:self.scan]
                  if j.has_deadline and self._urgent(j, now)]
        # most imminent deadline first; job_id tie-break keeps it deterministic
        urgent.sort(key=lambda j: (j.deadline, j.job_id))
        for job in urgent:
            if engine.start_now(job):
                events.append(PreemptionEvent(
                    now, "deadline-start", job.job_id,
                    f"deadline {job.deadline:.0f}s, free capacity"))
                continue
            if victims_left <= 0:
                continue
            victims = self._pick_victims(engine, job, victims_left)
            if victims is None:
                continue
            for vid, lost in victims:
                pen = cost.resume_penalty(engine.running[vid][0])
                engine.preempt_job(vid, cost)
                events.append(PreemptionEvent(
                    now, "preempt", vid,
                    f"evicted for deadline job {job.job_id}", pen))
                victims_left -= 1
            if engine.start_now(job):
                events.append(PreemptionEvent(
                    now, "deadline-start", job.job_id,
                    f"deadline {job.deadline:.0f}s, "
                    f"after {len(victims)} eviction(s)"))
        return events

    def _pick_victims(self, engine, job: Job, budget: int):
        """Cheapest best-effort victim set whose release fits ``job``,
        verified on a scratch cluster; None when no such set exists within
        ``budget`` evictions."""
        cands = []
        for jid, rec in engine.running.items():
            victim, _, st, _, speed = rec
            if victim.has_deadline:
                continue
            # uncheckpointed progress a preemption replays;
            # least-lost-first minimizes waste
            elapsed = max(0.0, engine.now - st)
            cands.append((elapsed * speed, jid))
        if not cands:
            return None
        cands.sort(key=lambda t: (t[0], t[1]))
        sim = ClusterState(engine.spec)
        sim.load_from(engine.cluster)
        chosen: list[tuple[int, float]] = []
        for lost_work, jid in cands[:budget]:
            rec = engine.running[jid]
            sim.release(rec[0], rec[1])
            chosen.append((jid, lost_work))
            if sim.find_placement(job, "pack") is not None:
                return chosen
        return None


class ElasticGangPolicy:
    """Resize elastic gangs against queue pressure.

    Shrink: while jobs queue and free capacity can't admit the queue head,
    halve the largest elastic gang (toward ``min_gpus``).  Grow: with an
    empty queue and idle GPUs, double the smallest resized gang back toward
    ``max_gpus``.  Both are checkpoint-restarts charged by the cost model;
    ``max_resizes_per_tick`` bounds churn per window.
    """

    def __init__(self, *, max_resizes_per_tick: int = 4):
        self.max_resizes_per_tick = max_resizes_per_tick

    def tick(self, engine, now: float, cost: CkptCostModel) \
            -> list[PreemptionEvent]:
        events: list[PreemptionEvent] = []
        budget = self.max_resizes_per_tick
        free, _ = engine.cluster.free_gpu_tallies()
        if engine.pending:
            head = engine.pending[0]
            # shrink the largest shrinkable gangs until the head would fit
            shrinkable = sorted(
                ((rec[0].num_gpus, jid) for jid, rec in
                 engine.running.items()
                 if rec[0].elastic and rec[0].num_gpus > rec[0].min_gpus),
                key=lambda t: (-t[0], t[1]))
            for gang, jid in shrinkable:
                if budget <= 0 or free >= head.num_gpus:
                    break
                job = engine.running[jid][0]
                target = max(job.min_gpus, gang // 2)
                pen = cost.resume_penalty(job)
                if engine.resize_job(jid, target, cost):
                    freed = gang - engine.running[jid][0].num_gpus \
                        if jid in engine.running else gang - target
                    free += freed
                    budget -= 1
                    events.append(PreemptionEvent(
                        now, "shrink", jid,
                        f"backlog: {gang} -> {target} GPUs frees capacity",
                        pen))
        elif free > 0:
            growable = sorted(
                ((rec[0].num_gpus, jid) for jid, rec in
                 engine.running.items()
                 if rec[0].elastic and rec[0].num_gpus < rec[0].max_gpus),
                key=lambda t: (t[0], t[1]))
            for gang, jid in growable:
                if budget <= 0:
                    break
                job = engine.running[jid][0]
                target = min(job.max_gpus, gang * 2, gang + free)
                if target <= gang:
                    continue
                pen = cost.resume_penalty(job)
                if engine.resize_job(jid, target, cost):
                    grown = engine.running[jid][0].num_gpus - gang \
                        if jid in engine.running else target - gang
                    free -= grown
                    budget -= 1
                    events.append(PreemptionEvent(
                        now, "grow", jid,
                        f"idle capacity: {gang} -> {target} GPUs", pen))
        return events


class PreemptionController:
    """Runs the configured policies once per rescan window.

    Tick ordering (documented in ``docs/ARCHITECTURE.md``): the service loop
    fires the autoscaler first (capacity moves), then this controller
    (placement moves against the post-scaling cluster), then ``on_window``
    observers.  The controller advances the engine to the window edge
    before acting so every lifecycle event is stamped at the tick instant,
    and kicks one extra scheduling pass when anything changed.
    """

    def __init__(self, policies=None, cost: CkptCostModel | None = None):
        if policies is None:
            policies = (SloDeadlinePolicy(), ElasticGangPolicy())
        self.policies = list(policies)
        self.cost = cost if cost is not None else CkptCostModel()
        self.events: list[PreemptionEvent] = []

    def control(self, engine, now: float, telemetry=None) \
            -> list[PreemptionEvent]:
        if now > engine.now:
            # window-edge alignment, decision-free: a controller whose
            # policies never act stays bit-identical, counters included
            engine.advance_to(now)
        events: list[PreemptionEvent] = []
        for p in self.policies:
            events.extend(p.tick(engine, now, self.cost))
        if events:
            self.events.extend(events)
            if telemetry is not None:
                note = getattr(telemetry, "note_preemption_events", None)
                if note is not None:
                    note(events)
            engine.reschedule(at=now)
        return events

    def event_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for e in self.events:
            counts[e.action] = counts.get(e.action, 0) + 1
        return counts
