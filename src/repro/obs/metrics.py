"""Metrics registry: counters / gauges / histograms + Prometheus text.

A deliberately small, dependency-free subset of the Prometheus client
model, sized for the scheduler control plane:

- ``Counter`` — monotone accumulator (``inc``).
- ``Gauge``   — last-write value (``set`` / ``inc`` / ``dec``).
- ``Histogram`` — cumulative-bucket distribution (``observe``) with
  ``_sum`` / ``_count``, rendered in the standard ``le``-labelled form.

``MetricsRegistry`` owns named metric families; series within a family are
keyed by their label set, so ``reg.counter("repro_fed_routed_total",
cluster="west")`` and ``cluster="east"`` are two series of one family.
``MetricsRegistry.merge`` folds registries together (counters and histogram
buckets sum; gauges sum too — fleet gauges like queue length are additive
across members) — the federation layer uses it to roll per-member
registries into one fleet-level exposition.

``EngineMetricsHook`` is the ``EngineHooks`` observer wiring a registry to
a ``SchedulerEngine``: hook-driven event counters and wait/JCT/alloc-wall
histograms, plus per-tick gauge samples and delta-mirrors of the engine's
cumulative decision/degradation counters.  It never reads ``snapshot()``
on the hot path.
"""
from __future__ import annotations

import math

from repro.sched.engine import EngineHooks

#: Default histogram buckets for control-plane wall-clock latencies (s).
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0)

#: Default histogram buckets for simulated-time job durations (s):
#: 1 min .. 4 days, roughly geometric.
SIM_DURATION_BUCKETS = (60.0, 300.0, 900.0, 1800.0, 3600.0, 2 * 3600.0,
                        4 * 3600.0, 8 * 3600.0, 16 * 3600.0, 86400.0,
                        2 * 86400.0, 4 * 86400.0)


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting: integral floats render bare."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class Counter:
    """Monotone counter; ``inc`` with a negative amount raises."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount

    def _merge(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    """Last-write value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount

    def _merge(self, other: "Gauge") -> None:
        self.value += other.value


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` semantics)."""

    __slots__ = ("buckets", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, buckets=LATENCY_BUCKETS):
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError("histogram buckets must be sorted ascending")
        self.counts = [0] * len(self.buckets)   # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.buckets):
            if value <= b:
                self.counts[i] += 1
                break

    def cumulative(self) -> list[int]:
        """Cumulative counts per bucket bound (excluding +Inf)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); 0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for c, b in zip(self.counts, self.buckets):
            acc += c
            if acc >= target:
                return b
        return math.inf

    def _merge(self, other: "Histogram") -> None:
        if other.buckets != self.buckets:
            raise ValueError("cannot merge histograms with different buckets")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Named metric families; get-or-create accessors per (name, labels)."""

    def __init__(self):
        # name -> {"kind": str, "help": str, "series": {labelkey: instrument}}
        self._families: dict[str, dict] = {}

    # ------------------------------------------------------------- create ----
    def _get(self, name: str, kind: str, help_: str, labels: dict, make):
        fam = self._families.get(name)
        if fam is None:
            fam = {"kind": kind, "help": help_, "series": {}}
            self._families[name] = fam
        elif fam["kind"] != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam['kind']}, not {kind}")
        key = _label_key(labels)
        inst = fam["series"].get(key)
        if inst is None:
            inst = fam["series"][key] = make()
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_BUCKETS, **labels) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets))

    # ------------------------------------------------------------ queries ----
    def value(self, name: str, **labels) -> float:
        """Scalar value of a counter/gauge series (0.0 when absent)."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        inst = fam["series"].get(_label_key(labels))
        return 0.0 if inst is None else inst.value

    def families(self) -> dict[str, dict]:
        return self._families

    def as_dict(self) -> dict:
        """JSON-friendly dump (bench artifacts embed this)."""
        out: dict = {}
        for name, fam in sorted(self._families.items()):
            series = {}
            for key, inst in sorted(fam["series"].items()):
                label = ",".join(f"{k}={v}" for k, v in key) or "_"
                if fam["kind"] == "histogram":
                    series[label] = {"sum": inst.sum, "count": inst.count}
                else:
                    series[label] = inst.value
            out[name] = {"kind": fam["kind"], "series": series}
        return out

    # -------------------------------------------------------------- merge ----
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` into this registry in place (fleet roll-up);
        returns self.  Counters/gauges/histogram buckets are summed."""
        for name, fam in other._families.items():
            mine = self._families.get(name)
            if mine is None:
                mine = {"kind": fam["kind"], "help": fam["help"],
                        "series": {}}
                self._families[name] = mine
            elif mine["kind"] != fam["kind"]:
                raise ValueError(f"metric {name!r} kind mismatch on merge")
            for key, inst in fam["series"].items():
                have = mine["series"].get(key)
                if have is None:
                    if fam["kind"] == "histogram":
                        have = Histogram(inst.buckets)
                    else:
                        have = type(inst)()
                    mine["series"][key] = have
                have._merge(inst)
        return self

    @classmethod
    def merged(cls, registries) -> "MetricsRegistry":
        out = cls()
        for reg in registries:
            if reg is not None:
                out.merge(reg)
        return out

    # ------------------------------------------------------------- render ----
    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        lines: list[str] = []
        for name, fam in sorted(self._families.items()):
            if fam["help"]:
                lines.append(f"# HELP {name} {_escape(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['kind']}")
            for key, inst in sorted(fam["series"].items()):
                base = ",".join(f'{k}="{_escape(v)}"' for k, v in key)
                if fam["kind"] != "histogram":
                    suffix = "{" + base + "}" if base else ""
                    lines.append(f"{name}{suffix} {_fmt(inst.value)}")
                    continue
                cum = inst.cumulative()
                for bound, c in zip(inst.buckets, cum):
                    lbl = (base + "," if base else "") + f'le="{_fmt(bound)}"'
                    lines.append(f"{name}_bucket{{{lbl}}} {c}")
                lbl = (base + "," if base else "") + 'le="+Inf"'
                lines.append(f"{name}_bucket{{{lbl}}} {inst.count}")
                suffix = "{" + base + "}" if base else ""
                lines.append(f"{name}_sum{suffix} {_fmt(inst.sum)}")
                lines.append(f"{name}_count{suffix} {inst.count}")
        return "\n".join(lines) + "\n"


#: (metric name, engine attribute) pairs mirrored as delta counters per tick.
_ENGINE_COUNTER_MIRRORS = (
    ("repro_decisions_total", "decisions"),
    ("repro_backfills_total", "backfills"),
    ("repro_restarts_total", "restarts"),
    ("repro_milp_calls_total", "milp_calls"),
    ("repro_milp_fallbacks_total", "milp_fallbacks"),
    ("repro_degraded_windows_total", "degraded_windows"),
    ("repro_reclaimed_jobs_total", "reclaimed_jobs"),
    ("repro_predicted_backfills_total", "bf_reservations"),
    ("repro_backfill_overruns_total", "bf_overruns"),
)


class EngineMetricsHook(EngineHooks):
    """EngineHooks observer feeding a ``MetricsRegistry``.

    All instruments are resolved once at construction (label churn off the
    hot path); ``on_tick`` does a handful of attribute reads and gauge
    sets.  Engine-side cumulative counters (decisions, MILP calls/
    fallbacks, degraded windows, ...) are mirrored as Prometheus counters
    by per-tick deltas so a crashed-and-restored engine never makes a
    counter run backwards."""

    def __init__(self, registry: MetricsRegistry, **labels):
        self.registry = registry
        self.labels = labels
        c, g, h = registry.counter, registry.gauge, registry.histogram
        self._submitted = c("repro_jobs_submitted_total",
                            "jobs accepted into the engine", **labels)
        self._started = c("repro_job_starts_total",
                          "job (re)starts, checkpoint resumes included",
                          **labels)
        self._finished = c("repro_jobs_finished_total",
                           "jobs run to completion", **labels)
        self._requeued = c("repro_jobs_requeued_total",
                           "fault / eviction requeues", **labels)
        self._preempted = c("repro_preemptions_total",
                            "lifecycle checkpoint evictions", **labels)
        self._resumed = c("repro_resumes_total",
                          "checkpoint resumes", **labels)
        self._penalty = c("repro_resume_penalty_seconds_total",
                          "resume-penalty work-seconds charged", **labels)
        self._queue = g("repro_queue_len", "pending jobs", **labels)
        self._running = g("repro_running_jobs", "running jobs", **labels)
        self._free = g("repro_free_gpus", "free GPUs on up nodes", **labels)
        self._util = g("repro_utilization",
                       "busy-GPU fraction, up nodes only", **labels)
        self._down = g("repro_nodes_down",
                       "failed (non-retired) nodes", **labels)
        self._wait = h("repro_job_wait_seconds",
                       "queue wait at first start (simulated)",
                       buckets=SIM_DURATION_BUCKETS, **labels)
        self._jct = h("repro_job_jct_seconds",
                      "job completion time (simulated)",
                      buckets=SIM_DURATION_BUCKETS, **labels)
        self._alloc = h("repro_alloc_wall_seconds",
                        "placement wall-clock per allocation attempt",
                        **labels)
        self._alloc_path = {
            path: c("repro_allocs_total", "successful placements by path",
                    path=path, **labels)
            for path in ("milp", "greedy-fallback", "heuristic")
        }
        self._mirror = [(c(name, f"engine cumulative {attr}", **labels),
                         attr, 0.0)
                        for name, attr in _ENGINE_COUNTER_MIRRORS]
        # prediction instruments (repro.predict): rolling MAPE per model and
        # the reservation-slack distribution (p90 headroom at backfill
        # commit), drained incrementally via the predictor's slack cursor
        self._mape_mlp = g("repro_prediction_mape",
                           "rolling MAPE of predicted runtimes",
                           model="mlp", **labels)
        self._mape_base = g("repro_prediction_mape",
                            "rolling MAPE of predicted runtimes",
                            model="baseline", **labels)
        self._overrun_ratio = g("repro_backfill_overrun_ratio",
                                "blown reservations per predictor-gated "
                                "backfill (clamped [0, 1])", **labels)
        self._slack = h("repro_reservation_slack_seconds",
                        "p90 headroom against the head-job reservation at "
                        "backfill commit (simulated)",
                        buckets=SIM_DURATION_BUCKETS, **labels)
        self._slack_cursor = 0

    # ----------------------------------------------------------- hook API ----
    def on_submit(self, job, now):
        self._submitted.inc()

    def on_start(self, job, now):
        self._started.inc()
        if job.first_start_time == now and job.restarts == 0:
            self._wait.observe(max(now - job.submit_time, 0.0))

    def on_finish(self, job, now):
        self._finished.inc()
        self._jct.observe(max(now - job.submit_time, 0.0))

    def on_requeue(self, job, now):
        self._requeued.inc()

    def on_preempt(self, job, now, penalty_s):
        self._preempted.inc()
        self._penalty.inc(max(penalty_s, 0.0))

    def on_resume(self, job, now):
        self._resumed.inc()

    def on_alloc(self, job, placement, now, wall_s, path):
        self._alloc.observe(wall_s)
        if placement is not None:
            self._alloc_path[path].inc()

    def on_tick(self, now, engine):
        self._queue.set(len(engine.pending))
        self._running.set(len(engine.running))
        cluster = engine.cluster
        free, _ = cluster.free_gpu_tallies()
        self._free.set(free)
        self._util.set(cluster.utilization(up_only=True))
        self._down.set(int((cluster.node_down & ~cluster.retired).sum()))
        mirror = self._mirror
        for i, (counter, attr, last) in enumerate(mirror):
            val = float(getattr(engine, attr, 0.0))
            if val > last:
                counter.inc(val - last)
                mirror[i] = (counter, attr, val)
        pred = getattr(engine, "predictor", None)
        if pred is not None:
            self._mape_mlp.set(pred.rolling_mape())
            self._mape_base.set(pred.baseline_rolling_mape())
            res = getattr(engine, "bf_reservations", 0)
            self._overrun_ratio.set(
                min(getattr(engine, "bf_overruns", 0) / max(res, 1), 1.0))
            slacks, self._slack_cursor = \
                pred.recent_slacks(self._slack_cursor)
            for s in slacks:
                self._slack.observe(s)

    # ------------------------------------------------- controller counters ----
    def note_controller(self, kind: str, n_events: int) -> None:
        """Count controller-tick actions (autoscaler / preemption / chaos);
        the service loop forwards each tick's emitted event count."""
        self.registry.counter("repro_controller_ticks_total",
                              "controller control ticks",
                              controller=kind, **self.labels).inc()
        if n_events:
            self.registry.counter("repro_controller_events_total",
                                  "controller actions emitted",
                                  controller=kind, **self.labels).inc(n_events)
