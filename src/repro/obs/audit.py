"""Decision audit log: which path ranked, which allocator placed, and why
jobs were skipped.

The engine's gated audit stream (``on_decision_audit`` /
``on_window_blocked``, see ``repro.sched.engine``) emits one record per
scheduling decision on the optimized path:

.. code-block:: python

    {"now": float,            # simulated decision instant
     "path": "policy" | "fcfs-degraded",     # who ranked the window
     "window": int,           # ranking-window size handed to the policy
     "rank_wall_s": float,    # wall-clock spent ranking
     "top_job": int,          # job id the policy put first
     "placed": bool,          # did the top job start this decision
     "alloc": "milp" | "greedy-fallback" | "heuristic" | "none",
     "skips": {reason: count},  # head-no-placement / backfill-overrun /
                                # backfill-no-placement
     "backfills": int}        # jobs EASY-backfilled under the reservation

``DecisionAuditLog`` aggregates the stream into exact cumulative counters
(path / allocator / skip-reason tallies, blocked-window count) and keeps
the most recent ``keep`` raw records for inspection — the aggregates are
never truncated, only the raw ring is.  ``python -m repro.obs.report``
prints the same summaries from an exported trace file.
"""
from __future__ import annotations

import collections

from repro.sched.engine import EngineHooks

#: skip reasons the engine reports (order = display order for ties)
SKIP_REASONS = ("head-no-placement", "backfill-overrun",
                "backfill-no-placement")


class DecisionAuditLog(EngineHooks):
    """Aggregating sink for the engine's decision-audit stream.

    Subclasses ``EngineHooks`` so it can be attached directly to an engine
    (the base-event dispatch calls every hook unconditionally); the gated
    audit stream still fires only because this class *overrides*
    ``on_decision_audit`` / ``on_window_blocked``."""

    def __init__(self, keep: int = 10_000):
        self.keep = keep
        self.records: collections.deque = collections.deque(maxlen=keep)
        self.decisions = 0
        self.path_counts: collections.Counter = collections.Counter()
        self.alloc_counts: collections.Counter = collections.Counter()
        self.skip_counts: collections.Counter = collections.Counter()
        self.backfills = 0
        self.blocked_windows = 0
        self.rank_wall_s = 0.0

    # ----------------------------------------------------------- hook API ----
    def on_decision_audit(self, rec: dict) -> None:
        self.decisions += 1
        self.path_counts[rec["path"]] += 1
        self.alloc_counts[rec.get("alloc", "none")] += 1
        self.rank_wall_s += rec.get("rank_wall_s", 0.0)
        self.backfills += rec.get("backfills", 0)
        for reason, n in rec.get("skips", {}).items():
            self.skip_counts[reason] += n
        self.records.append(rec)

    def on_window_blocked(self, now: float, queued: int) -> None:
        self.blocked_windows += 1

    # ------------------------------------------------------------ queries ----
    def top_skip_reasons(self, k: int = 3) -> list[tuple[str, int]]:
        """Top-k reasons queued jobs were passed over, most frequent
        first (ties in the engine's reporting order)."""
        order = {r: i for i, r in enumerate(SKIP_REASONS)}
        items = sorted(self.skip_counts.items(),
                       key=lambda kv: (-kv[1], order.get(kv[0], 99)))
        return items[:k]

    def summary(self) -> dict:
        """JSON-friendly aggregate (bench artifacts embed this)."""
        return {
            "decisions": self.decisions,
            "path_counts": dict(self.path_counts),
            "alloc_counts": dict(self.alloc_counts),
            "skip_counts": dict(self.skip_counts),
            "top_skip_reasons": self.top_skip_reasons(),
            "backfills": self.backfills,
            "blocked_windows": self.blocked_windows,
            "rank_wall_s": self.rank_wall_s,
            "records_kept": len(self.records),
        }
