"""repro.obs — end-to-end observability for the scheduling control plane.

One ``Observability`` bundle owns the three sinks and is what drivers
pass around (``run_stream(..., obs=obs)`` / ``run_fleet(..., obs=obs)``):

- :class:`~repro.obs.tracer.SpanTracer` — job-lifecycle + control-plane
  spans, exported as Chrome trace-event JSON (Perfetto-loadable).
- :class:`~repro.obs.metrics.MetricsRegistry` (fed by
  :class:`~repro.obs.metrics.EngineMetricsHook`) — counters / gauges /
  histograms with a Prometheus text exporter and fleet-level merge.
- :class:`~repro.obs.audit.DecisionAuditLog` — per-decision rank-path /
  allocator / skip-reason accounting.

``obs.hooks()`` yields the hook objects to attach to an engine (the
service loop composes them with telemetry and RL recorders through
``MultiHooks``); ``obs.member(i, name)`` derives a per-federation-member
child whose trace events and metrics roll up into the fleet-level
``export_trace`` / ``prometheus`` views.

Everything here is observational: with ``obs=None`` the engine and
drivers take bit-identical code paths (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import time

from repro.obs.audit import DecisionAuditLog
from repro.obs.metrics import (Counter, EngineMetricsHook, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.tracer import SpanTracer, merge_documents, validate_trace

__all__ = [
    "Observability", "SpanTracer", "MetricsRegistry", "EngineMetricsHook",
    "DecisionAuditLog", "Counter", "Gauge", "Histogram",
    "merge_documents", "validate_trace",
]


class Observability:
    """Bundle of tracer + metrics + audit log for one engine (or, via
    :meth:`member`, one federation).  Any sink can be switched off at
    construction; ``hooks()`` only returns the live ones."""

    def __init__(self, *, name: str = "cluster", member: int = 0,
                 trace: bool = True, metrics: bool = True,
                 audit: bool = True, max_trace_events: int = 2_000_000,
                 keep_audit_records: int = 10_000):
        self.name = name
        self.tracer = SpanTracer(name=name, member=member,
                                 max_events=max_trace_events) \
            if trace else None
        self.registry = MetricsRegistry() if metrics else None
        self.metrics_hook = EngineMetricsHook(self.registry, cluster=name) \
            if metrics else None
        self.audit = DecisionAuditLog(keep=keep_audit_records) \
            if audit else None
        self._members: dict[int, "Observability"] = {}
        self._finalized = False
        self._wall_start = time.perf_counter()
        self.wall_elapsed_s = 0.0

    # -------------------------------------------------------------- hooks ----
    def hooks(self) -> tuple:
        """Hook objects to attach to one engine, in dispatch order."""
        return tuple(h for h in (self.tracer, self.metrics_hook, self.audit)
                     if h is not None)

    # ---------------------------------------------------------- federation ----
    def member(self, i: int, name: str | None = None) -> "Observability":
        """Per-federation-member child bundle (memoized).  Members get
        disjoint trace pids and a ``cluster`` metric label of their own;
        fleet-level views merge them."""
        child = self._members.get(i)
        if child is None:
            child = Observability(
                name=name or f"{self.name}/{i}", member=i + 1,
                trace=self.tracer is not None,
                metrics=self.registry is not None,
                audit=self.audit is not None,
                max_trace_events=(self.tracer.max_events
                                  if self.tracer is not None else 0),
                keep_audit_records=(self.audit.keep
                                    if self.audit is not None else 0))
            self._members[i] = child
        return child

    def members(self) -> list["Observability"]:
        return [self._members[i] for i in sorted(self._members)]

    # --------------------------------------------------- control-plane API ----
    def note_controller(self, kind: str, n_events: int, wall_s: float,
                        now: float) -> None:
        """Record one controller tick (autoscaler / preemption / chaos /
        fleet-chaos): a wall-clock control-plane span plus tick/action
        counters.  The service loop calls this at every window edge."""
        if self.tracer is not None:
            self.tracer.control_span(kind, kind, wall_s, sim_t=now,
                                     events=n_events)
        if self.metrics_hook is not None:
            self.metrics_hook.note_controller(kind, n_events)

    def note_window(self, now: float, wall_s: float, processed: int) -> None:
        """Record one processed rescan window (engine.step to the edge)."""
        if self.tracer is not None:
            self.tracer.control_span("window-step", "window", wall_s,
                                     sim_t=now, events=processed)
        if self.registry is not None:
            self.registry.counter("repro_rescan_windows_total",
                                  "processed rescan windows",
                                  cluster=self.name).inc()

    def count(self, name: str, help: str = "", n: float = 1.0,
              **labels) -> None:
        """Bump a fleet-level counter (routing / deferral / migration);
        no-op with metrics off."""
        if self.registry is not None:
            self.registry.counter(name, help, **labels).inc(n)

    # ----------------------------------------------------------- finalize ----
    def finalize(self, engine=None) -> None:
        """Close open spans and take a final metrics sample.  Idempotent;
        drivers call it once at end-of-stream."""
        if self._finalized:
            return
        self._finalized = True
        self.wall_elapsed_s = time.perf_counter() - self._wall_start
        if self.tracer is not None:
            now = engine.now if engine is not None else None
            self.tracer.finalize(now)
        if self.metrics_hook is not None and engine is not None:
            self.metrics_hook.on_tick(engine.now, engine)

    def finalize_fleet(self, fed) -> None:
        """Finalize every member bundle against its engine."""
        for i, child in self._members.items():
            child.finalize(fed.engines[i] if i < len(fed.engines) else None)
        self.finalize()

    # -------------------------------------------------------------- views ----
    def trace_document(self) -> dict:
        """Fleet-merged Chrome trace document (self + members)."""
        docs = []
        if self.tracer is not None:
            docs.append(self.tracer.to_document())
        docs.extend(m.tracer.to_document() for m in self.members()
                    if m.tracer is not None)
        if len(docs) == 1:
            return docs[0]
        return merge_documents(docs)

    def export_trace(self, path: str) -> str:
        import json
        with open(path, "w") as fh:
            json.dump(self.trace_document(), fh)
        return path

    def merged_registry(self) -> MetricsRegistry:
        """Fleet-merged metrics registry (self + members)."""
        regs = [self.registry] + [m.registry for m in self.members()]
        return MetricsRegistry.merged(r for r in regs if r is not None)

    def prometheus(self) -> str:
        """Fleet-merged Prometheus text exposition."""
        return self.merged_registry().render()

    def write_prometheus(self, path: str) -> str:
        with open(path, "w") as fh:
            fh.write(self.prometheus())
        return path

    def audit_summary(self) -> dict:
        """Audit aggregate; per-member summaries attached under
        ``members`` when federation children exist."""
        out = self.audit.summary() if self.audit is not None else {}
        if self._members:
            out = dict(out)
            out["members"] = {m.name: m.audit.summary()
                              for m in self.members()
                              if m.audit is not None}
        return out
