"""Trace-file reporter: ``python -m repro.obs.report TRACE.json``.

Reads a Chrome trace-event document exported by ``repro.obs`` and prints:

- a **critical-path summary**: the top-K jobs by total lifecycle span
  (queued + running, preemption restarts included), with the queue /
  compute breakdown that says where each job's time actually went;
- a **top-queueing-cause summary**: decision-path counts (policy vs
  FCFS-degraded), allocator-path counts (MILP vs greedy fallback vs
  heuristic), capacity-blocked window count, and the top-k skip reasons
  from the engine's audit stream — fleet-wide, plus a per-job attribution
  over each critical-path job's longest wait.

``--validate`` checks the document against the trace-event schema first
and exits non-zero on any violation (the CI smoke job gates on this).
"""
from __future__ import annotations

import argparse
import bisect
import collections
import json
import os
import sys

from repro.obs.tracer import validate_trace


def _fmt_h(seconds: float) -> str:
    return f"{seconds / 3600.0:8.2f}h"


class JobTrack:
    """Per-job roll-up of ``cat == "job"`` spans and instants."""

    __slots__ = ("pid", "jid", "queued_s", "running_s", "preempts",
                 "requeues", "finished", "intervals", "gpus", "restarts")

    def __init__(self, pid, jid):
        self.pid = pid
        self.jid = jid
        self.queued_s = 0.0
        self.running_s = 0.0
        self.preempts = 0
        self.requeues = 0
        self.finished = False
        self.intervals = []       # absolute-sim-time (start, end) queued
        self.gpus = 0
        self.restarts = 0

    @property
    def total_s(self) -> float:
        return self.queued_s + self.running_s

    def longest_wait(self):
        return max(self.intervals, key=lambda iv: iv[1] - iv[0],
                   default=None)


def load_trace(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def analyze(doc: dict) -> dict:
    """Fold a trace document into the report's working model."""
    t0s = {int(k): float(v)
           for k, v in doc.get("otherData", {}).get("sim_t0", {}).items()}
    jobs: dict[tuple, JobTrack] = {}
    path_counts: collections.Counter = collections.Counter()
    alloc_counts: collections.Counter = collections.Counter()
    skip_counts: collections.Counter = collections.Counter()
    rank_events: list[tuple[float, dict]] = []   # (sim_t, skips)
    blocked = 0
    rank_wall_s = 0.0

    for ev in doc.get("traceEvents", ()):
        cat = ev.get("cat")
        name = ev.get("name", "")
        if cat == "job":
            key = (ev["pid"], ev["tid"])
            jt = jobs.get(key)
            if jt is None:
                jt = jobs[key] = JobTrack(*key)
            args = ev.get("args", {})
            if ev["ph"] == "X":
                dur_s = ev.get("dur", 0) / 1e6
                if name == "queued":
                    jt.queued_s += dur_s
                    base = t0s.get(ev["pid"], 0.0)
                    start = base + ev["ts"] / 1e6
                    jt.intervals.append((start, start + dur_s))
                elif name == "running":
                    jt.running_s += dur_s
                jt.gpus = max(jt.gpus, args.get("gpus", 0))
                jt.restarts = max(jt.restarts, args.get("restarts", 0))
            elif ev["ph"] == "i":
                if name == "preempt":
                    jt.preempts += 1
                elif name == "requeue":
                    jt.requeues += 1
                elif name == "finish":
                    jt.finished = True
        elif cat == "control" and ev.get("ph") == "X" \
                and name.startswith("rank:"):
            args = ev.get("args", {})
            path_counts[name.split(":", 1)[1]] += 1
            rank_wall_s += ev.get("dur", 0) / 1e6
            skips = args.get("skips") or {}
            for reason, n in skips.items():
                skip_counts[reason] += n
            rank_events.append((args.get("sim_t", 0.0), skips))
        elif cat == "control" and ev.get("ph") == "X" \
                and name.startswith("alloc:"):
            if ev.get("args", {}).get("placed"):
                alloc_counts[name.split(":", 1)[1]] += 1
        elif cat == "control" and name == "window-blocked":
            blocked += 1

    rank_events.sort(key=lambda kv: kv[0])
    return {"jobs": jobs, "path_counts": path_counts,
            "alloc_counts": alloc_counts, "skip_counts": skip_counts,
            "rank_events": rank_events, "blocked_windows": blocked,
            "rank_wall_s": rank_wall_s}


def _attribute_wait(model: dict, jt: JobTrack, k: int = 3):
    """Skip-reason tallies over the decisions made during ``jt``'s longest
    queued interval — 'what was the scheduler doing while this job sat'."""
    iv = jt.longest_wait()
    if iv is None or not model["rank_events"]:
        return []
    times = [t for t, _ in model["rank_events"]]
    lo = bisect.bisect_left(times, iv[0])
    hi = bisect.bisect_right(times, iv[1])
    local: collections.Counter = collections.Counter()
    for _, skips in model["rank_events"][lo:hi]:
        for reason, n in skips.items():
            local[reason] += n
    return local.most_common(k)


def print_report(doc: dict, top: int = 10, out=None) -> None:
    # sys.stdout resolved at call time, not def time — callers (and tests)
    # that swap stdout still capture the report
    out = out if out is not None else sys.stdout
    model = analyze(doc)
    jobs = sorted(model["jobs"].values(), key=lambda j: -j.total_s)
    w = out.write

    w(f"critical path — top {min(top, len(jobs))} of {len(jobs)} traced "
      f"jobs by lifecycle span\n")
    w(f"{'job':>10} {'total':>9} {'queued':>9} {'running':>9} "
      f"{'gpus':>5} {'restarts':>8} {'preempts':>8}  dominant wait cause\n")
    for jt in jobs[:top]:
        causes = _attribute_wait(model, jt, k=1)
        cause = f"{causes[0][0]} x{causes[0][1]}" if causes else "-"
        w(f"{jt.jid!s:>10} {_fmt_h(jt.total_s)} {_fmt_h(jt.queued_s)} "
          f"{_fmt_h(jt.running_s)} {jt.gpus:>5} {jt.restarts:>8} "
          f"{jt.preempts:>8}  {cause}\n")

    w("\ndecision paths (who ranked each window)\n")
    total = sum(model["path_counts"].values()) or 1
    for path, n in model["path_counts"].most_common():
        w(f"  {path:<16} {n:>8}  ({100.0 * n / total:5.1f}%)\n")
    if not model["path_counts"]:
        w("  (no rank spans in trace)\n")

    w("\nallocator paths (who placed each started job)\n")
    for path, n in model["alloc_counts"].most_common():
        w(f"  {path:<16} {n:>8}\n")
    if not model["alloc_counts"]:
        w("  (no alloc spans in trace)\n")

    w("\ntop queueing causes (jobs passed over, fleet-wide)\n")
    for reason, n in model["skip_counts"].most_common(5):
        w(f"  {reason:<24} {n:>8}\n")
    if not model["skip_counts"]:
        w("  (no skips recorded)\n")
    w(f"  capacity-blocked windows {model['blocked_windows']:>8}\n")
    w(f"  ranking wall-clock total {model['rank_wall_s']:>8.3f}s\n")
    dropped = doc.get("otherData", {}).get("dropped_events", 0)
    if dropped:
        w(f"\nWARNING: {dropped} events dropped at the tracer cap — "
          f"summaries undercount\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs Chrome trace-event file.")
    ap.add_argument("trace", help="trace JSON exported by repro.obs")
    ap.add_argument("--top", type=int, default=10,
                    help="critical-path rows to print (default 10)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the trace; non-zero exit on any "
                         "violation")
    args = ap.parse_args(argv)
    try:
        doc = load_trace(args.trace)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"error: cannot load {args.trace}: {exc}", file=sys.stderr)
        return 2
    if args.validate:
        problems = validate_trace(doc)
        if problems:
            for p in problems:
                print(f"schema violation: {p}", file=sys.stderr)
            return 1
        print(f"trace OK: {len(doc['traceEvents'])} events")
    try:
        print_report(doc, top=args.top)
    except BrokenPipeError:
        # reader (e.g. `| head`) closed the pipe — not an error; point
        # stdout at devnull so the interpreter's exit flush stays quiet
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
    return 0


if __name__ == "__main__":
    sys.exit(main())
