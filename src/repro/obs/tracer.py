"""Span tracer: job lifecycle + control-plane spans as Chrome trace events.

``SpanTracer`` is an ``EngineHooks`` observer that records two timelines
into one Chrome trace-event JSON document (the ``{"traceEvents": [...]}``
format Perfetto and ``chrome://tracing`` load directly):

- **Job lifecycle** (simulated time, one track per job): every job renders
  as alternating ``queued`` / ``running`` complete spans
  (submit -> start -> preempt/evict -> resume -> finish), with instant
  events marking preemptions (resume penalty attached), fault requeues,
  and checkpoint resumes.  ``tid`` is the job id; ``ts`` is microseconds
  of simulated time since the first observed instant.
- **Control plane** (wall-clock time, its own process track): per-decision
  ``rank`` spans (policy vs FCFS-degraded path, from the engine's audit
  stream), per-attempt ``alloc`` spans (MILP / greedy-fallback /
  heuristic), and per-rescan-window autoscaler / preemption / chaos
  controller ticks forwarded by the service loop.

The two timelines use different clocks, so they live in different trace
``pid``s — each is internally consistent, and control-plane events carry
``sim_t`` in ``args`` for cross-referencing.  ``validate_trace`` checks
the exported document against the trace-event schema (CI gates on it).

Jobs paused or migrated away (``pause_job`` / ``withdraw_pending`` fire no
engine hooks by design) keep their last span open until a later hook or
:meth:`finalize` closes it; cross-cluster migrations therefore appear as a
span ending on the source member's track and a fresh ``queued`` span
opening on the destination's.
"""
from __future__ import annotations

import json
import time

from repro.sched.engine import EngineHooks

#: trace pid carrying simulated-time job spans (offset by member index).
JOB_PID_BASE = 1
#: trace pid carrying wall-clock control-plane spans.
CONTROL_PID_BASE = 1001

_REQUIRED_KEYS = {"name", "ph", "ts", "pid", "tid"}


class SpanTracer(EngineHooks):
    """EngineHooks observer emitting Chrome trace events.

    ``member`` offsets the job/control pids so per-federation-member
    tracers merge into one fleet trace without track collisions.
    ``max_events`` bounds memory: past it, new events are counted in
    ``dropped`` instead of stored (open-span bookkeeping still runs, so
    spans that close after the cap don't corrupt earlier ones).
    """

    def __init__(self, *, name: str = "cluster", member: int = 0,
                 max_events: int = 2_000_000,
                 counter_interval: float = 600.0):
        self.name = name
        self.member = member
        self.job_pid = JOB_PID_BASE + member
        self.ctrl_pid = CONTROL_PID_BASE + member
        self.max_events = max_events
        self.counter_interval = counter_interval
        self.events: list[dict] = []
        self.dropped = 0
        self._t0: float | None = None          # sim-time origin
        self._wall0 = time.perf_counter()      # wall-time origin
        self._queued_since: dict[int, float] = {}
        self._running_since: dict[int, float] = {}
        self._preempting: set[int] = set()
        self._next_counter: float | None = None
        self._meta()

    # ---------------------------------------------------------- low level ----
    def _meta(self) -> None:
        for pid, label in ((self.job_pid, f"{self.name} jobs (sim time)"),
                           (self.ctrl_pid,
                            f"{self.name} control plane (wall clock)")):
            self.events.append({"name": "process_name", "ph": "M",
                                "pid": pid, "tid": 0, "ts": 0,
                                "args": {"name": label}})

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def _sim_us(self, t: float) -> int:
        if self._t0 is None:
            self._t0 = t
        return int(round((t - self._t0) * 1e6))

    def _wall_us(self) -> int:
        return int(round((time.perf_counter() - self._wall0) * 1e6))

    def _job_span(self, name: str, jid: int, t_start: float, t_end: float,
                  **args) -> None:
        ts = self._sim_us(t_start)
        self._emit({"name": name, "ph": "X", "cat": "job", "ts": ts,
                    "dur": max(self._sim_us(t_end) - ts, 0),
                    "pid": self.job_pid, "tid": jid, "args": args})

    def _job_instant(self, name: str, jid: int, t: float, **args) -> None:
        self._emit({"name": name, "ph": "i", "cat": "job", "s": "t",
                    "ts": self._sim_us(t), "pid": self.job_pid, "tid": jid,
                    "args": args})

    def control_span(self, name: str, tid: str, wall_s: float,
                     **args) -> None:
        """Record a wall-clock control-plane span ending *now* (the service
        loop and engine call this right after timing the work)."""
        dur = max(int(round(wall_s * 1e6)), 0)
        # clamp: a span timed before this tracer's wall origin (e.g. handed
        # in from an older clock) must not produce a negative timestamp
        ts = max(self._wall_us() - dur, 0)
        self._emit({"name": name, "ph": "X", "cat": "control",
                    "ts": ts, "dur": dur,
                    "pid": self.ctrl_pid, "tid": tid, "args": args})

    # ----------------------------------------------------------- hook API ----
    def on_submit(self, job, now):
        self._queued_since[job.job_id] = now

    def on_start(self, job, now):
        jid = job.job_id
        q = self._queued_since.pop(jid, None)
        if q is not None:
            self._job_span("queued", jid, q, now,
                           gpus=job.num_gpus, restarts=job.restarts)
        self._running_since[jid] = now

    def on_finish(self, job, now):
        jid = job.job_id
        r = self._running_since.pop(jid, None)
        if r is not None:
            self._job_span("running", jid, r, now, gpus=job.num_gpus,
                           restarts=job.restarts)
        self._job_instant("finish", jid, now, jct=job.jct)

    def on_preempt(self, job, now, penalty_s):
        jid = job.job_id
        r = self._running_since.pop(jid, None)
        if r is not None:
            self._job_span("running", jid, r, now, gpus=job.num_gpus,
                           restarts=job.restarts, evicted="preempt")
        self._preempting.add(jid)
        self._job_instant("preempt", jid, now, penalty_s=penalty_s)

    def on_requeue(self, job, now):
        jid = job.job_id
        r = self._running_since.pop(jid, None)
        if r is not None:
            # a requeue with an open running span and no preceding
            # on_preempt is a fault kill (or a resume from pause, whose
            # pause instant was unobservable — the span runs to here)
            self._job_span("running", jid, r, now, gpus=job.num_gpus,
                           restarts=job.restarts, evicted="fault")
        if jid in self._preempting:
            self._preempting.discard(jid)
        else:
            self._job_instant("requeue", jid, now)
        self._queued_since[jid] = now

    def on_resume(self, job, now):
        self._job_instant("resume", job.job_id, now,
                          progress=job.progress_at_ckpt)

    def on_tick(self, now, engine):
        if self._next_counter is None:
            self._next_counter = now
        if now >= self._next_counter:
            self._emit({"name": "load", "ph": "C", "ts": self._sim_us(now),
                        "pid": self.job_pid, "tid": 0,
                        "args": {"pending": len(engine.pending),
                                 "running": len(engine.running)}})
            self._next_counter = now + self.counter_interval

    # -- engine audit stream (gated: only fires when a hook defines these) --
    def on_alloc(self, job, placement, now, wall_s, path):
        self.control_span(f"alloc:{path}", "alloc", wall_s, sim_t=now,
                          job=job.job_id, placed=placement is not None,
                          gpus=job.num_gpus)

    def on_decision_audit(self, rec):
        self.control_span(f"rank:{rec['path']}", "rank",
                          rec.get("rank_wall_s", 0.0), sim_t=rec["now"],
                          window=rec["window"], top_job=rec["top_job"],
                          placed=rec["placed"], skips=rec.get("skips", {}))

    def on_window_blocked(self, now, queued):
        self._emit({"name": "window-blocked", "ph": "i", "cat": "control",
                    "s": "p", "ts": self._wall_us(), "pid": self.ctrl_pid,
                    "tid": "rank", "args": {"sim_t": now, "queued": queued}})

    # ----------------------------------------------------------- finalize ----
    def finalize(self, now: float | None = None) -> None:
        """Close spans still open at end-of-run (jobs queued or running
        when the stream ended, paused/migrated-away jobs).  Safe on a
        tracer that never emitted a span — e.g. a run that ended with
        every job still queued — where the sim origin is seeded from the
        earliest open timestamp instead of being lost."""
        open_ts = list(self._queued_since.values()) \
            + list(self._running_since.values())
        if self._t0 is None:
            if not open_ts:
                return
            self._t0 = min(open_ts)
        if now is None:
            now = max(open_ts, default=self._t0)
        for jid, q in list(self._queued_since.items()):
            self._job_span("queued", jid, q, max(now, q), open_at_end=True)
        self._queued_since.clear()
        for jid, r in list(self._running_since.items()):
            self._job_span("running", jid, r, max(now, r), open_at_end=True)
        self._running_since.clear()

    def to_document(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"tracer": self.name,
                              "dropped_events": self.dropped,
                              # sim-time origin per job pid: report tooling
                              # maps span ts back to absolute sim seconds
                              "sim_t0": {str(self.job_pid):
                                         self._t0 if self._t0 is not None
                                         else 0.0}}}

    def export(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_document(), fh)
        return path


def merge_documents(docs) -> dict:
    """Merge per-member trace documents into one fleet document (members
    already occupy disjoint pids via the ``member`` offset)."""
    events: list[dict] = []
    dropped = 0
    t0s: dict = {}
    for doc in docs:
        events.extend(doc.get("traceEvents", ()))
        other = doc.get("otherData", {})
        dropped += other.get("dropped_events", 0)
        t0s.update(other.get("sim_t0", {}))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"tracer": "fleet", "dropped_events": dropped,
                          "sim_t0": t0s}}


def validate_trace(doc) -> list[str]:
    """Validate a trace-event document; returns a list of problems (empty
    = valid).  Checks the JSON-object envelope, per-event required keys,
    known phase codes, numeric non-negative ``ts``/``dur``, and that
    complete/instant/counter/metadata events carry the right fields."""
    problems: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    if not events:
        problems.append("'traceEvents' is empty")
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        missing = _REQUIRED_KEYS - ev.keys()
        if missing:
            problems.append(f"{where}: missing keys {sorted(missing)}")
            continue
        ph = ev["ph"]
        if ph not in ("X", "B", "E", "i", "I", "C", "M", "b", "e", "n",
                      "s", "t", "f"):
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: complete event with bad "
                                f"dur {dur!r}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: counter event without args")
        if ph == "M" and "args" not in ev:
            problems.append(f"{where}: metadata event without args")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args is not an object")
    return problems
