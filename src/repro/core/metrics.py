"""Scheduling performance metrics (Sec. 4.4): wait time, JCT, bounded
slowdown, GPU utilization — plus batch-level aggregation used for rewards."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import Job

METRICS = ("wait", "jct", "bsld", "util")


@dataclasses.dataclass
class BatchResult:
    """Outcome of scheduling one batch of jobs."""

    jobs: list[Job]
    makespan: float
    gpu_seconds_used: float
    gpu_seconds_capacity: float
    decisions: int = 0
    milp_calls: int = 0
    backfills: int = 0
    restarts: int = 0

    @property
    def avg_wait(self) -> float:
        return float(np.mean([j.wait_time for j in self.jobs])) if self.jobs else 0.0

    @property
    def total_wait(self) -> float:
        return float(np.sum([j.wait_time for j in self.jobs])) if self.jobs else 0.0

    @property
    def avg_jct(self) -> float:
        return float(np.mean([j.jct for j in self.jobs])) if self.jobs else 0.0

    @property
    def avg_bsld(self) -> float:
        return float(np.mean([j.bsld() for j in self.jobs])) if self.jobs else 0.0

    @property
    def utilization(self) -> float:
        return float(self.gpu_seconds_used / max(self.gpu_seconds_capacity, 1e-9))

    def score(self, metric: str) -> float:
        """Aggregated batch score — LOWER is better for all metrics
        (utilization is negated)."""
        if metric == "wait":
            return self.avg_wait
        if metric == "jct":
            return self.avg_jct
        if metric == "bsld":
            return self.avg_bsld
        if metric == "util":
            return -self.utilization
        raise ValueError(f"unknown metric {metric!r}")


def reward_from_scores(abs_score: float, ars_score: float) -> float:
    """Paper reward: normalized performance gap between the base pipeline
    (ABS) and the RL pipeline (ARS).  Positive when RL beats the baseline.
    Normalization reduces variance across bursty/easy batches (Sec. 3.2)."""
    denom = max(abs(abs_score), 1e-6)
    return float(np.clip((abs_score - ars_score) / denom, -10.0, 10.0))
