"""Trace-driven discrete-event cluster simulator (Sec. 4.1).

Mimics a Slurm-like scheduler loop: jobs arrive, a prioritizer ranks the
queue at every decision point, the allocator (MILP / pack / spread) maps the
top job to nodes, EASY backfilling fills holes without delaying the reserved
top job, and completions free resources.  Heterogeneity: placements on
faster/slower SKUs scale the job's wall runtime.  Optional fault injection
(node failures, stragglers) exercises checkpoint/restart and re-queueing.

Ground-truth runtimes drive the simulation clock; user estimates are only
used by policies/backfill when `use_estimates=True` (evaluation realism).

The event loop itself lives in ``repro.sched.engine.SchedulerEngine`` (the
streaming service mode); ``Simulator.run_batch`` is a thin batch-semantics
wrapper over it — submit everything upfront, run to completion from an idle
cluster — and is bit-identical to the pre-extraction implementation on
fixed seeds.  ``Prioritizer`` / ``PolicyPrioritizer`` are re-exported here
for backwards compatibility.
"""
from __future__ import annotations

from repro.core.faults import FaultModel
from repro.core.metrics import BatchResult
from repro.core.prioritizer import PolicyPrioritizer, Prioritizer
from repro.core.types import ClusterSpec, Job

__all__ = ["Prioritizer", "PolicyPrioritizer", "Simulator"]


class Simulator:
    """Discrete-event simulator for one cluster (batch semantics)."""

    def __init__(
        self,
        spec: ClusterSpec,
        *,
        allocator: str = "milp",          # "milp" | "pack" | "spread" | "greedy"
        backfill: bool = True,
        lookahead_k: int = 8,
        fault_model: FaultModel | None = None,
        straggler_migration: bool = True,
        max_sim_time: float = 90 * 86400.0,
        queue_window: int | None = None,   # None = engine default (2560)
        optimized: bool = True,            # False = naive reference engine
    ):
        self.spec = spec
        self.allocator = allocator
        self.backfill = backfill
        self.lookahead_k = lookahead_k
        self.fault_model = fault_model
        self.straggler_migration = straggler_migration
        self.max_sim_time = max_sim_time
        self.queue_window = queue_window
        self.optimized = optimized

    def make_engine(self, prioritizer: Prioritizer) -> "SchedulerEngine":
        """A fresh streaming engine configured like this simulator."""
        # imported lazily: repro.sched layers on top of repro.core, so the
        # core package must be importable without sched being initialized
        from repro.sched.engine import SchedulerEngine
        return SchedulerEngine(
            self.spec, prioritizer, allocator=self.allocator,
            backfill=self.backfill, lookahead_k=self.lookahead_k,
            fault_model=self.fault_model,
            straggler_migration=self.straggler_migration,
            max_sim_time=self.max_sim_time, queue_window=self.queue_window,
            optimized=self.optimized,
        )

    # ------------------------------------------------------------------ run ----
    def run_batch(self, jobs: list[Job], prioritizer: Prioritizer,
                  start_idle: bool = True) -> BatchResult:
        """Schedule `jobs` to completion from an idle cluster; returns metrics."""
        assert start_idle
        engine = self.make_engine(prioritizer)
        engine.submit(jobs)
        engine.run_until_complete()
        return engine.result()
