"""Trace-driven discrete-event cluster simulator (Sec. 4.1).

Mimics a Slurm-like scheduler loop: jobs arrive, a prioritizer ranks the
queue at every decision point, the allocator (MILP / pack / spread) maps the
top job to nodes, EASY backfilling fills holes without delaying the reserved
top job, and completions free resources.  Heterogeneity: placements on
faster/slower SKUs scale the job's wall runtime.  Optional fault injection
(node failures, stragglers) exercises checkpoint/restart and re-queueing.

Ground-truth runtimes drive the simulation clock; user estimates are only
used by policies/backfill when `use_estimates=True` (evaluation realism).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Protocol

import numpy as np

from repro.core.cluster import ClusterState, Placement
from repro.core.faults import FaultInjector, FaultModel
from repro.core.metrics import BatchResult
from repro.core.milp import choose_allocation
from repro.core.policies import Policy
from repro.core.types import ClusterSpec, Job, JobState


class Prioritizer(Protocol):
    """Ranks the pending queue; index 0 = schedule first."""

    use_estimates: bool

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]: ...
    def observe_finish(self, job: Job) -> None: ...


class PolicyPrioritizer:
    """Adapter: a Table-5 policy as a Prioritizer (lowest score first)."""

    def __init__(self, policy: Policy):
        self.policy = policy
        self.use_estimates = getattr(policy, "use_estimates", False)

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        scores = [self.policy.score(j, now) for j in jobs]
        return list(np.argsort(scores, kind="stable"))

    def observe_finish(self, job: Job) -> None:
        self.policy.observe_finish(job)


class Simulator:
    """Discrete-event simulator for one cluster."""

    def __init__(
        self,
        spec: ClusterSpec,
        *,
        allocator: str = "milp",          # "milp" | "pack" | "spread" | "greedy"
        backfill: bool = True,
        lookahead_k: int = 8,
        fault_model: FaultModel | None = None,
        straggler_migration: bool = True,
        max_sim_time: float = 90 * 86400.0,
    ):
        self.spec = spec
        self.allocator = allocator
        self.backfill = backfill
        self.lookahead_k = lookahead_k
        self.fault_model = fault_model
        self.straggler_migration = straggler_migration
        self.max_sim_time = max_sim_time

    # ------------------------------------------------------------------ run ----
    def run_batch(self, jobs: list[Job], prioritizer: Prioritizer,
                  start_idle: bool = True) -> BatchResult:
        """Schedule `jobs` to completion from an idle cluster; returns metrics."""
        assert start_idle
        cluster = ClusterState(self.spec)
        jobs = sorted(jobs, key=lambda j: j.submit_time)
        t0 = jobs[0].submit_time if jobs else 0.0

        seq = itertools.count()
        events: list[tuple[float, int, str, object]] = []
        for j in jobs:
            heapq.heappush(events, (j.submit_time, next(seq), "arrival", j))

        injector = None
        if self.fault_model is not None:
            horizon = t0 + self.max_sim_time
            injector = FaultInjector(self.fault_model, len(self.spec.nodes), horizon)
            # fault marker events so the clock advances to fault instants
            for (ft, kind, node) in list(injector.events):
                heapq.heappush(events, (ft, next(seq), "fault", node))

        pending: list[Job] = []
        # job_id -> (job, placement, start, finish, speed, remaining_at_start)
        running: dict[int, list] = {}
        remaining: dict[int, float] = {j.job_id: j.runtime for j in jobs}
        completed: list[Job] = []
        gpu_seconds = 0.0
        decisions = milp_calls = backfills = restarts = 0
        slow_nodes: dict[int, float] = {}
        now = t0

        def effective_speed(placement: Placement) -> float:
            sp = min(cluster.speeds[i] * slow_nodes.get(i, 1.0) for i in placement)
            return max(float(sp), 1e-3)

        def start_job(job: Job, placement: Placement) -> None:
            nonlocal gpu_seconds
            cluster.allocate(job, placement)
            speed = effective_speed(placement)
            dur = remaining[job.job_id] / speed
            finish = now + dur
            if job.start_time < 0:
                job.start_time = now
            job.state = JobState.RUNNING
            job.placement = placement
            running[job.job_id] = [job, placement, now, finish, speed]
            heapq.heappush(events, (finish, next(seq), "finish", job.job_id))

        def est_rt(job: Job) -> float:
            rt = job.est_runtime if prioritizer.use_estimates else job.runtime
            return max(rt, 1.0)

        def alloc_for(job: Job, queue_rest: list[Job]) -> Placement | None:
            nonlocal milp_calls
            ways = cluster.candidate_ways(job)
            if not ways:
                return None
            if self.allocator in ("pack", "spread"):
                pl = cluster.find_placement(job, self.allocator)
                if pl is None:  # CPU/mem coupling edge: fall back to the other mode
                    other = "spread" if self.allocator == "pack" else "pack"
                    pl = cluster.find_placement(job, other)
                return pl
            use_solver = self.allocator == "milp"
            if use_solver and len(ways) > 1:
                milp_calls += 1
            res = choose_allocation(cluster, job, ways, queue_rest,
                                    lookahead_k=self.lookahead_k,
                                    use_solver=use_solver)
            return res.placement

    # -- EASY backfill: earliest start for the reserved job -----------------
        def earliest_start(job: Job) -> float:
            free = cluster.free_gpus.copy()
            sim = ClusterState(self.spec)
            sim.free_gpus = free.copy()
            sim.free_cpus = cluster.free_cpus.copy()
            sim.free_mem = cluster.free_mem.copy()
            sim.node_down = cluster.node_down.copy()
            if sim.find_placement(job, "pack") is not None:
                return now
            for jid, (rj, pl, st, fin, sp) in sorted(running.items(),
                                                     key=lambda kv: kv[1][3]):
                sim.release(rj, pl)
                if sim.find_placement(job, "pack") is not None:
                    return fin
            return float("inf")

        def kill_job(jid: int, preserve_ckpt: bool) -> None:
            nonlocal restarts
            job, placement, st, fin, speed = running.pop(jid)
            cluster.release(job, placement)
            elapsed = max(0.0, now - st)
            work_done = elapsed * speed
            if preserve_ckpt and injector is not None:
                k = int(elapsed // self.fault_model.ckpt_interval)
                work_done = min(k * self.fault_model.ckpt_interval * speed,
                                work_done)
            elif not preserve_ckpt:
                work_done = 0.0
            remaining[jid] = max(remaining[jid] - work_done, 1.0)
            job.state = JobState.PENDING
            job.placement = None
            job.restarts += 1
            restarts += 1
            pending.append(job)

        def finish_job(jid: int) -> None:
            nonlocal gpu_seconds
            rec = running.pop(jid, None)
            if rec is None:
                return
            job, placement, st, fin, speed = rec
            cluster.release(job, placement)
            job.finish_time = now
            job.state = JobState.COMPLETED
            gpu_seconds += job.num_gpus * (now - job.start_time)
            completed.append(job)
            prioritizer.observe_finish(job)

        def handle_faults() -> None:
            if injector is None:
                return
            for (ft, kind, node) in injector.pop_due(now):
                if kind == "fail":
                    cluster.fail_node(node)
                    for jid in [jid for jid, rec in running.items()
                                if node in rec[1]]:
                        kill_job(jid, preserve_ckpt=True)
                elif kind == "recover":
                    cluster.recover_node(node)
                elif kind == "slow":
                    slow_nodes[node] = self.fault_model.straggler_slowdown
                    _rescale_running(node)
                elif kind == "unslow":
                    slow_nodes.pop(node, None)
                    _rescale_running(node)

        def _rescale_running(node: int) -> None:
            for jid, rec in list(running.items()):
                job, placement, st, fin, speed = rec
                if node not in placement:
                    continue
                new_speed = effective_speed(placement)
                if self.straggler_migration and new_speed < 0.6 * speed:
                    # checkpoint + re-queue: the scheduler will replace it
                    kill_job(jid, preserve_ckpt=True)
                    continue
                left = max(fin - now, 0.0) * speed / new_speed
                rec[3] = now + left
                rec[4] = new_speed
                heapq.heappush(events, (rec[3], next(seq), "finish", jid))

        def try_schedule() -> None:
            nonlocal decisions, backfills
            while pending:
                pending.sort(key=lambda j: (j.submit_time, j.job_id))
                queue = pending[: 10 * 256]
                if not any(cluster.can_schedule_now(j) for j in queue):
                    return
                order = prioritizer.rank(queue, cluster, now)
                decisions += 1
                top = queue[order[0]]
                rest = [queue[i] for i in order[1:1 + self.lookahead_k]]
                placement = alloc_for(top, rest)
                if placement is not None:
                    pending.remove(top)
                    start_job(top, placement)
                    continue
                if not self.backfill:
                    return
                # EASY backfill under reservation for `top`
                t_res = earliest_start(top)
                progressed = False
                for i in order[1:]:
                    cand = queue[i]
                    if cand.state != JobState.PENDING or cand is top:
                        continue
                    if now + est_rt(cand) > t_res:
                        continue
                    pl = alloc_for(cand, [])
                    if pl is not None:
                        pending.remove(cand)
                        start_job(cand, pl)
                        backfills += 1
                        progressed = True
                if not progressed:
                    return
                # after backfills the reserved job may now fit; loop again
                if not cluster.can_schedule_now(top):
                    return

        # ------------------------------ main loop ------------------------------
        guard = 0
        guard_max = 200 * len(jobs) + 10_000 + \
            (4 * len(injector.events) if injector is not None else 0)
        while len(completed) < len(jobs):
            guard += 1
            assert guard < guard_max, "simulator stuck"
            if not events:
                break
            now, _, kind, payload = heapq.heappop(events)
            # fold in all events at the same instant
            batch_evts = [(kind, payload)]
            while events and events[0][0] <= now + 1e-9:
                _, _, k2, p2 = heapq.heappop(events)
                batch_evts.append((k2, p2))
            handle_faults()
            for k, p in batch_evts:
                if k == "arrival":
                    pending.append(p)
                elif k == "finish":
                    jid = p
                    rec = running.get(jid)
                    if rec is not None and abs(rec[3] - now) < 1e-6:
                        finish_job(jid)
            try_schedule()

        makespan = max((j.finish_time for j in completed), default=now) - t0
        capacity = self.spec.total_gpus * max(makespan, 1e-9)
        return BatchResult(
            jobs=completed, makespan=makespan, gpu_seconds_used=gpu_seconds,
            gpu_seconds_capacity=capacity, decisions=decisions,
            milp_calls=milp_calls, backfills=backfills, restarts=restarts,
        )
