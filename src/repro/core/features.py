"""Feature Building Module (FBM) + heuristic feature sampling (Sec. 3.2).

17 features per job are maintained; a heuristic sampler selects 8 for the
Observation Vector (OV) consumed by the actor and 5 core features for the
Critic Vector (CV).  All values are normalized to keep the RL input bounded.
"""
from __future__ import annotations

import numpy as np

from repro.core.cluster import ClusterState
from repro.core.types import Job

# canonical feature ordering (17 features total, Table 3)
FEATURE_NAMES: tuple[str, ...] = (
    # visible job features
    "job_id", "user", "req_gpus", "vc", "gpu_type_idx",
    "req_time", "submit_time", "req_cpu", "req_mem",
    # cluster characteristics
    "free_nodes", "can_schedule_now", "num_ways_to_schedule",
    # engineered features
    "dsr", "job_size", "urgency", "future_avail", "cff",
)
NUM_FEATURES = len(FEATURE_NAMES)
OV_SIZE = 8       # actor observation features per job
CV_SIZE = 5       # critic features per job
MAX_QUEUE_SIZE = 256

_IDX = {n: i for i, n in enumerate(FEATURE_NAMES)}

# the five core critic features (submit time, run time, can_schedule_now, ...)
CV_FEATURES = ("submit_time", "req_time", "can_schedule_now", "req_gpus", "urgency")


def _norm(x: float, scale: float) -> float:
    """Squash to [0, 1) with a soft scale (robust to heavy tails)."""
    return float(x / (x + scale)) if x > 0 else 0.0


def build_features(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    *,
    use_estimates: bool = False,
) -> np.ndarray:
    """(len(jobs), 17) feature matrix for the current queue at time `now`."""
    n = len(jobs)
    out = np.zeros((n, NUM_FEATURES), dtype=np.float32)
    if n == 0:
        return out

    total_free = float(cluster.free_gpus[~cluster.node_down].sum())
    free_nodes = int(((cluster.free_gpus == cluster.total_gpus) & ~cluster.node_down).sum())
    cff = cluster.fragmentation()
    gpu_types = sorted(set(cluster.gpu_types)) + ["any"]
    # total demand pending per type (for future availability Eq. (2))
    queued_demand = sum(j.num_gpus for j in jobs)

    for k, j in enumerate(jobs):
        rt = j.est_runtime if use_estimates else j.runtime
        wait = max(0.0, now - j.submit_time)
        ways = cluster.num_ways_to_schedule(j)

        free_t = cluster.free_gpus_of_type(j.gpu_type)
        # Eq. (1): demand-supply ratio for the requested type, normalized
        dsr = _norm(j.num_gpus / max(free_t, 1), 1.0)
        # Eq. (2): expected free GPUs after placing this job and the rest of
        # the queue's demand, normalized to [-1, 1] by total capacity
        fa = (total_free - j.num_gpus - (queued_demand - j.num_gpus)) \
            / max(float(cluster.total_gpus.sum()), 1.0)
        # job size & urgency
        size = _norm(j.num_gpus * rt, 8.0 * 3600.0 * 8.0)
        urgency = _norm(wait / max(rt, 60.0), 4.0)

        out[k, _IDX["job_id"]] = j.job_id % 1000 / 1000.0
        out[k, _IDX["user"]] = (j.user % 128) / 128.0
        out[k, _IDX["req_gpus"]] = _norm(j.num_gpus, 8.0)
        out[k, _IDX["vc"]] = j.vc / 8.0
        out[k, _IDX["gpu_type_idx"]] = gpu_types.index(j.gpu_type) / max(len(gpu_types), 1)
        out[k, _IDX["req_time"]] = _norm(rt, 8 * 3600.0)
        out[k, _IDX["submit_time"]] = _norm(wait, 3600.0)   # age since submission
        out[k, _IDX["req_cpu"]] = _norm(j.req_cpus, 64.0)
        out[k, _IDX["req_mem"]] = _norm(j.req_mem_gb, 512.0)
        out[k, _IDX["free_nodes"]] = free_nodes / max(len(cluster.gpu_types), 1)
        out[k, _IDX["can_schedule_now"]] = 1.0 if ways > 0 else 0.0
        out[k, _IDX["num_ways_to_schedule"]] = ways / 4.0
        out[k, _IDX["dsr"]] = dsr
        out[k, _IDX["job_size"]] = size
        out[k, _IDX["urgency"]] = urgency
        out[k, _IDX["future_avail"]] = np.clip(fa, -1.0, 1.0)
        out[k, _IDX["cff"]] = cff
    return out


def sample_features(feats: np.ndarray, cluster: ClusterState) -> tuple[np.ndarray, list[str]]:
    """Heuristic feature sampling: pick the 8 most situationally relevant
    features (Sec. 3.2).  Returns (n, 8) OV plus the chosen feature names.

    - high fragmentation  -> weight job_size (short jobs fill fragmented nodes)
    - low fragmentation   -> weight urgency (boost aged jobs)
    - flexible placements -> weight num_ways_to_schedule
    """
    cff = cluster.fragmentation()
    base = ["req_gpus", "req_time", "submit_time", "can_schedule_now",
            "dsr", "future_avail"]
    if cff > 0.5:
        chosen = base + ["job_size", "num_ways_to_schedule"]
        weights = {"job_size": 1.5, "num_ways_to_schedule": 1.25}
    else:
        chosen = base + ["urgency", "num_ways_to_schedule"]
        weights = {"urgency": 1.5, "num_ways_to_schedule": 1.25}
    idx = [_IDX[n] for n in chosen]
    ov = feats[:, idx].copy()
    for j, name in enumerate(chosen):
        ov[:, j] *= weights.get(name, 1.0)
    return ov.astype(np.float32), chosen


def critic_features(feats: np.ndarray) -> np.ndarray:
    """(n, 5) critic vector (submit time, run time, can_schedule_now, ...)."""
    idx = [_IDX[n] for n in CV_FEATURES]
    return feats[:, idx].astype(np.float32)


def pad_to_queue(x: np.ndarray, width: int, max_queue: int = MAX_QUEUE_SIZE) -> np.ndarray:
    """Zero-pad (n, width) -> (max_queue, width); truncates overflow."""
    out = np.zeros((max_queue, width), dtype=np.float32)
    n = min(x.shape[0], max_queue)
    if n:
        out[:n] = x[:n]
    return out


def build_state(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    *,
    use_estimates: bool = False,
    raw: bool = False,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Full state construction: returns (OV [256,8], CV [256,5], mask [256]).

    raw=True is the naive-RLTune ablation: the first 8 raw trace features are
    used directly with no engineering or sampling (Fig. 10).
    """
    feats = build_features(jobs, cluster, now, use_estimates=use_estimates)
    if raw:
        ov = feats[:, :OV_SIZE]
    else:
        ov, _ = sample_features(feats, cluster)
    cv = critic_features(feats)
    mask = np.zeros((MAX_QUEUE_SIZE,), dtype=np.float32)
    mask[:min(len(jobs), MAX_QUEUE_SIZE)] = 1.0
    return pad_to_queue(ov, OV_SIZE), pad_to_queue(cv, CV_SIZE), mask
