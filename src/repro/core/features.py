"""Feature Building Module (FBM) + heuristic feature sampling (Sec. 3.2).

17 features per job are maintained; a heuristic sampler selects 8 for the
Observation Vector (OV) consumed by the actor and 5 core features for the
Critic Vector (CV).  All values are normalized to keep the RL input bounded.

Two construction paths, bit-identical by contract (differential-pinned in
``tests/test_features.py``):

- the retained scalar loop (O(window * 17) Python work per decision) — the
  reference, and the fallback when no field arrays are available;
- a vectorized path over the engine's incrementally-maintained
  ``WindowFields`` views (``fields=...``): all arithmetic features become
  whole-column numpy ops; only the placement-dependent ``ways`` query (one
  memoized call per distinct job *shape*, not per job) and the non-numeric
  gathers (``gpu_type`` strings, CPU/mem requests) stay per-job.  Float
  results are identical because every vector op applies the same IEEE
  operation to the same float64 operands the scalar loop used, in the same
  order, before the single float32 store.
"""
from __future__ import annotations

import numpy as np

from repro.core.cluster import ClusterState
from repro.core.prioritizer import WindowFields
from repro.core.types import Job

# canonical feature ordering (17 features total, Table 3)
FEATURE_NAMES: tuple[str, ...] = (
    # visible job features
    "job_id", "user", "req_gpus", "vc", "gpu_type_idx",
    "req_time", "submit_time", "req_cpu", "req_mem",
    # cluster characteristics
    "free_nodes", "can_schedule_now", "num_ways_to_schedule",
    # engineered features
    "dsr", "job_size", "urgency", "future_avail", "cff",
)
NUM_FEATURES = len(FEATURE_NAMES)
OV_SIZE = 8       # actor observation features per job
CV_SIZE = 5       # critic features per job
MAX_QUEUE_SIZE = 256

_IDX = {n: i for i, n in enumerate(FEATURE_NAMES)}

# the five core critic features (submit time, run time, can_schedule_now, ...)
CV_FEATURES = ("submit_time", "req_time", "can_schedule_now", "req_gpus", "urgency")


def _norm(x: float, scale: float) -> float:
    """Squash to [0, 1) with a soft scale (robust to heavy tails)."""
    return float(x / (x + scale)) if x > 0 else 0.0


def build_features(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    *,
    use_estimates: bool = False,
    fields: WindowFields | None = None,
) -> np.ndarray:
    """(len(jobs), 17) feature matrix for the current queue at time `now`.

    With ``fields`` (the engine's ``WindowFields`` views, aligned
    index-for-index with ``jobs``) the matrix is built with vectorized
    column ops; otherwise the retained scalar reference loop runs.  Both
    paths are bit-identical (differential-pinned)."""
    if fields is not None and len(jobs) == fields.submit_time.shape[0]:
        return _build_features_vec(jobs, cluster, now, fields,
                                   use_estimates=use_estimates)
    return _build_features_scalar(jobs, cluster, now,
                                  use_estimates=use_estimates)


def _build_features_scalar(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    *,
    use_estimates: bool = False,
) -> np.ndarray:
    n = len(jobs)
    out = np.zeros((n, NUM_FEATURES), dtype=np.float32)
    if n == 0:
        return out

    # placeable capacity only: free GPUs on cordoned/retired nodes cannot
    # host anything, and retired capacity is no longer provisioned — the
    # policy state must not overstate supply after an autoscaler scale-down
    # (identical to the raw masks whenever autoscaling never acted)
    placeable = cluster.placeable_mask()
    total_free = float(cluster.free_gpus[placeable].sum())
    free_nodes = int(((cluster.free_gpus == cluster.total_gpus)
                      & placeable).sum())
    total_capacity = max(float(cluster.provisioned_gpu_totals()[0]), 1.0)
    cff = cluster.fragmentation()
    gpu_types = sorted(set(cluster.gpu_types)) + ["any"]
    # total demand pending per type (for future availability Eq. (2))
    queued_demand = sum(j.num_gpus for j in jobs)

    for k, j in enumerate(jobs):
        rt = j.est_runtime if use_estimates else j.runtime
        wait = max(0.0, now - j.submit_time)
        ways = cluster.num_ways_to_schedule(j)

        free_t = cluster.free_gpus_of_type(j.gpu_type)
        # Eq. (1): demand-supply ratio for the requested type, normalized
        dsr = _norm(j.num_gpus / max(free_t, 1), 1.0)
        # Eq. (2): expected free GPUs after placing this job and the rest of
        # the queue's demand, normalized to [-1, 1] by provisioned capacity
        fa = (total_free - j.num_gpus - (queued_demand - j.num_gpus)) \
            / total_capacity
        # job size & urgency
        size = _norm(j.num_gpus * rt, 8.0 * 3600.0 * 8.0)
        urgency = _norm(wait / max(rt, 60.0), 4.0)

        out[k, _IDX["job_id"]] = j.job_id % 1000 / 1000.0
        out[k, _IDX["user"]] = (j.user % 128) / 128.0
        out[k, _IDX["req_gpus"]] = _norm(j.num_gpus, 8.0)
        out[k, _IDX["vc"]] = j.vc / 8.0
        out[k, _IDX["gpu_type_idx"]] = gpu_types.index(j.gpu_type) / max(len(gpu_types), 1)
        out[k, _IDX["req_time"]] = _norm(rt, 8 * 3600.0)
        out[k, _IDX["submit_time"]] = _norm(wait, 3600.0)   # age since submission
        out[k, _IDX["req_cpu"]] = _norm(j.req_cpus, 64.0)
        out[k, _IDX["req_mem"]] = _norm(j.req_mem_gb, 512.0)
        out[k, _IDX["free_nodes"]] = free_nodes / max(len(cluster.gpu_types), 1)
        out[k, _IDX["can_schedule_now"]] = 1.0 if ways > 0 else 0.0
        out[k, _IDX["num_ways_to_schedule"]] = ways / 4.0
        out[k, _IDX["dsr"]] = dsr
        out[k, _IDX["job_size"]] = size
        out[k, _IDX["urgency"]] = urgency
        out[k, _IDX["future_avail"]] = np.clip(fa, -1.0, 1.0)
        out[k, _IDX["cff"]] = cff
    # NaN/inf guard: corrupt trace fields (inf est_runtime, NaN memory)
    # must not poison a whole policy/predictor batch; identity on finite
    # inputs, so well-formed paths are bit-unchanged
    return np.nan_to_num(out, nan=0.0, posinf=1.0, neginf=-1.0)


def _vnorm(x: np.ndarray, scale: float) -> np.ndarray:
    """Vectorized ``_norm``: same IEEE divide where x > 0, exact 0 elsewhere
    (all feature inputs are >= 0, so x + scale never hits zero)."""
    return np.where(x > 0, x / (x + scale), 0.0)


def _build_features_vec(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    fields: WindowFields,
    *,
    use_estimates: bool = False,
) -> np.ndarray:
    """Vectorized FBM over the engine's contiguous field arrays.  Scalars
    that the loop recomputed per job (cluster aggregates, queued demand)
    are hoisted; per-job Python work shrinks to the placement-dependent
    ``ways`` query (memoized per distinct job shape) and the non-numeric
    gathers (``gpu_type``, CPU/mem requests) the field views don't carry."""
    n = len(jobs)
    out = np.zeros((n, NUM_FEATURES), dtype=np.float32)
    if n == 0:
        return out

    # same placeable/provisioned capacity view as the scalar reference
    placeable = cluster.placeable_mask()
    total_free = float(cluster.free_gpus[placeable].sum())
    free_nodes = int(((cluster.free_gpus == cluster.total_gpus)
                      & placeable).sum())
    total_capacity = max(float(cluster.provisioned_gpu_totals()[0]), 1.0)
    cff = cluster.fragmentation()
    gpu_types = sorted(set(cluster.gpu_types)) + ["any"]
    tindex = {t: i for i, t in enumerate(gpu_types)}
    # the scalar loop sums python ints; fields carry exact integer-valued
    # float64, so the float sum is the same value converted
    queued_demand = float(fields.num_gpus.sum())

    rt = fields.est_runtime if use_estimates else fields.runtime
    gpus = fields.num_gpus
    wait = np.maximum(0.0, now - fields.submit_time)

    # per-job placement queries: one memoized call per distinct shape
    jt = [j.gpu_type for j in jobs]
    ways = np.empty(n, dtype=np.float64)
    shape_ways: dict[tuple, int] = {}
    for k, j in enumerate(jobs):
        key = (j.num_gpus, j.gpu_type, j.req_cpus, j.req_mem_gb)
        w = shape_ways.get(key)
        if w is None:
            w = cluster.num_ways_to_schedule(j)
            shape_ways[key] = w
        ways[k] = w
    free_t_map = {t: cluster.free_gpus_of_type(t) for t in set(jt)}
    free_t = np.array([free_t_map[t] for t in jt], dtype=np.float64)
    type_idx = np.array([tindex[t] for t in jt], dtype=np.float64)
    req_cpus = np.array([j.req_cpus for j in jobs], dtype=np.float64)
    req_mem = np.array([j.req_mem_gb for j in jobs], dtype=np.float64)
    job_ids = np.array([j.job_id for j in jobs], dtype=np.float64)

    fa = (total_free - gpus - (queued_demand - gpus)) / total_capacity

    out[:, _IDX["job_id"]] = np.mod(job_ids, 1000.0) / 1000.0
    out[:, _IDX["user"]] = np.mod(fields.user, 128.0) / 128.0
    out[:, _IDX["req_gpus"]] = _vnorm(gpus, 8.0)
    out[:, _IDX["vc"]] = fields.vc / 8.0
    out[:, _IDX["gpu_type_idx"]] = type_idx / max(len(gpu_types), 1)
    out[:, _IDX["req_time"]] = _vnorm(rt, 8 * 3600.0)
    out[:, _IDX["submit_time"]] = _vnorm(wait, 3600.0)
    out[:, _IDX["req_cpu"]] = _vnorm(req_cpus, 64.0)
    out[:, _IDX["req_mem"]] = _vnorm(req_mem, 512.0)
    out[:, _IDX["free_nodes"]] = free_nodes / max(len(cluster.gpu_types), 1)
    out[:, _IDX["can_schedule_now"]] = (ways > 0).astype(np.float32)
    out[:, _IDX["num_ways_to_schedule"]] = ways / 4.0
    out[:, _IDX["dsr"]] = _vnorm(gpus / np.maximum(free_t, 1.0), 1.0)
    out[:, _IDX["job_size"]] = _vnorm(gpus * rt, 8.0 * 3600.0 * 8.0)
    out[:, _IDX["urgency"]] = _vnorm(wait / np.maximum(rt, 60.0), 4.0)
    out[:, _IDX["future_avail"]] = np.clip(fa, -1.0, 1.0)
    out[:, _IDX["cff"]] = cff
    # same NaN/inf guard as the scalar reference (identity on finite values)
    return np.nan_to_num(out, nan=0.0, posinf=1.0, neginf=-1.0)


def sample_features(feats: np.ndarray, cluster: ClusterState) -> tuple[np.ndarray, list[str]]:
    """Heuristic feature sampling: pick the 8 most situationally relevant
    features (Sec. 3.2).  Returns (n, 8) OV plus the chosen feature names.

    - high fragmentation  -> weight job_size (short jobs fill fragmented nodes)
    - low fragmentation   -> weight urgency (boost aged jobs)
    - flexible placements -> weight num_ways_to_schedule
    """
    cff = cluster.fragmentation()
    base = ["req_gpus", "req_time", "submit_time", "can_schedule_now",
            "dsr", "future_avail"]
    if cff > 0.5:
        chosen = base + ["job_size", "num_ways_to_schedule"]
        weights = {"job_size": 1.5, "num_ways_to_schedule": 1.25}
    else:
        chosen = base + ["urgency", "num_ways_to_schedule"]
        weights = {"urgency": 1.5, "num_ways_to_schedule": 1.25}
    idx = [_IDX[n] for n in chosen]
    ov = feats[:, idx].copy()
    for j, name in enumerate(chosen):
        ov[:, j] *= weights.get(name, 1.0)
    return ov.astype(np.float32), chosen


def critic_features(feats: np.ndarray) -> np.ndarray:
    """(n, 5) critic vector (submit time, run time, can_schedule_now, ...)."""
    idx = [_IDX[n] for n in CV_FEATURES]
    return feats[:, idx].astype(np.float32)


def pad_to_queue(x: np.ndarray, width: int, max_queue: int = MAX_QUEUE_SIZE) -> np.ndarray:
    """Zero-pad (n, width) -> (max_queue, width); truncates overflow."""
    out = np.zeros((max_queue, width), dtype=np.float32)
    n = min(x.shape[0], max_queue)
    if n:
        out[:n] = x[:n]
    return out


def build_state(
    jobs: list[Job],
    cluster: ClusterState,
    now: float,
    *,
    use_estimates: bool = False,
    raw: bool = False,
    fields: WindowFields | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Full state construction: returns (OV [256,8], CV [256,5], mask [256]).

    raw=True is the naive-RLTune ablation: the first 8 raw trace features are
    used directly with no engineering or sampling (Fig. 10).  ``fields``
    selects the vectorized FBM over engine-maintained field arrays
    (bit-identical to the scalar loop).
    """
    feats = build_features(jobs, cluster, now, use_estimates=use_estimates,
                           fields=fields)
    if raw:
        ov = feats[:, :OV_SIZE]
    else:
        ov, _ = sample_features(feats, cluster)
    cv = critic_features(feats)
    mask = np.zeros((MAX_QUEUE_SIZE,), dtype=np.float32)
    mask[:min(len(jobs), MAX_QUEUE_SIZE)] = 1.0
    return pad_to_queue(ov, OV_SIZE), pad_to_queue(cv, CV_SIZE), mask
