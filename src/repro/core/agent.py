"""PPO Actor-Critic agent in JAX (Sec. 3.2, Fig. 9).

The actor is a 3-layer MLP applied per-job with shared weights (the paper's
"sliding-window" evaluation) over the 8-feature Observation Vector; a softmax
over the queue yields normalized priorities.  The critic is a 3-layer MLP over
the flattened 5-feature Critic Vector (all jobs at once) estimating the batch
return.  MAX_QUEUE_SIZE = 256 with zero-padding keeps state/action spaces
fixed.  Training uses PPO-clip over one of two reward pathways:

- **terminal** (paper-faithful, ``finish_episode``): the sparse batch reward
  is the normalized base-vs-RL performance gap, assigned to every step
  (gamma = 1); pinned bit-identical for the legacy batch trainer.
- **dense** (``finish_episode_dense``, used by ``repro.rl``): per-step shaped
  rewards from rolling-telemetry deltas with GAE(gamma, lambda) advantages —
  the streaming-episode pathway.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import CV_SIZE, MAX_QUEUE_SIZE, OV_SIZE

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    actor_hidden: tuple[int, int] = (64, 32)
    critic_hidden: tuple[int, int] = (128, 64)
    lr: float = 3e-4
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    update_epochs: int = 4
    max_grad_norm: float = 0.5
    max_steps: int = 512          # trajectory padding length
    episodes_per_update: int = 1  # >1: batch episodes before PPO (beyond-paper
    #                               variance reduction; 1 = paper-faithful)
    gamma: float = 0.99           # dense-reward discount (GAE pathway only;
    gae_lambda: float = 0.95      #  the terminal pathway stays gamma = 1)
    seed: int = 0


# ------------------------------------------------------------------ networks ----


def _mlp_init(key: jax.Array, sizes: list[int], scale: float = 1.0) -> list[dict]:
    layers = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        s = scale if i == len(sizes) - 2 else 1.0
        w = jax.random.normal(sub, (fan_in, fan_out)) * s * jnp.sqrt(2.0 / fan_in)
        layers.append({"w": w, "b": jnp.zeros((fan_out,))})
    return layers


def _mlp_apply(layers: list[dict], x: jnp.ndarray) -> jnp.ndarray:
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(layers) - 1:
            x = jnp.tanh(x)
    return x


def init_params(cfg: PPOConfig, key: jax.Array | None = None) -> Params:
    key = jax.random.PRNGKey(cfg.seed) if key is None else key
    ka, kc = jax.random.split(key)
    h1, h2 = cfg.actor_hidden
    c1, c2 = cfg.critic_hidden
    return {
        "actor": _mlp_init(ka, [OV_SIZE, h1, h2, 1], scale=0.01),
        "critic": _mlp_init(kc, [MAX_QUEUE_SIZE * CV_SIZE, c1, c2, 1], scale=0.1),
    }


def actor_logits(params: Params, ov: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """(Q, 8), (Q,) -> masked logits (Q,).  Shared MLP per job (sliding window)."""
    logits = _mlp_apply(params["actor"], ov)[..., 0]
    return jnp.where(mask > 0, logits, -1e9)


def value(params: Params, cv: jnp.ndarray) -> jnp.ndarray:
    """(Q, 5) -> scalar value estimate."""
    return _mlp_apply(params["critic"], cv.reshape(-1))[0]


@functools.partial(jax.jit, static_argnames=())
def policy_step(params: Params, ov: jnp.ndarray, cv: jnp.ndarray,
                mask: jnp.ndarray, key: jax.Array) -> dict[str, jnp.ndarray]:
    """One decision: sample an action (job index), return logp/value/logits."""
    logits = actor_logits(params, ov, mask)
    action = jax.random.categorical(key, logits)
    logp = jax.nn.log_softmax(logits)[action]
    return {"action": action, "logp": logp, "value": value(params, cv),
            "logits": logits}


@jax.jit
def greedy_step(params: Params, ov: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Deterministic ranking (descending priority) for evaluation."""
    logits = actor_logits(params, ov, mask)
    return jnp.argsort(-logits)


# ---------------------------------------------------------------------- Adam -----


def adam_init(params: Params) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params: Params, grads: Params, state: dict, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                max_norm: float = 0.5) -> tuple[Params, dict]:
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                          params, mhat, vhat)
    return params, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------- PPO update ----


def _ppo_loss(params: Params, batch: dict, clip_eps: float, value_coef: float,
              entropy_coef: float) -> jnp.ndarray:
    def per_step(ov, cv, mask, action, old_logp, ret, adv, valid):
        logits = actor_logits(params, ov, mask)
        logp_all = jax.nn.log_softmax(logits)
        logp = logp_all[action]
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps)
        pg = -jnp.minimum(ratio * adv, clipped * adv)
        v = value(params, cv)
        v_loss = (v - ret) ** 2
        probs = jax.nn.softmax(logits)
        ent = -jnp.sum(jnp.where(mask > 0, probs * logp_all, 0.0))
        return valid * (pg + value_coef * v_loss - entropy_coef * ent)

    losses = jax.vmap(per_step)(
        batch["ov"], batch["cv"], batch["mask"], batch["action"],
        batch["logp"], batch["ret"], batch["adv"], batch["valid"])
    return jnp.sum(losses) / jnp.maximum(jnp.sum(batch["valid"]), 1.0)


@functools.partial(jax.jit, static_argnames=("clip_eps", "value_coef",
                                             "entropy_coef", "lr", "max_norm"))
def ppo_update_step(params: Params, opt_state: dict, batch: dict, *,
                    clip_eps: float, value_coef: float, entropy_coef: float,
                    lr: float, max_norm: float) -> tuple[Params, dict, jnp.ndarray]:
    loss, grads = jax.value_and_grad(_ppo_loss)(
        params, batch, clip_eps, value_coef, entropy_coef)
    params, opt_state = adam_update(params, grads, opt_state, lr,
                                    max_norm=max_norm)
    return params, opt_state, loss


def gae_advantages(rewards: np.ndarray, values: np.ndarray,
                   bootstrap_value: float, gamma: float,
                   lam: float) -> np.ndarray:
    """Generalized Advantage Estimation over one episode.

    ``bootstrap_value`` is V(s_{T+1}) for truncated episodes (0.0 for
    terminal ones): adv_t = sum_l (gamma*lam)^l * delta_{t+l} with
    delta_t = r_t + gamma * V_{t+1} - V_t.
    """
    T = len(rewards)
    adv = np.zeros(T, dtype=np.float32)
    last = 0.0
    nxt = float(bootstrap_value)
    for t in range(T - 1, -1, -1):
        delta = float(rewards[t]) + gamma * nxt - float(values[t])
        last = delta + gamma * lam * last
        adv[t] = last
        nxt = float(values[t])
    return adv


_TRAJ_KEYS = ("ov", "cv", "mask", "action", "logp", "value")


class PPOAgent:
    """Stateful wrapper: rollout recording + PPO updates."""

    def __init__(self, cfg: PPOConfig | None = None, key: jax.Array | None = None):
        self.cfg = cfg or PPOConfig()
        self.params = init_params(self.cfg, key)
        self.opt_state = adam_init(self.params)
        self._key = jax.random.PRNGKey(self.cfg.seed + 1)
        self.reset_buffer()

    # ------------------------------------------------------------- rollout ----
    def reset_buffer(self) -> None:
        self._traj: dict[str, list] = {k: [] for k in _TRAJ_KEYS}
        if not hasattr(self, "_episodes"):
            self._episodes: list[tuple[dict, float]] = []
        if not hasattr(self, "_dense"):
            # (traj, per-step rewards, bootstrap value) per dense episode
            self._dense: list[tuple[dict, np.ndarray, float]] = []

    @property
    def rollout_len(self) -> int:
        """Steps recorded in the open (unfinished) episode."""
        return len(self._traj["action"])

    @property
    def rollout_values(self) -> list[float]:
        """Critic value estimates of the open episode's recorded steps."""
        return list(self._traj["value"])

    def act(self, ov: np.ndarray, cv: np.ndarray, mask: np.ndarray,
            explore: bool = True, record: bool = True) -> tuple[int, np.ndarray]:
        """Returns (chosen index, full logits) and records the step."""
        if explore:
            self._key, sub = jax.random.split(self._key)
            out = policy_step(self.params, jnp.asarray(ov), jnp.asarray(cv),
                              jnp.asarray(mask), sub)
            action = int(out["action"])
            if record:
                self._traj["ov"].append(ov)
                self._traj["cv"].append(cv)
                self._traj["mask"].append(mask)
                self._traj["action"].append(action)
                self._traj["logp"].append(float(out["logp"]))
                self._traj["value"].append(float(out["value"]))
            return action, np.asarray(out["logits"])
        order = greedy_step(self.params, jnp.asarray(ov), jnp.asarray(mask))
        logits = np.zeros(mask.shape, dtype=np.float32)
        logits[np.asarray(order)] = -np.arange(len(mask), dtype=np.float32)
        return int(order[0]), logits

    # -------------------------------------------------------------- update ----
    def _run_update(self, cat: dict[str, list], rets: np.ndarray,
                    adv: np.ndarray, Tc: int) -> float:
        """Pad the concatenated rollout to ``max_steps`` and run the PPO-clip
        epochs.  Shared by the terminal and dense reward pathways; the ops
        are exactly the pre-refactor ``finish_episode`` tail, so the terminal
        path remains bit-identical on fixed seeds."""
        cfg = self.cfg
        P = cfg.max_steps

        def padded(arr, shape, dtype=np.float32):
            out = np.zeros((P,) + shape, dtype=dtype)
            out[:Tc] = np.asarray(arr[:Tc], dtype=dtype)
            return out

        batch = {
            "ov": padded(cat["ov"], (MAX_QUEUE_SIZE, OV_SIZE)),
            "cv": padded(cat["cv"], (MAX_QUEUE_SIZE, CV_SIZE)),
            "mask": padded(cat["mask"], (MAX_QUEUE_SIZE,)),
            "action": padded(cat["action"], (), np.int32),
            "logp": padded(cat["logp"], ()),
            "ret": padded(rets, ()),
            "adv": padded(adv, ()),
            "valid": padded(np.ones((Tc,)), ()),
        }
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss = 0.0
        for _ in range(cfg.update_epochs):
            self.params, self.opt_state, loss = ppo_update_step(
                self.params, self.opt_state, batch,
                clip_eps=cfg.clip_eps, value_coef=cfg.value_coef,
                entropy_coef=cfg.entropy_coef, lr=cfg.lr,
                max_norm=cfg.max_grad_norm)
        return float(loss)

    def finish_episode(self, reward: float) -> dict[str, float]:
        """Assign the terminal batch reward to every step (gamma = 1, sparse
        terminal reward => return_t = R).  With episodes_per_update > 1,
        episodes are pooled before the PPO update (variance reduction)."""
        T = len(self._traj["action"])
        steps = T
        if T:
            self._episodes.append((self._traj, reward))
        self._traj = {k: [] for k in _TRAJ_KEYS}
        if not self._episodes or \
                len(self._episodes) < self.cfg.episodes_per_update:
            return {"loss": 0.0, "steps": steps, "updated": 0.0}
        cfg = self.cfg
        P = cfg.max_steps

        # concatenate pooled episodes (truncate to the padding budget)
        cat: dict[str, list] = {k: [] for k in _TRAJ_KEYS}
        rets_l: list[float] = []
        for traj, rew in self._episodes:
            n = len(traj["action"])
            for k in cat:
                cat[k].extend(traj[k])
            rets_l.extend([rew] * n)
        Tc = min(len(cat["action"]), P)

        values = np.asarray(cat["value"][:Tc], dtype=np.float32)
        rets = np.asarray(rets_l[:Tc], dtype=np.float32)
        # NOTE: no per-episode advantage normalization — with a constant
        # terminal reward it would divide by the (tiny) std of the value
        # net's noise and blow up the gradient.  The critic is the baseline.
        adv = np.clip(rets - values, -5.0, 5.0)

        loss = self._run_update(cat, rets, adv, Tc)
        self._episodes = []
        return {"loss": loss, "steps": steps, "updated": 1.0}

    def finish_episode_dense(self, rewards, *,
                             bootstrap_value: float = 0.0) -> dict[str, float]:
        """Close the open episode with **per-step dense rewards** and run a
        GAE(gamma, lambda) PPO update (the streaming pathway, ``repro.rl``).

        ``rewards`` must have one entry per recorded step;
        ``bootstrap_value`` is V(s_{T+1}) for truncated (non-terminal)
        episodes.  Advantages are normalized per update — safe here because
        shaped rewards vary step to step (contrast the terminal pathway's
        constant-reward note) — then clipped like the terminal path.
        Respects ``episodes_per_update`` pooling.
        """
        T = len(self._traj["action"])
        rewards = np.asarray(rewards, dtype=np.float32)
        if rewards.shape != (T,):
            raise ValueError(f"got {rewards.shape[0] if rewards.ndim else 0} "
                             f"rewards for {T} recorded steps")
        steps = T
        if T:
            self._dense.append((self._traj, rewards, float(bootstrap_value)))
        self._traj = {k: [] for k in _TRAJ_KEYS}
        if not self._dense or len(self._dense) < self.cfg.episodes_per_update:
            return {"loss": 0.0, "steps": steps, "updated": 0.0,
                    "mean_reward": float(rewards.mean()) if T else 0.0}
        cfg = self.cfg

        cat: dict[str, list] = {k: [] for k in _TRAJ_KEYS}
        rets_l: list[np.ndarray] = []
        advs_l: list[np.ndarray] = []
        rews_l: list[np.ndarray] = []
        for traj, rews, boot in self._dense:
            vals = np.asarray(traj["value"], dtype=np.float32)
            adv = gae_advantages(rews, vals, boot, cfg.gamma, cfg.gae_lambda)
            rets_l.append(adv + vals)
            advs_l.append(adv)
            rews_l.append(rews)
            for k in cat:
                cat[k].extend(traj[k])
        Tc = min(len(cat["action"]), cfg.max_steps)
        rets = np.concatenate(rets_l)[:Tc].astype(np.float32)
        adv = np.concatenate(advs_l)[:Tc].astype(np.float32)
        std = float(adv.std())
        if std > 1e-6:
            adv = (adv - float(adv.mean())) / (std + 1e-8)
        adv = np.clip(adv, -5.0, 5.0)

        loss = self._run_update(cat, rets, adv, Tc)
        mean_r = float(np.concatenate(rews_l).mean())
        self._dense = []
        return {"loss": loss, "steps": steps, "updated": 1.0,
                "mean_reward": mean_r}

    # ------------------------------------------------------------- persist ----
    def state_dict(self) -> dict:
        return {"params": jax.tree.map(np.asarray, self.params)}

    def load_state_dict(self, state: dict) -> None:
        self.params = jax.tree.map(jnp.asarray, state["params"])
        self.opt_state = adam_init(self.params)
