"""Legacy import surface for the batch RLTune trainer.

The RL training stack now lives in ``repro.rl``: the batch-pair pipeline
moved (verbatim) to ``repro.rl.batch`` as the terminal-reward special case
of the streaming machinery, and ``repro.rl.trainer.StreamingTrainer`` is
the streaming-episode pathway.  This module re-exports the batch classes so
existing callers (``repro.core``, benchmarks, tests) keep working — behavior
is pinned bit-identical on fixed seeds by ``tests/test_system.py`` and the
engine seed goldens.
"""
from repro.rl.batch import (EpochStats, RLTuneTrainer, TrainerConfig,
                            improvement)

__all__ = ["EpochStats", "RLTuneTrainer", "TrainerConfig", "improvement"]
