"""Queue-prioritizer interface shared by the batch simulator and the
streaming engine (leaf module: keeps repro.core <-> repro.sched acyclic)."""
from __future__ import annotations

from typing import Protocol

import numpy as np

from repro.core.cluster import ClusterState
from repro.core.policies import Policy
from repro.core.types import Job


class Prioritizer(Protocol):
    """Ranks the pending queue; index 0 = schedule first."""

    use_estimates: bool

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]: ...
    def observe_finish(self, job: Job) -> None: ...


class PolicyPrioritizer:
    """Adapter: a Table-5 policy as a Prioritizer (lowest score first)."""

    def __init__(self, policy: Policy):
        self.policy = policy
        self.use_estimates = getattr(policy, "use_estimates", False)

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        scores = [self.policy.score(j, now) for j in jobs]
        return list(np.argsort(scores, kind="stable"))

    def observe_finish(self, job: Job) -> None:
        self.policy.observe_finish(job)
