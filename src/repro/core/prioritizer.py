"""Queue-prioritizer interface shared by the batch simulator and the
streaming engine (leaf module: keeps repro.core <-> repro.sched acyclic)."""
from __future__ import annotations

from typing import Protocol

import numpy as np

from repro.core.cluster import ClusterState
from repro.core.policies import Policy
from repro.core.types import Job


class WindowFields:
    """Contiguous float64 arrays of the hot job fields for one ranking
    window, aligned index-for-index with the job list handed to ``rank``.

    The streaming engine maintains these arrays incrementally alongside its
    indexed pending queue and passes O(1) views per decision, so batch
    scoring never re-gathers Python attributes.  Arrays are read-only by
    convention; integer-valued fields (``num_gpus``, ``user``, ``vc``) are
    stored as float64 — exact for any realistic value (< 2**53), and float
    keys hash/compare equal to the original ints so dict-based policy state
    (fair-share usage, runtime history) stays collision-free.
    """

    __slots__ = ("submit_time", "runtime", "est_runtime", "num_gpus",
                 "user", "vc")

    def __init__(self, submit_time: np.ndarray, runtime: np.ndarray,
                 est_runtime: np.ndarray, num_gpus: np.ndarray,
                 user: np.ndarray, vc: np.ndarray):
        self.submit_time = submit_time
        self.runtime = runtime
        self.est_runtime = est_runtime
        self.num_gpus = num_gpus
        self.user = user
        self.vc = vc

    @classmethod
    def from_jobs(cls, jobs: list[Job]) -> "WindowFields":
        return cls(
            np.array([j.submit_time for j in jobs], dtype=np.float64),
            np.array([j.runtime for j in jobs], dtype=np.float64),
            np.array([j.est_runtime for j in jobs], dtype=np.float64),
            np.array([j.num_gpus for j in jobs], dtype=np.float64),
            np.array([j.user for j in jobs], dtype=np.float64),
            np.array([j.vc for j in jobs], dtype=np.float64),
        )

    def take(self, indices: list[int]) -> "WindowFields":
        """Row-subset copy for wrapper prioritizers that rank a partition
        of the window (e.g. the non-SLA lane) through their base."""
        ix = np.asarray(indices, dtype=np.intp)
        return WindowFields(self.submit_time[ix], self.runtime[ix],
                            self.est_runtime[ix], self.num_gpus[ix],
                            self.user[ix], self.vc[ix])


class Prioritizer(Protocol):
    """Ranks the pending queue; index 0 = schedule first.

    Implementations may additionally expose
    ``rank_window(jobs, cluster, now, fields)`` accepting a
    :class:`WindowFields`; the engine uses it when present and falls back
    to ``rank`` otherwise (wrapper prioritizers that reorder sublists keep
    working unchanged)."""

    use_estimates: bool

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]: ...
    def observe_finish(self, job: Job) -> None: ...


def _order(scores: np.ndarray) -> list[int]:
    """Stable lowest-score-first permutation of a float64 score array."""
    # a stable argsort of a non-decreasing array is the identity
    # permutation — the engine's window arrives sorted by
    # (submit_time, job_id), so e.g. FCFS always takes this exit
    if scores.size > 1 and bool((scores[1:] >= scores[:-1]).all()):
        return list(range(scores.size))
    # .tolist() materializes plain ints ~2x faster than list()
    return np.argsort(scores, kind="stable").tolist()


class PolicyPrioritizer:
    """Adapter: a Table-5 policy as a Prioritizer (lowest score first).

    Scores the window with one ``policy.score_batch`` call over contiguous
    job-field arrays when the policy provides it (all built-in policies do,
    bit-identical to the scalar loop); ``batch=False`` forces the per-job
    ``policy.score`` loop — the retained naive reference path used by the
    differential equivalence tests.
    """

    def __init__(self, policy: Policy, batch: bool = True):
        self.policy = policy
        self.use_estimates = getattr(policy, "use_estimates", False)
        self.batch = batch and hasattr(policy, "score_batch")

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        if self.batch:
            return _order(self.policy.score_batch(jobs, now))
        scores = [self.policy.score(j, now) for j in jobs]
        return list(np.argsort(scores, kind="stable"))

    def rank_window(self, jobs: list[Job], cluster: ClusterState, now: float,
                    fields: WindowFields | None) -> list[int]:
        """``rank`` with engine-maintained contiguous field arrays."""
        if self.batch:
            return _order(self.policy.score_batch(jobs, now, fields))
        return self.rank(jobs, cluster, now)

    def observe_finish(self, job: Job) -> None:
        self.policy.observe_finish(job)
