"""Baseline scheduling policies (Table 5) + Slurm multifactor + QSSF.

Each policy maps (job, now) -> score; the simulator schedules the job with the
LOWEST score first (RLScheduler convention).  Runtime `rt` uses the user
estimate when `use_estimates=True` (evaluation) and ground truth otherwise.

Every policy also exposes ``score_batch(jobs, now) -> np.ndarray`` scoring a
whole queue window in one call.  The batch path is **bit-identical** to the
scalar ``score`` loop: it vectorizes only the IEEE-exact operations
(add/sub/mul/div/min/max/negate, which round identically in numpy and
CPython) and routes every transcendental through the *same* ``math.*``
libm call as the scalar path, memoized per distinct input (``np.log10`` et
al. are not bit-identical to ``math.log10`` on SIMD builds, and a 1-ulp
score difference can flip an argsort and change the schedule).
"""
from __future__ import annotations

import math
from operator import attrgetter
from typing import Callable, Protocol

import numpy as np

from repro.core.types import Job

ScoreFn = Callable[[Job, float], float]

# C-level field gather: map(attrgetter) + fromiter fills the array without
# a Python-level loop body (the per-decision cost floor of batch scoring)
_GET_SUBMIT = attrgetter("submit_time")
_GET_RUNTIME = attrgetter("runtime")
_GET_EST = attrgetter("est_runtime")
_GET_GPUS = attrgetter("num_gpus")
_GET_VC = attrgetter("vc")


def _farr(jobs: list[Job], getter) -> np.ndarray:
    return np.fromiter(map(getter, jobs), np.float64, count=len(jobs))


class Policy(Protocol):
    name: str

    def score(self, job: Job, now: float) -> float: ...
    def score_batch(self, jobs: list[Job], now: float,
                    fields: "WindowFields | None" = None) -> np.ndarray: ...
    def observe_finish(self, job: Job) -> None: ...


def _rt(job: Job, use_estimates: bool) -> float:
    return max(job.est_runtime if use_estimates else job.runtime, 1.0)


def _rt_arr(jobs: list[Job], use_estimates: bool, fields=None) -> np.ndarray:
    if fields is not None:
        raw = fields.est_runtime if use_estimates else fields.runtime
    else:
        raw = _farr(jobs, _GET_EST if use_estimates else _GET_RUNTIME)
    return np.maximum(raw, 1.0)


class _Memo(dict):
    """Value-keyed libm memo: ``__missing__`` computes once, after which
    ``map(memo.__getitem__, values)`` runs entirely at C level — the same
    jobs are re-ranked every decision, so warm windows never re-enter
    Python per element.  Bounded: continuous-valued keys (runtimes) would
    otherwise grow without limit on indefinite streams, so the memo resets
    once it hits ``limit`` entries (values are recomputed deterministically,
    so a reset never changes results)."""

    __slots__ = ("_fn", "_limit")

    def __init__(self, fn, limit: int = 1 << 20):
        super().__init__()
        self._fn = fn
        self._limit = limit

    def __missing__(self, key):
        if len(self) >= self._limit:
            self.clear()
        v = self._fn(key)
        self[key] = v
        return v


# memoized libm transcendentals (value-keyed => collision-free, amortized to
# one math.* call per distinct input while the same jobs are re-ranked)
_LOG10 = _Memo(math.log10)
_LOG1P = _Memo(math.log1p)
_LOG2_GPUS = _Memo(lambda g: math.log2(max(g, 2)))


class _FnPolicy:
    """Stateless policy from a scalar score function + exact batch variant."""

    def __init__(self, name: str, fn: Callable[[Job, float, bool], float],
                 batch_fn: Callable[[list[Job], float, bool], np.ndarray],
                 use_estimates: bool = False):
        self.name = name
        self._fn = fn
        self._batch_fn = batch_fn
        self.use_estimates = use_estimates

    def score(self, job: Job, now: float) -> float:
        return self._fn(job, now, self.use_estimates)

    def score_batch(self, jobs: list[Job], now: float,
                    fields=None) -> np.ndarray:
        return self._batch_fn(jobs, now, self.use_estimates, fields)

    def observe_finish(self, job: Job) -> None:  # stateless
        pass


def _fcfs(j: Job, now: float, est: bool) -> float:
    return j.submit_time


def _fcfs_batch(jobs: list[Job], now: float, est: bool,
                fields=None) -> np.ndarray:
    if fields is not None:
        return fields.submit_time
    return _farr(jobs, _GET_SUBMIT)


def _sjf(j: Job, now: float, est: bool) -> float:
    return _rt(j, est)


def _sjf_batch(jobs: list[Job], now: float, est: bool,
               fields=None) -> np.ndarray:
    return _rt_arr(jobs, est, fields)


def _wfp3(j: Job, now: float, est: bool) -> float:
    wt = max(0.0, now - j.submit_time)
    rt = _rt(j, est)
    return -((wt / rt) ** 3) * j.num_gpus


def _wfp3_batch(jobs: list[Job], now: float, est: bool,
                fields=None) -> np.ndarray:
    st = fields.submit_time if fields is not None else _farr(jobs, _GET_SUBMIT)
    g = fields.num_gpus if fields is not None else _farr(jobs, _GET_GPUS)
    x = np.maximum(0.0, now - st) / _rt_arr(jobs, est, fields)
    # `x ** 3` must match CPython's pow(x, 3.0); np.power special-cases small
    # integer exponents differently, so cube through the scalar operator
    cube = np.asarray([v ** 3 for v in x.tolist()], dtype=np.float64)
    return -cube * g


def _unicep(j: Job, now: float, est: bool) -> float:
    wt = max(0.0, now - j.submit_time)
    rt = _rt(j, est)
    return -wt / (math.log2(max(j.num_gpus, 2)) * rt)


def _unicep_batch(jobs: list[Job], now: float, est: bool,
                  fields=None) -> np.ndarray:
    if fields is not None:
        st = fields.submit_time
        # float keys hash/compare equal to the scalar path's int keys and
        # produce the same libm value, so the memo stays collision-free
        gpu_keys = fields.num_gpus.tolist()
    else:
        st = _farr(jobs, _GET_SUBMIT)
        gpu_keys = map(_GET_GPUS, jobs)
    lg = np.fromiter(map(_LOG2_GPUS.__getitem__, gpu_keys),
                     np.float64, count=len(jobs))
    wt = np.maximum(0.0, now - st)
    return -wt / (lg * _rt_arr(jobs, est, fields))


def _f1(j: Job, now: float, est: bool) -> float:
    rt = _rt(j, est)
    st = max(j.submit_time, 1.0)
    return math.log10(rt) * j.num_gpus + 870.0 * math.log10(st)


def _f1_batch(jobs: list[Job], now: float, est: bool,
              fields=None) -> np.ndarray:
    n = len(jobs)
    lrt = np.fromiter(
        map(_LOG10.__getitem__, _rt_arr(jobs, est, fields).tolist()),
        np.float64, count=n)
    # np.maximum(st, 1.0) == max(j.submit_time, 1.0) elementwise (exact)
    st = fields.submit_time if fields is not None else _farr(jobs, _GET_SUBMIT)
    sm = np.maximum(st, 1.0)
    lst = np.fromiter(map(_LOG10.__getitem__, sm.tolist()),
                      np.float64, count=n)
    g = fields.num_gpus if fields is not None else _farr(jobs, _GET_GPUS)
    return lrt * g + 870.0 * lst


class SlurmMultifactor:
    """Slurm's multifactor priority plugin, GPU-adapted (Sec. 5.4).

    priority = w_age*age + w_fairshare*fairshare + w_jobsize*jobsize
             + w_partition*partition + w_qos*qos,  all weights = 1000.
    Higher priority first => score = -priority.
    Fairshare maps CPU fair-share math onto GPU-seconds usage with decay.
    """

    name = "slurm-mf"

    def __init__(self, use_estimates: bool = False, half_life: float = 7 * 86400.0):
        self.use_estimates = use_estimates
        self.half_life = half_life
        self._usage: dict[int, float] = {}   # user -> decayed GPU-seconds
        self._last_decay = 0.0
        self.weights = dict(age=1000.0, fairshare=1000.0, jobsize=1000.0,
                            partition=1000.0, qos=1000.0)

    def _decay(self, now: float) -> None:
        dt = now - self._last_decay
        if dt <= 0:
            return
        f = 0.5 ** (dt / self.half_life)
        for u in self._usage:
            self._usage[u] *= f
        self._last_decay = now

    def _fairshare(self, user: int, total: float) -> float:
        share = self._usage.get(user, 0.0) / total
        return 2.0 ** (-share * 8.0)

    def score(self, job: Job, now: float) -> float:
        self._decay(now)
        age = min(max(0.0, now - job.submit_time) / (7 * 86400.0), 1.0)
        total = sum(self._usage.values()) + 1e-9
        fairshare = self._fairshare(job.user, total)   # low usage => high
        rt = _rt(job, self.use_estimates)
        jobsize = 1.0 / (1.0 + math.log1p(rt / 3600.0))  # requested runtime factor
        partition = 1.0 - (job.vc / 10.0)            # per-queue priority
        qos = 1.0
        w = self.weights
        pri = (w["age"] * age + w["fairshare"] * fairshare + w["jobsize"] * jobsize
               + w["partition"] * partition + w["qos"] * qos)
        return -pri

    def score_batch(self, jobs: list[Job], now: float,
                    fields=None) -> np.ndarray:
        self._decay(now)
        n = len(jobs)
        st = fields.submit_time if fields is not None \
            else _farr(jobs, _GET_SUBMIT)
        age = np.minimum(np.maximum(0.0, now - st) / (7 * 86400.0), 1.0)
        total = sum(self._usage.values()) + 1e-9
        # float user keys (engine field arrays) hash/compare equal to the
        # scalar path's int keys, so usage lookups and the per-user memo
        # stay collision-free and bit-identical
        users = fields.user.tolist() if fields is not None \
            else [j.user for j in jobs]
        fs_by_user = {u: self._fairshare(u, total) for u in set(users)}
        fairshare = np.fromiter(map(fs_by_user.__getitem__, users),
                                np.float64, count=n)
        hours = _rt_arr(jobs, self.use_estimates, fields) / 3600.0
        l1p = np.fromiter(map(_LOG1P.__getitem__, hours.tolist()),
                          np.float64, count=n)
        jobsize = 1.0 / (1.0 + l1p)
        vc = fields.vc if fields is not None else _farr(jobs, _GET_VC)
        partition = 1.0 - vc / 10.0
        qos = 1.0
        w = self.weights
        pri = (w["age"] * age + w["fairshare"] * fairshare
               + w["jobsize"] * jobsize + w["partition"] * partition
               + w["qos"] * qos)
        return -pri

    def observe_finish(self, job: Job) -> None:
        self._usage[job.user] = (self._usage.get(job.user, 0.0)
                                 + job.runtime * job.num_gpus)


class QSSF:
    """Quasi-Shortest-Service-First (Helios, Hu et al. '21).

    Service = predicted_runtime * num_gpus; prediction is history-based:
    the rolling mean of the user's past runtimes (cold-start: user estimate).
    """

    name = "qssf"

    def __init__(self, use_estimates: bool = True, window: int = 16):
        self.use_estimates = use_estimates
        self.window = window
        self._hist: dict[int, list[float]] = {}

    def predict_runtime(self, job: Job) -> float:
        h = self._hist.get(job.user)
        if not h:
            return _rt(job, self.use_estimates)
        return sum(h) / len(h)

    def score(self, job: Job, now: float) -> float:
        return self.predict_runtime(job) * job.num_gpus

    def score_batch(self, jobs: list[Job], now: float,
                    fields=None) -> np.ndarray:
        means = {u: sum(h) / len(h) for u, h in self._hist.items() if h}
        if fields is not None:
            # float user keys hash equal to the history's int keys; the
            # cold-start fallback is _rt_arr's elementwise max (== _rt)
            cold = _rt_arr(jobs, self.use_estimates, fields).tolist()
            pred = np.fromiter(
                (means[u] if u in means else c
                 for u, c in zip(fields.user.tolist(), cold)),
                np.float64, count=len(jobs))
        else:
            pred = np.fromiter(
                (means[j.user] if j.user in means
                 else _rt(j, self.use_estimates) for j in jobs),
                np.float64, count=len(jobs))
        g = fields.num_gpus if fields is not None else _farr(jobs, _GET_GPUS)
        return pred * g

    def observe_finish(self, job: Job) -> None:
        h = self._hist.setdefault(job.user, [])
        h.append(job.runtime)
        if len(h) > self.window:
            h.pop(0)


_FNS: dict[str, tuple[Callable[[Job, float, bool], float],
                      Callable[[list[Job], float, bool], np.ndarray]]] = {
    "fcfs": (_fcfs, _fcfs_batch), "fifo": (_fcfs, _fcfs_batch),
    "sjf": (_sjf, _sjf_batch), "wfp3": (_wfp3, _wfp3_batch),
    "unicep": (_unicep, _unicep_batch), "f1": (_f1, _f1_batch),
}


def make_policy(name: str, use_estimates: bool = False) -> Policy:
    name = name.lower()
    if name in _FNS:
        fn, batch_fn = _FNS[name]
        return _FnPolicy(name, fn, batch_fn, use_estimates)
    if name in ("slurm", "slurm-mf", "multifactor"):
        return SlurmMultifactor(use_estimates)
    if name == "qssf":
        return QSSF(use_estimates)
    raise ValueError(f"unknown policy {name!r}")


BASE_POLICIES = ("fcfs", "sjf", "wfp3", "unicep", "f1", "qssf", "slurm-mf")
