"""Baseline scheduling policies (Table 5) + Slurm multifactor + QSSF.

Each policy maps (job, now) -> score; the simulator schedules the job with the
LOWEST score first (RLScheduler convention).  Runtime `rt` uses the user
estimate when `use_estimates=True` (evaluation) and ground truth otherwise.
"""
from __future__ import annotations

import math
from typing import Callable, Protocol

from repro.core.types import Job

ScoreFn = Callable[[Job, float], float]


class Policy(Protocol):
    name: str

    def score(self, job: Job, now: float) -> float: ...
    def observe_finish(self, job: Job) -> None: ...


def _rt(job: Job, use_estimates: bool) -> float:
    return max(job.est_runtime if use_estimates else job.runtime, 1.0)


class _FnPolicy:
    """Stateless policy from a score function."""

    def __init__(self, name: str, fn: Callable[[Job, float, bool], float],
                 use_estimates: bool = False):
        self.name = name
        self._fn = fn
        self.use_estimates = use_estimates

    def score(self, job: Job, now: float) -> float:
        return self._fn(job, now, self.use_estimates)

    def observe_finish(self, job: Job) -> None:  # stateless
        pass


def _fcfs(j: Job, now: float, est: bool) -> float:
    return j.submit_time


def _sjf(j: Job, now: float, est: bool) -> float:
    return _rt(j, est)


def _wfp3(j: Job, now: float, est: bool) -> float:
    wt = max(0.0, now - j.submit_time)
    rt = _rt(j, est)
    return -((wt / rt) ** 3) * j.num_gpus


def _unicep(j: Job, now: float, est: bool) -> float:
    wt = max(0.0, now - j.submit_time)
    rt = _rt(j, est)
    return -wt / (math.log2(max(j.num_gpus, 2)) * rt)


def _f1(j: Job, now: float, est: bool) -> float:
    rt = _rt(j, est)
    st = max(j.submit_time, 1.0)
    return math.log10(rt) * j.num_gpus + 870.0 * math.log10(st)


class SlurmMultifactor:
    """Slurm's multifactor priority plugin, GPU-adapted (Sec. 5.4).

    priority = w_age*age + w_fairshare*fairshare + w_jobsize*jobsize
             + w_partition*partition + w_qos*qos,  all weights = 1000.
    Higher priority first => score = -priority.
    Fairshare maps CPU fair-share math onto GPU-seconds usage with decay.
    """

    name = "slurm-mf"

    def __init__(self, use_estimates: bool = False, half_life: float = 7 * 86400.0):
        self.use_estimates = use_estimates
        self.half_life = half_life
        self._usage: dict[int, float] = {}   # user -> decayed GPU-seconds
        self._last_decay = 0.0
        self.weights = dict(age=1000.0, fairshare=1000.0, jobsize=1000.0,
                            partition=1000.0, qos=1000.0)

    def _decay(self, now: float) -> None:
        dt = now - self._last_decay
        if dt <= 0:
            return
        f = 0.5 ** (dt / self.half_life)
        for u in self._usage:
            self._usage[u] *= f
        self._last_decay = now

    def score(self, job: Job, now: float) -> float:
        self._decay(now)
        age = min(max(0.0, now - job.submit_time) / (7 * 86400.0), 1.0)
        total = sum(self._usage.values()) + 1e-9
        share = self._usage.get(job.user, 0.0) / total
        fairshare = 2.0 ** (-share * 8.0)            # low usage => high factor
        rt = _rt(job, self.use_estimates)
        jobsize = 1.0 / (1.0 + math.log1p(rt / 3600.0))  # requested runtime factor
        partition = 1.0 - (job.vc / 10.0)            # per-queue priority
        qos = 1.0
        w = self.weights
        pri = (w["age"] * age + w["fairshare"] * fairshare + w["jobsize"] * jobsize
               + w["partition"] * partition + w["qos"] * qos)
        return -pri

    def observe_finish(self, job: Job) -> None:
        self._usage[job.user] = (self._usage.get(job.user, 0.0)
                                 + job.runtime * job.num_gpus)


class QSSF:
    """Quasi-Shortest-Service-First (Helios, Hu et al. '21).

    Service = predicted_runtime * num_gpus; prediction is history-based:
    the rolling mean of the user's past runtimes (cold-start: user estimate).
    """

    name = "qssf"

    def __init__(self, use_estimates: bool = True, window: int = 16):
        self.use_estimates = use_estimates
        self.window = window
        self._hist: dict[int, list[float]] = {}

    def predict_runtime(self, job: Job) -> float:
        h = self._hist.get(job.user)
        if not h:
            return _rt(job, self.use_estimates)
        return sum(h) / len(h)

    def score(self, job: Job, now: float) -> float:
        return self.predict_runtime(job) * job.num_gpus

    def observe_finish(self, job: Job) -> None:
        h = self._hist.setdefault(job.user, [])
        h.append(job.runtime)
        if len(h) > self.window:
            h.pop(0)


_FNS: dict[str, Callable[[Job, float, bool], float]] = {
    "fcfs": _fcfs, "fifo": _fcfs, "sjf": _sjf, "wfp3": _wfp3,
    "unicep": _unicep, "f1": _f1,
}


def make_policy(name: str, use_estimates: bool = False) -> Policy:
    name = name.lower()
    if name in _FNS:
        return _FnPolicy(name, _FNS[name], use_estimates)
    if name in ("slurm", "slurm-mf", "multifactor"):
        return SlurmMultifactor(use_estimates)
    if name == "qssf":
        return QSSF(use_estimates)
    raise ValueError(f"unknown policy {name!r}")


BASE_POLICIES = ("fcfs", "sjf", "wfp3", "unicep", "f1", "qssf", "slurm-mf")
