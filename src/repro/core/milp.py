"""MILP-based allocation optimization (Algorithm 1 of the paper).

A binary variable `x` selects way1 (spreading) vs way2 (packing) for the head
job; a boolean occupancy matrix `CJO` (node x GPU-slot) is constrained by
per-node GPU/CPU/memory capacity; the objective maximizes total GPU occupancy.
Look-ahead: the top-K prioritized queue jobs are modeled as extra integer
allocation layers so the spread-vs-pack choice accounts for upcoming demand
(Sec. 3.2 "current and future job requirements ... across multiple time slots").

The paper uses CVXPY + GLPK_MI; this container has no GLPK, so we solve the
identical formulation with `scipy.optimize.milp` (HiGHS, also exact MI).  A
greedy fragmentation-aware fallback handles solver absence/failure.

Constraint-skeleton memoization
-------------------------------
For a fixed ``(n_nodes, gpn, K)`` the *structure* of the capacity and gang
constraint rows, the variable bounds, the integrality vector, and the
objective template never change between calls — only a handful of values do
(per-node free resources, per-job CPU/mem-per-GPU coefficients, look-ahead
GPU demands).  ``_Skeleton`` preallocates those arrays once per key and
every solve fills the changing entries **in place** instead of rebuilding
dense matrices row by row; only the (small, way-dependent) Algorithm-1
equality block is constructed per call and concatenated in front.  Row
ordering is preserved exactly, so the solver sees the same problem as the
per-call builder (retained as ``_solve_milp_reference`` for the
differential equivalence test); construction cost drops ~2x and the full
solve ~15-20% on helios-sized clusters with K=8 look-ahead.

Skeletons are held per *thread* (``_SKELETONS`` is a ``threading.local``
store with a dict surface): parallel federation stepping solves MILPs from
worker threads concurrently, and the skeleton arrays are filled in place
per solve, so sharing one across threads would race.

Solution cache
--------------
``choose_allocation`` additionally memoizes the full result per
``(job shape, candidate ways, look-ahead shapes, use_solver)`` key at the
current ``(cluster.version, cluster.topo_version)``.  Everything the solve
reads — free resources, eligibility masks, the ways themselves — is a pure
function of shape and version, so a hit is exact; any cluster mutation
bumps the version and drops the whole cache (see
``tests/test_milp.py::test_solution_cache_invalidation``).  Within one
rescan window over a deep queue, repeated job shapes then skip the solver
entirely; ``solution_cache=False`` restores the uncached reference path
(differential-pinned).
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

try:  # pragma: no cover - import guard
    from scipy.optimize import Bounds, LinearConstraint, milp
    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

from repro.core.cluster import ClusterState, Placement, _job_shape
from repro.core.types import Job


@dataclasses.dataclass
class MILPResult:
    placement: Placement
    way_index: int            # 0 = way1 (spread), 1 = way2 (pack)
    objective: float
    used_solver: bool
    lookahead_scheduled: int  # how many look-ahead jobs the solution also fits


def _slot_ranges(ways: list[Placement]) -> list[dict[int, tuple[int, int]]]:
    """Assign disjoint symbolic slot ranges per node for each way so the
    equality constraints of Algorithm 1 never collide on shared nodes."""
    offset: dict[int, int] = {}
    ranges: list[dict[int, tuple[int, int]]] = []
    for way in ways:
        r: dict[int, tuple[int, int]] = {}
        for node, cnt in way.items():
            s = offset.get(node, 0)
            r[node] = (s, s + cnt)
            offset[node] = s + cnt
        ranges.append(r)
    return ranges


def _lookahead_weights(lookahead: list[Job],
                       durations: list[float] | None) -> list[float] | None:
    """Objective weights from predicted look-ahead durations: the decayed
    credit for fitting look-ahead job k scales with its predicted GPU-time
    (hours, clamped to [0.1, 8] so one wild prediction cannot dominate the
    occupancy terms).  ``None`` (no predictor) keeps the declared-duration
    assumption — the exact pre-prediction coefficients.  Weights are
    rounded so the solution cache keys on the same values the solver
    reads."""
    if durations is None or not lookahead:
        return None
    out = []
    for k in range(len(lookahead)):
        d = durations[k] if k < len(durations) else 3600.0
        out.append(round(min(max(d / 3600.0, 0.1), 8.0), 4))
    return out


def choose_allocation(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job] | None = None,
    *,
    lookahead_k: int = 8,
    use_solver: bool = True,
    solution_cache: bool = True,
    durations: list[float] | None = None,
) -> MILPResult:
    """Pick the best of `ways` for `job` under multi-resource + look-ahead MILP.

    `ways` must be non-empty feasible placements (way1=spread first, way2=pack).

    ``durations`` (optional, aligned with ``lookahead``) are predicted
    runtimes replacing the declared-duration assumption in the look-ahead
    objective terms (see ``_lookahead_weights``); ``None`` is bit-identical
    to the pre-prediction solver.

    With ``solution_cache`` (default) the result is memoized on the cluster
    instance keyed by (job shape, ways, look-ahead shapes, duration
    weights) at the current cluster version — exact, since every input the
    solve reads is a pure function of those; any mutation bumps the
    version and invalidates.
    """
    assert ways, "choose_allocation requires at least one candidate way"
    if len(ways) == 1:
        return MILPResult(ways[0], 0, float(job.num_gpus), False, 0)
    ways = ways[:2]  # Algorithm 1 is binary: way1 vs way2
    lookahead = (lookahead or [])[:lookahead_k]
    weights = _lookahead_weights(lookahead, durations)

    cache = key = None
    if solution_cache:
        ver = (cluster.version, cluster.topo_version)
        store = getattr(cluster, "_milp_sol_cache", None)
        if store is None or store[0] != ver:
            store = (ver, {})
            cluster._milp_sol_cache = store
        cache = store[1]
        key = (_job_shape(job),
               tuple(tuple(sorted(w.items())) for w in ways),
               tuple(_job_shape(lj) for lj in lookahead),
               use_solver,
               None if weights is None else tuple(weights))
        hit = cache.get(key)
        if hit is not None:
            return hit

    if use_solver and _HAVE_SCIPY:
        res = _solve_milp(cluster, job, ways, lookahead, weights)
    else:
        res = None
    if res is None:
        res = _greedy_choice(cluster, job, ways, lookahead, weights)
    if cache is not None:
        cache[key] = res
    return res


# ---------------------------------------------------------------------- solver ---


class _Skeleton:
    """Preallocated constraint structure for one ``(n_nodes, gpn, K)`` key.

    Variable layout (same as the reference builder):
    ``[x | CJO (n_nodes*gpn) | y (K*n_nodes) | z (K)]``.  ``A_fixed`` holds
    the per-node capacity triples (GPU/CPU/mem, rows ``3i..3i+2``) followed
    by the K gang rows; constant coefficients (the GPU-row ones, the gang
    y-sums) are written once here, per-call values are filled in place via
    precomputed flat index arrays before every solve.
    """

    __slots__ = ("n_nodes", "gpn", "K", "n_cjo", "nvar", "A_fixed",
                 "row_lb", "row_ub", "lb", "ub", "integrality", "c",
                 "cpu_cjo_idx", "mem_cjo_idx", "cpu_y_idx", "mem_y_idx",
                 "y0", "z0")

    def __init__(self, n_nodes: int, gpn: int, K: int):
        self.n_nodes, self.gpn, self.K = n_nodes, gpn, K
        self.n_cjo = n_nodes * gpn
        self.nvar = 1 + self.n_cjo + K * n_nodes + K
        self.y0 = 1 + self.n_cjo                 # first y variable
        self.z0 = 1 + self.n_cjo + K * n_nodes   # first z variable
        nvar = self.nvar
        A = np.zeros((3 * n_nodes + K, nvar))
        cpu_cjo, mem_cjo = [], []
        cpu_y = [[] for _ in range(K)]
        mem_y = [[] for _ in range(K)]
        for i in range(n_nodes):
            cols = np.arange(1 + i * gpn, 1 + (i + 1) * gpn)
            A[3 * i, cols] = 1.0                           # GPU row: constant
            cpu_cjo.extend(((3 * i + 1) * nvar + cols).tolist())
            mem_cjo.extend(((3 * i + 2) * nvar + cols).tolist())
            for k in range(K):
                yc = self.y0 + k * n_nodes + i
                A[3 * i, yc] = 1.0                         # GPU row: constant
                cpu_y[k].append((3 * i + 1) * nvar + yc)
                mem_y[k].append((3 * i + 2) * nvar + yc)
        for k in range(K):                                 # gang rows
            r = 3 * n_nodes + k
            A[r, self.y0 + k * n_nodes: self.y0 + (k + 1) * n_nodes] = 1.0
        self.A_fixed = A
        self.cpu_cjo_idx = np.asarray(cpu_cjo, dtype=np.intp)
        self.mem_cjo_idx = np.asarray(mem_cjo, dtype=np.intp)
        self.cpu_y_idx = [np.asarray(ix, dtype=np.intp) for ix in cpu_y]
        self.mem_y_idx = [np.asarray(ix, dtype=np.intp) for ix in mem_y]
        self.row_lb = np.zeros(3 * n_nodes + K)            # all rows lo = 0
        self.row_ub = np.zeros(3 * n_nodes + K)            # capacity filled
        self.lb = np.zeros(nvar)
        self.ub = np.ones(nvar)
        self.integrality = np.ones(nvar)
        self.c = np.zeros(nvar)
        self.c[1:1 + self.n_cjo] = -1.0


class _SkeletonStore(threading.local):
    """Per-thread skeleton memo with a dict surface.  Skeleton arrays are
    filled in place on every solve, so a store shared across the parallel
    federation's worker threads would race; ``threading.local`` gives each
    thread its own dict (built lazily on first access) while ``len`` /
    ``get`` / item assignment keep working for existing callers."""

    def __init__(self):
        self.d: dict[tuple[int, int, int], _Skeleton] = {}

    def __len__(self) -> int:
        return len(self.d)

    def get(self, key):
        return self.d.get(key)

    def __setitem__(self, key, sk) -> None:
        self.d[key] = sk


_SKELETONS = _SkeletonStore()


def _skeleton(n_nodes: int, gpn: int, K: int) -> _Skeleton:
    key = (n_nodes, gpn, K)
    sk = _SKELETONS.get(key)
    if sk is None:
        sk = _SKELETONS[key] = _Skeleton(n_nodes, gpn, K)
    return sk


def _equality_block(sk: _Skeleton, ways: list[Placement]):
    """Algorithm-1 equality rows (way slots tied to 1-x / x) — the only
    way-dependent block, built per call; a handful of rows at most."""
    rows, lbs, ubs = [], [], []
    ranges = _slot_ranges(ways)
    for w, (way, val_is_x) in enumerate(zip(ways, (False, True))):
        for node, (s, e) in ranges[w].items():
            for g in range(s, min(e, sk.gpn)):
                row = np.zeros(sk.nvar)
                row[1 + node * sk.gpn + g] = 1.0
                if val_is_x:   # CJO == x      -> CJO - x == 0
                    row[0] = -1.0
                    lbs.append(0.0)
                    ubs.append(0.0)
                else:          # CJO == 1 - x  -> CJO + x == 1
                    row[0] = 1.0
                    lbs.append(1.0)
                    ubs.append(1.0)
                rows.append(row)
    return np.vstack(rows), np.asarray(lbs), np.asarray(ubs)


def _solve_milp(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job],
    weights: list[float] | None = None,
) -> MILPResult | None:
    n_nodes = len(cluster.gpu_types)
    gpn = int(cluster.total_gpus.max())             # gpus_per_node (slot count)
    K = len(lookahead)
    sk = _skeleton(n_nodes, gpn, K)

    # ---- fill the per-call values in place (every structural nonzero is
    # reassigned each call, so no cross-call zeroing is needed) -------------
    A = sk.A_fixed
    cpu_pg = job.req_cpus / max(job.num_gpus, 1)
    mem_pg = job.req_mem_gb / max(job.num_gpus, 1)
    A.flat[sk.cpu_cjo_idx] = cpu_pg
    A.flat[sk.mem_cjo_idx] = mem_pg
    for k, lj in enumerate(lookahead):
        A.flat[sk.cpu_y_idx[k]] = lj.req_cpus / max(lj.num_gpus, 1)
        A.flat[sk.mem_y_idx[k]] = lj.req_mem_gb / max(lj.num_gpus, 1)
        A[3 * n_nodes + k, sk.z0 + k] = -float(lj.num_gpus)   # gang z coeff
        zc = -(0.5 ** (k + 1)) * lj.num_gpus
        sk.c[sk.z0 + k] = zc if weights is None else zc * weights[k]
        # y are integer GPU counts, bounded by node free GPUs and job demand;
        # nodes_for hits the cluster's topology-versioned eligibility cache
        elig = cluster.nodes_for(lj)
        y0 = sk.y0 + k * n_nodes
        sk.ub[y0:y0 + n_nodes] = np.where(
            elig, np.minimum(cluster.free_gpus, lj.num_gpus), 0)
    # per-node capacity bounds (rows 3i / 3i+1 / 3i+2 = GPU / CPU / mem)
    sk.row_ub[0:3 * n_nodes:3] = cluster.free_gpus
    sk.row_ub[1:3 * n_nodes:3] = cluster.free_cpus
    sk.row_ub[2:3 * n_nodes:3] = cluster.free_mem

    A_eq, eq_lb, eq_ub = _equality_block(sk, ways)
    # one concatenated constraint (equality block first — same row order as
    # the reference); scipy's per-LinearConstraint conversion overhead makes
    # a two-constraint split measurably slower than this single concat
    try:
        res = milp(
            c=sk.c,
            constraints=LinearConstraint(
                np.concatenate([A_eq, A]),
                np.concatenate([eq_lb, sk.row_lb]),
                np.concatenate([eq_ub, sk.row_ub])),
            integrality=sk.integrality,
            bounds=Bounds(sk.lb, sk.ub),
            options={"time_limit": 2.0, "presolve": True},
        )
    except Exception:  # pragma: no cover - solver hiccup
        return None
    if not res.success or res.x is None:
        return None
    x = res.x[0]
    way_index = 1 if x > 0.5 else 0
    z_count = int(round(sum(res.x[sk.z0 + k] for k in range(K)))) if K else 0
    return MILPResult(ways[way_index], way_index, -float(res.fun), True, z_count)


def _solve_milp_reference(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job],
    weights: list[float] | None = None,
) -> MILPResult | None:
    """Per-call dense matrix builder (the pre-memoization implementation),
    retained verbatim as the differential reference for ``_solve_milp``."""
    n_nodes = len(cluster.gpu_types)
    gpn = int(cluster.total_gpus.max())             # gpus_per_node (slot count)
    K = len(lookahead)

    # variable layout: [x | CJO (n_nodes*gpn) | y (K*n_nodes) | z (K)]
    n_cjo = n_nodes * gpn
    nvar = 1 + n_cjo + K * n_nodes + K

    def cjo(i: int, g: int) -> int:
        return 1 + i * gpn + g

    def yvar(k: int, i: int) -> int:
        return 1 + n_cjo + k * n_nodes + i

    def zvar(k: int) -> int:
        return 1 + n_cjo + K * n_nodes + k

    lb = np.zeros(nvar)
    ub = np.ones(nvar)
    integrality = np.ones(nvar)
    for k, lj in enumerate(lookahead):
        elig = cluster.nodes_for(lj)
        y0 = yvar(k, 0)
        ub[y0:y0 + n_nodes] = np.where(
            elig, np.minimum(cluster.free_gpus, lj.num_gpus), 0)

    A_rows, lbs, ubs = [], [], []

    def add(row: np.ndarray, lo: float, hi: float) -> None:
        A_rows.append(row)
        lbs.append(lo)
        ubs.append(hi)

    # Algorithm 1 equality constraints: way slots tied to (1-x) / x
    ranges = _slot_ranges(ways)
    for w, (way, val_is_x) in enumerate(zip(ways, (False, True))):
        for node, (s, e) in ranges[w].items():
            for g in range(s, min(e, gpn)):
                row = np.zeros(nvar)
                row[cjo(node, g)] = 1.0
                if val_is_x:   # CJO == x      -> CJO - x == 0
                    row[0] = -1.0
                    add(row, 0.0, 0.0)
                else:          # CJO == 1 - x  -> CJO + x == 1
                    row[0] = 1.0
                    add(row, 1.0, 1.0)

    cpu_pg = job.req_cpus / max(job.num_gpus, 1)
    mem_pg = job.req_mem_gb / max(job.num_gpus, 1)
    # per-node multi-resource capacity (GPU / CPU / memory)
    for i in range(n_nodes):
        g_row = np.zeros(nvar)
        c_row = np.zeros(nvar)
        m_row = np.zeros(nvar)
        for g in range(gpn):
            g_row[cjo(i, g)] = 1.0
            c_row[cjo(i, g)] = cpu_pg
            m_row[cjo(i, g)] = mem_pg
        for k, lj in enumerate(lookahead):
            g_row[yvar(k, i)] = 1.0
            c_row[yvar(k, i)] = lj.req_cpus / max(lj.num_gpus, 1)
            m_row[yvar(k, i)] = lj.req_mem_gb / max(lj.num_gpus, 1)
        add(g_row, 0.0, float(cluster.free_gpus[i]))
        add(c_row, 0.0, float(cluster.free_cpus[i]))
        add(m_row, 0.0, float(cluster.free_mem[i]))

    # gang constraint for look-ahead jobs: sum_i y[k,i] == req_k * z_k
    for k, lj in enumerate(lookahead):
        row = np.zeros(nvar)
        for i in range(n_nodes):
            row[yvar(k, i)] = 1.0
        row[zvar(k)] = -float(lj.num_gpus)
        add(row, 0.0, 0.0)

    # objective: maximize occupancy + decayed look-ahead placements
    c = np.zeros(nvar)
    c[1:1 + n_cjo] = -1.0
    for k, lj in enumerate(lookahead):
        zc = -(0.5 ** (k + 1)) * lj.num_gpus
        c[zvar(k)] = zc if weights is None else zc * weights[k]

    try:
        res = milp(
            c=c,
            constraints=LinearConstraint(np.vstack(A_rows), np.array(lbs), np.array(ubs)),
            integrality=integrality,
            bounds=Bounds(lb, ub),
            options={"time_limit": 2.0, "presolve": True},
        )
    except Exception:  # pragma: no cover - solver hiccup
        return None
    if not res.success or res.x is None:
        return None
    x = res.x[0]
    way_index = 1 if x > 0.5 else 0
    z_count = int(round(sum(res.x[zvar(k)] for k in range(K)))) if K else 0
    return MILPResult(ways[way_index], way_index, -float(res.fun), True, z_count)


# -------------------------------------------------------------------- fallback ---


def _greedy_choice(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job],
    weights: list[float] | None = None,
) -> MILPResult:
    """Fragmentation-aware heuristic: prefer packing when it leaves larger
    contiguous blocks for upcoming multi-GPU jobs; spread under contention."""
    def score(way: Placement) -> float:
        free_after = cluster.free_gpus.copy()
        for i, g in way.items():
            free_after[i] -= g
        # largest contiguous block preserved + look-ahead satisfiability
        big = float(free_after.max()) if len(free_after) else 0.0
        satisfied = 0.0
        tmp = np.sort(free_after)[::-1].astype(float)
        for k, lj in enumerate(lookahead):
            need = lj.num_gpus
            for ii in range(len(tmp)):
                take = min(tmp[ii], need)
                tmp[ii] -= take
                need -= take
                if need <= 0:
                    credit = 0.5 ** (k + 1)
                    satisfied += credit if weights is None \
                        else credit * weights[k]
                    break
        return big * 0.01 + satisfied

    scores = [score(w) for w in ways]
    idx = int(np.argmax(scores))
    return MILPResult(ways[idx], idx, scores[idx], False, 0)
