"""MILP-based allocation optimization (Algorithm 1 of the paper).

A binary variable `x` selects way1 (spreading) vs way2 (packing) for the head
job; a boolean occupancy matrix `CJO` (node x GPU-slot) is constrained by
per-node GPU/CPU/memory capacity; the objective maximizes total GPU occupancy.
Look-ahead: the top-K prioritized queue jobs are modeled as extra integer
allocation layers so the spread-vs-pack choice accounts for upcoming demand
(Sec. 3.2 "current and future job requirements ... across multiple time slots").

The paper uses CVXPY + GLPK_MI; this container has no GLPK, so we solve the
identical formulation with `scipy.optimize.milp` (HiGHS, also exact MI).  A
greedy fragmentation-aware fallback handles solver absence/failure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

try:  # pragma: no cover - import guard
    from scipy.optimize import Bounds, LinearConstraint, milp
    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False

from repro.core.cluster import ClusterState, Placement
from repro.core.types import Job


@dataclasses.dataclass
class MILPResult:
    placement: Placement
    way_index: int            # 0 = way1 (spread), 1 = way2 (pack)
    objective: float
    used_solver: bool
    lookahead_scheduled: int  # how many look-ahead jobs the solution also fits


def _slot_ranges(ways: list[Placement]) -> list[dict[int, tuple[int, int]]]:
    """Assign disjoint symbolic slot ranges per node for each way so the
    equality constraints of Algorithm 1 never collide on shared nodes."""
    offset: dict[int, int] = {}
    ranges: list[dict[int, tuple[int, int]]] = []
    for way in ways:
        r: dict[int, tuple[int, int]] = {}
        for node, cnt in way.items():
            s = offset.get(node, 0)
            r[node] = (s, s + cnt)
            offset[node] = s + cnt
        ranges.append(r)
    return ranges


def choose_allocation(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job] | None = None,
    *,
    lookahead_k: int = 8,
    use_solver: bool = True,
) -> MILPResult:
    """Pick the best of `ways` for `job` under multi-resource + look-ahead MILP.

    `ways` must be non-empty feasible placements (way1=spread first, way2=pack).
    """
    assert ways, "choose_allocation requires at least one candidate way"
    if len(ways) == 1:
        return MILPResult(ways[0], 0, float(job.num_gpus), False, 0)
    ways = ways[:2]  # Algorithm 1 is binary: way1 vs way2
    lookahead = (lookahead or [])[:lookahead_k]

    if use_solver and _HAVE_SCIPY:
        res = _solve_milp(cluster, job, ways, lookahead)
        if res is not None:
            return res
    return _greedy_choice(cluster, job, ways, lookahead)


# ---------------------------------------------------------------------- solver ---


def _solve_milp(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job],
) -> MILPResult | None:
    n_nodes = len(cluster.gpu_types)
    gpn = int(cluster.total_gpus.max())             # gpus_per_node (slot count)
    K = len(lookahead)

    # variable layout: [x | CJO (n_nodes*gpn) | y (K*n_nodes) | z (K)]
    n_cjo = n_nodes * gpn
    nvar = 1 + n_cjo + K * n_nodes + K

    def cjo(i: int, g: int) -> int:
        return 1 + i * gpn + g

    def yvar(k: int, i: int) -> int:
        return 1 + n_cjo + k * n_nodes + i

    def zvar(k: int) -> int:
        return 1 + n_cjo + K * n_nodes + k

    lb = np.zeros(nvar)
    ub = np.ones(nvar)
    integrality = np.ones(nvar)
    # y are integer GPU counts, bounded by node free GPUs and job demand;
    # nodes_for hits the cluster's topology-versioned eligibility cache and
    # the bound row is computed vectorized instead of per-node
    for k, lj in enumerate(lookahead):
        elig = cluster.nodes_for(lj)
        y0 = yvar(k, 0)
        ub[y0:y0 + n_nodes] = np.where(
            elig, np.minimum(cluster.free_gpus, lj.num_gpus), 0)

    A_rows, lbs, ubs = [], [], []

    def add(row: np.ndarray, lo: float, hi: float) -> None:
        A_rows.append(row)
        lbs.append(lo)
        ubs.append(hi)

    # Algorithm 1 equality constraints: way slots tied to (1-x) / x
    ranges = _slot_ranges(ways)
    for w, (way, val_is_x) in enumerate(zip(ways, (False, True))):
        for node, (s, e) in ranges[w].items():
            for g in range(s, min(e, gpn)):
                row = np.zeros(nvar)
                row[cjo(node, g)] = 1.0
                if val_is_x:   # CJO == x      -> CJO - x == 0
                    row[0] = -1.0
                    add(row, 0.0, 0.0)
                else:          # CJO == 1 - x  -> CJO + x == 1
                    row[0] = 1.0
                    add(row, 1.0, 1.0)

    cpu_pg = job.req_cpus / max(job.num_gpus, 1)
    mem_pg = job.req_mem_gb / max(job.num_gpus, 1)
    # per-node multi-resource capacity (GPU / CPU / memory)
    for i in range(n_nodes):
        g_row = np.zeros(nvar)
        c_row = np.zeros(nvar)
        m_row = np.zeros(nvar)
        for g in range(gpn):
            g_row[cjo(i, g)] = 1.0
            c_row[cjo(i, g)] = cpu_pg
            m_row[cjo(i, g)] = mem_pg
        for k, lj in enumerate(lookahead):
            g_row[yvar(k, i)] = 1.0
            c_row[yvar(k, i)] = lj.req_cpus / max(lj.num_gpus, 1)
            m_row[yvar(k, i)] = lj.req_mem_gb / max(lj.num_gpus, 1)
        add(g_row, 0.0, float(cluster.free_gpus[i]))
        add(c_row, 0.0, float(cluster.free_cpus[i]))
        add(m_row, 0.0, float(cluster.free_mem[i]))

    # gang constraint for look-ahead jobs: sum_i y[k,i] == req_k * z_k
    for k, lj in enumerate(lookahead):
        row = np.zeros(nvar)
        for i in range(n_nodes):
            row[yvar(k, i)] = 1.0
        row[zvar(k)] = -float(lj.num_gpus)
        add(row, 0.0, 0.0)

    # objective: maximize occupancy + decayed look-ahead placements
    c = np.zeros(nvar)
    c[1:1 + n_cjo] = -1.0
    for k, lj in enumerate(lookahead):
        c[zvar(k)] = -(0.5 ** (k + 1)) * lj.num_gpus

    try:
        res = milp(
            c=c,
            constraints=LinearConstraint(np.vstack(A_rows), np.array(lbs), np.array(ubs)),
            integrality=integrality,
            bounds=Bounds(lb, ub),
            options={"time_limit": 2.0, "presolve": True},
        )
    except Exception:  # pragma: no cover - solver hiccup
        return None
    if not res.success or res.x is None:
        return None
    x = res.x[0]
    way_index = 1 if x > 0.5 else 0
    z_count = int(round(sum(res.x[zvar(k)] for k in range(K)))) if K else 0
    return MILPResult(ways[way_index], way_index, -float(res.fun), True, z_count)


# -------------------------------------------------------------------- fallback ---


def _greedy_choice(
    cluster: ClusterState,
    job: Job,
    ways: list[Placement],
    lookahead: list[Job],
) -> MILPResult:
    """Fragmentation-aware heuristic: prefer packing when it leaves larger
    contiguous blocks for upcoming multi-GPU jobs; spread under contention."""
    def score(way: Placement) -> float:
        free_after = cluster.free_gpus.copy()
        for i, g in way.items():
            free_after[i] -= g
        # largest contiguous block preserved + look-ahead satisfiability
        big = float(free_after.max()) if len(free_after) else 0.0
        satisfied = 0.0
        tmp = np.sort(free_after)[::-1].astype(float)
        for k, lj in enumerate(lookahead):
            need = lj.num_gpus
            for ii in range(len(tmp)):
                take = min(tmp[ii], need)
                tmp[ii] -= take
                need -= take
                if need <= 0:
                    satisfied += 0.5 ** (k + 1)
                    break
        return big * 0.01 + satisfied

    scores = [score(w) for w in ways]
    idx = int(np.argmax(scores))
    return MILPResult(ways[idx], idx, scores[idx], False, 0)
