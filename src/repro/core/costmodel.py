"""Cost model linking the scheduler to the DL platform substrate.

Job runtimes for platform-generated traces are derived from the per-arch
roofline terms (dry-run artifacts when present, analytic model otherwise):
a training job of `steps` steps on `chips` chips of a given GPU/TPU SKU
takes  steps x max(compute, memory, collective) x (ref_chips / chips) /
sku_speed  seconds.  This closes the loop: RLTune schedules the same
architectures whose distributed execution the substrate lowers.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.types import Job

_ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "benchmarks", "artifacts", "dryrun", "singlepod")

# relative throughput of cluster SKUs vs the roofline reference chip (v5e)
SKU_SPEED = {"v5e": 1.0, "V100": 0.63, "P100": 0.24, "T4": 0.33,
             "K80": 0.11, "M40": 0.15, "any": 0.5}


def _load_terms(arch: str, shape: str) -> dict | None:
    path = os.path.join(_ARTIFACTS, f"{arch}__{shape}.json")
    if os.path.exists(path):
        with open(path) as f:
            d = json.load(f)
        return {"compute_s": d["compute_s"], "memory_s": d["memory_s"],
                "collective_s": d["collective_s"], "chips": d["chips"]}
    return None


def step_time(arch: str, shape: str = "train_4k", chips: int = 256,
              sku: str = "v5e") -> float:
    """Roofline-bound step time (s) for (arch, shape) on `chips` chips."""
    terms = _load_terms(arch, shape)
    if terms is None:
        from repro.configs import get_config
        from repro.launch.roofline import analytic_cost, roofline_terms
        from repro.models.lm import LM
        cfg = get_config(arch)
        ana = analytic_cost(cfg, shape, chips=256, model=LM(cfg))
        terms = {**roofline_terms(ana["flops_per_chip"],
                                  ana["hbm_bytes_per_chip"], 0.0),
                 "chips": 256}
    # production pipelines reduce-scatter + overlap collectives; the CPU-dry-run
    # collective term is a known 10-16x upper bound (EXPERIMENTS.md §Roofline),
    # so weight it down rather than let it dominate job runtimes
    bound = max(terms["compute_s"], terms["memory_s"],
                0.1 * terms["collective_s"])
    return bound * terms["chips"] / max(chips, 1) / SKU_SPEED.get(sku, 0.5)


def platform_job_runtime(arch: str, num_gpus: int, sku: str,
                         steps: int, shape: str = "train_4k") -> float:
    """Wall seconds for a training job of `steps` steps on num_gpus of sku."""
    return steps * step_time(arch, shape, chips=num_gpus, sku=sku)


def generate_platform_trace(num_jobs: int, seed: int = 0,
                            arrival_rate: float = 0.03) -> list[Job]:
    """A trace whose jobs are the assigned architectures with roofline-derived
    runtimes (alternative to the statistical Philly/Helios/Alibaba profiles)."""
    from repro.configs import ALL_ARCHS
    rng = np.random.default_rng(seed)
    jobs: list[Job] = []
    t = 0.0
    for i in range(num_jobs):
        t += float(rng.exponential(1.0 / arrival_rate))
        arch = str(rng.choice(ALL_ARCHS))
        num_gpus = int(rng.choice([1, 2, 4, 8, 16], p=[.35, .25, .2, .15, .05]))
        steps = int(rng.lognormal(4.0, 1.0))
        rt = float(np.clip(platform_job_runtime(arch, num_gpus, "V100", steps),
                           60.0, 7 * 86400.0))
        est = rt * float(rng.lognormal(0.0, 0.5))
        jobs.append(Job(job_id=i, user=int(rng.integers(0, 64)),
                        submit_time=t, runtime=rt, est_runtime=est,
                        num_gpus=num_gpus, gpu_type="any", arch=arch))
    return jobs
