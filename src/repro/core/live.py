"""Live-deployment driver mimicking the paper's real Slurm integration
(Sec. 3.1.2 / 5.6): every `rescan_interval` seconds of cluster time the job
queue is rescanned, the RL agent re-prioritizes waiting + newly arrived jobs
(the `scontrol update priority=` path), and the MILP's spread-vs-pack verdict
toggles the OverSubscribe flag for the next placement.

SLA lane (Sec. 3.1.2): jobs flagged SLA-bound bypass RLTune and are ranked by
the baseline scheduler at the head of the queue, so RLTune's operational
overhead can never delay them.
"""
from __future__ import annotations

import dataclasses


from repro.core.agent import PPOAgent
from repro.core.cluster import ClusterState
from repro.core.features import MAX_QUEUE_SIZE, build_state
from repro.core.policies import Policy, make_policy
from repro.core.types import ClusterSpec, Job
from repro.sched.service import run_stream


@dataclasses.dataclass
class LiveConfig:
    rescan_interval: float = 60.0        # paper: 1-minute scontrol loop
    sla_users: frozenset[int] = frozenset()
    base_policy: str = "slurm-mf"


class LivePrioritizer:
    """Prioritizer with cached priorities refreshed on a rescan interval,
    plus an SLA bypass lane ranked by the baseline scheduler."""

    def __init__(self, agent: PPOAgent, cfg: LiveConfig,
                 use_estimates: bool = True):
        self.agent = agent
        self.cfg = cfg
        self.use_estimates = use_estimates
        self.base: Policy = make_policy(cfg.base_policy, use_estimates)
        self._last_scan = -1e18
        self._prio: dict[int, float] = {}
        self.rescans = 0

    def _rescan(self, jobs: list[Job], cluster: ClusterState, now: float) -> None:
        ov, cv, mask = build_state(jobs, cluster, now,
                                   use_estimates=self.use_estimates)
        _, logits = self.agent.act(ov, cv, mask, explore=False, record=False)
        n = min(len(jobs), MAX_QUEUE_SIZE)
        for i in range(n):
            self._prio[jobs[i].job_id] = float(logits[i])
        for j in jobs[n:]:
            self._prio.setdefault(j.job_id, -1e6 - j.submit_time)
        self._last_scan = now
        self.rescans += 1

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        if now - self._last_scan >= self.cfg.rescan_interval or \
                any(j.job_id not in self._prio for j in jobs):
            self._rescan(jobs, cluster, now)
        sla = [i for i, j in enumerate(jobs) if j.user in self.cfg.sla_users]
        rest = [i for i, j in enumerate(jobs) if j.user not in self.cfg.sla_users]
        sla.sort(key=lambda i: self.base.score(jobs[i], now))
        rest.sort(key=lambda i: -self._prio.get(jobs[i].job_id, -1e9))
        return sla + rest          # SLA lane always schedules first

    def observe_finish(self, job: Job) -> None:
        self.base.observe_finish(job)
        self._prio.pop(job.job_id, None)


def run_live(spec: ClusterSpec, jobs: list[Job], agent: PPOAgent,
             cfg: LiveConfig | None = None):
    """Simulated live deployment: returns (BatchResult, rescans).

    Routes through the streaming service driver (repro.sched.service): the
    engine steps in `rescan_interval` windows exactly as the Slurm loop
    would poll it.  Window boundaries are unobservable to the schedule, so
    results match the former batch path bit-for-bit."""
    cfg = cfg or LiveConfig()
    pri = LivePrioritizer(agent, cfg)
    res = run_stream(spec, [j.clone_pending() for j in jobs], pri,
                     rescan_interval=cfg.rescan_interval, allocator="milp")
    return res.batch, pri.rescans
