"""RLTune core: hybrid RL + MILP dynamic scheduling (the paper's contribution)."""
from repro.core.agent import PPOAgent, PPOConfig
from repro.core.cluster import ClusterState
from repro.core.env import InspectorPrioritizer, RLPrioritizer
from repro.core.faults import FaultInjector, FaultModel
from repro.core.metrics import BatchResult, reward_from_scores
from repro.core.milp import MILPResult, choose_allocation
from repro.core.policies import BASE_POLICIES, make_policy
from repro.core.simulator import PolicyPrioritizer, Simulator
from repro.core.trace import (ALIBABA, HELIOS, PHILLY, PROFILES, batch_iter,
                              generate_trace, load_trace_csv, make_cluster,
                              train_eval_split)
from repro.core.types import ClusterSpec, Job, JobState, NodeSpec

#: trainer names are re-exported lazily (PEP 562): the batch trainer now
#: lives in repro.rl.batch, which imports repro.core submodules — an eager
#: import here would be circular whichever package loads first.
_LAZY_TRAINER = ("RLTuneTrainer", "TrainerConfig", "EpochStats",
                 "improvement")


def __getattr__(name: str):
    if name in _LAZY_TRAINER:
        from repro.core import trainer
        return getattr(trainer, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")

__all__ = [
    "PPOAgent", "PPOConfig", "ClusterState", "InspectorPrioritizer",
    "RLPrioritizer", "FaultInjector", "FaultModel", "BatchResult",
    "reward_from_scores", "MILPResult", "choose_allocation", "BASE_POLICIES",
    "make_policy", "PolicyPrioritizer", "Simulator", "ALIBABA", "HELIOS",
    "PHILLY", "PROFILES", "batch_iter", "generate_trace", "load_trace_csv",
    "make_cluster", "train_eval_split", "RLTuneTrainer", "TrainerConfig",
    "improvement", "ClusterSpec", "Job", "JobState", "NodeSpec",
]
