"""RLTune core: hybrid RL + MILP dynamic scheduling (the paper's contribution)."""
from repro.core.agent import PPOAgent, PPOConfig
from repro.core.cluster import ClusterState
from repro.core.env import InspectorPrioritizer, RLPrioritizer
from repro.core.faults import FaultInjector, FaultModel
from repro.core.metrics import BatchResult, reward_from_scores
from repro.core.milp import MILPResult, choose_allocation
from repro.core.policies import BASE_POLICIES, make_policy
from repro.core.simulator import PolicyPrioritizer, Simulator
from repro.core.trace import (ALIBABA, HELIOS, PHILLY, PROFILES, batch_iter,
                              generate_trace, load_trace_csv, make_cluster,
                              train_eval_split)
from repro.core.trainer import RLTuneTrainer, TrainerConfig, improvement
from repro.core.types import ClusterSpec, Job, JobState, NodeSpec

__all__ = [
    "PPOAgent", "PPOConfig", "ClusterState", "InspectorPrioritizer",
    "RLPrioritizer", "FaultInjector", "FaultModel", "BatchResult",
    "reward_from_scores", "MILPResult", "choose_allocation", "BASE_POLICIES",
    "make_policy", "PolicyPrioritizer", "Simulator", "ALIBABA", "HELIOS",
    "PHILLY", "PROFILES", "batch_iter", "generate_trace", "load_trace_csv",
    "make_cluster", "train_eval_split", "RLTuneTrainer", "TrainerConfig",
    "improvement", "ClusterSpec", "Job", "JobState", "NodeSpec",
]
