"""Runtime cluster state: allocation tracking + placement enumeration.

The cluster tracks free GPUs/CPUs/memory per node, supports gang allocation
across nodes, and enumerates candidate placements ("ways") for a job:

- way1 "spread": prefer empty / least-loaded nodes (isolation, low contention)
- way2 "pack":   prefer most-loaded nodes that still fit (utilization)

The MILP module (Algorithm 1 of the paper) chooses between them.

Versioned feasibility cache
---------------------------
Every mutation (``allocate`` / ``release`` / ``fail_node`` / ``recover_node``
/ ``load_from``) bumps ``version``.  With ``cache=True`` the placement
queries (``find_placement`` / ``candidate_ways`` / ``can_schedule_now``),
the SKU eligibility masks, and the per-SKU free-GPU tallies are memoized per
(job shape, version): between two mutations a saturated scheduler re-asks the
same feasibility questions for the whole queue window, and every repeat is a
dict hit instead of a placement search.  Job "shape" is the tuple of fields
placement actually depends on: ``(num_gpus, gpu_type, req_cpus, req_mem_gb)``.

Caching is opt-out by default because callers that mutate the resource arrays
directly (some tests do) would otherwise read stale entries; the scheduler
engine owns its ``ClusterState`` and constructs it with ``cache=True``.

Elastic capacity
----------------
The autoscaling layer (``repro.scale``) mutates capacity at runtime:

- ``add_node(spec)`` appends a node (arrays grow, SKU masks rebuild) and
  returns its node id; ids are stable for the cluster's lifetime.
- ``remove_node(node_id)`` retires an idle node immediately; a busy node is
  **cordoned** instead (drain semantics): excluded from placement and the
  feasibility tallies, but its running jobs keep their GPUs and the node
  still counts as *provisioned*.  Once its last allocation is released the
  node auto-retires.  ``uncordon_node`` cancels a pending drain (scale-up
  reuses draining nodes before adding new ones).
- retired nodes are permanently excluded everywhere (placement, tallies,
  utilization, provisioned totals) but keep their array slot so node ids in
  live placements never shift.

Every capacity mutation bumps ``topo_version`` (and therefore ``version``)
exactly like ``fail_node``/``recover_node``, so the per-version feasibility
caches and memoized ratios can never serve pre-mutation answers.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ClusterSpec, Job, NodeSpec

Placement = dict[int, int]  # node_id -> gpus taken

_MISS = object()   # cache sentinel (cached values may legitimately be None)


def _job_shape(job: Job) -> tuple:
    """The fields placement feasibility depends on — the cache key."""
    return (job.num_gpus, job.gpu_type, job.req_cpus, job.req_mem_gb)


class ClusterState:
    """Mutable multi-resource state of a heterogeneous cluster."""

    def __init__(self, spec: ClusterSpec, cache: bool = False):
        self.spec = spec
        n = len(spec.nodes)
        self.free_gpus = np.array([nd.num_gpus for nd in spec.nodes], dtype=np.int64)
        self.free_cpus = np.array([nd.num_cpus for nd in spec.nodes], dtype=np.int64)
        self.free_mem = np.array([nd.mem_gb for nd in spec.nodes], dtype=np.float64)
        self.gpu_types = np.array([nd.gpu_type for nd in spec.nodes])
        self.speeds = np.array([nd.speed for nd in spec.nodes], dtype=np.float64)
        self.total_gpus = np.array([nd.num_gpus for nd in spec.nodes], dtype=np.int64)
        self.node_down = np.zeros(n, dtype=bool)   # fault injection
        self.cordoned = np.zeros(n, dtype=bool)    # draining for removal
        self.retired = np.zeros(n, dtype=bool)     # removed (slot kept)
        # per-SKU node-index masks (rebuilt only when add_node grows the
        # cluster; a node's SKU never changes in place)
        self._rebuild_static_masks()
        # version counters: `version` bumps on every mutation; `topo_version`
        # only when node up/down topology changes (eligibility masks depend
        # solely on topology, not on free-resource levels)
        self.version = 0
        self.topo_version = 0
        self.cache_enabled = bool(cache)
        self._placement_cache: dict[tuple, Placement | None] = {}
        self._ways_cache: dict[tuple, list[Placement]] = {}
        self._eligible_cache: dict[str, np.ndarray] = {}
        self._tallies: tuple[int, dict[str, int]] | None = None
        self._up_ratios: tuple[float, float] | None = None
        self._prov_totals: tuple[int, tuple[int, dict[str, int]]] | None = None

    def _rebuild_static_masks(self) -> None:
        n = len(self.gpu_types)
        self._sku_masks: dict[str, np.ndarray] = {
            t: self.gpu_types == t for t in set(str(t) for t in self.gpu_types)}
        self._all_mask = np.ones(n, dtype=bool)
        self._no_mask = np.zeros(n, dtype=bool)
        self._total_by_type = {t: int(self.total_gpus[m].sum())
                               for t, m in self._sku_masks.items()}

    # ---------------------------------------------------------------- caching --
    def _bump(self) -> None:
        self.version += 1
        if self._placement_cache:
            self._placement_cache.clear()
        if self._ways_cache:
            self._ways_cache.clear()
        self._tallies = None
        self._up_ratios = None

    def _bump_topology(self) -> None:
        self.topo_version += 1
        if self._eligible_cache:
            self._eligible_cache.clear()
        self._bump()

    def load_from(self, other: "ClusterState") -> None:
        """Copy the mutable resource state of ``other`` in place (scratch
        reuse for what-if simulation) and invalidate all caches.  Requires
        equal node counts — scratch owners rebuild when ``add_node`` grew
        the source cluster."""
        np.copyto(self.free_gpus, other.free_gpus)
        np.copyto(self.free_cpus, other.free_cpus)
        np.copyto(self.free_mem, other.free_mem)
        np.copyto(self.node_down, other.node_down)
        np.copyto(self.cordoned, other.cordoned)
        np.copyto(self.retired, other.retired)
        self._bump_topology()

    # ------------------------------------------------------------------ queries --
    def eligible_mask(self, gpu_type: str) -> np.ndarray:
        """Boolean mask of up nodes whose SKU satisfies ``gpu_type``.
        Callers must treat the returned array as read-only."""
        if self.cache_enabled:
            m = self._eligible_cache.get(gpu_type)
            if m is None:
                m = self._compute_eligible(gpu_type)
                self._eligible_cache[gpu_type] = m
            return m
        return self._compute_eligible(gpu_type)

    def _compute_eligible(self, gpu_type: str) -> np.ndarray:
        base = self._all_mask if gpu_type == "any" \
            else self._sku_masks.get(gpu_type, self._no_mask)
        return base & self.placeable_mask()

    def placeable_mask(self) -> np.ndarray:
        """Up, not draining, not removed: the nodes placement may use.
        Shared by the engine's schedulability prefilter, the RL feature
        builder, and the autoscaler's idle-capacity scan.  Treat the
        returned array as read-only."""
        return ~(self.node_down | self.cordoned | self.retired)

    def nodes_for(self, job: Job) -> np.ndarray:
        """Boolean mask of nodes whose SKU satisfies the job's request and are up."""
        return self.eligible_mask(job.gpu_type)

    def sku_mask(self, gpu_type: str) -> np.ndarray:
        """Static boolean node mask for one SKU (``any`` = all nodes);
        ignores up/cordon/retire state.  Treat as read-only."""
        if gpu_type == "any":
            return self._all_mask
        return self._sku_masks.get(gpu_type, self._no_mask)

    def free_gpu_tallies(self) -> tuple[int, dict[str, int]]:
        """``(total_free_placeable, {sku: free_gpus_placeable})`` over up,
        non-cordoned, non-retired nodes — cached per version so
        saturated-queue prefilters are O(1)."""
        if self.cache_enabled and self._tallies is not None:
            return self._tallies
        up = self.placeable_mask()
        total = int(self.free_gpus[up].sum())
        by_type = {t: int(self.free_gpus[m & up].sum())
                   for t, m in self._sku_masks.items()}
        tallies = (total, by_type)
        if self.cache_enabled:
            self._tallies = tallies
        return tallies

    def free_gpus_of_type(self, gpu_type: str) -> int:
        total, by_type = self.free_gpu_tallies()
        return total if gpu_type == "any" else by_type.get(gpu_type, 0)

    def total_gpus_of_type(self, gpu_type: str) -> int:
        if gpu_type == "any":
            return int(self.total_gpus.sum())
        return self._total_by_type.get(gpu_type, 0)

    def _fits_node(self, job: Job, i: int, gpus: int) -> bool:
        """Would `gpus` GPUs of `job` fit on node i respecting CPU/mem coupling?"""
        if gpus <= 0 or gpus > self.free_gpus[i]:
            return False
        frac = gpus / max(job.num_gpus, 1)
        return (self.free_cpus[i] >= round(job.req_cpus * frac)
                and self.free_mem[i] >= job.req_mem_gb * frac)

    def can_schedule_now(self, job: Job) -> bool:
        return self.find_placement(job, mode="pack") is not None

    # -------------------------------------------------------------- placements --
    def find_placement(self, job: Job, mode: str = "pack") -> Placement | None:
        """Greedy gang placement. mode: 'pack' (most-loaded-first) or
        'spread' (least-loaded-first / fewest co-tenants)."""
        if self.cache_enabled:
            key = (job.num_gpus, job.gpu_type, job.req_cpus, job.req_mem_gb,
                   mode)
            hit = self._placement_cache.get(key, _MISS)
            if hit is not _MISS:
                return hit
            p = self._find_placement(job, mode)
            self._placement_cache[key] = p
            return p
        return self._find_placement(job, mode)

    def _find_placement(self, job: Job, mode: str) -> Placement | None:
        eligible = self.nodes_for(job)
        order = np.argsort(self.free_gpus if mode == "pack" else -self.free_gpus,
                           kind="stable")
        need = job.num_gpus
        placement: Placement = {}
        for i in order:
            if not eligible[i] or need <= 0:
                continue
            take = int(min(need, self.free_gpus[i]))
            # shrink until CPU/mem coupling fits
            while take > 0 and not self._fits_node(job, int(i), take):
                take -= 1
            if take > 0:
                placement[int(i)] = take
                need -= take
        return placement if need == 0 else None

    def candidate_ways(self, job: Job) -> list[Placement]:
        """Distinct candidate placements (spread & pack at minimum)."""
        if self.cache_enabled:
            key = _job_shape(job)
            hit = self._ways_cache.get(key, _MISS)
            if hit is not _MISS:
                return hit
            ways = self._candidate_ways(job)
            self._ways_cache[key] = ways
            return ways
        return self._candidate_ways(job)

    def _candidate_ways(self, job: Job) -> list[Placement]:
        ways: list[Placement] = []
        for mode in ("spread", "pack"):
            p = self.find_placement(job, mode)
            if p is not None and p not in ways:
                ways.append(p)
        # single-node way if the job fits whole on one eligible node
        eligible = self.nodes_for(job)
        for i in np.argsort(self.free_gpus, kind="stable"):
            if eligible[i] and self._fits_node(job, int(i), job.num_gpus):
                p = {int(i): job.num_gpus}
                if p not in ways:
                    ways.append(p)
                break
        return ways

    def num_ways_to_schedule(self, job: Job) -> int:
        return len(self.candidate_ways(job))

    # -------------------------------------------------------------- mutation ----
    def allocate(self, job: Job, placement: Placement) -> None:
        # validate the whole gang before mutating anything: a mid-loop
        # failure must not leave a partially-decremented cluster behind a
        # still-valid cache version (guards are RuntimeErrors, not asserts,
        # so they survive `python -O`)
        for i, g in placement.items():
            frac = g / max(job.num_gpus, 1)
            if self.free_gpus[i] < g:
                raise RuntimeError(f"GPU oversubscription on node {i}")
            if (self.free_cpus[i] < round(job.req_cpus * frac)
                    or self.free_mem[i] < job.req_mem_gb * frac - 1e-9):
                raise RuntimeError(f"CPU/mem oversubscription on node {i}")
        for i, g in placement.items():
            frac = g / max(job.num_gpus, 1)
            self.free_gpus[i] -= g
            self.free_cpus[i] -= round(job.req_cpus * frac)
            self.free_mem[i] -= job.req_mem_gb * frac
        self._bump()

    def release(self, job: Job, placement: Placement) -> None:
        for i, g in placement.items():
            if self.free_gpus[i] + g > self.total_gpus[i]:
                raise RuntimeError(f"double release on node {i}")
        drained = False
        for i, g in placement.items():
            frac = g / max(job.num_gpus, 1)
            self.free_gpus[i] += g
            self.free_cpus[i] += round(job.req_cpus * frac)
            self.free_mem[i] += job.req_mem_gb * frac
            # drain semantics: a cordoned node whose last allocation just
            # left retires on the spot (capacity leaves the provisioned pool)
            if self.cordoned[i] and self.free_gpus[i] == self.total_gpus[i]:
                self.cordoned[i] = False
                self.retired[i] = True
                drained = True
        if drained:
            self._bump_topology()
        else:
            self._bump()

    def placement_speed(self, placement: Placement) -> float:
        """Effective speed of a gang placement = slowest member SKU."""
        return float(min(self.speeds[i] for i in placement)) if placement else 1.0

    # ------------------------------------------------------------------ faults --
    def fail_node(self, node_id: int) -> None:
        self.node_down[node_id] = True
        self._bump_topology()

    def recover_node(self, node_id: int) -> None:
        self.node_down[node_id] = False
        self._bump_topology()

    # -------------------------------------------------------- elastic capacity --
    def add_node(self, node: NodeSpec) -> int:
        """Append a node (autoscaling scale-up).  The given spec's
        ``node_id`` is ignored; the assigned id (== array index) is
        returned and also recorded in ``spec.nodes`` so rebuilt scratch
        clusters see the same topology."""
        nid = len(self.spec.nodes)
        node = NodeSpec(node_id=nid, gpu_type=node.gpu_type,
                        num_gpus=node.num_gpus, num_cpus=node.num_cpus,
                        mem_gb=node.mem_gb, speed=node.speed)
        self.spec.nodes.append(node)
        self.free_gpus = np.append(self.free_gpus, node.num_gpus)
        self.free_cpus = np.append(self.free_cpus, node.num_cpus)
        self.free_mem = np.append(self.free_mem, node.mem_gb)
        self.gpu_types = np.append(self.gpu_types, node.gpu_type)
        self.speeds = np.append(self.speeds, node.speed)
        self.total_gpus = np.append(self.total_gpus, node.num_gpus)
        self.node_down = np.append(self.node_down, False)
        self.cordoned = np.append(self.cordoned, False)
        self.retired = np.append(self.retired, False)
        self._rebuild_static_masks()
        self._bump_topology()
        return nid

    def remove_node(self, node_id: int) -> bool:
        """Retire a node (autoscaling scale-down).  An idle node retires
        immediately (returns ``True``); a node with live allocations is
        cordoned instead — excluded from placement but still provisioned —
        and auto-retires when its last job releases (returns ``False``)."""
        if not 0 <= node_id < len(self.total_gpus):
            raise ValueError(f"no such node {node_id}")
        if self.retired[node_id]:
            raise ValueError(f"node {node_id} already retired")
        if self.free_gpus[node_id] == self.total_gpus[node_id]:
            self.cordoned[node_id] = False
            self.retired[node_id] = True
            self._bump_topology()
            return True
        self.cordoned[node_id] = True
        self._bump_topology()
        return False

    def uncordon_node(self, node_id: int) -> None:
        """Cancel a pending drain (scale-up re-admits a draining node
        before paying for a fresh one).  No-op unless cordoned."""
        if self.cordoned[node_id]:
            self.cordoned[node_id] = False
            self._bump_topology()

    def provisioned_gpu_totals(self) -> tuple[int, dict[str, int]]:
        """``(total, {sku: total})`` GPUs on non-retired nodes — the
        capacity currently paid for (cordoned/draining nodes included).
        Memoized per ``topo_version`` (capacity only moves on topology
        mutations, never on allocate/release that doesn't drain a cordon)."""
        if self._prov_totals is not None \
                and self._prov_totals[0] == self.topo_version:
            return self._prov_totals[1]
        mask = ~self.retired
        totals = (int(self.total_gpus[mask].sum()),
                  {t: int(self.total_gpus[m & mask].sum())
                   for t, m in self._sku_masks.items()})
        self._prov_totals = (self.topo_version, totals)
        return totals

    # ------------------------------------------------------------------ stats ---
    def _up_ratio_pair(self) -> tuple[float, float]:
        """(utilization, fragmentation) over up nodes — memoized per version
        so per-job snapshot refreshes during a routed burst (no cluster
        mutation in between) are dict hits, not O(nodes) reductions.

        Utilization counts up *provisioned* nodes (cordoned nodes still
        hold busy GPUs the operator pays for); fragmentation counts only
        placeable free GPUs (free capacity on a draining node cannot host
        anything, so it must not read as usable-but-fragmented)."""
        if self.cache_enabled and self._up_ratios is not None:
            return self._up_ratios
        up = ~(self.node_down | self.retired)
        tot = int(self.total_gpus[up].sum())
        total_busy = float((self.total_gpus[up] - self.free_gpus[up]).sum())
        util = total_busy / tot if tot > 0 else 0.0
        free = self.free_gpus[up & ~self.cordoned]
        total_free = float(free.sum())
        frag = 0.0
        if total_free > 0:
            # sum of squares is maximal when all free GPUs sit on one node
            frag = 1.0 - float((free.astype(np.float64) ** 2).sum()) \
                / (total_free ** 2)
        pair = (util, frag)
        if self.cache_enabled:
            self._up_ratios = pair
        return pair

    def utilization(self, up_only: bool = False) -> float:
        """Busy-GPU fraction.  ``up_only`` restricts both numerator and
        denominator to up nodes — the view a federation router should see,
        where a fully-failed cluster reads 0.0 instead of dividing by its
        vanished capacity.  Guarded against zero-GPU / empty clusters."""
        if up_only:
            return self._up_ratio_pair()[0]
        mask = ~self.retired
        tot = int(self.total_gpus[mask].sum())
        return float((self.total_gpus[mask] - self.free_gpus[mask]).sum()
                     / max(tot, 1))

    def fragmentation(self, up_only: bool = False) -> float:
        """Cluster Fragmentation Factor, Eq. (3) (normalized to [0, 1]).
        ``up_only`` ignores free GPUs stranded on down nodes (they are not
        placeable, so they should not read as usable-but-fragmented).
        Returns 0.0 for zero-free / zero-GPU / empty clusters."""
        if up_only:
            return self._up_ratio_pair()[1]
        free = self.free_gpus[~self.retired]
        total_free = float(free.sum())
        if total_free <= 0:
            return 0.0
        # sum of squares is maximal when all free GPUs sit on one node
        conc = float((free.astype(np.float64) ** 2).sum()) \
            / (total_free ** 2)
        return 1.0 - conc
