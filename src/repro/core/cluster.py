"""Runtime cluster state: allocation tracking + placement enumeration.

The cluster tracks free GPUs/CPUs/memory per node, supports gang allocation
across nodes, and enumerates candidate placements ("ways") for a job:

- way1 "spread": prefer empty / least-loaded nodes (isolation, low contention)
- way2 "pack":   prefer most-loaded nodes that still fit (utilization)

The MILP module (Algorithm 1 of the paper) chooses between them.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ClusterSpec, Job

Placement = dict[int, int]  # node_id -> gpus taken


class ClusterState:
    """Mutable multi-resource state of a heterogeneous cluster."""

    def __init__(self, spec: ClusterSpec):
        self.spec = spec
        n = len(spec.nodes)
        self.free_gpus = np.array([nd.num_gpus for nd in spec.nodes], dtype=np.int64)
        self.free_cpus = np.array([nd.num_cpus for nd in spec.nodes], dtype=np.int64)
        self.free_mem = np.array([nd.mem_gb for nd in spec.nodes], dtype=np.float64)
        self.gpu_types = [nd.gpu_type for nd in spec.nodes]
        self.speeds = np.array([nd.speed for nd in spec.nodes], dtype=np.float64)
        self.total_gpus = np.array([nd.num_gpus for nd in spec.nodes], dtype=np.int64)
        self.node_down = np.zeros(n, dtype=bool)   # fault injection

    # ------------------------------------------------------------------ queries --
    def nodes_for(self, job: Job) -> np.ndarray:
        """Boolean mask of nodes whose SKU satisfies the job's request and are up."""
        ok = np.array([job.gpu_type in ("any", t) for t in self.gpu_types])
        return ok & ~self.node_down

    def free_gpus_of_type(self, gpu_type: str) -> int:
        if gpu_type == "any":
            return int(self.free_gpus[~self.node_down].sum())
        idx = [i for i, t in enumerate(self.gpu_types)
               if t == gpu_type and not self.node_down[i]]
        return int(self.free_gpus[idx].sum())

    def total_gpus_of_type(self, gpu_type: str) -> int:
        if gpu_type == "any":
            return int(self.total_gpus.sum())
        return int(sum(g for g, t in zip(self.total_gpus, self.gpu_types) if t == gpu_type))

    def _fits_node(self, job: Job, i: int, gpus: int) -> bool:
        """Would `gpus` GPUs of `job` fit on node i respecting CPU/mem coupling?"""
        if gpus <= 0 or gpus > self.free_gpus[i]:
            return False
        frac = gpus / max(job.num_gpus, 1)
        return (self.free_cpus[i] >= round(job.req_cpus * frac)
                and self.free_mem[i] >= job.req_mem_gb * frac)

    def can_schedule_now(self, job: Job) -> bool:
        return self.find_placement(job, mode="pack") is not None

    # -------------------------------------------------------------- placements --
    def find_placement(self, job: Job, mode: str = "pack") -> Placement | None:
        """Greedy gang placement. mode: 'pack' (most-loaded-first) or
        'spread' (least-loaded-first / fewest co-tenants)."""
        eligible = self.nodes_for(job)
        order = np.argsort(self.free_gpus if mode == "pack" else -self.free_gpus,
                           kind="stable")
        need = job.num_gpus
        placement: Placement = {}
        for i in order:
            if not eligible[i] or need <= 0:
                continue
            take = int(min(need, self.free_gpus[i]))
            # shrink until CPU/mem coupling fits
            while take > 0 and not self._fits_node(job, int(i), take):
                take -= 1
            if take > 0:
                placement[int(i)] = take
                need -= take
        return placement if need == 0 else None

    def candidate_ways(self, job: Job) -> list[Placement]:
        """Distinct candidate placements (spread & pack at minimum)."""
        ways: list[Placement] = []
        for mode in ("spread", "pack"):
            p = self.find_placement(job, mode)
            if p is not None and p not in ways:
                ways.append(p)
        # single-node way if the job fits whole on one eligible node
        eligible = self.nodes_for(job)
        for i in np.argsort(self.free_gpus, kind="stable"):
            if eligible[i] and self._fits_node(job, int(i), job.num_gpus):
                p = {int(i): job.num_gpus}
                if p not in ways:
                    ways.append(p)
                break
        return ways

    def num_ways_to_schedule(self, job: Job) -> int:
        return len(self.candidate_ways(job))

    # -------------------------------------------------------------- mutation ----
    def allocate(self, job: Job, placement: Placement) -> None:
        for i, g in placement.items():
            frac = g / max(job.num_gpus, 1)
            assert self.free_gpus[i] >= g, "GPU oversubscription"
            self.free_gpus[i] -= g
            self.free_cpus[i] -= round(job.req_cpus * frac)
            self.free_mem[i] -= job.req_mem_gb * frac
            assert self.free_cpus[i] >= 0 and self.free_mem[i] >= -1e-9

    def release(self, job: Job, placement: Placement) -> None:
        for i, g in placement.items():
            frac = g / max(job.num_gpus, 1)
            self.free_gpus[i] += g
            self.free_cpus[i] += round(job.req_cpus * frac)
            self.free_mem[i] += job.req_mem_gb * frac
            assert self.free_gpus[i] <= self.total_gpus[i], "double release"

    def placement_speed(self, placement: Placement) -> float:
        """Effective speed of a gang placement = slowest member SKU."""
        return float(min(self.speeds[i] for i in placement)) if placement else 1.0

    # ------------------------------------------------------------------ faults --
    def fail_node(self, node_id: int) -> None:
        self.node_down[node_id] = True

    def recover_node(self, node_id: int) -> None:
        self.node_down[node_id] = False

    # ------------------------------------------------------------------ stats ---
    def utilization(self) -> float:
        tot = int(self.total_gpus.sum())
        return float((self.total_gpus - self.free_gpus).sum() / max(tot, 1))

    def fragmentation(self) -> float:
        """Cluster Fragmentation Factor, Eq. (3) (normalized to [0, 1])."""
        total_free = float(self.free_gpus.sum())
        if total_free <= 0:
            return 0.0
        # sum of squares is maximal when all free GPUs sit on one node
        conc = float((self.free_gpus.astype(np.float64) ** 2).sum()) / (total_free ** 2)
        return 1.0 - conc
