"""Fault-tolerance model for the cluster simulator.

Node failures (Poisson per node), repair times, straggler (slow-node) events,
and job checkpoint/restart semantics: a killed job loses work back to its last
checkpoint and is re-queued.  The scheduler sees failures only through the
cluster state (fewer free GPUs, re-queued jobs aging) — consistent with the
paper's application-agnostic stance.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class FaultModel:
    """Configuration for failure injection."""

    mtbf_per_node: float = 30 * 86400.0      # mean time between failures, per node
    repair_time: float = 2 * 3600.0
    straggler_prob: float = 0.01             # P(node slows) per failure draw
    straggler_slowdown: float = 0.5          # speed multiplier while straggling
    straggler_duration: float = 4 * 3600.0
    ckpt_interval: float = 1800.0            # job checkpoint period (seconds)
    seed: int = 0


class FaultInjector:
    """Generates failure / recovery / straggler events for a cluster."""

    def __init__(self, model: FaultModel, num_nodes: int, horizon: float):
        self.model = model
        rng = np.random.default_rng(model.seed)
        self.events: list[tuple[float, str, int]] = []  # (time, kind, node)
        for node in range(num_nodes):
            t = 0.0
            while True:
                t += float(rng.exponential(model.mtbf_per_node))
                if t >= horizon:
                    break
                if rng.random() < model.straggler_prob:
                    heapq.heappush(self.events, (t, "slow", node))
                    heapq.heappush(self.events, (t + model.straggler_duration,
                                                 "unslow", node))
                else:
                    heapq.heappush(self.events, (t, "fail", node))
                    heapq.heappush(self.events, (t + model.repair_time, "recover", node))

    def next_event_time(self) -> float:
        return self.events[0][0] if self.events else float("inf")

    def pop_due(self, now: float) -> list[tuple[float, str, int]]:
        due = []
        while self.events and self.events[0][0] <= now + 1e-9:
            due.append(heapq.heappop(self.events))
        return due

    def checkpointed_progress(self, elapsed: float, runtime: float) -> float:
        """Fraction of work preserved at the last checkpoint boundary."""
        if runtime <= 0:
            return 0.0
        k = int(elapsed // self.model.ckpt_interval)
        return min(1.0, k * self.model.ckpt_interval / runtime)
