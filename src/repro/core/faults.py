"""Fault-tolerance model for the cluster simulator.

Node failures (Poisson per node), repair times, straggler (slow-node) events,
and job checkpoint/restart semantics: a killed job loses work back to its last
checkpoint and is re-queued.  The scheduler sees failures only through the
cluster state (fewer free GPUs, re-queued jobs aging) — consistent with the
paper's application-agnostic stance.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class FaultModel:
    """Configuration for failure injection."""

    mtbf_per_node: float = 30 * 86400.0      # mean time between failures, per node
    repair_time: float = 2 * 3600.0
    straggler_prob: float = 0.01             # P(node slows) per failure draw
    straggler_slowdown: float = 0.5          # speed multiplier while straggling
    straggler_duration: float = 4 * 3600.0
    ckpt_interval: float = 1800.0            # job checkpoint period (seconds)
    seed: int = 0


class FaultInjector:
    """Generates failure / recovery / straggler events for a cluster.

    Timelines are drawn per node from one sequential RNG at construction
    (deterministic in ``model.seed``), so two injectors over the same model
    and node count carry byte-identical event heaps.  Two invariants:

    - **Pair-closing**: every ``fail``/``slow`` pushes its matching
      ``recover``/``unslow`` companion even when the companion lands past
      ``horizon`` — only the *failure draw* is horizon-bounded, so a node
      can never end a run permanently failed or slowed by timeline
      truncation (pinned by ``tests/test_faults.py``).
    - **Extension determinism**: nodes added at runtime (autoscaler
      scale-ups) get their own timeline via :meth:`extend_node`, seeded by
      ``(model.seed, node_id)`` — independent of when the node appears and
      of every other node's draws, so a grown cluster replays identically.
    """

    def __init__(self, model: FaultModel, num_nodes: int, horizon: float):
        self.model = model
        self.num_nodes = num_nodes
        self.horizon = horizon
        rng = np.random.default_rng(model.seed)
        self.events: list[tuple[float, str, int]] = []  # (time, kind, node)
        for node in range(num_nodes):
            self._draw_timeline(rng, node, 0.0)

    def _draw_timeline(self, rng, node: int, start: float) \
            -> list[tuple[float, str, int]]:
        """Draw one node's failure/straggler timeline from ``start`` and
        push it onto the heap (in draw order, exactly as the seed
        constructor did).  Companion (recover/unslow) events are pushed
        unconditionally — the pair-close invariant.  Returns the pushed
        events."""
        model = self.model
        drawn: list[tuple[float, str, int]] = []
        t = start
        while True:
            t += float(rng.exponential(model.mtbf_per_node))
            if t >= self.horizon:
                break
            if rng.random() < model.straggler_prob:
                drawn.append((t, "slow", node))
                drawn.append((t + model.straggler_duration, "unslow", node))
            else:
                drawn.append((t, "fail", node))
                drawn.append((t + model.repair_time, "recover", node))
        for e in drawn:
            heapq.heappush(self.events, e)
        return drawn

    def extend_node(self, node: int, start: float) \
            -> list[tuple[float, str, int]]:
        """Seed a deterministic failure timeline for a node added at
        runtime (autoscaler scale-up), starting its MTBF clock at ``start``.
        The timeline is drawn from a fresh RNG seeded by ``(model.seed,
        node)``, so it depends only on the model and the node id — never on
        how many events the construction-time RNG consumed.  Returns the
        newly pushed events (the engine mirrors them as marker events)."""
        rng = np.random.default_rng([self.model.seed, node])
        drawn = self._draw_timeline(rng, node, start)
        self.num_nodes = max(self.num_nodes, node + 1)
        return drawn

    def next_event_time(self) -> float:
        return self.events[0][0] if self.events else float("inf")

    def pop_due(self, now: float) -> list[tuple[float, str, int]]:
        due = []
        while self.events and self.events[0][0] <= now + 1e-9:
            due.append(heapq.heappop(self.events))
        return due

    def checkpointed_progress(self, elapsed: float, runtime: float) -> float:
        """Fraction of work preserved at the last checkpoint boundary."""
        if runtime <= 0:
            return 0.0
        k = int(elapsed // self.model.ckpt_interval)
        return min(1.0, k * self.model.ckpt_interval / runtime)
