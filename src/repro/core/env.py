"""RL environment glue: prioritizers that drive the simulator.

RLPrioritizer implements the paper's RL pipeline: build state (FBM + feature
sampling), run the actor, return a ranking whose head is the sampled action
(exploration) or the greedy argmax (evaluation).

InspectorPrioritizer reimplements the *mechanism* of SchedInspector (Zhang et
al. '22) for the Table-9 comparison: a base heuristic proposes the ranking and
an RL gate decides execute-vs-skip for the head job.

NaiveRLPrioritizer (raw features, no sampling) + allocator="pack" reproduces
both naive-RLTune (Fig. 10) and the RLScheduler mechanism adapted to GPUs.

Streaming observe path (``streaming=True``): the prioritizer maintains
rolling EWMA statistics of the finished-job stream (``StreamStats``) fed by
the engine's ``observe_finish`` callback, and exposes ``record`` — a toggle
the episode cutter (``repro.rl``) flips to warm a congested cluster under
the current policy without recording warm-up decisions into the PPO buffer.
Defaults (``streaming=False, record=True``) keep the legacy batch pipeline
bit-identical on fixed seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.agent import PPOAgent
from repro.core.cluster import ClusterState
from repro.core.features import (CV_SIZE, MAX_QUEUE_SIZE, OV_SIZE,
                                 build_features, build_state,
                                 critic_features, pad_to_queue,
                                 sample_features)
from repro.core.policies import Policy
from repro.core.types import Job


@dataclasses.dataclass
class StreamStats:
    """Rolling EWMA view of the finished-job stream (streaming observe
    path).  The first finish seeds the averages; afterwards each finish
    moves them by ``alpha``."""

    alpha: float = 0.05
    finished: int = 0
    ewma_wait: float = 0.0
    ewma_jct: float = 0.0

    def update(self, job: Job) -> None:
        self.finished += 1
        a = 1.0 if self.finished == 1 else self.alpha
        self.ewma_wait += a * (job.wait_time - self.ewma_wait)
        self.ewma_jct += a * (job.jct - self.ewma_jct)


class RLPrioritizer:
    """The RLTune prioritizer (pro- or naive- variant)."""

    def __init__(self, agent: PPOAgent, *, explore: bool = True,
                 use_estimates: bool = False, raw_features: bool = False,
                 streaming: bool = False, deep_scorer=None):
        self.agent = agent
        self.explore = explore
        self.use_estimates = use_estimates
        self.raw_features = raw_features
        self.record = True
        self.stream_stats = StreamStats() if streaming else None
        #: opt-in deep-window tail scoring (a
        #: ``repro.kernels.batch_score.BucketedScorer`` over the actor's
        #: own weights): queue rows beyond the MAX_QUEUE_SIZE actor window
        #: are ordered by the bucketed fused-MLP logits instead of FIFO.
        #: ``None`` (default) keeps the FIFO tail — bit-identical to the
        #: pre-scorer prioritizer, pinned by tests.
        self.deep_scorer = deep_scorer

    def set_mode(self, *, explore: bool | None = None,
                 record: bool | None = None) -> None:
        """Flip exploration/recording mid-stream (warm-up, greedy eval)."""
        if explore is not None:
            self.explore = explore
        if record is not None:
            self.record = record

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        return self._rank(jobs, cluster, now, None)

    def rank_window(self, jobs: list[Job], cluster: ClusterState, now: float,
                    fields) -> list[int]:
        """``rank`` over the engine's contiguous ``WindowFields`` views: the
        FBM feature matrix is built with vectorized column ops instead of
        the O(window * 17) scalar loop — bit-identical features, hence
        bit-identical actions and ranking (differential-pinned)."""
        return self._rank(jobs, cluster, now, fields)

    def _rank(self, jobs, cluster, now, fields) -> list[int]:
        n = min(len(jobs), MAX_QUEUE_SIZE)
        tail_logits = None
        if self.deep_scorer is not None and len(jobs) > MAX_QUEUE_SIZE:
            # one FBM pass over the whole window: the head state is built
            # from the exact rows build_state would produce (same feats ->
            # same act), and the tail rows are batch-scored through the
            # shape-bucketed fused-MLP kernel
            feats = build_features(jobs, cluster, now,
                                   use_estimates=self.use_estimates,
                                   fields=fields)
            if self.raw_features:
                ov_full = feats[:, :OV_SIZE]
            else:
                ov_full, _ = sample_features(feats, cluster)
            mask = np.zeros((MAX_QUEUE_SIZE,), dtype=np.float32)
            mask[:n] = 1.0
            ov = pad_to_queue(ov_full, OV_SIZE)
            cv = pad_to_queue(critic_features(feats), CV_SIZE)
            tail_logits = self.deep_scorer.score(ov_full[n:])
        else:
            ov, cv, mask = build_state(jobs, cluster, now,
                                       use_estimates=self.use_estimates,
                                       raw=self.raw_features, fields=fields)
        action, logits = self.agent.act(ov, cv, mask, explore=self.explore,
                                        record=self.explore and self.record)
        order = list(np.argsort(-logits[:n], kind="stable"))
        if action < n:
            order.remove(action)
            order.insert(0, action)
        if tail_logits is not None:
            # deep-window mode: tail ordered by the bucketed scorer
            # (stable argsort keeps FIFO among exact ties)
            order += [int(n + i)
                      for i in np.argsort(-tail_logits, kind="stable")]
        else:
            # jobs beyond the fixed-size window keep FIFO order at the tail
            order += list(range(n, len(jobs)))
        return order

    def observe_finish(self, job: Job) -> None:
        if self.stream_stats is not None:
            self.stream_stats.update(job)


class InspectorPrioritizer:
    """SchedInspector mechanism: base-policy ranking + RL execute/skip gate.

    The gate reuses the PPO agent with a 2-way action space encoded by
    restricting the mask to the first two queue slots: slot0 = execute the
    base decision, slot1 = skip this round (head job demoted once).
    """

    def __init__(self, agent: PPOAgent, base_policy: Policy, *,
                 explore: bool = True, use_estimates: bool = False):
        self.agent = agent
        self.base = base_policy
        self.explore = explore
        self.use_estimates = use_estimates

    def rank(self, jobs: list[Job], cluster: ClusterState, now: float) -> list[int]:
        scores = [self.base.score(j, now) for j in jobs]
        order = list(np.argsort(scores, kind="stable"))
        ov, cv, _ = build_state([jobs[i] for i in order], cluster, now,
                                use_estimates=self.use_estimates)
        gate_mask = np.zeros((MAX_QUEUE_SIZE,), dtype=np.float32)
        gate_mask[:min(2, len(jobs))] = 1.0
        action, _ = self.agent.act(ov, cv, gate_mask, explore=self.explore,
                                   record=self.explore)
        if action == 1 and len(order) > 1:   # skip: demote the head once
            order.append(order.pop(0))
        return order

    def observe_finish(self, job: Job) -> None:
        self.base.observe_finish(job)
