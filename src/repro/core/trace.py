"""Trace generation and loading.

Real Philly / Helios / Alibaba traces are not redistributable, so the default
path is a *statistically matched* synthetic generator per trace (Table 2 and
Table 4 of the paper): arrival rate, runtime scale, GPU-demand mix, user
population, burstiness.  A CSV loader accepts the real traces when available
(columns: job_id,user,submit_time,runtime,num_gpus[,gpu_type][,vc]).

Burstiness is modeled with a 2-state Markov-modulated Poisson process (calm /
burst), matching the paper's observation (Fig. 6) that batch-wise congestion
is highly non-stationary.
"""
from __future__ import annotations

import csv
import dataclasses
import math

import numpy as np

from repro.core.types import ClusterSpec, Job, NodeSpec

# ----------------------------------------------------------------------------------
# Trace profiles (Table 2: arrival rates & runtimes; Table 4: GPU types / clusters)
# ----------------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceProfile:
    name: str
    arrival_rate: float              # jobs/s (Table 2)
    runtime_mean: float              # s (Table 2)
    runtime_sigma: float             # lognormal sigma
    gpu_demand: tuple[tuple[int, float], ...]   # (num_gpus, prob)
    gpu_types: tuple[tuple[str, float], ...]    # (type, request prob); "any" allowed
    num_users: int
    burst_factor: float = 6.0        # arrival-rate multiplier in burst state
    burst_prob: float = 0.08         # P(calm->burst) per arrival
    calm_prob: float = 0.35          # P(burst->calm) per arrival
    est_noise_sigma: float = 0.9     # lognormal noise on user estimates
    max_runtime: float = 60 * 86400.0
    archs: tuple[str, ...] = ()      # workload architectures (informational)


_ARCH_POOL = (
    "internvl2-2b", "mamba2-780m", "qwen3-moe-235b-a22b", "granite-moe-1b-a400m",
    "jamba-v0.1-52b", "nemotron-4-15b", "stablelm-1.6b", "yi-6b",
    "h2o-danube-1.8b", "whisper-tiny",
)

PHILLY = TraceProfile(
    name="philly",
    arrival_rate=0.022333,
    runtime_mean=26299.2,
    runtime_sigma=2.1,
    # Philly: heavy multi-GPU mix, long jobs (ATC'19 analysis)
    gpu_demand=((1, 0.48), (2, 0.17), (4, 0.12), (8, 0.16), (16, 0.05), (32, 0.02)),
    gpu_types=(("P100", 0.75), ("any", 0.25)),
    num_users=319,
    burst_factor=4.0,
    max_runtime=60 * 86400.0,
    archs=_ARCH_POOL,
)

HELIOS = TraceProfile(
    name="helios",
    arrival_rate=0.032919,
    runtime_mean=2481.4,
    runtime_sigma=1.9,
    gpu_demand=((1, 0.60), (2, 0.15), (4, 0.12), (8, 0.11), (16, 0.02)),
    gpu_types=(("V100", 0.55), ("P100", 0.25), ("any", 0.20)),
    num_users=277,
    burst_factor=7.0,
    max_runtime=50 * 86400.0,
    archs=_ARCH_POOL,
)

ALIBABA = TraceProfile(
    name="alibaba",
    arrival_rate=0.077136,
    runtime_mean=5466.3,
    runtime_sigma=2.0,
    gpu_demand=((1, 0.78), (2, 0.12), (4, 0.06), (8, 0.04)),
    gpu_types=(("T4", 0.35), ("P100", 0.15), ("V100", 0.25), ("any", 0.25)),
    num_users=1242,
    burst_factor=8.0,
    max_runtime=30 * 86400.0,
    archs=_ARCH_POOL,
)

PROFILES: dict[str, TraceProfile] = {"philly": PHILLY, "helios": HELIOS, "alibaba": ALIBABA}


# ----------------------------------------------------------------------------------
# Cluster slices (Sec. 4.2: representative slices keeping realistic contention)
# ----------------------------------------------------------------------------------


def make_cluster(name: str) -> ClusterSpec:
    """Representative cluster slice per trace (Sec. 4.2 of the paper)."""
    nodes: list[NodeSpec] = []
    nid = 0

    def add(n: int, gpu_type: str, gpus: int, cpus: int, mem: float, speed: float) -> None:
        nonlocal nid
        for _ in range(n):
            nodes.append(NodeSpec(nid, gpu_type, gpus, cpus, mem, speed))
            nid += 1

    if name == "philly":
        # P100 2-GPU and 8-GPU SKUs (Table 4)
        add(8, "P100", 2, 16, 128.0, 1.0)
        add(10, "P100", 8, 64, 512.0, 1.0)
    elif name == "helios":
        # VC slice: 10 nodes x 8 GPUs, mixed Pascal/Volta (Table 4, Sec 4.2 —
        # slice sized to keep realistic contention for the trace arrival rate)
        add(5, "P100", 8, 64, 512.0, 1.0)
        add(5, "V100", 8, 64, 512.0, 1.5)
    elif name == "alibaba":
        add(8, "T4", 2, 32, 256.0, 0.6)
        add(6, "P100", 2, 32, 256.0, 1.0)
        add(8, "V100", 8, 96, 768.0, 1.5)
    elif name == "slurm-testbed":
        # Sec. 5.6 heterogeneous testbed: 2xP100(4), 2xK80(2), 1xM40(1)
        add(2, "P100", 4, 32, 256.0, 1.0)
        add(2, "K80", 2, 16, 128.0, 0.4)
        add(1, "M40", 1, 8, 64.0, 0.5)
    else:
        raise ValueError(f"unknown cluster {name!r}")
    return ClusterSpec(nodes=nodes, name=name)


# ----------------------------------------------------------------------------------
# Synthetic generator
# ----------------------------------------------------------------------------------


def generate_trace(profile: TraceProfile | str, num_jobs: int, seed: int = 0) -> list[Job]:
    """Generate `num_jobs` jobs matching a trace profile. Deterministic in seed."""
    if isinstance(profile, str):
        profile = PROFILES[profile]
    rng = np.random.default_rng(seed)

    demands, dprobs = zip(*profile.gpu_demand)
    types, tprobs = zip(*profile.gpu_types)
    dprobs = np.asarray(dprobs) / sum(dprobs)
    tprobs = np.asarray(tprobs) / sum(tprobs)

    # lognormal runtimes matching the trace mean
    sigma = profile.runtime_sigma
    mu = math.log(profile.runtime_mean) - 0.5 * sigma * sigma

    # zipf-ish user popularity
    user_w = 1.0 / np.arange(1, profile.num_users + 1) ** 1.1
    user_w /= user_w.sum()

    jobs: list[Job] = []
    t = 0.0
    bursty = False
    for i in range(num_jobs):
        rate = profile.arrival_rate * (profile.burst_factor if bursty else 1.0)
        t += float(rng.exponential(1.0 / rate))
        if bursty:
            if rng.random() < profile.calm_prob:
                bursty = False
        elif rng.random() < profile.burst_prob:
            bursty = True

        runtime = float(np.clip(rng.lognormal(mu, sigma), 30.0, profile.max_runtime))
        est = float(np.clip(runtime * rng.lognormal(0.0, profile.est_noise_sigma),
                            30.0, profile.max_runtime * 2))
        jobs.append(Job(
            job_id=i,
            user=int(rng.choice(profile.num_users, p=user_w)),
            submit_time=t,
            runtime=runtime,
            est_runtime=est,
            num_gpus=int(rng.choice(demands, p=dprobs)),
            gpu_type=str(rng.choice(types, p=tprobs)),
            vc=int(rng.integers(0, 5)),
            arch=str(rng.choice(profile.archs)) if profile.archs else "",
        ))
    return jobs


#: Stand-in runtime for unknown-duration jobs with no estimate either.
DEFAULT_UNKNOWN_RUNTIME_S = 3600.0


def load_trace_csv(path: str) -> list[Job]:
    """Load a real trace in the normalized CSV schema.

    A missing or empty ``runtime`` cell marks the job unknown-duration
    (``duration_known=False``): its ``runtime`` falls back to the declared
    estimate (or :data:`DEFAULT_UNKNOWN_RUNTIME_S` when that is absent too)
    and the runtime predictor, not the declared value, is expected to serve
    its reservations.  Real traces routinely drop durations for killed or
    still-running jobs — rejecting the whole file over them loses the rest.
    """
    jobs: list[Job] = []
    with open(path, newline="") as f:
        for i, row in enumerate(csv.DictReader(f)):
            raw_rt = (row.get("runtime") or "").strip()
            raw_est = (row.get("est_runtime") or "").strip()
            known = bool(raw_rt)
            if known:
                rt = float(raw_rt)
                est = float(raw_est) if raw_est else rt
            else:
                est = float(raw_est) if raw_est \
                    else DEFAULT_UNKNOWN_RUNTIME_S
                rt = est
            jobs.append(Job(
                job_id=int(row.get("job_id", i)),
                user=int(row.get("user", 0)),
                submit_time=float(row["submit_time"]),
                runtime=rt,
                est_runtime=est,
                num_gpus=int(row["num_gpus"]),
                gpu_type=row.get("gpu_type", "any") or "any",
                vc=int(row.get("vc", 0) or 0),
                duration_known=known,
            ))
    jobs.sort(key=lambda j: j.submit_time)
    return jobs


def batch_iter(jobs: list[Job], batch_size: int = 256):
    """Yield consecutive job batches (the paper trains on batches of 256)."""
    for i in range(0, len(jobs) - batch_size + 1, batch_size):
        yield jobs[i:i + batch_size]


def train_eval_split(jobs: list[Job], train_frac: float = 0.9) -> tuple[list[Job], list[Job]]:
    """90/10 split per Sec. 3.1.1."""
    k = int(len(jobs) * train_frac)
    return jobs[:k], jobs[k:]
