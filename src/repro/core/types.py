"""Core datatypes for the RLTune scheduler.

Jobs and nodes mirror the visible metadata available in the Philly / Helios /
Alibaba traces (Table 4 of the paper): the scheduler is application-agnostic,
so a Job carries *only* user-submitted metadata — never model semantics.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class JobState(enum.Enum):
    """Job lifecycle states.  Transitions between them are *enforced*: the
    legal-move map lives in ``repro.lifecycle.machine`` and every engine /
    controller path goes through ``lifecycle.transition``, which raises on
    an illegal move instead of silently corrupting scheduler state."""

    PENDING = 0      # queued, waiting for a placement
    RUNNING = 1
    COMPLETED = 2
    FAILED = 3
    PAUSED = 4       # checkpointed and suspended; holds no GPUs, not queued
    PREEMPTED = 5    # evicted by the preemption controller (transient)
    MIGRATING = 6    # withdrawn from one cluster, in flight to another


@dataclasses.dataclass(slots=True)
class Job:
    """A DL job as seen by the scheduler (visible features only).

    ``slots=True``: the scheduler hot path reads job fields millions of
    times per stream (batch scoring, feasibility shapes, backfill checks);
    slot access skips the per-instance dict and measurably speeds the
    decision loop."""

    job_id: int
    user: int
    submit_time: float          # seconds since trace start
    runtime: float              # ground-truth runtime (training reward signal)
    est_runtime: float          # user-provided (noisy) estimate, used at eval
    num_gpus: int               # gang-scheduled GPU demand (current target)
    gpu_type: str = "any"       # requested accelerator SKU ("any" = flexible)
    vc: int = 0                 # virtual cluster id
    req_cpus: int = 0           # 0 => inferred from GPU share
    req_mem_gb: float = 0.0     # 0 => inferred from GPU share
    arch: str = ""              # informational only (NOT visible to the agent)
    deadline: float = -1.0      # absolute SLO deadline (seconds); < 0 = none
    # elastic gang bounds: a job is elastic iff 0 < min_gpus < max_gpus;
    # the preemption controller may resize num_gpus inside [min, max]
    min_gpus: int = 0
    max_gpus: int = 0
    # False when the source trace carried no duration for this job — its
    # ``runtime`` is a stand-in (est_runtime or a default) and the runtime
    # predictor, not the declared estimate, should serve its reservations
    duration_known: bool = True

    # -- mutable scheduling state -------------------------------------------------
    state: JobState = JobState.PENDING
    start_time: float = -1.0
    finish_time: float = -1.0
    first_start_time: float = -1.0   # very first RUNNING instant, never reset
    placement: Optional[dict[int, int]] = None   # node_id -> gpus taken
    restarts: int = 0
    progress_at_ckpt: float = 0.0  # fraction of work checkpointed (fault tolerance)
    base_gpus: int = 0             # num_gpus as submitted (runtime reference)

    def __post_init__(self) -> None:
        if self.req_cpus <= 0:
            # GPU-proportionate CPU allocation (Sec. 2 of the paper)
            self.req_cpus = max(1, 4 * self.num_gpus)
        if self.req_mem_gb <= 0:
            self.req_mem_gb = 32.0 * self.num_gpus
        if self.base_gpus <= 0:
            self.base_gpus = self.num_gpus

    @property
    def elastic(self) -> bool:
        """May the scheduler resize this gang?  ``runtime`` is defined at
        ``base_gpus``; work rate scales linearly with the current gang."""
        return 0 < self.min_gpus < self.max_gpus

    @property
    def has_deadline(self) -> bool:
        return self.deadline >= 0.0

    # -- derived metrics ------------------------------------------------------------
    @property
    def wait_time(self) -> float:
        # first_start_time survives preempt/resume cycles; start_time is kept
        # as the legacy alias (the engine only ever sets it once as well)
        started = self.first_start_time if self.first_start_time >= 0 \
            else self.start_time
        if started < 0:
            raise RuntimeError(
                f"job {self.job_id} never started (state={self.state.name}); "
                f"wait_time is undefined")
        return started - self.submit_time

    @property
    def jct(self) -> float:
        if self.finish_time < 0:
            raise RuntimeError(
                f"job {self.job_id} never finished (state={self.state.name}); "
                f"jct is undefined")
        return self.finish_time - self.submit_time

    def bsld(self, tau: float = 10.0) -> float:
        """Bounded slowdown (Feitelson & Rudolph), bound tau seconds."""
        return max(1.0, self.jct / max(self.runtime, tau))

    def clone_pending(self) -> "Job":
        """A fresh PENDING copy (for replaying the same batch through two
        pipelines).  Resets to the *submitted* gang size: a clone of a
        resized elastic job asks for its original demand again."""
        return Job(
            job_id=self.job_id, user=self.user, submit_time=self.submit_time,
            runtime=self.runtime, est_runtime=self.est_runtime,
            num_gpus=self.base_gpus or self.num_gpus, gpu_type=self.gpu_type,
            vc=self.vc, req_cpus=self.req_cpus, req_mem_gb=self.req_mem_gb,
            arch=self.arch, deadline=self.deadline, min_gpus=self.min_gpus,
            max_gpus=self.max_gpus, duration_known=self.duration_known,
        )


@dataclasses.dataclass(slots=True)
class NodeSpec:
    """Static description of one node in a heterogeneous cluster."""

    node_id: int
    gpu_type: str
    num_gpus: int
    num_cpus: int
    mem_gb: float
    # relative speed of this SKU vs the trace's reference GPU; the simulator
    # scales runtimes by 1/speed when a job lands on a faster/slower SKU.
    speed: float = 1.0


@dataclasses.dataclass
class ClusterSpec:
    """A heterogeneous cluster: an ordered list of node specs."""

    nodes: list[NodeSpec]
    name: str = "cluster"

    @property
    def total_gpus(self) -> int:
        return sum(n.num_gpus for n in self.nodes)

    @property
    def gpu_types(self) -> list[str]:
        return sorted({n.gpu_type for n in self.nodes})

    def gpus_of_type(self, t: str) -> int:
        return sum(n.num_gpus for n in self.nodes if n.gpu_type == t)
