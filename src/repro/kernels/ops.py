"""jit'd public wrappers around the Pallas kernels.

Handle layout plumbing (GQA broadcast, head-dim padding, chunk padding) and
auto-select interpret mode off-TPU so the same call sites work everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.moe_router import moe_router as _moe_router
from repro.kernels.policy_mlp import policy_mlp as _policy_mlp
from repro.kernels.predict_mlp import predict_mlp as _predict_mlp
from repro.kernels.ssd_scan import ssd_scan_bh


def _interpret(flag: bool | None) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


def _pad_last(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    d = x.shape[-1]
    pad = (-d) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, L, D); k, v: (B, KV, L, D) -> (B, H, L, D)."""
    B, H, L, D = q.shape
    KV = k.shape[1]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=1)
        v = jnp.repeat(v, H // KV, axis=1)
    qf, D0 = _pad_last(q.reshape(B * H, L, D), 128)
    kf, _ = _pad_last(k.reshape(B * H, L, D), 128)
    vf, _ = _pad_last(v.reshape(B * H, L, D), 128)
    out = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=_interpret(interpret),
                             sm_scale=1.0 / (D0 ** 0.5))
    return out[..., :D0].reshape(B, H, L, D0)


def ssd_scan(xh, dt, A, Bs, Cs, *, chunk: int = 256, init_state=None,
             interpret: bool | None = None):
    """Layout-matching wrapper for models.mamba.ssd_chunked.

    xh: (B, L, H, P); dt: (B, L, H); A: (H,); Bs/Cs: (B, L, N).
    Returns (y (B, L, H, P), final_state (B, H, P, N)) — the final state is
    recomputed with one jnp pass (cheap relative to the scan itself)."""
    B, L, H, P = xh.shape
    x_bh = xh.transpose(0, 2, 1, 3)                     # (B, H, L, P)
    dt_bh = dt.transpose(0, 2, 1)[..., None]            # (B, H, L, 1)
    chunk = min(chunk, L)
    y = ssd_scan_bh(x_bh, dt_bh, A, Bs, Cs, chunk=chunk,
                    interpret=_interpret(interpret))
    y = y.transpose(0, 2, 1, 3)
    # final state via closed form (needed only at prefill->decode handoff)
    a = dt_bh[..., 0] * A[None, :, None]                # (B, H, L)
    cs = jnp.cumsum(a, axis=-1)
    total = cs[..., -1:]
    carry = jnp.exp(total - cs)                          # (B, H, L)
    xdt = x_bh.astype(jnp.float32) * dt_bh
    S = jnp.einsum("bhlp,bln,bhl->bhpn", xdt, Bs.astype(jnp.float32), carry)
    if init_state is not None:
        S0 = init_state.astype(jnp.float32)              # (B, H, P, N)
        S = S + S0 * jnp.exp(total)[..., None]
        # y also owes the initial state's contribution: exp(cs_t) C_t . S0
        y_init = jnp.einsum("bln,bhpn,bhl->blhp", Cs.astype(jnp.float32), S0,
                            jnp.exp(cs))
        y = (y.astype(jnp.float32) + y_init).astype(y.dtype)
    return y, S


def policy_mlp(x, params: list[dict], mask, *, interpret: bool | None = None):
    """Actor forward via the fused kernel. params = agent.params['actor']."""
    w1, b1 = params[0]["w"], params[0]["b"]
    w2, b2 = params[1]["w"], params[1]["b"]
    w3, b3 = params[2]["w"], params[2]["b"]
    return _policy_mlp(x, w1, b1, w2, b2, w3, b3, mask,
                       interpret=_interpret(interpret))


def predict_mlp(x, params: dict, *, interpret: bool | None = None):
    """Runtime-predictor forward via the fused kernel.

    params = ``repro.predict.QuantileMLP.params`` (keys w1/b1/w2/b2/w3/b3).
    Returns per-quantile log-runtime residuals (B, Q) in f32."""
    return _predict_mlp(x, params["w1"], params["b1"], params["w2"],
                        params["b2"], params["w3"], params["b3"],
                        interpret=_interpret(interpret))


def moe_router(x, router_w, k: int, *, interpret: bool | None = None):
    T = x.shape[0]
    block_t = 256 if T % 256 == 0 else T
    return _moe_router(x, router_w, k, block_t=block_t,
                       interpret=_interpret(interpret))
