"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid (B, H, nc) with the chunk axis innermost: the (P, N) state carries in
f32 VMEM scratch across chunks of one (batch, head).  Each chunk does the
quadratic intra-chunk piece as (Q x Q) MXU matmuls plus the state
update/output — the SSD formulation's whole point is that chunk-level
matmuls replace the length-L sequential scan (TPU-friendly).

Block shapes: Q (chunk) and N (state) are MXU-aligned by the wrapper
(pad N to 128 lanes when smaller); P = head_dim rides in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_scr, *,
                chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)
    A = a_ref[0].astype(jnp.float32)           # (1,) per-head decay rate
    Bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)          # (Q, N)

    a = dt * A                                 # (Q, 1) log-decay steps
    cs = jnp.cumsum(a, axis=0)                 # inclusive
    # intra-chunk: W[i,j] = (C_i . B_j) * exp(cs_i - cs_j) for j <= i
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    decay = jnp.exp(cs - cs.T)                 # (Q, Q) broadcast over columns
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    W = jnp.where(jj <= ii, G * decay, 0.0)
    xdt = x * dt                               # (Q, P)
    y = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y += exp(cs_i) * C_i . S   (S: (P, N))
    y += jnp.exp(cs) * jax.lax.dot_general(
        Cm, s_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: S' = exp(total) * S + sum_j exp(total - cs_j) x_j B_j^T
    total = cs[-1:, :]                         # (1, 1)
    carry = jnp.exp(total - cs)                # (Q, 1)
    dS = jax.lax.dot_general(xdt * carry, Bm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    s_scr[...] = s_scr[...] * jnp.exp(total[0, 0]) + dS


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bh(x, dt, A, Bs, Cs, *, chunk: int = 256,
                interpret: bool = False):
    """x: (B, H, L, P); dt: (B, H, L, 1); A: (H,); Bs/Cs: (B, L, N).
    Returns y: (B, H, L, P).  L % chunk == 0 (wrapper pads)."""
    B, H, L, P = x.shape
    N = Bs.shape[-1]
    assert L % chunk == 0
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bs, Cs)
