"""Shape-bucketed JIT batch scoring over the fused policy-MLP kernel.

Deep queue windows (qw >> MAX_QUEUE_SIZE=256) leave the actor blind to the
tail: ``RLPrioritizer`` ranks the first 256 jobs and keeps everything beyond
in FIFO order.  ``BucketedScorer`` scores arbitrary-length feature batches
through the same fused Pallas MLP (``kernels/policy_mlp.py``) so the tail
can be ordered by the policy too — the batch is padded up to a power-of-two
bucket, so ``jax.jit`` compiles once per bucket (log2 many shapes across a
whole run) instead of once per distinct queue depth.  Batches beyond the
largest bucket are scored in bucket-size chunks.

Off-TPU the kernel auto-selects interpret mode (same convention as
``kernels.ops``), which keeps the path importable and correct anywhere the
jax toolchain exists; the MXU win only materializes on real hardware.  The
scorer is opt-in end to end — nothing routes through it unless a caller
passes one to ``RLPrioritizer(deep_scorer=...)``.
"""
from __future__ import annotations

import numpy as np

#: bucket ladder bounds: smallest bucket matches the actor window, largest
#: caps compile count (and VMEM footprint) at 16k-deep benches
MIN_BUCKET = 256
MAX_BUCKET = 16384


def bucket_for(n: int, *, lo: int = MIN_BUCKET, hi: int = MAX_BUCKET) -> int:
    """Smallest power-of-two bucket >= n, clamped to [lo, hi]."""
    b = lo
    while b < n and b < hi:
        b <<= 1
    return b


class BucketedScorer:
    """Batch-score (n, F) feature rows with the fused policy MLP.

    ``params`` is the actor parameter list (``agent.params["actor"]``:
    three ``{"w", "b"}`` layers).  ``score`` pads the batch to its bucket,
    runs the Pallas kernel once per chunk, and returns the real rows'
    logits as float32 numpy.  ``compiled_buckets`` exposes which bucket
    shapes have been traced — tests pin that repeated nearby sizes reuse
    one compilation.
    """

    def __init__(self, params: list[dict], *, interpret: bool | None = None,
                 max_bucket: int = MAX_BUCKET):
        self.params = params
        self.interpret = interpret
        self.max_bucket = int(max_bucket)
        self._buckets: set[int] = set()

    @property
    def compiled_buckets(self) -> tuple[int, ...]:
        return tuple(sorted(self._buckets))

    def _score_bucket(self, x_pad: np.ndarray, mask: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        self._buckets.add(x_pad.shape[0])
        out = ops.policy_mlp(x_pad, self.params, mask,
                             interpret=self.interpret)
        return np.asarray(out, dtype=np.float32)

    def score(self, feats: np.ndarray) -> np.ndarray:
        """(n, F) float32 rows -> (n,) float32 logits (masked rows never
        leak: padding is scored at -1e9 and sliced away)."""
        feats = np.asarray(feats, dtype=np.float32)
        n = feats.shape[0]
        if n == 0:
            return np.zeros((0,), dtype=np.float32)
        out = np.empty((n,), dtype=np.float32)
        for lo in range(0, n, self.max_bucket):
            chunk = feats[lo:lo + self.max_bucket]
            m = chunk.shape[0]
            b = bucket_for(m, hi=self.max_bucket)
            x_pad = np.zeros((b, feats.shape[1]), dtype=np.float32)
            x_pad[:m] = chunk
            mask = np.zeros((b,), dtype=np.float32)
            mask[:m] = 1.0
            out[lo:lo + m] = self._score_bucket(x_pad, mask)[:m]
        return out
