"""Fused RLTune policy-MLP Pallas kernel — the paper's inference hot path.

One kernel evaluates the 3-layer actor MLP over the whole 256-job queue
(sliding-window shared weights), applies the queue mask, and emits logits:
x(256,8) -> tanh(xW1+b1) -> tanh(.W2+b2) -> .W3+b3 -> mask.  Everything fits
in VMEM (a few KB), so fusion removes all HBM round-trips between layers —
this is what keeps the paper's ~0.7 ms decision latency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _policy_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                   mask_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.tanh(jax.lax.dot_general(
        x, w1_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b1_ref[...])
    h = jnp.tanh(jax.lax.dot_general(
        h, w2_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b2_ref[...])
    logits = jax.lax.dot_general(
        h, w3_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b3_ref[...]
    logits = logits[:, 0]
    o_ref[...] = jnp.where(mask_ref[...] > 0, logits, -1e9).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def policy_mlp(x, w1, b1, w2, b2, w3, b3, mask, *, interpret: bool = False):
    """x: (Q, F); w1: (F, H1); w2: (H1, H2); w3: (H2, 1); mask: (Q,).
    Returns masked logits (Q,) in f32."""
    Q = x.shape[0]
    return pl.pallas_call(
        _policy_kernel,
        grid=(),
        in_specs=[pl.BlockSpec(x.shape, None), pl.BlockSpec(w1.shape, None),
                  pl.BlockSpec(b1.shape, None), pl.BlockSpec(w2.shape, None),
                  pl.BlockSpec(b2.shape, None), pl.BlockSpec(w3.shape, None),
                  pl.BlockSpec(b3.shape, None), pl.BlockSpec(mask.shape, None)],
        out_specs=pl.BlockSpec((Q,), None),
        out_shape=jax.ShapeDtypeStruct((Q,), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3, mask)
