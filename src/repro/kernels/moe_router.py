"""Fused MoE router Pallas kernel: logits -> top-k -> softmax over the k.

Grid over token blocks; the router weight (d, E) stays resident in VMEM
across the grid (index_map constant), the token block (bt, d) streams in,
and the iterative top-k (k is small: 2/8) runs as k masked row-max passes —
avoiding an HBM round trip for the (T, E) logits and the sort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_kernel(x_ref, w_ref, wout_ref, iout_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                   # (bt, d)
    w = w_ref[...].astype(jnp.float32)                   # (d, E)
    logits = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    bt, E = logits.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    work = logits
    vals = []
    idxs = []
    for _ in range(k):
        m = jnp.max(work, axis=1, keepdims=True)         # (bt, 1)
        amax = jnp.argmax(work, axis=1)                  # (bt,)
        vals.append(m[:, 0])
        idxs.append(amax.astype(jnp.int32))
        work = jnp.where(cols == amax[:, None], NEG_INF, work)
    v = jnp.stack(vals, axis=1)                          # (bt, k)
    i = jnp.stack(idxs, axis=1)                          # (bt, k)
    p = jax.nn.softmax(v, axis=1)
    wout_ref[...] = p.astype(wout_ref.dtype)
    iout_ref[...] = i


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def moe_router(x, router_w, k: int, *, block_t: int = 256,
               interpret: bool = False):
    """x: (T, d); router_w: (d, E).  Returns (weights (T,k) f32, idx (T,k) i32)."""
    T, d = x.shape
    E = router_w.shape[1]
    block_t = min(block_t, T)
    assert T % block_t == 0
    kernel = functools.partial(_router_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(T // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, E), lambda i: (0, 0)),     # resident in VMEM
        ],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((T, k), jnp.float32),
                   jax.ShapeDtypeStruct((T, k), jnp.int32)],
        interpret=interpret,
    )(x, router_w)
