"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA).

Blockwise online-softmax attention: grid (B*H, nq, nk) with the kv axis
innermost so VMEM scratch (acc, m, l) carries across kv blocks of one
(head, q-block).  Causal + SWA handled by block skipping (pl.when) and an
in-block position mask.  MXU alignment: block sizes are multiples of 128 on
the seq dims; head_dim is padded to 128 lanes by the wrapper in ops.py.

TPU adaptation of the GPU flash algorithm: instead of warp-level tiling we
tile for VMEM residency (q block + kv block + f32 accumulators must fit) and
let the MXU consume (bq x d) @ (d x bk) whole-block matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  sm_scale: float, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                   # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        acc_scr[...] = acc_scr[...] * alpha + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal or window > 0:
        # skip fully-masked kv blocks
        ok = k_start <= q_start + block_q - 1
        if window > 0:
            ok &= k_start + block_k - 1 > q_start - window
        pl.when(ok)(compute)
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "sm_scale"))
def flash_attention_bh(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False, sm_scale: float | None = None):
    """q: (BH, L, D); k, v: (BH, Lk, D) — kv already broadcast per q-head.
    Returns (BH, L, D).  sm_scale: pass 1/sqrt(unpadded head_dim) when D is
    lane-padded."""
    BH, L, D = q.shape
    Lk = k.shape[1]
    block_q = min(block_q, L)
    block_k = min(block_k, Lk)
    assert L % block_q == 0 and Lk % block_k == 0
    nq, nk = L // block_q, Lk // block_k
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, sm_scale=sm_scale, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, D), q.dtype),
        scratch_shapes=[
            # (bq, 1) running max / denom + (bq, D) accumulator, all f32 VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
