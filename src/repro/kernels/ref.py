"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (BH, Lq, D); k, v: (BH, Lk, D) -> (BH, Lq, D). Naive softmax attn."""
    BH, Lq, D = q.shape
    Lk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    qpos = jnp.arange(Lq)[:, None]
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)


def ssd_scan_ref(x, dt, A, Bs, Cs):
    """Naive quadratic SSD (1-semiseparable attention form).

    x: (B, H, L, P); dt: (B, H, L, 1); A: (H,); Bs/Cs: (B, L, N).
    y[t] = sum_{s<=t} (C_t . B_s) * exp(sum_{u in (s, t]} dt_u A_h) * dt_s x_s
    """
    B, H, L, P = x.shape
    a = dt[..., 0] * A[None, :, None]                    # (B, H, L)
    cs = jnp.cumsum(a, axis=-1)
    decay = jnp.exp(cs[..., :, None] - cs[..., None, :])  # (B, H, L, L)
    ii = jnp.arange(L)
    tri = (ii[None, :] <= ii[:, None])[None, None]       # s <= t
    G = jnp.einsum("btn,bsn->bts", Cs.astype(jnp.float32),
                   Bs.astype(jnp.float32))               # (B, L, L)
    W = jnp.where(tri, G[:, None] * decay, 0.0)          # (B, H, L, L)
    xdt = x.astype(jnp.float32) * dt                     # (B, H, L, P)
    y = jnp.einsum("bhts,bhsp->bhtp", W, xdt)
    return y.astype(x.dtype)


def policy_mlp_ref(x, w1, b1, w2, b2, w3, b3, mask):
    h = jnp.tanh(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1)
    h = jnp.tanh(h @ w2.astype(jnp.float32) + b2)
    logits = (h @ w3.astype(jnp.float32) + b3)[:, 0]
    return jnp.where(mask > 0, logits, -1e9)


def moe_router_ref(x, router_w, k: int):
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    vals, idx = jax.lax.top_k(logits, k)
    return jax.nn.softmax(vals, axis=-1), idx.astype(jnp.int32)
