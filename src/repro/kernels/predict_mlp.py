"""Fused quantile-head runtime-predictor MLP Pallas kernel.

One kernel evaluates the 2-hidden-layer predictor over a whole pending
window and emits every quantile head at once:
x(B,F) -> tanh(xW1+b1) -> tanh(.W2+b2) -> .W3+b3 -> (B,Q) residuals.
The heads predict *log-runtime residuals* over the declared-estimate
anchor (see ``repro.predict``), so the kernel output feeds directly into
``anchor * exp(residual)``.  Like ``policy_mlp``, everything fits in VMEM,
so fusing the three matmuls removes the HBM round-trips between layers —
batched window scoring stays off the decision-loop critical path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                    o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.tanh(jax.lax.dot_general(
        x, w1_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b1_ref[...])
    h = jnp.tanh(jax.lax.dot_general(
        h, w2_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b2_ref[...])
    out = jax.lax.dot_general(
        h, w3_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b3_ref[...]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def predict_mlp(x, w1, b1, w2, b2, w3, b3, *, interpret: bool = False):
    """x: (B, F); w1: (F, H1); w2: (H1, H2); w3: (H2, Q).
    Returns per-quantile log-runtime residuals (B, Q) in f32."""
    B = x.shape[0]
    Q = w3.shape[1]
    return pl.pallas_call(
        _predict_kernel,
        grid=(),
        in_specs=[pl.BlockSpec(x.shape, None), pl.BlockSpec(w1.shape, None),
                  pl.BlockSpec(b1.shape, None), pl.BlockSpec(w2.shape, None),
                  pl.BlockSpec(b2.shape, None), pl.BlockSpec(w3.shape, None),
                  pl.BlockSpec(b3.shape, None)],
        out_specs=pl.BlockSpec((B, Q), None),
        out_shape=jax.ShapeDtypeStruct((B, Q), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)
