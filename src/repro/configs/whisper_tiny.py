"""whisper-tiny — encoder-decoder audio model; conv frontend is a stub
(input_specs() provides 1500 precomputed frame embeddings).
[arXiv:2212.04356]  4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865.
Deviation: RoPE replaces whisper's learned/sinusoidal positions so the
synthetic 32k-deep decode shapes stay well-defined (DESIGN.md)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    is_encoder_decoder=True, encoder_layers=4, encoder_frames=1500,
    activation="gelu", norm="layernorm", qkv_bias=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    is_encoder_decoder=True, encoder_layers=2, encoder_frames=8,
    activation="gelu", norm="layernorm", qkv_bias=True, tie_embeddings=True,
)

register(FULL, SMOKE)
