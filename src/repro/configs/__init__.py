"""Assigned architecture configs (one module per arch) + registry access."""
from repro.configs.base import (ModelConfig, SHAPES, ShapeConfig, get_config,
                                input_specs, list_archs, shape_applicable)

# importing the modules registers the configs
from repro.configs import (granite_moe_1b_a400m, h2o_danube_1_8b,  # noqa: F401
                           internvl2_2b, jamba_v0_1_52b, mamba2_780m,
                           nemotron_4_15b, qwen3_moe_235b_a22b, stablelm_1_6b,
                           whisper_tiny, yi_6b)

ALL_ARCHS = list_archs()

__all__ = ["ModelConfig", "SHAPES", "ShapeConfig", "get_config", "input_specs",
           "list_archs", "shape_applicable", "ALL_ARCHS"]
