"""nemotron-4-15b — dense GQA with squared-ReLU MLP.
[arXiv:2402.16819]  32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    rope_theta=10_000.0, rope_pct=0.5, activation="relu2", norm="layernorm",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    rope_pct=0.5, activation="relu2", norm="layernorm", tie_embeddings=False,
)

register(FULL, SMOKE)
