"""mamba2-780m — SSD (state-space duality), attention-free.
[arXiv:2405.21060]  48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    norm="rmsnorm", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=32,
    norm="rmsnorm", tie_embeddings=True,
)

register(FULL, SMOKE)
