"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]  24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
SWA window 4096 => sub-quadratic; eligible for long_500k."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    window=4096, rope_theta=10_000.0, activation="silu", norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    window=16, activation="silu", norm="rmsnorm", tie_embeddings=False,
)

register(FULL, SMOKE)
