"""qwen3-moe-235b-a22b — 128-expert top-8 MoE.
[hf:Qwen/Qwen3-*; hf]  94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    rope_theta=1_000_000.0, activation="silu", norm="rmsnorm",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512, head_dim=16,
    num_experts=4, experts_per_token=2, moe_d_ff=64,
    activation="silu", norm="rmsnorm", tie_embeddings=False,
)

register(FULL, SMOKE)
