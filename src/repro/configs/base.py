"""Model configuration schema + assigned input shapes.

Every assigned architecture provides a full config (exact published numbers)
and a reduced smoke config (same family, tiny dims) via its module in
`repro.configs`.  `input_specs()` builds ShapeDtypeStruct stand-ins for the
dry-run — weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Jamba) ---
    attn_period: int = 0        # 1 attention layer per `attn_period` layers
    attn_offset: int = 3        # position of the attention layer in the period
    moe_period: int = 0         # MoE FFN every `moe_period` layers
    # --- attention ---
    window: int = 0             # sliding-window size (0 = full attention)
    rope_theta: float = 10_000.0
    activation: str = "silu"    # silu | gelu | relu2
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_pct: float = 1.0       # fraction of head_dim rotated (stablelm: 0.25)
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # --- vlm ---
    num_patches: int = 0
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.window > 0


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    """TPU systems pad the vocab so it tiles over the model axis and the MXU
    (MaxText-style).  Padded logit columns are masked to -inf at use sites."""
    return (vocab + multiple - 1) // multiple * multiple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md §Arch-applicability)."""
    if shape == "long_500k":
        return cfg.is_subquadratic
    return True


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    train:   {tokens, labels[, patch_embeds | audio_frames]}
    prefill: {tokens[, frontend embeds]}
    decode:  {tokens (B, 1), cache_len}  (the KV/state cache itself is part of
             the serve state threaded by the step factory, not an input spec)
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b: int, n: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((b, n), i32)

    out: dict[str, Any] = {}
    if shape.kind == "train":
        text_len = L - cfg.num_patches if cfg.family == "vlm" else L
        out["tokens"] = tok(B, text_len)
        out["labels"] = tok(B, text_len)
    elif shape.kind == "prefill":
        text_len = L - cfg.num_patches if cfg.family == "vlm" else L
        out["tokens"] = tok(B, text_len)
    else:  # decode: one new token against a seq_len-deep cache
        out["tokens"] = tok(B, 1)
    if cfg.family == "vlm" and shape.kind != "decode":
        # precomputed ViT patch embeddings (frontend is a stub)
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "audio" and shape.kind != "decode":
        # precomputed conv-frontend frame embeddings
        out["audio_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    return out


# ---------------------------------------------------------------- registry ------

_REGISTRY: dict[str, tuple[ModelConfig, ModelConfig]] = {}


def register(full: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[full.name] = (full, smoke)
    return full


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    full, sm = _REGISTRY[name]
    return sm if smoke else full


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
