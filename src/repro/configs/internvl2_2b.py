"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The ViT frontend is a stub: input_specs() provides 256 precomputed patch
embeddings prepended inside the sequence window."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    num_patches=256, rope_theta=1_000_000.0, activation="silu",
    norm="rmsnorm", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="internvl2-2b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    num_patches=4, activation="silu", norm="rmsnorm", tie_embeddings=False,
)

register(FULL, SMOKE)
