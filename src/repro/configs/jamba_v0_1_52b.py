"""jamba-v0.1-52b — hybrid Mamba + attention (1:7 interleave) with 16-expert
top-2 MoE on odd layers.  [arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Adaptations (DESIGN.md): Jamba ships Mamba-1 layers; we use the Mamba2/SSD
formulation (TPU-friendly chunked matmuls) with Jamba's small state (16).
Jamba uses no positional encoding; we keep RoPE on its 4 attention layers
(harmless, documented)."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    num_experts=16, experts_per_token=2, moe_d_ff=14336,
    attn_period=8, attn_offset=3, moe_period=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    activation="silu", norm="rmsnorm", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    num_experts=4, experts_per_token=2, moe_d_ff=128,
    attn_period=4, attn_offset=1, moe_period=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=32,
    activation="silu", norm="rmsnorm", tie_embeddings=False,
)

register(FULL, SMOKE)
