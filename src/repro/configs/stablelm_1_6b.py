"""stablelm-1.6b — dense, kv=32 (full MHA), partial RoPE, LayerNorm, QKV bias.
[hf:stabilityai/stablelm-2-1_6b]  24L d_model=2048 32H d_ff=5632 vocab=100352."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100352, head_dim=64,
    rope_theta=10_000.0, rope_pct=0.25, activation="silu", norm="layernorm",
    qkv_bias=True, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="stablelm-1.6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    rope_pct=0.25, activation="silu", norm="layernorm", qkv_bias=True,
    tie_embeddings=False,
)

register(FULL, SMOKE)
