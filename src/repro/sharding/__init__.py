from repro.sharding.specs import (AxisRules, DEFAULT_RULES, logical_spec,
                                  spec_tree, with_logical_constraint)

__all__ = ["AxisRules", "DEFAULT_RULES", "logical_spec", "spec_tree",
           "with_logical_constraint"]
