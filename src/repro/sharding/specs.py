"""Logical-axis sharding: named axes on every tensor dim -> PartitionSpec.

The production mesh is `(data, model)` single-pod or `(pod, data, model)`
multi-pod.  Logical axes map as:

- batch        -> (pod, data)        activation data parallelism
- embed        -> data               FSDP/ZeRO-3-style parameter + optimizer
                                     state sharding (gathered per layer)
- vocab/heads/ffn/experts/ssm_inner
               -> model              tensor / expert parallelism
- kv_seq       -> model              decode KV-cache length sharding
- seq          -> None (or data for sequence parallelism in prefill)

Rules are a plain dict so perf iterations can swap schemes without touching
model code (`train_step(..., rules=...)`).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as PS

AxisRules = dict[str, object]   # logical axis -> mesh axis | tuple | None

PRODUCTION_TP = 16              # model-axis size of the production meshes

DEFAULT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",           # FSDP weight shard axis
    "embed_table": None,       # embedding table embed dim (gather-friendly)
    "embed_act": None,         # activations' embed dim stays replicated
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",       # sanitized to None when KV % model != 0
    "head_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_cap": None,
    "ssm_inner": "model",      # mamba inner channels (heads)
    "ssm_state": None,
    "kv_seq": "model",         # decode-time KV cache length
    "frames": None,
    "conv": None,
}

# Alternative rule sets used by the perf hillclimb (§Perf in EXPERIMENTS.md).
SEQ_PARALLEL_RULES: AxisRules = dict(DEFAULT_RULES, seq="data", batch=("pod",))
NO_FSDP_RULES: AxisRules = dict(DEFAULT_RULES, embed=None)
TP_ONLY_RULES: AxisRules = dict(DEFAULT_RULES, embed=None, batch=("pod", "data"))
# pure data parallelism over every mesh axis: zero TP activation all-reduces,
# one grad all-reduce per step; only for models whose params+opt fit per chip
DP_ONLY_RULES: AxisRules = dict(
    DEFAULT_RULES, embed=None, vocab=None, heads=None, kv_heads=None,
    ffn=None, experts=None, ssm_inner=None, kv_seq=None,
    batch=("pod", "data", "model"))


def _mesh_axes(mesh: jax.sharding.Mesh | None) -> set[str]:
    return set(mesh.axis_names) if mesh is not None else {"pod", "data", "model"}


def logical_spec(logical: tuple[str | None, ...], rules: AxisRules | None = None,
                 mesh: jax.sharding.Mesh | None = None) -> PS:
    """Map a tuple of logical axis names to a PartitionSpec.

    Mesh axes not present in the mesh (e.g. 'pod' on the single-pod mesh) are
    dropped, so the same rules serve both meshes.
    """
    rules = rules or DEFAULT_RULES
    present = _mesh_axes(mesh)
    used: set[str] = set()
    out: list[object] = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        target = rules.get(name)
        if target is None:
            out.append(None)
            continue
        if isinstance(target, (tuple, list)):
            axes = tuple(a for a in target if a in present and a not in used)
            used.update(axes)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        else:
            if target in present and target not in used:
                used.add(target)
                out.append(target)
            else:
                out.append(None)
    while out and out[-1] is None:
        out.pop()
    return PS(*out)


def spec_tree(logical_tree, rules: AxisRules | None = None,
              mesh: jax.sharding.Mesh | None = None):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda lg: logical_spec(lg, rules, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, str) or e is None for e in x),
    )


def _axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sanitize_spec(spec: PS, shape: tuple[int, ...],
                  mesh: jax.sharding.Mesh) -> PS:
    """Drop mesh axes whose size doesn't divide the dim (jit in/out shardings
    require exact divisibility; internal constraints don't)."""
    sizes = _axis_sizes(mesh)
    out: list[object] = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        kept: list[str] = []
        s = 1
        for a in axes:
            if shape[i] % (s * sizes[a]) == 0:
                kept.append(a)
                s *= sizes[a]
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return PS(*out)


def sanitize_tree(spec_tree, abstract_tree, mesh: jax.sharding.Mesh):
    """Sanitize a PartitionSpec tree against matching ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, a: sanitize_spec(s, a.shape, mesh),
        spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, PS))


def with_logical_constraint(x: jax.Array, logical: tuple[str | None, ...],
                            rules: AxisRules | None = None,
                            mesh: jax.sharding.Mesh | None = None) -> jax.Array:
    """with_sharding_constraint via logical names; no-op outside a mesh ctx."""
    try:
        spec = logical_spec(logical, rules, mesh)
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
