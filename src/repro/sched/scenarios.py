"""Named stress scenarios for the streaming scheduler (Fig. 6 congestion).

Each scenario composes the statistically-matched trace generators in
``repro.core.trace`` with a workload shaper (arrival-time warps, demand
skews, tenant mixes) and optionally a fault model, producing a
``ScenarioRun`` that the service driver replays through the engine.
Scenarios are deterministic in ``seed``.

Registry: ``SCENARIOS`` maps name -> ``Scenario``; use
``get_scenario(name)`` / ``list_scenarios()``.  Registered scenarios:

- ``steady``       — baseline Helios traffic (control).
- ``diurnal``      — day/night sinusoidal arrival intensity (inverse
                     rate-integral time warp of the base arrivals).
- ``flash-crowd``  — calm traffic with a dense conference-deadline spike.
- ``multi-tenant`` — 4 virtual clusters with skewed demand vs. quota
                     (fairness stress; telemetry tracks Jain's index).
- ``sla-mix``      — an SLA-bound user population mixed into best-effort
                     traffic (exercises the Sec. 3.1.2 SLA bypass lane).
- ``fault-storm``  — aggressive MTBF + stragglers (checkpoint/restart churn).
- ``sku-skew``     — demand concentrated on the scarce fast SKU of a
                     heterogeneous cluster (placement-quality stress).
- ``trace-replay`` — real arrival/duration/GPU-demand rows from a CSV
                     (normalized ``repro.core.trace`` schema) replayed
                     through the engine; ``REPRO_TRACE_CSV`` points at an
                     external trace, defaulting to a packaged fixture.
- ``slo-lanes``    — deadline storm: congestion spike plus a deadline-
                     carrying job population and elastic gangs (the
                     ``repro.lifecycle`` preemption-policy stress).
- ``chaos-storm``  — correlated chaos (``repro.chaos``): rack bursts, spot-
                     reclamation waves and a straggler storm layered over
                     mild organic faults and a deadline-carrying population.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable

import numpy as np

from repro.core.faults import FaultModel
from repro.core.trace import generate_trace, load_trace_csv, make_cluster
from repro.core.types import ClusterSpec, Job


@dataclasses.dataclass(frozen=True)
class ScenarioRun:
    """A concrete, replayable workload: cluster + job stream + faults."""

    name: str
    spec: ClusterSpec
    jobs: list[Job]
    fault_model: FaultModel | None = None
    sla_users: frozenset[int] = frozenset()
    vc_quotas: dict[int, float] | None = None   # VC id -> cluster share
    #: optional correlated-chaos timeline (a ``repro.chaos.ChaosSchedule``,
    #: duck-typed to keep this module chaos-agnostic); the service driver
    #: wraps it in a fresh injector per run
    chaos: object | None = None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named scenario: deterministic builder of ScenarioRuns."""

    name: str
    description: str
    build: Callable[[int, int], ScenarioRun]    # (num_jobs, seed) -> run


SCENARIOS: dict[str, Scenario] = {}


def register(name: str, description: str):
    def deco(fn: Callable[[int, int], ScenarioRun]):
        SCENARIOS[name] = Scenario(name=name, description=description, build=fn)
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {', '.join(sorted(SCENARIOS))}")
    return SCENARIOS[name]


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------- shapers ----


def _warp_arrivals(jobs: list[Job], rate: Callable[[float], float],
                   step: float = 600.0) -> None:
    """Re-time arrivals so instantaneous intensity follows ``rate(t)``
    (mean ~1.0, strictly positive) while preserving the base process's
    randomness: each original arrival time t maps to the s solving
    ``integral_0^s rate = t``.  The cumulative integral is tabulated on a
    coarse grid (rate varies slowly vs. ``step``) and inverted with a single
    monotone interpolation — O(grid + n) for the whole stream."""
    if not jobs:
        return
    target_max = max(j.submit_time for j in jobs)
    ts = [0.0]
    cum = [0.0]
    while cum[-1] < target_max:
        t0, t1 = ts[-1], ts[-1] + step
        cum.append(cum[-1] + 0.5 * (rate(t0) + rate(t1)) * step)
        ts.append(t1)
        assert len(ts) < 10_000_000, "rate(t) too close to zero to invert"
    targets = np.array([j.submit_time for j in jobs])
    warped = np.interp(targets, np.array(cum), np.array(ts))
    for j, s in zip(jobs, warped):
        j.submit_time = float(s)
    jobs.sort(key=lambda j: j.submit_time)


# --------------------------------------------------------------- scenarios ----


@register("steady", "Baseline Helios traffic, no shaping (control).")
def _steady(num_jobs: int, seed: int) -> ScenarioRun:
    return ScenarioRun(name="steady", spec=make_cluster("helios"),
                       jobs=generate_trace("helios", num_jobs, seed=seed))


@register("diurnal",
          "Day/night sinusoidal arrival intensity: 3x daytime peak vs "
          "nighttime trough over a 24h period.")
def _diurnal(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)

    def rate(t: float) -> float:
        # mean 1.0; peak 1.75, trough 0.25 (roughly 7:1 day/night swing)
        return 1.0 + 0.75 * math.sin(2 * math.pi * t / 86400.0)

    _warp_arrivals(jobs, rate)
    return ScenarioRun(name="diurnal", spec=make_cluster("helios"), jobs=jobs)


@register("flash-crowd",
          "Calm traffic with a dense spike: 30% of jobs re-arrive inside a "
          "10-minute window (conference-deadline crowd).")
def _flash_crowd(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 101)
    if jobs:
        horizon = jobs[-1].submit_time
        t_spike = 0.5 * horizon
        crowd = rng.random(len(jobs)) < 0.30
        for j, hit in zip(jobs, crowd):
            if hit:
                j.submit_time = t_spike + float(rng.uniform(0.0, 600.0))
        jobs.sort(key=lambda j: j.submit_time)
    return ScenarioRun(name="flash-crowd", spec=make_cluster("helios"),
                       jobs=jobs)


@register("multi-tenant",
          "Four virtual clusters with skewed demand (55/25/12/8%) against "
          "even 25% quotas — fairness stress for per-VC telemetry.")
def _multi_tenant(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("alibaba", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 202)
    shares = np.array([0.55, 0.25, 0.12, 0.08])
    vcs = rng.choice(4, size=len(jobs), p=shares)
    for j, vc in zip(jobs, vcs):
        j.vc = int(vc)
    return ScenarioRun(name="multi-tenant", spec=make_cluster("alibaba"),
                       jobs=jobs,
                       vc_quotas={0: 0.25, 1: 0.25, 2: 0.25, 3: 0.25})


@register("sla-mix",
          "10% of users are SLA-bound (Sec. 3.1.2 bypass lane) amid "
          "best-effort traffic.")
def _sla_mix(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)
    users = sorted({j.user for j in jobs})
    rng = np.random.default_rng(seed + 303)
    k = max(1, len(users) // 10)
    sla = frozenset(int(u) for u in rng.choice(users, size=k, replace=False))
    return ScenarioRun(name="sla-mix", spec=make_cluster("helios"), jobs=jobs,
                       sla_users=sla)


@register("fault-storm",
          "Aggressive failures: 6h per-node MTBF, 10-minute repairs, 30% "
          "straggler draws — checkpoint/restart and re-queue churn.")
def _fault_storm(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("philly", num_jobs, seed=seed)
    fm = FaultModel(mtbf_per_node=6 * 3600.0, repair_time=600.0,
                    straggler_prob=0.3, straggler_slowdown=0.4,
                    ckpt_interval=900.0, seed=seed + 404)
    return ScenarioRun(name="fault-storm", spec=make_cluster("philly"),
                       jobs=jobs, fault_model=fm)


#: Environment override for the trace-replay scenario's CSV source.
TRACE_CSV_ENV = "REPRO_TRACE_CSV"
_DEFAULT_TRACE_CSV = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "data", "trace_small.csv")


def replay_trace_jobs(path: str, num_jobs: int) -> list[Job]:
    """Adapt a normalized-CSV trace (``repro.core.trace.load_trace_csv``)
    into a ``num_jobs``-long stream: rows are truncated or tiled (each copy
    time-shifted by the trace span plus one mean inter-arrival gap, so
    copies never interleave) and re-id'd sequentially.  Deterministic — a
    replay has no seed."""
    base = load_trace_csv(path)
    if not base:
        raise ValueError(f"empty trace CSV: {path!r}")
    t0 = base[0].submit_time
    span = base[-1].submit_time - t0
    period = span + max(span / len(base), 1.0)
    jobs: list[Job] = []
    shift = 0.0
    while len(jobs) < num_jobs:
        for j in base:
            if len(jobs) >= num_jobs:
                break
            c = j.clone_pending()
            c.job_id = len(jobs)
            c.submit_time = j.submit_time + shift
            jobs.append(c)
        shift += period
    return jobs


@register("trace-replay",
          "Replay real arrival/duration/GPU-demand rows from a CSV "
          "(REPRO_TRACE_CSV, else a packaged fixture) through the engine.")
def _trace_replay(num_jobs: int, seed: int) -> ScenarioRun:
    path = os.environ.get(TRACE_CSV_ENV) or _DEFAULT_TRACE_CSV
    return ScenarioRun(name="trace-replay", spec=make_cluster("helios"),
                       jobs=replay_trace_jobs(path, num_jobs))


@register("slo-lanes",
          "Deadline storm under congestion: a 30-minute arrival spike, ~30% "
          "of jobs carrying hard deadlines (1.5-3x their estimate), and ~25% "
          "elastic gangs — the repro.lifecycle preemption stress.")
def _slo_lanes(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 707)
    if jobs:
        # congestion: re-time a third of the stream into one dense spike so
        # deadline jobs genuinely contend for GPUs
        horizon = jobs[-1].submit_time
        t_spike = 0.5 * horizon
        crowd = rng.random(len(jobs)) < 0.35
        for j, hit in zip(jobs, crowd):
            if hit:
                j.submit_time = t_spike + float(rng.uniform(0.0, 1800.0))
        jobs.sort(key=lambda j: j.submit_time)
    dl = rng.random(len(jobs)) < 0.30
    factors = rng.uniform(1.5, 3.0, size=len(jobs))
    el = rng.random(len(jobs)) < 0.25
    for j, is_dl, f, is_el in zip(jobs, dl, factors, el):
        if is_dl:
            # deadline anchored on the *user-visible* estimate, like a real
            # SLO contract; floored so sub-10-minute jobs get usable slack
            j.deadline = j.submit_time + float(f) * max(j.est_runtime, 600.0)
        elif is_el and j.num_gpus >= 2:
            j.min_gpus = max(1, j.num_gpus // 2)
            j.max_gpus = j.num_gpus * 2
    return ScenarioRun(name="slo-lanes", spec=make_cluster("helios"),
                       jobs=jobs)


@register("chaos-storm",
          "Correlated chaos over Helios: two rack bursts, two P100 spot-"
          "reclamation waves, one straggler storm — layered on mild organic "
          "faults and a ~20% deadline population (repro.chaos stress).")
def _chaos_storm(num_jobs: int, seed: int) -> ScenarioRun:
    from repro.chaos import ChaosSchedule
    jobs = generate_trace("helios", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 910)
    dl = rng.random(len(jobs)) < 0.20
    factors = rng.uniform(2.0, 4.0, size=len(jobs))
    for j, is_dl, f in zip(jobs, dl, factors):
        if is_dl:
            j.deadline = j.submit_time + float(f) * max(j.est_runtime, 600.0)
    horizon = jobs[-1].submit_time if jobs else 86400.0
    # helios: nodes 0-4 are the P100 half, 5-9 the V100 half — each burst
    # takes most of one rack; reclamation sweeps the preemptible P100 pool
    chaos = (ChaosSchedule()
             .add_rack_burst(0.25 * horizon, nodes=range(0, 4),
                             down_for=2 * 3600.0, note="rack-P100")
             .add_spot_wave(0.45 * horizon, sku="P100", count=3,
                            down_for=2 * 3600.0)
             .add_spot_wave(0.55 * horizon, sku="P100", count=3,
                            down_for=2 * 3600.0)
             .add_straggler_storm(0.6 * horizon, nodes=range(4, 8),
                                  duration=3 * 3600.0, slowdown=0.4)
             .add_rack_burst(0.7 * horizon, nodes=range(5, 9),
                             down_for=3 * 3600.0, note="rack-V100"))
    fm = FaultModel(mtbf_per_node=14 * 86400.0, repair_time=1800.0,
                    straggler_prob=0.05, straggler_slowdown=0.5,
                    ckpt_interval=900.0, seed=seed + 909)
    return ScenarioRun(name="chaos-storm", spec=make_cluster("helios"),
                       jobs=jobs, fault_model=fm, chaos=chaos)


@register("padded-estimates",
          "Flash-crowd congestion where every user habitually pads their "
          "walltime request (est 2-8x true runtime, the documented "
          "production pattern) — blind backfill sees oversized estimates "
          "and leaves reservation windows empty; a learned p90 unlocks "
          "them.")
def _padded_estimates(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 808)
    if jobs:
        horizon = jobs[-1].submit_time
        t_spike = 0.5 * horizon
        crowd = rng.random(len(jobs)) < 0.30
        for j, hit in zip(jobs, crowd):
            if hit:
                j.submit_time = t_spike + float(rng.uniform(0.0, 600.0))
        jobs.sort(key=lambda j: j.submit_time)
    # each user pads by a *habitual* factor (people re-submit the same
    # walltime request), with mild per-job jitter — the per-(user, size)
    # structure the predictor's anchor debiasing learns
    users = sorted({j.user for j in jobs})
    pad = {int(u): float(rng.uniform(2.0, 8.0)) for u in users}
    for j in jobs:
        j.est_runtime = j.runtime * pad[j.user] * \
            float(rng.lognormal(0.0, 0.25))
    return ScenarioRun(name="padded-estimates",
                       spec=make_cluster("helios"), jobs=jobs)


@register("overcommit-queue",
          "Sustained overload on the Alibaba cluster — arrival intensity "
          "doubled through the middle of the stream — where every user "
          "habitually pads their walltime request 2-10x: the deep queue "
          "is full of backfill candidates blind estimate-gating cannot "
          "see.")
def _overcommit_queue(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("alibaba", num_jobs, seed=seed)
    if jobs:
        horizon = jobs[-1].submit_time

        def rate(t: float) -> float:
            # mid-stream crunch: 2.2x intensity over the middle 40%
            return 2.2 if 0.3 * horizon < t < 0.7 * horizon else 0.6

        _warp_arrivals(jobs, rate)
    rng = np.random.default_rng(seed + 909)
    users = sorted({j.user for j in jobs})
    pad = {int(u): float(rng.uniform(2.0, 10.0)) for u in users}
    for j in jobs:
        j.est_runtime = j.runtime * pad[j.user] * \
            float(rng.lognormal(0.0, 0.25))
    return ScenarioRun(name="overcommit-queue",
                       spec=make_cluster("alibaba"), jobs=jobs)


@register("mispredict-storm",
          "Flash-crowd congestion with two-sided cohort mis-estimation: "
          "30% of users severely lowball (declared est 5-30% of truth) "
          "while 40% pad 3-8x — worst case for estimate-trusting backfill "
          "and the predictor's overrun band.")
def _mispredict_storm(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("helios", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 707)
    if jobs:
        horizon = jobs[-1].submit_time
        t_spike = 0.5 * horizon
        crowd = rng.random(len(jobs)) < 0.30
        for j, hit in zip(jobs, crowd):
            if hit:
                j.submit_time = t_spike + float(rng.uniform(0.0, 600.0))
        jobs.sort(key=lambda j: j.submit_time)
    # user cohorts (not i.i.d. jobs) systematically mis-estimate — the
    # per-(user, size) structure is what the predictor can learn.  Liars
    # make blind backfill overcommit reservation windows; padders make it
    # leave them empty.
    users = sorted({j.user for j in jobs})
    k = max(1, int(0.3 * len(users)))
    perm = [int(u) for u in rng.permutation(users)]
    liars = frozenset(perm[:k])
    padders = frozenset(perm[k:k + max(1, int(0.4 * len(users)))])
    for j in jobs:
        if j.user in liars:
            j.est_runtime = max(60.0, j.runtime *
                                float(rng.uniform(0.05, 0.30)))
        elif j.user in padders:
            j.est_runtime = j.runtime * float(rng.uniform(3.0, 8.0))
    return ScenarioRun(name="mispredict-storm", spec=make_cluster("helios"),
                       jobs=jobs)


@register("sku-skew",
          "Demand concentrated on the scarce fast SKU: 60% of jobs demand "
          "V100 on a mostly-T4/P100 cluster.")
def _sku_skew(num_jobs: int, seed: int) -> ScenarioRun:
    jobs = generate_trace("alibaba", num_jobs, seed=seed)
    rng = np.random.default_rng(seed + 505)
    draws = rng.random(len(jobs))
    for j, u in zip(jobs, draws):
        j.gpu_type = "V100" if u < 0.60 else ("T4" if u < 0.85 else "any")
    return ScenarioRun(name="sku-skew", spec=make_cluster("alibaba"),
                       jobs=jobs)
