"""repro.sched: streaming scheduler engine, scenario suite, and service
drivers layered over repro.core (see docs/ARCHITECTURE.md)."""
from repro.sched.engine import (DEFAULT_QUEUE_WINDOW, EngineHooks,
                                EngineSnapshot, MultiHooks,
                                PolicyPrioritizer, Prioritizer,
                                SchedulerEngine)
from repro.sched.scenarios import (SCENARIOS, Scenario, ScenarioRun,
                                   get_scenario, list_scenarios, register)
from repro.sched.service import (QuotaPrioritizer, SlaLanePrioritizer,
                                 StreamResult, run_scenario, run_stream,
                                 wrap_tenancy)
from repro.sched.telemetry import (RollingTelemetry, TelemetrySample,
                                   jain_index)

__all__ = [
    "DEFAULT_QUEUE_WINDOW", "EngineHooks", "EngineSnapshot", "MultiHooks",
    "PolicyPrioritizer", "Prioritizer", "SchedulerEngine", "SCENARIOS",
    "Scenario", "ScenarioRun", "get_scenario", "list_scenarios", "register",
    "QuotaPrioritizer", "SlaLanePrioritizer", "StreamResult", "run_scenario",
    "run_stream", "wrap_tenancy", "RollingTelemetry", "TelemetrySample",
    "jain_index",
]
